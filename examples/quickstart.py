"""Quickstart: core attention disaggregation in ~50 lines.

Builds a packed two-rank batch, plans CA-tasks through a ``CADSession``
(the attention-service entry point — plan policies are selected by
name), dispatches them through the CAD runtime (global simulation of the
attention-server pool on CPU), and checks the result equals monolithic
attention.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cad import CADConfig, CADSession
from repro.core import cad_attention, ref_attention
from repro.core.cost_model import CommModel

# --- a packed batch: 2 ranks x 1024 tokens, documents of 1-4 blocks ----
BLK, D, S = 128, 2, 1024
rng = np.random.default_rng(0)
segs = np.zeros((D, S), np.int32)
poss = np.zeros((D, S), np.int32)
sid = 1
for r in range(D):
    t = 0
    while t < S:
        dl = min(int(rng.integers(1, 5)) * BLK, S - t)
        segs[r, t:t + dl] = sid
        poss[r, t:t + dl] = np.arange(dl)
        sid += 1
        t += dl

# --- the attention service: pool geometry + plan policy by name --------
nb = S // BLK
session = CADSession(
    cfg=CADConfig(n_servers=D, blk=BLK, nb=nb, cq=nb, ckv=2 * nb,
                  nkv=4 * nb),
    kernel="xla", plan_policy="balanced", tolerance=0.05, jmax=nb,
    comm=CommModel(n_heads=4, head_dim=64, n_kv_heads=2))

plan, stats = session.plan(segs)          # one step's typed StepPlan
print(f"planner[{session.plan_policy}]: {stats['n_moves']} migrations, "
      f"{stats['comm_bytes']/2**20:.2f} MiB moved, "
      f"straggler x{stats['load_max_over_mean']:.3f}")

# --- dispatch through the CAD runtime, compare to monolithic CA --------
ctx = session.context()
ctx = ctx.cad.bind_plan(ctx, plan)        # bind this step's plan

key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (D, S, 4, 64))
k = jax.random.normal(kk, (D, S, 2, 64))
v = jax.random.normal(kv, (D, S, 2, 64))
seg, pos = jnp.asarray(segs), jnp.asarray(poss)

out_cad = cad_attention(q, k, v, seg, pos, seg, pos, ctx=ctx)
out_ref = ref_attention(q, k, v, seg, pos, seg, pos)
err = float(jnp.max(jnp.abs(out_cad - out_ref)))
print(f"CAD == monolithic attention: max |err| = {err:.2e}")
assert err < 1e-4
print("OK")
