"""Quickstart: core attention disaggregation in ~60 lines.

Builds a packed two-rank batch, schedules CA-tasks with the greedy
balancer, dispatches them through the CAD runtime (global simulation of
the attention-server pool on CPU), and checks the result equals monolithic
attention.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CADConfig, CADContext, CommModel, cad_attention,
                        imbalance, plan_from_schedule, ref_attention,
                        schedule)
from repro.parallel import ParallelContext

# --- a packed batch: 2 ranks x 1024 tokens, documents of 1-4 blocks ----
BLK, D, S = 128, 2, 1024
rng = np.random.default_rng(0)
segs = np.zeros((D, S), np.int32)
poss = np.zeros((D, S), np.int32)
sid = 1
for r in range(D):
    t = 0
    while t < S:
        dl = min(int(rng.integers(1, 5)) * BLK, S - t)
        segs[r, t:t + dl] = sid
        poss[r, t:t + dl] = np.arange(dl)
        sid += 1
        t += dl

# --- schedule: balance CA FLOPs across the 2 attention servers ---------
nb = S // BLK
cfg = CADConfig(n_servers=D, blk=BLK, nb=nb, cq=nb, ckv=2 * nb, nkv=4 * nb)
comm = CommModel(n_heads=4, head_dim=64, n_kv_heads=2)
sched = schedule(segs, blk=BLK, n_servers=D, comm=comm, caps=cfg.caps(),
                 tolerance=0.05)
print(f"scheduler: {sched.n_moves} migrations, "
      f"imbalance {imbalance(sched.loads):.3f}, "
      f"comm {sched.comm_bytes/2**20:.1f} MiB")

# --- dispatch through the CAD runtime ----------------------------------
plan = jax.tree.map(jnp.asarray, plan_from_schedule(cfg, sched))
key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (D, S, 4, 64))
k = jax.random.normal(kk, (D, S, 2, 64))
v = jax.random.normal(kv, (D, S, 2, 64))
seg, pos = jnp.asarray(segs), jnp.asarray(poss)

cad = CADContext(cfg=cfg, plan=plan, kernel="xla", jmax=nb)
ctx = ParallelContext(mesh=None, attn_impl="cad", cad=cad)
out_cad = cad_attention(q, k, v, seg, pos, seg, pos, ctx=ctx)
out_ref = ref_attention(q, k, v, seg, pos, seg, pos)
err = float(jnp.max(jnp.abs(out_cad - out_ref)))
print(f"CAD == monolithic attention: max |err| = {err:.2e}")
assert err < 1e-4
print("OK")
