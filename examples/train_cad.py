"""End-to-end training driver: train a ~100M-parameter llama-family model
on packed synthetic documents with CAD active — the scheduler balances
CA-tasks across a (simulated, on CPU) pool of attention servers every
step, exactly the production dataflow.

Run:  PYTHONPATH=src python examples/train_cad.py --steps 300
Tiny: PYTHONPATH=src python examples/train_cad.py --steps 20 --tiny
"""
import argparse
import dataclasses

from repro.cad import CADSession, available_policies
from repro.configs import ModelConfig, get_config, register
from repro.data.pipeline import PipelineConfig
from repro.train.trainer import TrainConfig, train

# ~100M params: 12L, d=768, llama-style (GPT-2-small scale)
SMOL_100M = ModelConfig(
    arch_id="llama-100m", family="dense", source="examples/train_cad",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32000, layer_pattern=("global",),
    tie_embeddings=True, param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced model for a fast smoke run")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--no-cad", action="store_true")
    ap.add_argument("--pingpong", action="store_true")
    ap.add_argument("--plan-policy", default="balanced",
                    choices=list(available_policies()))
    args = ap.parse_args()

    cfg = SMOL_100M.reduced() if args.tiny else SMOL_100M
    print(f"model: {cfg.arch_id} ({cfg.n_params()/1e6:.1f}M params)")
    pipe = PipelineConfig(distribution="pretrain",
                          max_doc_len=args.seq, seq_len=args.seq,
                          global_batch=args.batch, n_ranks=args.ranks,
                          vocab_size=cfg.vocab_size, seed=0)
    ctx = session = None
    if args.no_cad:
        from repro.parallel import ParallelContext
        ctx = ParallelContext(attn_impl="xla", remat=True)
    else:
        # one object owns pool geometry, kernel, ping-pong, tolerance and
        # plan policy; plans are prefetched one step ahead of the device
        session = CADSession.for_pipeline(cfg, pipe, kernel="xla",
                                          pingpong=args.pingpong,
                                          plan_policy=args.plan_policy)
    res = train(cfg, pipe, TrainConfig(steps=args.steps, peak_lr=3e-4,
                                       warmup=min(50, args.steps // 5),
                                       log_every=max(1, args.steps // 20)),
                ctx=ctx, session=session)
    h = res["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
