"""Explore the communication-aware greedy scheduler (paper §4.2).

Samples packed batches from the Pretrain/ProLong distributions, runs the
scheduler at several tolerance factors, and prints per-server loads,
migrations, and comm volume — an ASCII version of paper Fig. 12.  Also
compares the registered plan policies (identity / per_doc_cp /
balanced) head-to-head through the repro.cad registry.

Run: PYTHONPATH=src python examples/schedule_explore.py
"""
import numpy as np

from repro.cad import CADConfig, PlanCapacityError, available_policies, \
    get_planner
from repro.configs import get_config
from repro.core import CommModel, Caps, imbalance, schedule
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents

ARCH = "llama3-8b"
N_RANKS = 8
TOKENS_PER_RANK = 65536
MAX_DOC = 65536

cfg = get_config(ARCH)
comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
rng = np.random.default_rng(0)

for dist in ("pretrain", "prolong"):
    lens = []
    while sum(lens) < N_RANKS * TOKENS_PER_RANK * 1.2:
        lens.extend(sample_lengths(dist, rng, 64, MAX_DOC).tolist())
    chunks = pack_documents(lens, TOKENS_PER_RANK, N_RANKS, rng=rng)
    segs = np.stack([c.segment_ids for c in chunks])
    nb = TOKENS_PER_RANK // BLOCK

    print(f"\n=== {dist}: {N_RANKS} ranks x {TOKENS_PER_RANK} tokens ===")
    for tol in (0.0, 0.1, 0.3):
        sch = schedule(segs, blk=BLOCK, n_servers=N_RANKS, comm=comm,
                       caps=Caps(cq=nb, ckv=2 * nb, nkv=4 * nb),
                       tolerance=tol)
        loads = sch.loads / max(sch.loads.mean(), 1e-9)
        bars = " ".join(f"{x:4.2f}" for x in loads)
        print(f"tol={tol:4.2f}  imb={imbalance(sch.loads):5.3f}  "
              f"moves={sch.n_moves:3d}  comm={sch.comm_bytes/2**20:7.1f}MiB"
              f"  loads/mean: {bars}")
    # plan policies head-to-head (the registry the pipeline/benchmarks
    # select from); identity == the no-CAD home baseline
    cadcfg = CADConfig(n_servers=N_RANKS, blk=BLOCK, nb=nb, cq=nb,
                      ckv=2 * nb, nkv=4 * nb)
    for pol in available_policies():
        try:
            # build_plan=True on purpose: the capacity feasibility check
            # (PlanCapacityError below) is part of the comparison
            res = get_planner(pol)(cadcfg, segs, comm=comm, tolerance=0.1)
        except PlanCapacityError as e:
            print(f"policy {pol:10s}  infeasible at this pool geometry: "
                  f"{e.capacity} needs {e.needed} > {e.available} slots")
            continue
        print(f"policy {pol:10s}  imb={imbalance(res.loads):5.3f}  "
              f"moves={res.stats['n_moves']:4d}  "
              f"comm={res.stats['comm_bytes']/2**20:7.1f}MiB")
