"""Batched serving example: prefill + greedy decode with KV/ring/SSM/LRU
caches on a reduced gemma2 (alternating local/global attention) and a
reduced mamba2 (attention-free decode state).

Run: PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.parallel import ParallelContext
from repro.serve.engine import Engine, ServeConfig

CTX = ParallelContext(attn_impl="ref", remat=False)


def run(arch, batch=4, prompt_len=12, new_tokens=16):
    cfg = get_config(arch).reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, CTX,
                 ServeConfig(max_seq=prompt_len + new_tokens + 1,
                             max_new_tokens=new_tokens),
                 batch_size=batch)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 1, cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompt)
    dt = time.time() - t0
    print(f"{arch:22s} generated {out.shape} in {dt:.1f}s "
          f"({batch*new_tokens/dt:.1f} tok/s on CPU)")
    print("  first row:", out[0].tolist())
    assert bool(jnp.isfinite(out).all() if out.dtype != jnp.int32
                else True)


if __name__ == "__main__":
    for arch in ("gemma2-2b", "mamba2-370m", "recurrentgemma-9b"):
        run(arch)
