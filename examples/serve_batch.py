"""Serving example: fused packed chunked prefill + continuous batching
on a reduced gemma2 (alternating local/global attention), plus legacy
batched decode on attention-free state-space archs (mamba2 /
recurrentgemma) whose prompts stream per-token (DESIGN.md §8).

Run: PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.parallel import ParallelContext
from repro.serve import Engine, ServeConfig

CTX = ParallelContext(attn_impl="ref", remat=False)


def run_continuous(arch="gemma2-2b", slots=2, new_tokens=8):
    """6 ragged requests through 2 cache slots: fused chunked prefill,
    batched ragged decode, admission/eviction between steps."""
    cfg = get_config(arch).reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    lens = (9, 30, 5, 17, 3, 22)
    prompts = [rng.integers(1, cfg.vocab_size, int(l)).astype(np.int32)
               for l in lens]
    eng = Engine(cfg, params, CTX,
                 ServeConfig(max_seq=64, max_new_tokens=new_tokens,
                             chunk_tokens=128),
                 batch_size=slots)
    t0 = time.time()
    results = eng.serve(prompts)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in results.values())
    print(f"{arch:22s} continuous batching: {len(prompts)} ragged requests "
          f"(lens {lens}) through {slots} slots")
    print(f"{'':22s} {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s on CPU); "
          f"events: {[e for e, _ in eng.last_trace]}")
    for rid in sorted(results):
        print(f"  req {rid} ({lens[rid]:2d} prompt toks):",
              results[rid].tolist())


def run_static(arch, batch=4, prompt_len=12, new_tokens=16):
    """Dense-batch generate: fused prefill where the arch supports it,
    per-token prefill (decode-mode chunks) otherwise."""
    cfg = get_config(arch).reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, CTX,
                 ServeConfig(max_seq=prompt_len + new_tokens + 1,
                             max_new_tokens=new_tokens),
                 batch_size=batch)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 1, cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompt)
    dt = time.time() - t0
    mode = "fused prefill" if eng.fused_ok else "per-token prefill"
    print(f"{arch:22s} generated {out.shape} in {dt:.1f}s "
          f"({batch * new_tokens / dt:.1f} tok/s on CPU, {mode})")
    print("  first row:", out[0].tolist())
    assert bool(jnp.isfinite(out).all() if out.dtype != jnp.int32
                else True)


if __name__ == "__main__":
    run_continuous()
    for arch in ("gemma2-2b", "mamba2-370m", "recurrentgemma-9b"):
        run_static(arch)
