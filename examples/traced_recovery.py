"""Flight-recorder demo: trace a fault-injected elastic run.

Runs the decomposed elastic executor (per-server dispatch — the path
that narrates `serve`/`recover` spans and `kill`/`speculate` instants
onto one trace track per attention server, DESIGN.md §14) under a
deterministic fault schedule: one server killed mid-run, another
slowed enough to trip straggler speculation.  Saves the
Chrome-trace/Perfetto JSON + the metrics snapshot, then prints the
per-step straggler attribution over the trace it just wrote.

Run:  PYTHONPATH=src python examples/traced_recovery.py
      # then load /tmp/recovery.trace.json in ui.perfetto.dev
"""
import argparse
import json

import numpy as np

from repro.cad import CADConfig, CADSession
from repro.core.cost_model import CommModel
from repro.launch.trace_report import report_lines
from repro.obs import MetricsRegistry, TraceRecorder
from repro.runtime import ElasticExecutor, FaultSchedule, ServerPool

BLK = 16


def make_segs(d, nb, seed):
    rng = np.random.default_rng(seed)
    segs = np.zeros((d, nb * BLK), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < nb:
            dbl = int(rng.integers(1, min(4, nb - t) + 1))
            segs[r, t * BLK:(t + dbl) * BLK] = sid
            sid += 1
            t += dbl
    return segs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--blocks", type=int, default=8,
                    help="q blocks per rank")
    ap.add_argument("--trace", default="/tmp/recovery.trace.json")
    ap.add_argument("--metrics", default="/tmp/recovery.metrics.json")
    ap.add_argument("--speculate-pct", type=float, default=0.9)
    args = ap.parse_args()

    d, nb = args.ranks, args.blocks
    kill, slow_lo = max(1, args.steps // 3), max(2, args.steps // 2)
    spec = (f"kill:1@{kill},"
            f"slow:{d - 1}x3@{slow_lo}-{args.steps - 1}")
    print(f"pool: {d} servers | faults: {spec} | "
          f"speculate_pct={args.speculate_pct}")

    cfg = CADConfig(n_servers=d, blk=BLK, nb=nb, cq=2 * nb, ckv=4 * nb,
                    nkv=8 * nb)
    session = CADSession(cfg=cfg, comm=CommModel(2, 8, 2),
                         tolerance=0.05, jmax=nb, prefetch=0)
    session = session.with_pool(ServerPool(d))
    rec = TraceRecorder(capacity=65536)
    mx = MetricsRegistry()
    ex = ElasticExecutor(session, faults=FaultSchedule.parse(spec),
                         speculate_pct=args.speculate_pct,
                         recorder=rec, metrics=mx)

    for step in range(args.steps):
        segs = make_segs(d, nb, seed=step)
        pos = np.broadcast_to(np.arange(segs.shape[1]),
                              segs.shape).copy()
        q, k, v, p = ex.synth_inputs(segs, pos, seed=step)
        _, rep = ex.run_step(step, q, k, v, p, segs)
        note = []
        if rep.failed:
            note.append(f"failed={sorted(rep.failed)}")
        if rep.speculated:
            note.append(f"speculated={sorted(rep.speculated)}")
        print(f"step {step} epoch {rep.epoch} "
              f"step_s {rep.step_seconds:.3g} "
              f"{' '.join(note)}".rstrip())

    rec.save(args.trace)
    with open(args.metrics, "w") as f:
        json.dump(mx.to_dict(), f, indent=2)
    print(f"trace: {len(rec)} events -> {args.trace} "
          f"({rec.n_dropped} dropped)")
    print(f"metrics: -> {args.metrics}")
    print()
    for line in report_lines(rec.to_chrome_trace()):
        print(line)

    evs = rec.events()
    assert any(e.name == "kill" for e in evs), "kill must be traced"
    assert any(e.name == "recover" for e in evs), \
        "recovery must be traced"
    if args.speculate_pct:
        assert any(e.name == "speculate" for e in evs), \
            "speculation must be traced"


if __name__ == "__main__":
    main()
