"""Elastic training demo: lose an attention server mid-run, keep going.

Trains a tiny llama-family model with CAD active on a pool of
attention servers and — via a deterministic :class:`FaultSchedule` —
kills one server halfway through.  The pool's membership epoch bumps,
the planner is re-invoked against the survivors (any prefetched plan
from the dead epoch is re-planned at pull), and training finishes every
configured step with a finite loss.  Flap the server instead with
``--flap`` to watch it rejoin a few steps later.

Run:  PYTHONPATH=src python examples/elastic_train.py
      PYTHONPATH=src python examples/elastic_train.py --steps 12 --flap
"""
import argparse

from repro.cad import CADSession
from repro.configs import ModelConfig
from repro.data.pipeline import PipelineConfig
from repro.runtime import ServerPool
from repro.train.trainer import TrainConfig, train

TINY = ModelConfig(
    arch_id="llama-tiny-elastic", family="dense",
    source="examples/elastic_train",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, layer_pattern=("global",),
    tie_embeddings=True, param_dtype="float32",
    compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--victim", type=int, default=1)
    ap.add_argument("--flap", action="store_true",
                    help="kill + rejoin instead of a permanent kill")
    args = ap.parse_args()

    kill_step = max(1, args.steps // 2)
    spec = (f"flap:{args.victim}@{kill_step}+2" if args.flap
            else f"kill:{args.victim}@{kill_step}")
    print(f"model: {TINY.arch_id} | pool: {args.ranks} servers | "
          f"fault schedule: {spec}")

    pipe = PipelineConfig(distribution="pretrain", max_doc_len=args.seq,
                          seq_len=args.seq, global_batch=2 * args.ranks,
                          n_ranks=args.ranks, vocab_size=TINY.vocab_size,
                          seed=0)
    session = CADSession.for_pipeline(TINY, pipe, plan_policy="balanced")
    session = session.with_pool(ServerPool(session.cfg.n_servers))

    res = train(TINY, pipe, TrainConfig(
        steps=args.steps, peak_lr=1e-3, warmup=1, log_every=1,
        fault_schedule=spec), session=session)

    h = res["history"]
    assert len(h) == args.steps, "training must finish every step"
    epochs = sorted({m.get("sched_pool_epoch", 0.0) for m in h})
    print(f"finished {args.steps}/{args.steps} steps | "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} | "
          f"pool epochs seen: {[int(e) for e in epochs]}")
    print(f"membership log: {session.pool.history()}")


if __name__ == "__main__":
    main()
