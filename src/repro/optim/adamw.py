"""AdamW with decoupled weight decay, sharding-friendly (states mirror the
param tree so GSPMD shards them identically)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        else:
            gnorm = jnp.zeros(())
            scale = 1.0

        b1, b2 = self.b1, self.b2
        lr = self._lr(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), m, v

        # flatten to lists: the param tree contains structural tuples, so
        # tuple-returning tree.map leaves cannot be disambiguated otherwise
        g_l, treedef = jax.tree.flatten(grads)
        m_l = treedef.flatten_up_to(state.mu)
        v_l = treedef.flatten_up_to(state.nu)
        p_l = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(g_l, m_l, v_l, p_l)]
        newp = jax.tree.unflatten(treedef, [t[0] for t in out])
        mu = jax.tree.unflatten(treedef, [t[1] for t in out])
        nu = jax.tree.unflatten(treedef, [t[2] for t in out])
        return newp, AdamWState(step=step, mu=mu, nu=nu), gnorm


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr
