"""Serve-tenant workload: seeded inference traffic as real CA tasks.

Each :class:`ServeRequest` owns deterministic q/k/v content — a pure
function of ``(seed, rid, position)`` — so a task's output is a pure
function of ``(rid, task index)``, *wherever and whenever it runs*.
That is the paper's statelessness property made testable: per-request
output digests must match between a shared-pool run, a statically
partitioned run, and a run that loses a server mid-decode
(``tests/test_fabric.py`` pins this down).

The request lifecycle mirrors the serving engine: prefill chunks of up
to one 128-token block (the q-block purity the kernels require), then
one decode task per round.  ``build_batch`` packs the tasks admitted
onto one server into the exact fused layout
``core.dispatch.serve_task_batch`` consumes — q tasks padded to one
block with dead (-1) rows, a dense kv-block buffer, and a
``task_kv_start``/``task_kv_len`` plan — so serve-tenant execution
runs through the *same* server kernels as training CA tasks.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fabric.tenancy import ServeTaskReq


def _digest(x) -> str:
    return hashlib.sha1(np.ascontiguousarray(np.asarray(x))
                        .tobytes()).hexdigest()


@dataclasses.dataclass
class ServeRequest:
    """One inference request plus its workload-owned runtime state."""
    rid: int
    prompt_len: int
    max_new_tokens: int
    arrival_step: int
    # deterministic content, generated once at construction
    qc: np.ndarray = dataclasses.field(repr=False, default=None)
    kc: np.ndarray = dataclasses.field(repr=False, default=None)
    vc: np.ndarray = dataclasses.field(repr=False, default=None)
    # runtime
    n_prefilled: int = 0
    n_decoded: int = 0
    digests: List[str] = dataclasses.field(default_factory=list)
    done_step: int = -1

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def done(self) -> bool:
        return self.n_prefilled >= self.prompt_len \
            and self.n_decoded >= self.max_new_tokens

    def next_task(self, blk: int = 128) -> Optional[Tuple[int, int, int]]:
        """(seq, q_tokens, kv_tokens) of the next CA task, or None.
        The task sequence is fixed per request — prefill chunks of up
        to ``blk`` tokens, then one task per decoded token — so task
        ``seq``'s content (hence output) never depends on *when* or
        *where* earlier tasks ran."""
        if self.n_prefilled < self.prompt_len:
            seq = self.n_prefilled // blk
            qt = min(blk, self.prompt_len - self.n_prefilled)
            return seq, qt, self.n_prefilled + qt
        if self.n_decoded < self.max_new_tokens:
            nchunks = -(-self.prompt_len // blk)
            p = self.prompt_len + self.n_decoded
            return nchunks + self.n_decoded, 1, p + 1
        return None


class ServeWorkload:
    """A set of seeded requests + the fused-batch builder.

    ``arrivals`` is ``[(arrival_step, prompt_len, max_new_tokens), ...]``
    (rid = list index).  ``slots`` bounds tasks per fused server batch
    (longer placements execute in slot-sized groups); the kv buffer
    holds ``slots * ceil(max_total / blk)`` blocks, and ``jmax`` (for
    the server kernel's scan bound) is the per-request block count."""

    def __init__(self, arrivals: Sequence[Tuple[int, int, int]], *,
                 n_heads: int = 2, head_dim: int = 16,
                 n_kv_heads: Optional[int] = None, blk: int = 128,
                 slots: int = 8, seed: int = 0):
        self.blk = int(blk)
        self.h, self.dh = int(n_heads), int(head_dim)
        self.hkv = int(n_kv_heads or n_heads)
        self.slots = int(slots)
        self.seed = int(seed)
        self.requests: List[ServeRequest] = []
        root = jax.random.PRNGKey(seed)
        max_total = 0
        for rid, (arr, plen, mnew) in enumerate(arrivals):
            if plen < 1:
                raise ValueError(f"request {rid}: empty prompt")
            total = plen + mnew
            max_total = max(max_total, total)
            pad = -(-total // self.blk) * self.blk
            kq, kk, kv = jax.random.split(jax.random.fold_in(root, rid), 3)
            self.requests.append(ServeRequest(
                rid=rid, prompt_len=int(plen), max_new_tokens=int(mnew),
                arrival_step=int(arr),
                qc=np.asarray(jax.random.normal(
                    kq, (pad, self.h, self.dh), jnp.float32)),
                kc=np.asarray(jax.random.normal(
                    kk, (pad, self.hkv, self.dh), jnp.float32)),
                vc=np.asarray(jax.random.normal(
                    kv, (pad, self.hkv, self.dh), jnp.float32))))
        self.req_blocks = max(1, -(-max_total // self.blk))
        self.kv_blocks = self.slots * self.req_blocks
        self.jmax = self.req_blocks
        self.waits: Dict[int, int] = {}       # rid -> deferred rounds
        self.tokens_executed = 0

    # ------------------------------------------------------------ queries
    def pending(self, step: int) -> List[ServeTaskReq]:
        """One ready task per arrived, unfinished request, FCFS order
        (arrival step, then rid) — the admission round's input."""
        out = []
        for r in self.requests:
            if r.arrival_step > step or r.done:
                continue
            seq, qt, kvt = r.next_task(self.blk)
            out.append(ServeTaskReq(rid=r.rid, seq=seq, q_tokens=qt,
                                    kv_tokens=kvt,
                                    arrival_step=r.arrival_step))
        out.sort(key=lambda t: (t.arrival_step, t.rid))
        return out

    def all_done(self) -> bool:
        return all(r.done for r in self.requests)

    def record_waits(self, deferred: Sequence[ServeTaskReq]) -> None:
        for t in deferred:
            self.waits[t.rid] = self.waits.get(t.rid, 0) + 1

    # ---------------------------------------------------------- execution
    def build_batch(self, tasks: Sequence[ServeTaskReq]):
        """Fused inputs for up to ``slots`` tasks on one server:
        ``((q_tasks, qpos, k_buf, v_buf, kpos), plan)`` in
        ``serve_task_batch``'s layout.  Dead q rows carry position -1
        (masked by the kernel), kv padding rows likewise."""
        if len(tasks) > self.slots:
            raise ValueError(f"{len(tasks)} tasks > {self.slots} slots")
        blk, h, dh, hkv = self.blk, self.h, self.dh, self.hkv
        q_tasks = np.zeros((self.slots, blk, h, dh), np.float32)
        qpos = -np.ones((self.slots, blk), np.int32)
        k_buf = np.zeros((self.kv_blocks, blk, hkv, dh), np.float32)
        v_buf = np.zeros((self.kv_blocks, blk, hkv, dh), np.float32)
        kpos = -np.ones((self.kv_blocks, blk), np.int32)
        kv_start = np.zeros(self.slots, np.int32)
        kv_len = np.zeros(self.slots, np.int32)
        cur = 0
        for i, t in enumerate(tasks):
            r = self.requests[t.rid]
            qt, kvt = t.q_tokens, t.kv_tokens
            lo = kvt - qt                      # q rows' absolute positions
            q_tasks[i, :qt] = r.qc[lo:lo + qt]
            qpos[i, :qt] = np.arange(lo, lo + qt, dtype=np.int32)
            nbk = -(-kvt // blk)
            k_buf[cur:cur + nbk] = r.kc[:nbk * blk].reshape(
                nbk, blk, hkv, dh)
            v_buf[cur:cur + nbk] = r.vc[:nbk * blk].reshape(
                nbk, blk, hkv, dh)
            p = np.arange(nbk * blk, dtype=np.int32)
            kpos[cur:cur + nbk] = np.where(p < kvt, p, -1).reshape(
                nbk, blk)
            kv_start[i], kv_len[i] = cur, nbk
            cur += nbk
        inputs = tuple(jnp.asarray(a) for a in
                       (q_tasks, qpos, k_buf, v_buf, kpos))
        plan = {"task_kv_start": jnp.asarray(kv_start),
                "task_kv_len": jnp.asarray(kv_len)}
        return inputs, plan

    def commit(self, task: ServeTaskReq, out_rows, step: int) -> None:
        """Record one executed task's output digest and advance the
        request.  The digest covers exactly the live q rows, so it is
        independent of batch-mates and placement."""
        r = self.requests[task.rid]
        r.digests.append(_digest(out_rows[:task.q_tokens]))
        if r.n_prefilled < r.prompt_len:
            r.n_prefilled += task.q_tokens
        else:
            r.n_decoded += 1
        if r.done:
            r.done_step = step
        self.waits.pop(task.rid, None)
        self.tokens_executed += task.q_tokens

    # ------------------------------------------------------------ reports
    def digest_map(self) -> Dict[int, Tuple[str, ...]]:
        return {r.rid: tuple(r.digests) for r in self.requests}

    def completion(self) -> Dict[int, int]:
        return {r.rid: r.done_step for r in self.requests}
