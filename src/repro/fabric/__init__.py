"""Multi-tenant attention fabric (DESIGN.md §10).

One elastic :class:`~repro.runtime.pool.ServerPool` serves two
tenants: training step-plans (throughput class, owns the pool) and
inference prefill/decode traffic (latency class, backfills idle
capacity and preempts only speculation).  Admission, execution and
recovery all run against one epoch-stamped ``CalibrationSnapshot``
per round, so every mixed step is deterministic and replayable.
"""
from repro.fabric.executor import FabricExecutor, FabricStepReport
from repro.fabric.tenancy import (LATENCY, SERVE, THROUGHPUT, TRAIN,
                                  AdmissionPolicy, AdmissionRound,
                                  ServeTaskReq, TenantClass, admit_serve)
from repro.fabric.workload import ServeRequest, ServeWorkload

__all__ = [
    "AdmissionPolicy", "AdmissionRound", "FabricExecutor",
    "FabricStepReport", "LATENCY", "SERVE", "ServeRequest",
    "ServeTaskReq", "ServeWorkload", "THROUGHPUT", "TRAIN",
    "TenantClass", "admit_serve",
]
