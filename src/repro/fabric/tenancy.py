"""Tenant classes and SLO-aware admission for the multi-tenant fabric.

One elastic :class:`~repro.runtime.pool.ServerPool` serves two tenants
(DESIGN.md §10):

  * **train** — the throughput class.  Its primary ``StepPlan`` tasks
    own the pool: admission never delays them, and a serve task is only
    placed into a server's *idle* capacity (the gap between the
    server's predicted primary load and the step cadence).
  * **serve** — the latency class.  Its prefill/decode CA tasks backfill
    idle capacity, and under SLO pressure they *preempt
    speculation-eligible training blocks* — the straggler backup
    re-executions, which are redundant by construction — never primary
    tasks.

Admission is deterministic: one :class:`CalibrationSnapshot` and one
``pool_epoch``-stamped membership view per round (the discipline
``CADSession.plan`` follows), FCFS order with head-of-line blocking
(the serve scheduler's documented semantics), ties broken by the lowest
slot.  The head-of-line task's budget goes soft after
``max_wait_rounds`` — the same forward-progress guarantee the serve
scheduler gives its last request.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

TRAIN_NAME, SERVE_NAME = "train", "serve"
THROUGHPUT, LATENCY = "throughput", "latency"


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """Priority class of one fabric tenant.  ``kind`` picks the
    scheduling objective (throughput = own the step plan, latency =
    backfill + SLO); lower ``priority`` wins a capacity conflict.
    ``preempts_speculation`` lets the latency class reclaim the
    capacity straggler speculation would burn on redundant backups."""
    name: str
    kind: str
    priority: int
    preempts_speculation: bool = False

    def __post_init__(self):
        if self.kind not in (THROUGHPUT, LATENCY):
            raise ValueError(f"unknown tenant kind {self.kind!r}")


TRAIN = TenantClass(name=TRAIN_NAME, kind=THROUGHPUT, priority=0)
SERVE = TenantClass(name=SERVE_NAME, kind=LATENCY, priority=1,
                    preempts_speculation=True)


@dataclasses.dataclass(frozen=True)
class ServeTaskReq:
    """One serve-tenant CA task awaiting placement: request ``rid``'s
    next prefill chunk or decode step — ``q_tokens`` query tokens
    against a ``kv_tokens``-token context, the exact shape the cost
    model prices."""
    rid: int
    seq: int                      # task index within the request
    q_tokens: int
    kv_tokens: int
    arrival_step: int             # request arrival (FCFS key)


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs of the serve tenant's admission.

    ``slo_rounds``: target rounds from readiness to execution; a task
    waiting longer counts as an SLO miss in the round report.
    ``max_wait_rounds``: after this many deferrals the head-of-line
    task is force-admitted onto the least-loaded candidate even if the
    idle budget is exhausted (stretching the step — forward progress
    beats cadence).  ``allowed``: restrict serve placement to these
    slots (None = the whole pool) — a static partition expressed in the
    same machinery, which is exactly what ``benchmarks/fabric_mix.py``
    uses as its baseline."""
    slo_rounds: int = 4
    max_wait_rounds: int = 8
    allowed: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class AdmissionRound:
    """The (deterministic, replayable) outcome of one admission round."""
    pool_epoch: int
    calib_version: int
    placements: Dict[int, List[ServeTaskReq]]   # server -> tasks
    deferred: Tuple[ServeTaskReq, ...]
    forced: Tuple[int, ...]            # rids admitted past the budget
    idle_before: Dict[int, float]      # per-server idle seconds offered
    idle_after: Dict[int, float]       # ... left after placement
    slo_misses: int                    # deferred tasks older than SLO

    @property
    def n_admitted(self) -> int:
        return sum(len(t) for t in self.placements.values())


def admit_serve(tasks: Sequence[ServeTaskReq],
                busy: Dict[int, float],
                interval: float,
                snapshot,
                view,
                *,
                policy: AdmissionPolicy = AdmissionPolicy(),
                candidates: Optional[Sequence[int]] = None,
                waits: Optional[Dict[int, int]] = None) -> AdmissionRound:
    """Place serve tasks into the pool's idle capacity for one round.

    ``busy`` maps server -> predicted primary train seconds this step
    (0 for servers with no train tasks — e.g. draining slots kept alive
    for serving); ``interval`` is the step cadence, so a server's idle
    budget is ``interval - busy``.  ``snapshot`` prices every task
    (``cost_model.predict(q, kv) / speed``); ``view`` (a ``PoolView``
    or None) stamps the round with the membership epoch and, when
    ``candidates`` is not given, supplies active + draining slots —
    draining servers take no *new train* tasks but still serve.

    Placement: FCFS over ``tasks``; each task goes to the candidate
    with the most remaining idle that fits it (ties -> lowest slot).
    The first unfittable task defers the rest (head-of-line blocking,
    deterministic order) — unless it has waited ``max_wait_rounds``
    (per ``waits``, keyed by rid), in which case it is force-admitted
    onto the least-loaded candidate and admission continues."""
    cm = snapshot.cost_model
    speeds = snapshot.speeds
    if candidates is None:
        if view is not None:
            candidates = tuple(sorted(view.active + view.draining))
        else:
            candidates = tuple(sorted(busy))
    if policy.allowed is not None:
        candidates = tuple(s for s in candidates if s in policy.allowed)
    epoch = -1 if view is None else int(view.epoch)
    idle = {s: max(0.0, float(interval) - float(busy.get(s, 0.0)))
            for s in candidates}
    idle_before = dict(idle)
    placements: Dict[int, List[ServeTaskReq]] = {}
    deferred: List[ServeTaskReq] = []
    forced: List[int] = []
    waits = waits or {}
    blocked = False
    for t in tasks:
        if blocked:
            deferred.append(t)
            continue
        cost = float(cm.predict(t.q_tokens, t.kv_tokens))
        best, best_left = -1, 0.0
        for s in candidates:
            need = cost / float(speeds[s])
            left = idle[s] - need
            if left >= 0.0 and (best < 0 or left > best_left):
                best, best_left = s, left
        if best < 0:
            if candidates and waits.get(t.rid, 0) >= policy.max_wait_rounds:
                # forward progress: budget goes soft for the head of
                # line, mirroring the serve scheduler's sole-request rule
                best = max(candidates, key=lambda s: (idle[s], -s))
                forced.append(t.rid)
            else:
                deferred.append(t)
                blocked = True           # head-of-line blocking
                continue
        idle[best] -= cost / float(speeds[best])
        placements.setdefault(best, []).append(t)
    slo_misses = sum(1 for t in deferred
                     if waits.get(t.rid, 0) >= policy.slo_rounds)
    return AdmissionRound(pool_epoch=epoch,
                          calib_version=int(snapshot.version),
                          placements=placements,
                          deferred=tuple(deferred),
                          forced=tuple(forced),
                          idle_before=idle_before,
                          idle_after=idle,
                          slo_misses=slo_misses)
