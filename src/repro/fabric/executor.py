"""FabricExecutor: mixed train + serve steps on one elastic pool.

Extends :class:`~repro.runtime.executor.ElasticExecutor` — the training
path is *literally* the elastic executor's (``begin_step`` /
``finish_step``), so train outputs are bit-identical to a dedicated-pool
run by construction: admission reads the step state (predicted
per-server loads, the membership view, the pricing snapshot) but never
touches training tensors.

Per ``run_mixed_step`` (DESIGN.md §10):

  1. ``begin_step`` — membership events, the train plan, per-server
     primary predictions, one cost view;
  2. serve admission — one :func:`~repro.fabric.tenancy.admit_serve`
     round against the *same* snapshot and pool view the plan used,
     placing pending serve tasks into ``interval - busy`` idle budgets.
     Pending serve traffic preempts *speculation* (the straggler
     backups are redundant work) by zeroing the step's
     ``speculate_pct`` — never a primary task;
  3. ``finish_step`` — primary execution, failure recovery via
     ``build_recovery_plan``, exactly-once merge;
  4. serve execution — each server's placed tasks run through the same
     ``serve_task_batch`` kernels as training CA tasks.  Tasks placed
     on a server that was killed mid-step are lost with its train
     tasks and **re-admitted onto the least-loaded survivors in the
     same round** — the serve-side mirror of the recovery sub-plan's
     placement rule (and priced from the same epoch-stamped snapshot);
  5. accounting: the fabric step completes at
     ``max(interval, busiest server)`` — backfill never stretches the
     training cadence unless a forced admission or recovery does.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax

from repro.core.cost_model import CalibrationSnapshot
from repro.core.dispatch import CADContext, serve_task_batch
from repro.fabric.tenancy import (SERVE, TRAIN, AdmissionPolicy,
                                  AdmissionRound, ServeTaskReq,
                                  admit_serve)
from repro.fabric.workload import ServeWorkload
from repro.obs import server_track
from repro.runtime.executor import ElasticExecutor, StepReport


@dataclasses.dataclass(frozen=True)
class FabricStepReport:
    """One mixed step: the train tenant's StepReport plus the serve
    tenant's admission/execution/recovery accounting."""
    train: StepReport
    pool_epoch: int
    calib_version: int
    interval: float
    admitted: int
    executed: int
    deferred: int
    forced: Tuple[int, ...]
    lost_serve: int                    # tasks lost to a mid-step kill
    readmitted: int
    slo_misses: int
    spec_preempted: bool               # serve claimed speculation slack
    serve_seconds: Dict[int, float]
    serve_tokens: int
    step_seconds: float                # fabric completion (>= interval)

    def summary(self) -> str:
        bits = [f"step {self.train.step} epoch {self.pool_epoch} "
                f"serve {self.executed}/{self.executed + self.deferred} "
                f"tok={self.serve_tokens}"]
        if self.lost_serve:
            bits.append(f"lost={self.lost_serve} "
                        f"readmitted={self.readmitted}")
        if self.spec_preempted:
            bits.append("spec-preempted")
        return self.train.summary() + " | " + " | ".join(bits)


class FabricExecutor(ElasticExecutor):
    """One pool, two tenants.  ``workload`` is the serve tenant
    (:class:`ServeWorkload`); ``policy`` its admission knobs — set
    ``policy.allowed`` to a slot subset (with those slots drained in
    the pool) to express a static partition in the same machinery."""

    def __init__(self, session, workload: ServeWorkload, *,
                 faults=None, policy: AdmissionPolicy = AdmissionPolicy(),
                 speculate_pct: float = 0.0, speculate_slack: float = 1.5,
                 timer: str = "model", feed_calibrator: bool = False,
                 recorder=None, metrics=None, clock=None):
        super().__init__(session, faults=faults,
                         speculate_pct=speculate_pct,
                         speculate_slack=speculate_slack, timer=timer,
                         feed_calibrator=feed_calibrator,
                         recorder=recorder, metrics=metrics, clock=clock)
        if workload.blk != session.cfg.blk:
            raise ValueError(
                f"workload blk {workload.blk} != pool blk "
                f"{session.cfg.blk}")
        self.workload = workload
        self.policy = policy
        self.tenants = (TRAIN, SERVE)
        # serve tasks share the pool's kernels but their own (smaller)
        # fused shapes; jmax bounds each task's kv-block scan
        self._serve_cad = CADContext(cfg=session.cfg,
                                     kernel=session.kernel,
                                     bwd=session.bwd,
                                     jmax=workload.jmax)

    # ---------------------------------------------------------- stepping
    def run_mixed_step(self, step: int, q, k, v, pos, segment_ids, *,
                       interval: float):
        """One fabric step at cadence ``interval`` (seconds): the train
        step plus serve backfill.  Returns
        ``(train_out, FabricStepReport)``."""
        st = self.begin_step(step, q, k, v, pos, segment_ids)
        # ONE pricing basis per admission round: the same cost view the
        # plan was built from, stamped with the step's pool epoch
        snap = CalibrationSnapshot(
            version=int(st.stats.get("calib_version", -1)),
            cost_model=st.cm, speeds=tuple(float(x) for x in st.speeds))
        tasks = self.workload.pending(step)

        spec_preempted = False
        if tasks and st.speculate_pct > 0 and SERVE.preempts_speculation:
            # latency class reclaims the speculation slack: backup
            # re-executions of straggler blocks are redundant work, so
            # serve takes that capacity; primary tasks are untouchable
            st.speculate_pct = 0.0
            spec_preempted = True

        candidates = tuple(sorted(st.view.active + st.view.draining))
        if self.policy.allowed is not None:
            candidates = tuple(s for s in candidates
                               if s in self.policy.allowed)
        busy = {s: float(st.preds.get(s, 0.0)) for s in candidates}
        rnd = admit_serve(tasks, busy, interval, snap, st.view,
                          policy=self.policy, candidates=candidates,
                          waits=self.workload.waits)
        t_base = self._trace_t           # this step's timeline origin
        self.recorder.instant(
            "admission", "fabric", ts=t_base, step=step,
            args={"admitted": rnd.n_admitted,
                  "deferred": len(rnd.deferred),
                  "forced": list(rnd.forced),
                  "slo_misses": rnd.slo_misses,
                  "spec_preempted": spec_preempted,
                  "pool_epoch": rnd.pool_epoch,
                  "calib_version": rnd.calib_version})

        train_out, trep = self.finish_step(st)

        # serve execution; a mid-step kill loses the victim's serve
        # tasks along with its train tasks
        serve_secs: Dict[int, float] = {}
        lost: List[ServeTaskReq] = []
        executed, tokens = 0, 0
        for s in sorted(rnd.placements):
            placed = rnd.placements[s]
            if s in trep.failed:
                lost.extend(placed)
                continue
            secs = self._run_serve(s, placed, snap, step)
            serve_secs[s] = serve_secs.get(s, 0.0) + secs
            executed += len(placed)
            tokens += sum(t.q_tokens for t in placed)

        # same-round recovery: lost serve tasks re-place onto the
        # least-loaded survivors (the recovery sub-plan's rule), priced
        # from the same snapshot — then execute
        readmitted = 0
        if lost:
            survivors = [s for s in candidates if s not in trep.failed]
            if survivors:
                load = {s: busy.get(s, 0.0) + serve_secs.get(s, 0.0)
                        + trep.recovery_seconds.get(s, 0.0)
                        for s in survivors}
                regroup: Dict[int, List[ServeTaskReq]] = {}
                for t in lost:
                    cost = float(snap.cost_model.predict(
                        t.q_tokens, t.kv_tokens))
                    tgt = min(survivors,
                              key=lambda x: (load[x]
                                             + cost / snap.speeds[x], x))
                    load[tgt] += cost / snap.speeds[tgt]
                    regroup.setdefault(tgt, []).append(t)
                for s in sorted(regroup):
                    secs = self._run_serve(s, regroup[s], snap, step)
                    serve_secs[s] = serve_secs.get(s, 0.0) + secs
                    executed += len(regroup[s])
                    tokens += sum(t.q_tokens for t in regroup[s])
                    readmitted += len(regroup[s])

        self.workload.record_waits(rnd.deferred)

        totals = [trep.server_seconds.get(s, 0.0)
                  + trep.recovery_seconds.get(s, 0.0)
                  + serve_secs.get(s, 0.0)
                  for s in set(candidates) | set(trep.server_seconds)]
        step_seconds = max([float(interval)] + totals)
        self._record_mixed(step, t_base, float(step_seconds), rnd,
                           trep, serve_secs, len(lost), readmitted,
                           spec_preempted)
        rep = FabricStepReport(
            train=trep, pool_epoch=rnd.pool_epoch,
            calib_version=rnd.calib_version, interval=float(interval),
            admitted=rnd.n_admitted, executed=executed,
            deferred=len(rnd.deferred), forced=rnd.forced,
            lost_serve=len(lost), readmitted=readmitted,
            slo_misses=rnd.slo_misses, spec_preempted=spec_preempted,
            serve_seconds=dict(serve_secs), serve_tokens=tokens,
            step_seconds=float(step_seconds))
        return train_out, rep

    # ----------------------------------------------------- observability
    def _record_mixed(self, step: int, t_base: float,
                      step_seconds: float, rnd: AdmissionRound,
                      trep: StepReport, serve_secs: Dict[int, float],
                      n_lost: int, readmitted: int,
                      spec_preempted: bool) -> None:
        """Narrate the serve tenant's half of the step (DESIGN.md §14).
        ``finish_step`` already advanced the timeline by the train
        completion; the fabric completes at ``max(interval, busiest)``,
        so re-anchor the cumulative origin to the fabric's end."""
        rec, mx = self.recorder, self.metrics
        self._trace_t = t_base + step_seconds
        if rec.enabled:
            for s, secs in sorted(serve_secs.items()):
                # backfill runs after the server's train tasks (and any
                # recovery it absorbed) — same order as execution
                start = t_base + trep.server_seconds.get(s, 0.0) \
                    + trep.recovery_seconds.get(s, 0.0)
                rec.add_span("serve.backfill", server_track(s), start,
                             secs, step=step)
        mx.counter("cad_serve_admitted_total",
                   "serve tasks admitted").inc(rnd.n_admitted)
        mx.counter("cad_serve_deferred_total",
                   "serve tasks deferred to a later round").inc(
            len(rnd.deferred))
        mx.counter("cad_serve_forced_total",
                   "starvation-forced admissions").inc(len(rnd.forced))
        mx.counter("cad_serve_slo_misses_total",
                   "admissions past their SLO").inc(rnd.slo_misses)
        mx.counter("cad_serve_lost_total",
                   "serve tasks lost to a mid-step kill").inc(n_lost)
        mx.counter("cad_serve_readmitted_total",
                   "lost serve tasks re-placed same round").inc(
            readmitted)
        mx.counter("cad_spec_preempted_total",
                   "steps where serve reclaimed speculation slack").inc(
            1 if spec_preempted else 0)
        wait_h = mx.histogram(
            "cad_serve_queue_wait_rounds",
            "rounds a deferred serve task has waited",
            buckets=(1, 2, 4, 8, 16, 32))
        for t in rnd.deferred:
            wait_h.observe(self.workload.waits.get(t.rid, 0))

    # ----------------------------------------------------------- serving
    def _run_serve(self, server: int, placed, snap, step: int) -> float:
        """Execute one server's placed serve tasks (slot-sized fused
        groups) and commit their outputs.  Returns the server's serve
        seconds under the executor's timer."""
        slow = self.faults.slow_factor(step, server)
        secs = 0.0
        w = self.workload
        for i in range(0, len(placed), w.slots):
            group = placed[i:i + w.slots]
            inputs, plan = w.build_batch(group)
            if self.timer == "wall":
                t0 = self.clock.monotonic()
                out = jax.block_until_ready(serve_task_batch(
                    self._serve_cad, inputs, plan))
                secs += (self.clock.monotonic() - t0) * slow
            else:
                out = serve_task_batch(self._serve_cad, inputs, plan)
                secs += sum(float(snap.cost_model.predict(
                    t.q_tokens, t.kv_tokens)) for t in group) \
                    / float(snap.speeds[server]) * slow
            for j, t in enumerate(group):
                w.commit(t, out[j], step)
        if self.feed_calibrator and placed:
            self.session.observe_server(
                server, [(t.q_tokens, t.kv_tokens) for t in placed],
                secs)
        return secs
