"""Serving subsystem (DESIGN.md §8): fused packed chunked prefill, the
ragged-decode kernel path, and host-side continuous batching.

  engine     — Engine / ServeConfig: device loop over two static shapes
               (prefill chunk, decode batch)
  scheduler  — ContinuousScheduler: admission / chunk packing / eviction
"""
from repro.serve.engine import Engine, ServeConfig
from repro.serve.scheduler import (ContinuousScheduler, PrefillChunk,
                                   Request, SchedulerConfig)

__all__ = ["Engine", "ServeConfig", "ContinuousScheduler", "PrefillChunk",
           "Request", "SchedulerConfig"]
