"""Serving engine: fused packed prefill + continuous batching
(DESIGN.md §8).

Prefill no longer feeds prompts one token at a time through the decode
path: prompts are packed cu_seqlens-style into fixed-size chunks (pieces
128-aligned per request, the same block purity the training packer
guarantees for documents) and each chunk is ONE ``serve_chunk_step``
call — the context-independent layers run fused over the ragged token
stream, k/v scatter straight into the serving cache, and attention is a
single ``ragged_decode_attention`` call per layer.  The old per-token
loop survives as ``prefill="loop"`` — it is the benchmark baseline and,
because both paths route every token through the same row-independent
block kernels, the fused chunked prefill reproduces its logits
*bit-exactly* (``tests/test_serve.py`` pins this down).

``Engine.serve`` runs continuous batching on top: a host-side
``ContinuousScheduler`` admits/evicts requests between decode steps
under a token budget (admission scored with the CAD cost model), while
the device sees only two static shapes — the prefill chunk and the
decode batch.

Architectures outside the serving cache layout (cross-attention /
encoder archs) fall back to the legacy dense decode path; recurrent and
MoE archs use the serve layout but prefill per-token (decode-mode
chunks), since their mixers are sequential (ssd/rglru) or batch-global
(MoE routing).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.scheduler import (DECODE, DONE, ContinuousScheduler,
                                   Request, SchedulerConfig)
from repro.train.step import make_serve_chunk_step, make_serve_step


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    max_new_tokens: int = 32
    chunk_tokens: int = 512          # fused prefill chunk (128 multiple)
    prefill: str = "fused"           # "fused" | "loop"
    decode_impl: Optional[str] = None  # ragged kernel: None/"pallas"/"xla"
    token_budget: Optional[int] = None   # continuous-batching kv budget
    admission: str = "fcfs"          # "fcfs" | "cost"
    step_cost_budget: float = 0.0    # predicted CA seconds per decode step
    eos_id: Optional[int] = None
    # live admission pricing: a () -> CalibrationSnapshot callable (e.g.
    # CADSession.snapshot_provider()); when set, cost admission re-prices
    # every round from the calibrator instead of the static analytic model
    snapshot_provider: Optional[Callable] = None


class Engine:
    def __init__(self, cfg, params, ctx, serve_cfg: ServeConfig,
                 memory: Optional[jnp.ndarray] = None, batch_size: int = 1):
        self.cfg, self.params = cfg, params
        self.scfg = serve_cfg
        self.memory = memory
        self.batch_size = batch_size
        if serve_cfg.decode_impl is not None:
            ctx = dataclasses.replace(ctx,
                                      decode_impl=serve_cfg.decode_impl)
        self.ctx = ctx
        # serving layout hosts everything but cross-attention/encoder archs
        self.serve_layout = memory is None \
            and not (cfg.encoder and cfg.encoder.n_layers) \
            and "cross" not in cfg.layer_pattern
        # fused chunked prefill additionally needs attention-only non-MoE
        self.fused_ok = self.serve_layout \
            and all(k in ("global", "local") for k in cfg.layer_pattern) \
            and not (cfg.moe and cfg.moe.n_experts)
        if self.serve_layout:
            self.cache = M.init_cache(params, cfg, batch_size,
                                      serve_cfg.max_seq, ctx=ctx,
                                      layout="serve")
            self._chunk = jax.jit(make_serve_chunk_step(cfg, ctx))
            self._reset = jax.jit(
                lambda cache, mask: M.reset_serve_slots(cache, cfg, mask))
        else:
            self.cache = M.init_cache(params, cfg, batch_size,
                                      serve_cfg.max_seq, memory=memory,
                                      ctx=ctx)
            self._step = jax.jit(make_serve_step(cfg, ctx))

    # ----------------------------------------------------- chunk dispatch
    def _chunk_call(self, tokens, pos, block_req, kv_len_next):
        """All serve-layout device calls go through here.  On
        fused-capable (attention-only) archs, single-row chunks are
        padded with one dead row: XLA CPU lowers M=1 matmuls to a gemv
        whose reduction order differs from the M>=2 gemm, which would
        break the loop-vs-fused bit-parity guarantee for batch_size=1
        engines.  Dead rows are masked everywhere (scatter dropped,
        attention zero, logits row ignored).  Recurrent archs are never
        padded: they have no fused path (so no parity contract) and
        their per-request state is indexed by the row dim."""
        tokens = jnp.asarray(tokens, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)
        block_req = jnp.asarray(block_req, jnp.int32)
        if tokens.shape[0] == 1 and self.fused_ok:
            tokens = jnp.concatenate([tokens, jnp.zeros(1, jnp.int32)])
            pos = jnp.concatenate([pos, -jnp.ones(1, jnp.int32)])
            block_req = jnp.concatenate([block_req,
                                         -jnp.ones(1, jnp.int32)])
            lg, self.cache = self._chunk(self.params, self.cache, tokens,
                                         pos, block_req,
                                         jnp.asarray(kv_len_next,
                                                     jnp.int32))
            return lg[:1]
        lg, self.cache = self._chunk(self.params, self.cache, tokens, pos,
                                     block_req,
                                     jnp.asarray(kv_len_next, jnp.int32))
        return lg

    # ------------------------------------------------- static-batch prefill
    def prefill(self, tokens: jnp.ndarray, mode: Optional[str] = None,
                return_logits: bool = False):
        """Prefill a dense [B, P] prompt batch into the cache.

        mode "fused" (default when supported): chunked packed prefill —
        one ``serve_chunk_step`` per ``chunk_tokens`` over the ragged
        batch.  mode "loop": the per-token baseline.  Returns the
        last-position logits [B, V] (and, with ``return_logits``, the
        full teacher-forced [B, P, V] — what the parity test compares).
        """
        if tokens.shape[1] > self.scfg.max_seq:
            raise ValueError(
                f"prompt length {tokens.shape[1]} exceeds max_seq "
                f"{self.scfg.max_seq}: cache writes past the end would be "
                f"silently dropped")
        mode = mode or (self.scfg.prefill if self.fused_ok else "loop")
        if not self.serve_layout:
            if mode == "fused":
                raise ValueError(
                    f"fused prefill unsupported for {self.cfg.arch_id}: "
                    f"cross-attention/encoder archs use the legacy path")
            if return_logits:
                raise ValueError("return_logits requires the serving "
                                 "cache layout")
            return self._prefill_legacy(tokens)
        # a prefill starts a fresh generation for every slot: drop kv
        # visibility and zero recurrent state (a second generate() on a
        # recurrent arch must not inherit the previous batch's state)
        self.cache = self._reset(
            self.cache, jnp.ones((self.batch_size,), bool))
        if mode == "fused":
            if not self.fused_ok:
                raise ValueError(
                    f"fused prefill unsupported for {self.cfg.arch_id} "
                    f"(pattern {self.cfg.layer_pattern})")
            return self._prefill_fused(tokens, return_logits)
        if mode == "loop":
            return self._prefill_loop(tokens, return_logits)
        raise ValueError(f"unknown prefill mode {mode!r}")

    def _prefill_fused(self, tokens, return_logits=False):
        b, p = tokens.shape
        assert b == self.batch_size
        prompts = np.asarray(tokens)
        sched = ContinuousScheduler(SchedulerConfig(
            n_slots=b, max_seq=self.scfg.max_seq,
            chunk_tokens=self.scfg.chunk_tokens))
        for i in range(b):
            # max_new_tokens=0: prefill-only — a full-max_seq prompt must
            # pass submit()'s prompt+new capacity check like the loop does
            sched.submit(Request(rid=i, prompt=prompts[i],
                                 max_new_tokens=0))
        sched.admit()
        full = np.zeros((b, p, self.cfg.vocab_size), np.float32) \
            if return_logits else None
        last = np.zeros((b, self.cfg.vocab_size), np.float32)
        while True:
            chunk = sched.next_prefill_chunk(fused=True)
            if chunk is None:
                break
            lg = np.asarray(self._chunk_call(chunk.tokens, chunk.pos,
                                             chunk.block_req,
                                             chunk.kv_len_next))
            if return_logits:
                live = chunk.pos >= 0
                tok_req = np.repeat(chunk.block_req,
                                    len(chunk.tokens) // len(chunk.block_req))
                full[tok_req[live], chunk.pos[live]] = lg[live]
            for slot, row in chunk.last_rows:
                last[slot] = lg[row]
        last = jnp.asarray(last)
        return (last, jnp.asarray(full)) if return_logits else last

    def _prefill_loop(self, tokens, return_logits=False):
        b, p = tokens.shape
        assert b == self.batch_size
        block_req = jnp.arange(b, dtype=jnp.int32)
        rows = []
        lg = None
        for t in range(p):
            lg = self._chunk_call(tokens[:, t], jnp.full((b,), t,
                                                         jnp.int32),
                                  block_req,
                                  jnp.full((b,), t + 1, jnp.int32))
            if return_logits:
                rows.append(lg)
        if return_logits:
            return lg, jnp.stack(rows, axis=1)
        return lg

    def _prefill_legacy(self, tokens):
        b, p = tokens.shape
        last = None
        for t in range(p):
            pos = jnp.full((b,), t, jnp.int32)
            _, last, self.cache = self._step(self.params, self.cache,
                                             tokens[:, t:t + 1], pos)
        return last[:, -1]

    # ------------------------------------------------- static-batch decode
    def generate(self, prompt: jnp.ndarray) -> jnp.ndarray:
        """Greedy decode of a dense [B, P] batch; returns [B, max_new]."""
        b, p = prompt.shape
        # tokens are cached at positions 0 .. p + max_new - 2
        if p + self.scfg.max_new_tokens - 1 > self.scfg.max_seq:
            raise ValueError(
                f"prompt {p} + max_new_tokens {self.scfg.max_new_tokens} "
                f"does not fit max_seq {self.scfg.max_seq}")
        if not self.serve_layout:
            return self._generate_legacy(prompt)
        lg = self.prefill(prompt)
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out = [nxt]
        block_req = jnp.arange(b, dtype=jnp.int32)
        for i in range(self.scfg.max_new_tokens - 1):
            lg = self._chunk_call(nxt, jnp.full((b,), p + i, jnp.int32),
                                  block_req,
                                  jnp.full((b,), p + i + 1, jnp.int32))
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            out.append(nxt)
        return jnp.stack(out, axis=1)

    def _generate_legacy(self, prompt):
        b, p = prompt.shape
        lg = self._prefill_legacy(prompt)
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out = [nxt]
        for i in range(self.scfg.max_new_tokens - 1):
            pos = jnp.full((b,), p + i, jnp.int32)
            nxt, _, self.cache = self._step(self.params, self.cache,
                                            nxt[:, None], pos)
            out.append(nxt)
        return jnp.stack(out, axis=1)

    # --------------------------------------------------- continuous batching
    def make_scheduler(self, *, snapshot_provider=None) \
            -> ContinuousScheduler:
        """A ContinuousScheduler configured from this engine's
        ServeConfig — the state machine ``serve_round`` steps.  With a
        ``snapshot_provider`` (argument or ``ServeConfig`` field), cost
        admission prices from one live calibration snapshot per round;
        otherwise it falls back to the static analytic model."""
        if not self.serve_layout:
            raise ValueError("continuous batching needs the serving cache "
                             "layout (no cross-attention/encoder archs)")
        scfg = self.scfg
        provider = snapshot_provider or scfg.snapshot_provider
        need_cost = scfg.admission == "cost" or scfg.step_cost_budget
        return ContinuousScheduler(SchedulerConfig(
            n_slots=self.batch_size, max_seq=scfg.max_seq,
            chunk_tokens=scfg.chunk_tokens,
            token_budget=scfg.token_budget,
            admission=scfg.admission,
            cost_model=self._cost_model()
            if (need_cost and provider is None) else None,
            snapshot_provider=provider if need_cost else None,
            step_cost_budget=scfg.step_cost_budget,
            eos_id=scfg.eos_id))

    def serve_round(self, sched: ContinuousScheduler, *,
                    on_token=None) -> bool:
        """One continuous-batching round: admit -> (prefill chunk |
        evict + decode step).  Returns False when the scheduler had no
        work.  ``on_token(rid, token, done)`` streams every newly
        sampled token (the launch/serve.py daemon's hook).  ``serve``
        is a loop over exactly these rounds, so daemon-driven serving
        and batch serving share one code path (and one trace order)."""
        if not sched.has_work():
            return False
        obs_metrics.get_registry().counter(
            "serve_rounds_total", "continuous-batching rounds").inc()
        with obs_trace.get_recorder().span(
                "serve.round", "serve",
                args={"active": len(sched.active),
                      "waiting": len(sched.waiting)}):
            return self._serve_round_inner(sched, on_token)

    def _serve_round_inner(self, sched: ContinuousScheduler,
                           on_token) -> bool:
        newly = sched.admit()
        if newly:
            mask = np.zeros(self.batch_size, bool)
            for r in newly:
                mask[r.slot] = True
            self.cache = self._reset(self.cache, jnp.asarray(mask))
        fused = self.fused_ok and self.scfg.prefill == "fused"
        if sched.has_prefill():
            chunk = sched.next_prefill_chunk(fused=fused)
            lg = self._chunk_call(chunk.tokens, chunk.pos,
                                  chunk.block_req, chunk.kv_len_next)
            if chunk.last_rows:
                reqs = {slot: sched.active[slot]
                        for slot, _row in chunk.last_rows}
                nxt = np.asarray(jnp.argmax(lg, axis=-1))
                sched.commit_prefill(
                    chunk, {slot: nxt[row]
                            for slot, row in chunk.last_rows})
                if on_token is not None:
                    for slot, req in sorted(reqs.items()):
                        if req.out_tokens:
                            on_token(req.rid, req.out_tokens[-1],
                                     req.state == DONE)
                        elif req.state == DONE:     # prefill-only
                            on_token(req.rid, None, True)
            return True
        sched.evict_for_budget()
        batch = sched.decode_batch()
        if batch is None:
            return True
        tokens, pos, block_req, kv_next = batch
        decoding = {slot: r for slot, r in sched.active.items()
                    if r.state == DECODE}
        lg = self._chunk_call(tokens, pos, block_req, kv_next)
        sched.commit_decode(np.asarray(jnp.argmax(lg, axis=-1)))
        if on_token is not None:
            for _slot, req in sorted(decoding.items()):
                on_token(req.rid, req.out_tokens[-1],
                         req.state == DONE)
        return True

    def serve(self, prompts: List[np.ndarray],
              max_new_tokens: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Continuous batching: stream an arbitrary number of ragged
        requests through ``batch_size`` cache slots.  Returns
        {rid: generated tokens} with rid = submission index."""
        sched = self.make_scheduler()
        mn = self.scfg.max_new_tokens if max_new_tokens is None \
            else max_new_tokens
        for i, pr in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=np.asarray(pr, np.int32),
                                 max_new_tokens=mn))
        while self.serve_round(sched):
            pass
        out = {r.rid: np.asarray(r.out_tokens, np.int32)
               for r in sched.done}
        self.last_trace = sched.trace
        return out

    def _cost_model(self):
        from repro.core.cost_model import CostModel
        return CostModel.analytic(self.cfg.n_heads, self.cfg.head_dim)
