"""Minimal batched serving engine: prefill (teacher-forced forward filling
the KV cache) + batched greedy decode.  Used by the serving example and
the decode-shape dry-runs."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.train.step import make_serve_step


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    max_new_tokens: int = 32


class Engine:
    def __init__(self, cfg, params, ctx, serve_cfg: ServeConfig,
                 memory: Optional[jnp.ndarray] = None, batch_size: int = 1):
        self.cfg, self.params, self.ctx = cfg, params, ctx
        self.scfg = serve_cfg
        self.memory = memory
        self.batch_size = batch_size
        self.cache = M.init_cache(params, cfg, batch_size, serve_cfg.max_seq,
                                  memory=memory, ctx=ctx)
        self._step = jax.jit(make_serve_step(cfg, ctx))

    def prefill(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """tokens [B, P]: feed prompt one position at a time through the
        decode path (simple, exactly matches decode semantics)."""
        b, p = tokens.shape
        last = None
        for t in range(p):
            pos = jnp.full((b,), t, jnp.int32)
            last, _, self.cache = self._step(self.params, self.cache,
                                             tokens[:, t:t + 1], pos)
        return last

    def generate(self, prompt: jnp.ndarray) -> jnp.ndarray:
        b, p = prompt.shape
        nxt = self.prefill(prompt)
        out = [nxt]
        for i in range(self.scfg.max_new_tokens - 1):
            pos = jnp.full((b,), p + i, jnp.int32)
            nxt, _, self.cache = self._step(self.params, self.cache,
                                            nxt[:, None], pos)
            out.append(nxt)
        return jnp.stack(out, axis=1)
