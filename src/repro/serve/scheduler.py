"""Continuous-batching scheduler for the serving engine (DESIGN.md §8).

Host-side, numpy — the serving analogue of the CAD training scheduler
(``core/scheduler.py``): where training balances CA-task FLOPs across a
fixed attention-server pool, serving balances *requests* across a fixed
pool of cache slots, between decode steps, under capacity bounds that
mirror the compiled step's static shapes.

Three mechanisms:

  admission  — WAITING requests claim free slots while the projected kv
               footprint stays under ``token_budget`` and (optionally)
               the predicted per-step core-attention time stays under
               ``step_cost_budget``, scored with the same
               ``core.cost_model.CostModel`` the CAD planner uses.
               Policy "fcfs" admits in arrival order (head-of-line
               blocking keeps ordering deterministic); "cost" admits
               cheapest-predicted-first — the planner's balance logic
               repurposed for serving.
  prefill    — prompts stream through fixed-size chunks: each chunk packs
               pieces of the active prefilling prompts cu_seqlens-style,
               every piece aligned to the 128-token kernel block so q
               blocks stay request-pure (the invariant
               ``ragged_decode_attention`` relies on, exactly like the
               training packer's document-pure blocks).  ``fused=False``
               degrades to one-token-per-request decode-mode chunks (the
               per-token path for recurrent/MoE archs — and the
               benchmark baseline).
  eviction   — when live requests outgrow the token budget (decode
               lengthens kv every step), the most recently admitted
               request is preempted LIFO, its progress discarded, and it
               is requeued at the *front* of the waiting queue
               (vLLM-style recompute preemption).

The scheduler owns all request state; the engine owns device state and
calls ``admit -> next_prefill_chunk -> commit_prefill`` /
``decode_batch -> commit_decode`` in a loop.  ``trace`` logs
(event, rid) pairs for every admit/finish/evict — the ordering contract
the tests pin down.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import CalibrationSnapshot, CostModel
from repro.data.packing import BLOCK
from repro.obs import metrics as obs_metrics

WAITING, PREFILL, DECODE, DONE = "waiting", "prefill", "decode", "done"


@dataclasses.dataclass
class Request:
    """One generation request plus its scheduler-owned runtime state."""
    rid: int
    prompt: np.ndarray                 # [P] int32
    max_new_tokens: int = 32
    # runtime
    state: str = WAITING
    slot: int = -1
    n_prefilled: int = 0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    n_evictions: int = 0
    admit_seq: int = -1                # monotone admission stamp (LIFO key)

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def total_len(self) -> int:
        """Upper bound on this request's kv footprint."""
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass
class PrefillChunk:
    """Device-ready arrays for one ``serve_chunk_step`` call."""
    tokens: np.ndarray                 # [T] int32 (0 on padding rows)
    pos: np.ndarray                    # [T] int32 (-1 on padding rows)
    block_req: np.ndarray              # [nq] int32 (-1 = dead block)
    kv_len_next: np.ndarray            # [n_slots] int32
    last_rows: List[Tuple[int, int]]   # (slot, row of last prompt token)
                                       # for requests finishing prefill


@dataclasses.dataclass
class SchedulerConfig:
    n_slots: int
    max_seq: int
    chunk_tokens: int = 512
    token_budget: Optional[int] = None   # cap on Σ projected kv tokens
    admission: str = "fcfs"              # "fcfs" | "cost"
    cost_model: Optional[CostModel] = None
    # live pricing: pulled once per admission round, so admission prices
    # with the same calibrated snapshot the CAD planner plans from
    # (instead of a static cost_model that ignores a live calibrator)
    snapshot_provider: \
        Optional[Callable[[], CalibrationSnapshot]] = None
    step_cost_budget: float = 0.0        # seconds of predicted CA per
                                         # decode step; 0 disables
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.chunk_tokens % BLOCK != 0:
            raise ValueError(
                f"chunk_tokens {self.chunk_tokens} must be a multiple "
                f"of {BLOCK}")
        if self.token_budget is None:
            self.token_budget = self.n_slots * self.max_seq
        if self.admission not in ("fcfs", "cost"):
            raise ValueError(f"unknown admission policy {self.admission!r}")
        if (self.admission == "cost" or self.step_cost_budget) \
                and self.cost_model is None \
                and self.snapshot_provider is None:
            raise ValueError("cost-based admission needs a cost_model "
                             "or a snapshot_provider")


class ContinuousScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.kv_len = np.zeros(cfg.n_slots, np.int32)
        self.free = list(range(cfg.n_slots))          # kept sorted
        self.done: List[Request] = []
        self.trace: List[Tuple[str, int]] = []
        self._admit_counter = 0
        self._round_cm = cfg.cost_model
        self.last_calib_version = -1      # snapshot version priced with

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        if req.prompt_len == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.total_len > self.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+new {req.total_len} exceeds "
                f"max_seq {self.cfg.max_seq}")
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    def has_prefill(self) -> bool:
        return any(r.state == PREFILL for r in self.active.values())

    # ------------------------------------------------------------ budgets
    def _live_tokens(self) -> int:
        """Committed kv footprint: an admitted request's whole prompt
        counts from admission (prefill is committed work — otherwise two
        large prompts could co-admit under the budget at kv_len 0 and
        one would be evicted right after its prefill was paid for),
        plus one decode step of growth for decoding requests."""
        total = 0
        for slot, r in self.active.items():
            if r.state == PREFILL:
                total += r.prompt_len
            else:
                total += int(self.kv_len[slot]) + 1
        return total

    def _refresh_cost_model(self) -> Optional[CostModel]:
        """The cost model this admission round prices with: ONE snapshot
        per round from ``snapshot_provider`` when attached (admission
        then agrees with the calibrated planner), else the static
        ``cost_model``.  All of a round's decisions — ordering, the
        step-cost budget — use the same pull."""
        if self.cfg.snapshot_provider is not None:
            snap = self.cfg.snapshot_provider()
            self._round_cm = snap.cost_model
            self.last_calib_version = int(snap.version)
        return self._round_cm

    def _step_cost(self, extra: Optional[Request] = None) -> float:
        cm = self._round_cm
        reqs = list(self.active.values()) + ([extra] if extra else [])
        return float(sum(cm.predict(1, r.total_len) for r in reqs))

    def _admissible(self, req: Request) -> bool:
        # +1 decode-step growth, unless the request is prefill-only
        grow = min(1, req.max_new_tokens)
        if self._live_tokens() + req.prompt_len + grow \
                > self.cfg.token_budget:
            return False
        if self.cfg.step_cost_budget and self.active \
                and self._step_cost(req) > self.cfg.step_cost_budget:
            return False
        return True

    # ---------------------------------------------------------- admission
    def admit(self) -> List[Request]:
        """Move waiting requests into free slots under the budgets."""
        admitted = []
        cm = self._refresh_cost_model()
        while self.free and self.waiting:
            if self.cfg.admission == "cost":
                i = int(np.argmin([float(cm.predict(1, r.total_len))
                                   for r in self.waiting]))
            else:
                i = 0
            req = self.waiting[i]
            if not self._admissible(req):
                break        # head-of-line blocking: deterministic order
            del self.waiting[i]
            slot = self.free.pop(0)
            req.state, req.slot, req.n_prefilled = PREFILL, slot, 0
            req.out_tokens = []
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.active[slot] = req
            self.kv_len[slot] = 0
            self.trace.append(("admit", req.rid))
            admitted.append(req)
        if not admitted and not self.active and self.waiting:
            raise RuntimeError(
                f"request {self.waiting[0].rid} can never be admitted "
                f"under token_budget={self.cfg.token_budget}")
        reg = obs_metrics.get_registry()
        reg.counter("serve_admitted_total",
                    "requests admitted into cache slots").inc(
            len(admitted))
        reg.gauge("serve_queue_depth",
                  "requests waiting for a cache slot").set(
            len(self.waiting))
        reg.gauge("serve_calib_version",
                  "calibration snapshot version admission priced "
                  "with").set(self.last_calib_version)
        return admitted

    # ----------------------------------------------------------- eviction
    def evict_for_budget(self) -> List[Request]:
        """Preempt LIFO until the next decode step fits the budget.

        The oldest active request is never evicted: it runs to completion
        even if it alone exceeds the budget (the budget goes soft for
        the last request).  That guarantees forward progress — without
        it, a single over-budget request would be admitted, decoded to
        the budget, evicted with progress discarded, and re-admitted
        forever."""
        evicted = []
        order = sorted(self.active, key=lambda s: self.active[s].admit_seq)
        while self._live_tokens() > self.cfg.token_budget and len(order) > 1:
            slot = order.pop()                 # most recently admitted
            req = self.active.pop(slot)
            req.state, req.slot = WAITING, -1
            req.n_prefilled, req.out_tokens = 0, []
            req.n_evictions += 1
            self.kv_len[slot] = 0
            self.free.append(slot)
            self.free.sort()
            self.waiting.appendleft(req)
            self.trace.append(("evict", req.rid))
            evicted.append(req)
        if evicted:
            obs_metrics.get_registry().counter(
                "serve_evictions_total",
                "recompute preemptions (LIFO budget evictions)").inc(
                len(evicted))
        return evicted

    # ------------------------------------------------------------ prefill
    def next_prefill_chunk(self, fused: bool = True) \
            -> Optional[PrefillChunk]:
        """Pack the next chunk of prompt tokens.

        fused=True: up to ``chunk_tokens`` tokens, pieces 128-aligned per
        request.  fused=False: a decode-mode chunk (blk_q = 1) advancing
        every prefilling request by exactly one token."""
        if fused:
            return self._chunk_fused()
        return self._chunk_loop()

    def _chunk_fused(self) -> Optional[PrefillChunk]:
        t_total = self.cfg.chunk_tokens
        tokens = np.zeros(t_total, np.int32)
        pos = -np.ones(t_total, np.int32)
        block_req = -np.ones(t_total // BLOCK, np.int32)
        last_rows: List[Tuple[int, int]] = []
        t = 0
        for slot in sorted(self.active):
            req = self.active[slot]
            if req.state != PREFILL:
                continue
            remaining = req.prompt_len - req.n_prefilled
            if remaining <= 0 or t >= t_total:
                continue
            nblocks = min(-(-remaining // BLOCK), (t_total - t) // BLOCK)
            if nblocks == 0:
                break
            take = min(remaining, nblocks * BLOCK)
            lo = req.n_prefilled
            tokens[t:t + take] = req.prompt[lo:lo + take]
            pos[t:t + take] = np.arange(lo, lo + take)
            block_req[t // BLOCK: t // BLOCK + nblocks] = slot
            req.n_prefilled += take
            self.kv_len[slot] = req.n_prefilled
            if req.n_prefilled == req.prompt_len:
                last_rows.append((slot, t + take - 1))
                req.state = DECODE
            t += nblocks * BLOCK
        if t == 0:
            return None
        return PrefillChunk(tokens, pos, block_req, self.kv_len.copy(),
                            last_rows)

    def _chunk_loop(self) -> Optional[PrefillChunk]:
        n = self.cfg.n_slots
        tokens = np.zeros(n, np.int32)
        pos = -np.ones(n, np.int32)
        block_req = -np.ones(n, np.int32)
        last_rows: List[Tuple[int, int]] = []
        any_live = False
        for slot in sorted(self.active):
            req = self.active[slot]
            if req.state != PREFILL:
                continue
            any_live = True
            tokens[slot] = req.prompt[req.n_prefilled]
            pos[slot] = req.n_prefilled
            block_req[slot] = slot
            req.n_prefilled += 1
            self.kv_len[slot] = req.n_prefilled
            if req.n_prefilled == req.prompt_len:
                last_rows.append((slot, slot))
                req.state = DECODE
        if not any_live:
            return None
        return PrefillChunk(tokens, pos, block_req, self.kv_len.copy(),
                            last_rows)

    def commit_prefill(self, chunk: PrefillChunk,
                       first_tokens: Dict[int, int]) -> List[Request]:
        """Record the first sampled token of each request whose prefill
        completed in ``chunk`` (keyed by slot).  Prefill-only requests
        (max_new_tokens == 0) finish with no output."""
        finished = []
        for slot, _row in chunk.last_rows:
            req = self.active[slot]
            if req.max_new_tokens > 0:
                req.out_tokens.append(int(first_tokens[slot]))
            if self._is_finished(req):
                finished.append(self._finish(req))
        return finished

    # ------------------------------------------------------------- decode
    def decode_batch(self) \
            -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray]]:
        """(tokens [B], pos [B], block_req [B], kv_len_next [B]) for one
        batched decode step, or None when nothing is decoding."""
        n = self.cfg.n_slots
        tokens = np.zeros(n, np.int32)
        pos = -np.ones(n, np.int32)
        block_req = -np.ones(n, np.int32)
        kv_next = self.kv_len.copy()
        any_live = False
        for slot in sorted(self.active):
            req = self.active[slot]
            if req.state != DECODE:
                continue
            any_live = True
            tokens[slot] = req.out_tokens[-1]
            pos[slot] = self.kv_len[slot]
            block_req[slot] = slot
            kv_next[slot] += 1
        if not any_live:
            return None
        return tokens, pos, block_req, kv_next

    def commit_decode(self, next_tokens: np.ndarray) -> List[Request]:
        """Append sampled tokens; finish requests hitting max_new/eos.
        Returns the finished requests (slots freed)."""
        finished = []
        for slot in sorted(self.active):
            req = self.active[slot]
            if req.state != DECODE:
                continue
            self.kv_len[slot] += 1
            req.out_tokens.append(int(next_tokens[slot]))
            if self._is_finished(req):
                finished.append(self._finish(req))
        return finished

    # ------------------------------------------------------------ helpers
    def _is_finished(self, req: Request) -> bool:
        if len(req.out_tokens) >= req.max_new_tokens:
            return True
        return self.cfg.eos_id is not None \
            and req.out_tokens[-1] == self.cfg.eos_id

    def _finish(self, req: Request) -> Request:
        slot = req.slot
        req.state, req.slot = DONE, -1
        del self.active[slot]
        self.kv_len[slot] = 0
        self.free.append(slot)
        self.free.sort()
        self.done.append(req)
        self.trace.append(("finish", req.rid))
        obs_metrics.get_registry().counter(
            "serve_finished_total", "requests run to completion").inc()
        return req
