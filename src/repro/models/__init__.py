from repro.models import layers, model
