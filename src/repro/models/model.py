"""Model assembly: init / forward / decode for every assigned architecture.

Layers are grouped into ``n_layers // period`` *super-blocks* (one slot per
``layer_pattern`` entry); parameters are stacked on a leading group dim and
executed with ``jax.lax.scan`` so the lowered HLO stays small even for
96-layer models.  ``jax.checkpoint`` (remat) wraps the scan body.

Batch dict (training):
  tokens       [B,S] int32
  labels       [B,S] int32  (-1 = no loss)
  segment_ids  [B,S] int32  (0 = padding; docs numbered from 1)
  positions    [B,S] int32  (position within document)
  memory       [B,M,D] optional (vlm patch embeddings / audio frames)
  memory_mask  [B,M] optional

Decode: see ``init_cache`` / ``decode_step``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# serving cache padding must equal the prefill packer's piece alignment
# and the ragged kernel's 128-token kv tile (request-pure-block
# invariant) — one constant, imported, not re-declared
from repro.data.packing import BLOCK as SERVE_BLOCK
from repro.models import layers as L


# ------------------------------------------------------------------- init
def slot_init(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.norm_init(cfg.d_model, cfg.pdtype, cfg.norm)}
    if kind in ("global", "local", "cross", "enc"):
        p["attn"] = L.attn_init(ks[0], cfg, cross=(kind == "cross"))
        p["norm2"] = L.norm_init(cfg.d_model, cfg.pdtype, cfg.norm)
        if kind == "cross":
            p["xnorm"] = L.norm_init(cfg.d_model, cfg.pdtype, cfg.norm)
        if cfg.moe and cfg.moe.n_experts and kind != "enc":
            p["moe"] = L.moe_init(ks[1], cfg)
        else:
            p["ffn"] = L.ffn_init(ks[1], cfg)
        if cfg.post_norms:
            p["pnorm1"] = L.norm_init(cfg.d_model, cfg.pdtype, cfg.norm)
            p["pnorm2"] = L.norm_init(cfg.d_model, cfg.pdtype, cfg.norm)
    elif kind == "ssd":
        p["mixer"] = L.ssd_init(ks[0], cfg)
    elif kind == "rglru":
        p["mixer"] = L.rglru_init(ks[0], cfg)
        p["norm2"] = L.norm_init(cfg.d_model, cfg.pdtype, cfg.norm)
        p["ffn"] = L.ffn_init(ks[1], cfg)
    else:
        raise ValueError(kind)
    return p


def init(key, cfg):
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    params["embed"] = {"embed": L.dense_init(
        keys[0], cfg.d_model, (cfg.vocab_size, cfg.d_model), cfg.pdtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = {"unembed": L.dense_init(
            keys[1], cfg.d_model, (cfg.vocab_size, cfg.d_model), cfg.pdtype)}
    params["final_norm"] = L.norm_init(cfg.d_model, cfg.pdtype, cfg.norm)

    g = cfg.n_groups
    slots = []
    for si, kind in enumerate(cfg.layer_pattern):
        kslot = jax.random.fold_in(keys[2], si)
        gkeys = jax.random.split(kslot, g)
        slots.append(jax.vmap(lambda k, kd=kind: slot_init(k, cfg, kd))(gkeys))
    params["blocks"] = tuple(slots)

    if cfg.encoder and cfg.encoder.n_layers:
        ekeys = jax.random.split(keys[3], cfg.encoder.n_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: slot_init(k, cfg, "enc"))(ekeys)
        params["enc_final_norm"] = L.norm_init(cfg.d_model, cfg.pdtype,
                                               cfg.norm)
    return params


# ---------------------------------------------------------------- forward
def block_apply(kind, p, h, batch, cfg, ctx, aux):
    if kind in ("global", "local", "cross", "enc"):
        causal = kind != "enc"
        window = cfg.window if kind == "local" else 0
        a = L.self_attn_apply(p["attn"], L.norm_apply(p["norm1"], h, cfg.norm),
                              batch, cfg, ctx, causal=causal, window=window)
        if cfg.post_norms:
            a = L.norm_apply(p["pnorm1"], a, cfg.norm)
        h = h + a
        if kind == "cross":
            xa = L.cross_attn_apply(
                p["attn"], L.norm_apply(p["xnorm"], h, cfg.norm), batch, cfg,
                ctx)
            h = h + xa
        f_in = L.norm_apply(p["norm2"], h, cfg.norm)
        if "moe" in p:
            f, losses = L.moe_apply(p["moe"], f_in, cfg, ctx)
            aux = {k: aux.get(k, 0.0) + v for k, v in losses.items()} | \
                {k: v for k, v in aux.items() if k not in losses}
        else:
            f = L.ffn_apply(p["ffn"], f_in, cfg, ctx)
        if cfg.post_norms:
            f = L.norm_apply(p["pnorm2"], f, cfg.norm)
        h = h + f
    elif kind == "ssd":
        h = h + L.ssd_apply(p["mixer"],
                            L.norm_apply(p["norm1"], h, cfg.norm),
                            batch, cfg, ctx)
    elif kind == "rglru":
        h = h + L.rglru_apply(p["mixer"],
                              L.norm_apply(p["norm1"], h, cfg.norm),
                              batch, cfg, ctx)
        h = h + L.ffn_apply(p["ffn"],
                            L.norm_apply(p["norm2"], h, cfg.norm), cfg, ctx)
    else:
        raise ValueError(kind)
    return ctx.cons(h, "batch", "residual_seq", None), aux


def _embed(params, cfg, tokens, ctx):
    h = jnp.take(params["embed"]["embed"], tokens, axis=0)
    h = h.astype(cfg.cdtype)
    if cfg.scale_embed:
        h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    return ctx.cons(h, "batch", "residual_seq", None)


def _unembed(params, cfg, h):
    table = (params["embed"]["embed"] if cfg.tie_embeddings
             else params["unembed"]["unembed"])
    logits = jnp.einsum("bsd,vd->bsv", h, table).astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) \
            * cfg.final_logit_softcap
    return logits


def encode(params, cfg, memory_raw, ctx):
    """Whisper-style encoder over stub frame embeddings [B,M,D]."""
    b, m, _ = memory_raw.shape
    pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (b, m))
    h = memory_raw.astype(cfg.cdtype) + L.sinusoidal_pos(pos, cfg.d_model,
                                                         cfg.cdtype)
    ebatch = {"segment_ids": jnp.ones((b, m), jnp.int32), "positions": pos}

    def body(carry, gp):
        hh, aux = carry
        hh, aux = block_apply("enc", gp, hh, ebatch, cfg, ctx, aux)
        return (hh, aux), None

    fn = jax.checkpoint(body) if ctx.remat else body
    (h, _), _ = jax.lax.scan(fn, (h, {}), params["enc_blocks"])
    return L.norm_apply(params["enc_final_norm"], h, cfg.norm)


def forward(params, cfg, batch, ctx) -> Tuple[jnp.ndarray, Dict]:
    """Packed-LM forward.  Returns (logits [B,S,V] f32, aux-losses)."""
    batch = dict(batch)
    if cfg.encoder and cfg.encoder.n_layers and "memory" in batch:
        batch["memory"] = encode(params, cfg, batch["memory"], ctx)
    elif "memory" in batch and batch["memory"] is not None:
        batch["memory"] = batch["memory"].astype(cfg.cdtype)
    h = _embed(params, cfg, batch["tokens"], ctx)
    if not cfg.use_rope and cfg.has_attention():
        h = h + L.sinusoidal_pos(batch["positions"], cfg.d_model, cfg.cdtype)

    pattern = cfg.layer_pattern
    aux0 = {"moe_lb": jnp.zeros((), jnp.float32),
            "moe_z": jnp.zeros((), jnp.float32)} \
        if (cfg.moe and cfg.moe.n_experts) else {}

    def body(carry, group_params):
        hh, aux = carry
        for kind, gp in zip(pattern, group_params):
            hh, aux = block_apply(kind, gp, hh, batch, cfg, ctx, aux)
        return (hh, aux), None

    fn = jax.checkpoint(body) if ctx.remat else body
    (h, aux), _ = jax.lax.scan(fn, (h, aux0), params["blocks"])
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    return _unembed(params, cfg, h), aux


# ----------------------------------------------------------------- decode


def init_cache(params, cfg, batch_size: int, max_seq: int,
               memory: Optional[jnp.ndarray] = None, ctx=None,
               layout: str = "decode"):
    """Build the decode cache pytree (zeros; positions -1 = empty).

    ``layout="serve"`` builds the ragged serving layout instead
    (DESIGN.md §8): attention slots are flat per-request buffers where
    slot index == absolute position (local layers get full-length buffers
    rather than ring ones — the window is enforced by the ragged kernel's
    mask and its block pruning recovers the compute bound), the cache
    length is padded to the 128-token kernel tile, and a per-request
    ``kv_len`` visibility bound rides at the top level so requests at
    different fill levels share one batch (continuous batching).
    """
    if layout == "serve":
        return _init_serve_cache(params, cfg, batch_size, max_seq)
    if layout != "decode":
        raise ValueError(f"unknown cache layout {layout!r}")
    b, dt = batch_size, cfg.cdtype
    dh, hkv = cfg.head_dim, cfg.n_kv_heads
    g = cfg.n_groups
    if memory is not None and cfg.encoder and cfg.encoder.n_layers:
        memory = encode(params, cfg, memory, ctx)

    slots = []
    for si, kind in enumerate(cfg.layer_pattern):
        if kind in ("global", "cross"):
            c = {"k": jnp.zeros((g, b, max_seq, hkv, dh), dt),
                 "v": jnp.zeros((g, b, max_seq, hkv, dh), dt),
                 "kv_pos": -jnp.ones((g, b, max_seq), jnp.int32)}
            if kind == "cross":
                assert memory is not None
                sp = params["blocks"][si]
                m = memory.shape[1]

                def xkv(gp):
                    k = (memory @ gp["attn"]["xwk"]).reshape(b, m, hkv, dh)
                    v = (memory @ gp["attn"]["xwv"]).reshape(b, m, hkv, dh)
                    return k, v
                xk, xv = jax.vmap(xkv)(sp)
                c["xk"], c["xv"] = xk, xv
            slots.append(c)
        elif kind == "local":
            w = min(cfg.window, max_seq)
            slots.append({"k": jnp.zeros((g, b, w, hkv, dh), dt),
                          "v": jnp.zeros((g, b, w, hkv, dh), dt),
                          "kv_pos": -jnp.ones((g, b, w), jnp.int32)})
        elif kind == "ssd":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.d_state
            slots.append({
                "conv": jnp.zeros((g, b, s.conv_width - 1, conv_ch), dt),
                "state": jnp.zeros((g, b, nh, s.d_state, s.head_dim),
                                   jnp.float32)})
        elif kind == "rglru":
            w = cfg.rglru.lru_width or cfg.d_model
            slots.append({
                "conv": jnp.zeros((g, b, cfg.rglru.conv_width - 1, w), dt),
                "h": jnp.zeros((g, b, w), jnp.float32)})
        else:
            raise ValueError(kind)
    return {"slots": tuple(slots)}


def _init_serve_cache(params, cfg, batch_size: int, max_seq: int):
    """Ragged serving layout: see ``init_cache(layout="serve")``."""
    if (cfg.encoder and cfg.encoder.n_layers) \
            or "cross" in cfg.layer_pattern:
        raise ValueError("serve cache layout does not support "
                         "cross-attention/encoder architectures")
    b, dt = batch_size, cfg.cdtype
    dh, hkv = cfg.head_dim, cfg.n_kv_heads
    g = cfg.n_groups
    s_pad = -(-max_seq // SERVE_BLOCK) * SERVE_BLOCK
    slots = []
    for kind in cfg.layer_pattern:
        if kind in ("global", "local"):
            slots.append({"k": jnp.zeros((g, b, s_pad, hkv, dh), dt),
                          "v": jnp.zeros((g, b, s_pad, hkv, dh), dt)})
        elif kind == "ssd":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.d_state
            slots.append({
                "conv": jnp.zeros((g, b, s.conv_width - 1, conv_ch), dt),
                "state": jnp.zeros((g, b, nh, s.d_state, s.head_dim),
                                   jnp.float32)})
        elif kind == "rglru":
            w = cfg.rglru.lru_width or cfg.d_model
            slots.append({
                "conv": jnp.zeros((g, b, cfg.rglru.conv_width - 1, w), dt),
                "h": jnp.zeros((g, b, w), jnp.float32)})
        else:
            raise ValueError(kind)
    return {"slots": tuple(slots),
            "kv_len": jnp.zeros((b,), jnp.int32)}


def reset_serve_slots(cache, cfg, reset_mask):
    """Recycle request slots for continuous-batching admission.

    Attention kv needs no clearing — visibility is bounded by ``kv_len``,
    which drops to 0 — but recurrent states and conv windows persist
    across tokens and must be zeroed.  ``reset_mask`` [B] bool."""
    def zero(x):
        m = reset_mask.reshape((1, -1) + (1,) * (x.ndim - 2))
        return jnp.where(m, jnp.zeros_like(x), x)

    new_slots = []
    for kind, c in zip(cfg.layer_pattern, cache["slots"]):
        if kind in ("ssd", "rglru"):
            new_slots.append({k: zero(v) for k, v in c.items()})
        else:
            new_slots.append(c)
    new = dict(cache)
    new["slots"] = tuple(new_slots)
    new["kv_len"] = jnp.where(reset_mask, 0, cache["kv_len"])
    return new


def _serve_attn(p, h, cache_slot, pos, token_req, block_req, kv_len_next,
                cfg, ctx, kind):
    """Packed ragged-batch cache attention: scatter this step's k/v into
    the serve-layout cache, then one fused ``ragged_decode_attention``
    call over the whole batch.  h [1,T,D]."""
    from repro.kernels.packed_flash import ops as pf_ops
    t = h.shape[1]
    dh = cfg.head_dim
    posc = jnp.maximum(pos, 0)
    q, k, v = L.qkv_proj(p, h, cfg, posc[None] if cfg.use_rope else None)
    r, s = cache_slot["k"].shape[0], cache_slot["k"].shape[1]
    live = pos >= 0
    # dead rows scatter out of bounds -> dropped
    wr = jnp.where(live, token_req, r)
    ws = jnp.where(live, pos, s)
    ck = cache_slot["k"].at[wr, ws].set(k[0], mode="drop")
    cv = cache_slot["v"].at[wr, ws].set(v[0], mode="drop")
    out = pf_ops.ragged_decode_attention(
        q[0], ck, cv, block_req, pos, kv_len_next,
        window=cfg.window if kind == "local" else 0,
        softcap=cfg.attn_logit_softcap,
        impl=getattr(ctx, "decode_impl", None))
    out = out.reshape(1, t, cfg.n_heads * dh) @ p["wo"]
    return out, {"k": ck, "v": cv}


def _block_serve(kind, p, h, cache_slot, pos, token_req, block_req,
                 kv_len_next, cfg, ctx):
    """Serving analogue of ``block_decode`` over packed [1,T,D] tokens."""
    if kind in ("global", "local"):
        a_in = L.norm_apply(p["norm1"], h, cfg.norm)
        a, new_slot = _serve_attn(p["attn"], a_in, cache_slot, pos,
                                  token_req, block_req, kv_len_next, cfg,
                                  ctx, kind)
        return _attn_residual_tail(p, h, a, cfg, ctx), new_slot
    if kind in ("ssd", "rglru"):
        # decode mode only (one token per request, token i == request i):
        # reinterpret the packed row dim as the request batch and reuse
        # the decode branches unchanged.  Rows with pos == -1 are idle
        # slots (e.g. a DECODE-state request waiting while another
        # prefills): their recurrent state must NOT advance — and the
        # rglru pos==0 reset must not fire — so dead rows keep their
        # old state verbatim.
        hb = h[0][:, None]                               # [B,1,D]
        hb, upd = block_decode(kind, p, hb, cache_slot,
                               jnp.maximum(pos, 0), cfg, ctx)
        live = pos >= 0

        def keep(new, old):
            m = live.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(m, new, old)

        new_slot = {k: keep(v, cache_slot[k]) for k, v in upd.items()}
        return hb[:, 0][None], new_slot
    raise ValueError(kind)


def serve_chunk_step(params, cfg, cache, tokens, pos, block_req,
                     kv_len_next, ctx):
    """One serving step over a packed ragged request batch (DESIGN.md §8).

    A single entry point serves both halves of the engine: a **fused
    prefill chunk** (blk_q = 128 request-pure q blocks packed
    cu_seqlens-style) and a **batched decode step** (blk_q = 1,
    ``block_req == arange(B)``).  All context-independent layers run once
    over the packed [T] stream — the linear-layer batching win — and
    attention runs as one fused ragged cache call per layer.

    tokens [T] int32 packed (0 on padding rows)
    pos    [T] int32 absolute position per token (-1 = padding row)
    block_req [nq] int32 request slot per q block (-1 = dead block);
            blk_q = T // nq, and every q block is request-pure
    kv_len_next [B] int32 per-request visibility bound AFTER this step's
            cache writes (prompt progress so far + this chunk)

    Returns (logits [T, V] f32, new_cache).  Recurrent (ssd/rglru) layers
    are decode-mode only; fused prefill requires attention-only patterns
    (MoE routing is batch-global, so MoE archs also prefill per-token —
    the engine gates this).
    """
    t = tokens.shape[0]
    nq = block_req.shape[0]
    assert t % nq == 0, (t, nq)
    blk_q = t // nq
    decode_mode = blk_q == 1
    if not decode_mode:
        bad = [k for k in cfg.layer_pattern if k not in ("global", "local")]
        if bad or (cfg.moe and cfg.moe.n_experts):
            raise ValueError(
                f"fused chunked prefill supports attention-only non-MoE "
                f"patterns; got {cfg.layer_pattern} moe={bool(cfg.moe and cfg.moe.n_experts)}")
    token_req = jnp.repeat(block_req, blk_q)
    h = _embed(params, cfg, tokens[None], ctx)
    if not cfg.use_rope and cfg.has_attention():
        h = h + L.sinusoidal_pos(jnp.maximum(pos, 0)[None], cfg.d_model,
                                 cfg.cdtype)
    pattern = cfg.layer_pattern

    def body(hh, xs):
        group_params, group_cache = xs
        new_cache = []
        for kind, gp, gc in zip(pattern, group_params, group_cache):
            hh, nc = _block_serve(kind, gp, hh, gc, pos, token_req,
                                  block_req, kv_len_next, cfg, ctx)
            new_cache.append(nc)
        return hh, tuple(new_cache)

    h, new_slots = jax.lax.scan(body, h, (params["blocks"], cache["slots"]))
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    logits = _unembed(params, cfg, h)[0]
    new_cache = dict(cache)
    new_cache["slots"] = new_slots
    new_cache["kv_len"] = kv_len_next
    return logits, new_cache


def _write_cache(cache_k, cache_v, kv_pos, k_new, v_new, pos, ring: bool):
    """Write one token's k/v at (ring) position.  pos [B]."""
    size = cache_k.shape[1]
    slot = (pos % size) if ring else pos

    def upd(c, x, i):
        return jax.vmap(
            lambda cc, xx, ii: jax.lax.dynamic_update_slice_in_dim(
                cc, xx, ii, axis=0))(c, x, i)
    cache_k = upd(cache_k, k_new, slot)
    cache_v = upd(cache_v, v_new, slot)
    kv_pos = jax.vmap(
        lambda kp, pp, ii: jax.lax.dynamic_update_slice_in_dim(
            kp, pp[None], ii, axis=0))(kv_pos, pos, slot)
    return cache_k, cache_v, kv_pos


def attn_decode(p, h, cache_slot, pos, cfg, ctx, kind):
    """h [B,1,D].  Returns (out [B,1,D], new_cache_slot)."""
    b = h.shape[0]
    dh = cfg.head_dim
    posb = pos[:, None]                                   # [B,1]
    q, k, v = L.qkv_proj(p, h, cfg,
                         posb if cfg.use_rope else None)
    ring = kind == "local"
    ck, cv, kp = _write_cache(cache_slot["k"], cache_slot["v"],
                              cache_slot["kv_pos"], k, v, pos, ring)
    mask = kp >= 0
    window = cfg.window if kind == "local" else 0
    out = L.decode_attention(q, ck, cv, mask, posb, kp,
                             window=window,
                             softcap=cfg.attn_logit_softcap)
    out = out.reshape(b, 1, cfg.n_heads * dh) @ p["wo"]
    new_slot = dict(cache_slot)
    new_slot.update(k=ck, v=cv, kv_pos=kp)
    return out, new_slot


def cross_decode(p, h, cache_slot, cfg):
    b = h.shape[0]
    dh = cfg.head_dim
    q = (h @ p["xwq"]).reshape(b, 1, cfg.n_heads, dh)
    m = cache_slot["xk"].shape[1]
    mask = jnp.ones((b, m), bool)
    zero = jnp.zeros((b, m), jnp.int32)
    out = L.decode_attention(q, cache_slot["xk"], cache_slot["xv"], mask,
                             zero[:, :1], zero, window=0,
                             softcap=cfg.attn_logit_softcap)
    out = out.reshape(b, 1, cfg.n_heads * dh) @ p["xwo"]
    if "xgate" in p:
        out = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(out.dtype) * out
    return out


def _attn_residual_tail(p, h, a, cfg, ctx, cross_fn=None):
    """Post-attention wiring shared by the decode and serving block
    bodies: post-norm, residual, optional cross-attention insert,
    norm2 -> (MoE | FFN), post-norm, residual.  One copy, so the fused
    serving path can never silently diverge from decode."""
    if cfg.post_norms:
        a = L.norm_apply(p["pnorm1"], a, cfg.norm)
    h = h + a
    if cross_fn is not None:
        h = h + cross_fn(h)
    f_in = L.norm_apply(p["norm2"], h, cfg.norm)
    if "moe" in p:
        f, _ = L.moe_apply(p["moe"], f_in, cfg, ctx, no_drop=True)
    else:
        f = L.ffn_apply(p["ffn"], f_in, cfg, ctx)
    if cfg.post_norms:
        f = L.norm_apply(p["pnorm2"], f, cfg.norm)
    return h + f


def block_decode(kind, p, h, cache_slot, pos, cfg, ctx):
    if kind in ("global", "local", "cross"):
        a_in = L.norm_apply(p["norm1"], h, cfg.norm)
        a, new_slot = attn_decode(p["attn"], a_in, cache_slot, pos, cfg, ctx,
                                  kind)
        cross_fn = None
        if kind == "cross":
            cross_fn = lambda hh: cross_decode(
                p["attn"], L.norm_apply(p["xnorm"], hh, cfg.norm),
                new_slot, cfg)
        return _attn_residual_tail(p, h, a, cfg, ctx, cross_fn), new_slot
    if kind == "ssd":
        y, conv, state = L.ssd_decode(
            p["mixer"], L.norm_apply(p["norm1"], h, cfg.norm),
            cache_slot["conv"], cache_slot["state"], cfg)
        return h + y, {"conv": conv, "state": state}
    if kind == "rglru":
        mixer = p["mixer"]
        xin = L.norm_apply(p["norm1"], h, cfg.norm)
        gate_br = jax.nn.gelu(xin @ mixer["w_gate_br"])
        x = xin @ mixer["w_x"]
        x, conv = L._causal_conv(x, mixer["conv_w"], mixer["conv_b"],
                                 cache_slot["conv"])
        hstate = L.rglru_decode(mixer, x, cache_slot["h"], reset=(pos == 0))
        y = (hstate[:, None].astype(h.dtype) * gate_br) @ mixer["w_out"]
        h = h + y
        h = h + L.ffn_apply(p["ffn"], L.norm_apply(p["norm2"], h, cfg.norm),
                            cfg, ctx)
        return h, {"conv": conv, "h": hstate}
    raise ValueError(kind)


def decode_step(params, cfg, cache, tokens, pos, ctx):
    """One decode step.  tokens [B,1], pos [B] (#tokens already cached).
    Returns (logits [B,1,V], new_cache)."""
    h = _embed(params, cfg, tokens, ctx)
    if not cfg.use_rope and cfg.has_attention():
        h = h + L.sinusoidal_pos(pos[:, None], cfg.d_model, cfg.cdtype)
    pattern = cfg.layer_pattern

    def body(h, xs):
        group_params, group_cache = xs
        new_cache = []
        for kind, gp, gc in zip(pattern, group_params, group_cache):
            h, nc = block_decode(kind, gp, h, gc, pos, cfg, ctx)
        # NOTE: loop rebinding -- collect inside the loop
            new_cache.append(nc)
        return h, tuple(new_cache)

    h, new_slots = jax.lax.scan(body, h, (params["blocks"], cache["slots"]))
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    logits = _unembed(params, cfg, h)
    new_cache = dict(cache)
    new_cache["slots"] = new_slots
    return logits, new_cache
