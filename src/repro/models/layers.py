"""Layer implementations: norms, RoPE, GQA attention, (Mo)MLP, Mamba-2
SSD, RG-LRU, cross-attention.  Pure-functional: ``*_init`` builds a param
dict, ``*_apply`` consumes it.

Weight names follow the conventions consumed by
``repro.parallel.param_pspecs`` (wq/wk/wv/wo, w_gate/w_up/w_down,
experts_*, ...), so sharding specs are derived from the tree structure.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.attention import (_repeat_kv, core_attention,
                                  decode_attention)


# ----------------------------------------------------------------- helpers
def dense_init(key, fan_in, shape, dtype):
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


def norm_init(d, dtype, kind="rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32)
        if "bias" in p:
            out = out + p["bias"].astype(jnp.float32)
    else:
        ms = (xf ** 2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x, positions, theta: float):
    """x [B,S,H,dh], positions [B,S] (within-document for packed data)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d, dtype):
    half = d // 2
    freqs = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def activation_fn(name):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


# --------------------------------------------------------------- attention
def attn_init(key, cfg, cross=False):
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], d, (d, hq * dh), cfg.pdtype),
        "wk": dense_init(ks[1], d, (d, hkv * dh), cfg.pdtype),
        "wv": dense_init(ks[2], d, (d, hkv * dh), cfg.pdtype),
        "wo": dense_init(ks[3], hq * dh, (hq * dh, d), cfg.pdtype),
    }
    if cross:
        p["xwq"] = dense_init(ks[4], d, (d, hq * dh), cfg.pdtype)
        p["xwk"] = dense_init(ks[5], d, (d, hkv * dh), cfg.pdtype)
        p["xwv"] = dense_init(ks[6], d, (d, hkv * dh), cfg.pdtype)
        p["xwo"] = dense_init(ks[7], hq * dh, (hq * dh, d), cfg.pdtype)
        p["xgate"] = jnp.zeros((), cfg.pdtype)  # llama3.2-vision tanh gate
    return p


def qkv_proj(p, h, cfg, positions, prefix="w"):
    b, s, _ = h.shape
    dh = cfg.head_dim
    q = (h @ p[prefix + "q"]).reshape(b, s, cfg.n_heads, dh)
    k = (h @ p[prefix + "k"]).reshape(b, s, cfg.n_kv_heads, dh)
    v = (h @ p[prefix + "v"]).reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _model_axis_size(ctx):
    mesh = getattr(ctx, "mesh", None)
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def _pad_heads_for_tp(q, k, v, ctx):
    """When n_heads does not divide the model axis, core attention would be
    fully REPLICATED across TP ranks (a model-axis-x flops blowup).
    Instead: MHA-ize (repeat kv to q heads) and zero-pad heads up to the
    next model-axis multiple so CA stays TP-sharded (DESIGN.md §4).
    Returns (q, k, v, orig_heads, padded?)."""
    hq = q.shape[2]
    m = _model_axis_size(ctx)
    if m <= 1 or ctx.rules.heads is not None or hq % m == 0:
        return q, k, v, hq, False
    hkv = k.shape[2]
    if hkv != hq:
        k = _repeat_kv(k, hq // hkv)
        v = _repeat_kv(v, hq // hkv)
    target = ((hq + m - 1) // m) * m
    padw = [(0, 0), (0, 0), (0, target - hq), (0, 0)]
    return (jnp.pad(q, padw), jnp.pad(k, padw), jnp.pad(v, padw), hq, True)


def self_attn_apply(p, h, batch, cfg, ctx, *, causal=True, window=0):
    """h [B,S,D]; batch provides segment_ids/positions."""
    b, s, _ = h.shape
    seg, pos = batch["segment_ids"], batch["positions"]
    q, k, v = qkv_proj(p, h, cfg, pos if cfg.use_rope else None)
    q, k, v, hq_orig, padded = _pad_heads_for_tp(q, k, v, ctx)
    hspec = "heads" if not padded else "padded_heads"
    kspec = "kv_heads" if not padded else "padded_heads"
    q = ctx.cons(q, "batch", "seq", hspec, None)
    k = ctx.cons(k, "batch", "seq", kspec, None)
    v = ctx.cons(v, "batch", "seq", kspec, None)
    out = core_attention(q, k, v, seg, pos, seg, pos, causal=causal,
                         window=window, softcap=cfg.attn_logit_softcap,
                         ctx=ctx)
    # pin the CA output to the head sharding: without this GSPMD shards
    # the flash-scan accumulators on the sequence-block dim (to match the
    # residual's seq sharding) and every per-pair dynamic-slice becomes a
    # full all-gather (§Perf P7)
    out = ctx.cons(out, "batch", None, hspec, None)
    if padded:
        out = out[:, :, :hq_orig, :]
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return ctx.cons(out @ p["wo"], "batch", "residual_seq", None)


def cross_attn_apply(p, h, batch, cfg, ctx):
    """Cross-attention over encoder/vision memory [B,M,D]."""
    b, s, _ = h.shape
    dh = cfg.head_dim
    mem = batch["memory"]
    mem_mask = batch.get("memory_mask")
    q = (h @ p["xwq"]).reshape(b, s, cfg.n_heads, dh)
    k = (mem @ p["xwk"]).reshape(b, mem.shape[1], cfg.n_kv_heads, dh)
    v = (mem @ p["xwv"]).reshape(b, mem.shape[1], cfg.n_kv_heads, dh)
    seg_q = batch["segment_ids"]
    pos_q = batch["positions"]
    m = mem.shape[1]
    seg_kv = (jnp.ones((b, m), jnp.int32) if mem_mask is None
              else mem_mask.astype(jnp.int32))
    # cross attention: every query token may see every (valid) memory token
    # regardless of document id -> give kv the query's segment by using a
    # broadcast trick: all query segs attend seg 1; queries with seg 0 are
    # padding and masked by their own seg.
    seg_q_x = (seg_q > 0).astype(jnp.int32)
    pos_kv = jnp.zeros((b, m), jnp.int32)
    out = core_attention(q, k, v, seg_q_x, pos_q, seg_kv, pos_kv,
                         causal=False, window=0, softcap=0.0, ctx=ctx)
    out = out.reshape(b, s, cfg.n_heads * dh) @ p["xwo"]
    if "xgate" in p:
        out = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(out.dtype) * out
    return ctx.cons(out, "batch", "residual_seq", None)


# --------------------------------------------------------------------- ffn
def ffn_init(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.gated_mlp:
        return {"w_gate": dense_init(ks[0], d, (d, f), cfg.pdtype),
                "w_up": dense_init(ks[1], d, (d, f), cfg.pdtype),
                "w_down": dense_init(ks[2], f, (f, d), cfg.pdtype)}
    return {"w_up": dense_init(ks[0], d, (d, f), cfg.pdtype),
            "w_down": dense_init(ks[1], f, (f, d), cfg.pdtype)}


def ffn_apply(p, h, cfg, ctx):
    act = activation_fn(cfg.activation)
    if "w_gate" in p:
        inner = act(h @ p["w_gate"]) * (h @ p["w_up"])
    else:
        inner = act(h @ p["w_up"])
    inner = ctx.cons(inner, "batch", "seq", "ffn")
    return ctx.cons(inner @ p["w_down"], "batch", "residual_seq", None)


# --------------------------------------------------------------------- moe
def moe_init(key, cfg):
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(key, 8)
    p = {"router": dense_init(ks[0], d, (d, e.n_experts), cfg.pdtype),
         "experts_gate": dense_init(ks[1], d, (e.n_experts, d, f), cfg.pdtype),
         "experts_up": dense_init(ks[2], d, (e.n_experts, d, f), cfg.pdtype),
         "experts_down": dense_init(ks[3], f, (e.n_experts, f, d), cfg.pdtype)}
    if e.n_shared_experts:
        fs = f * e.n_shared_experts
        p["w_gate"] = dense_init(ks[4], d, (d, fs), cfg.pdtype)
        p["w_up"] = dense_init(ks[5], d, (d, fs), cfg.pdtype)
        p["w_down"] = dense_init(ks[6], fs, (fs, d), cfg.pdtype)
    return p


def moe_apply(p, h, cfg, ctx, no_drop=False):
    """Capacity-based MoE with sort-based gather/scatter dispatch.

    Tokens pick top-k experts; each expert processes at most C tokens
    (C = tokens*top_k/E * capacity_factor).  Dispatch/return are gathers
    and scatter-adds (no one-hot einsums: a dense [T,E,C] dispatch tensor
    costs T·E·C·d matmul flops, which dwarfs the expert compute at
    E=128).  With ``expert_parallel`` the expert dim is sharded over
    "data" and GSPMD lowers the gather/scatter into all-to-alls;
    otherwise experts are replicated and dispatch is local.
    Returns (out, aux_losses).
    """
    e = cfg.moe
    b, s, d = h.shape
    act = activation_fn(cfg.activation)
    n_tok = b * s
    x = h.reshape(n_tok, d)

    logits = (x @ p["router"]).astype(jnp.float32)            # [T,E]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(probs, e.top_k)            # [T,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    def route_compute(x_g, idx_g, gate_g, cap):
        """Route one token group through the (closed-over) experts.
        x_g [Tg, d]; idx_g/gate_g [Tg, k].  Returns out [Tg, d]."""
        tg = x_g.shape[0]
        tk = tg * e.top_k
        flat_e = idx_g.reshape(tk)
        order = jnp.argsort(flat_e, stable=True)              # [Tk]
        sorted_e = flat_e[order]
        grp_start = jnp.searchsorted(sorted_e, jnp.arange(e.n_experts),
                                     side="left")             # [E]
        rank_sorted = jnp.arange(tk) - grp_start[sorted_e]
        pos = jnp.zeros(tk, jnp.int32).at[order].set(
            rank_sorted.astype(jnp.int32))                    # [Tk]
        in_cap = pos < cap
        slot = flat_e * cap + pos                             # [Tk]
        token_id = jnp.repeat(jnp.arange(tg), e.top_k)
        safe_slot = jnp.where(in_cap, slot, e.n_experts * cap)
        token_of_slot = jnp.full(e.n_experts * cap + 1, -1, jnp.int32) \
            .at[safe_slot].set(token_id.astype(jnp.int32))[:-1]
        gate_of_slot = jnp.zeros(e.n_experts * cap + 1, h.dtype) \
            .at[safe_slot].set(gate_g.reshape(tk).astype(h.dtype))[:-1]

        live = (token_of_slot >= 0)
        xs = x_g[jnp.maximum(token_of_slot, 0)] \
            * live[:, None].astype(h.dtype)                   # [E*C, D]
        xs = xs.reshape(e.n_experts, cap, d)
        if e.expert_parallel:
            xs = ctx.cons(xs, "experts", None, None)
        gate = act(jnp.einsum("ecd,edf->ecf", xs, p["experts_gate"]))
        up = jnp.einsum("ecd,edf->ecf", xs, p["experts_up"])
        inner = gate * up
        if e.expert_parallel:
            inner = ctx.cons(inner, "experts", None, "ffn")
        ys = jnp.einsum("ecf,efd->ecd", inner, p["experts_down"])
        ys = (ys.reshape(e.n_experts * cap, d)
              * gate_of_slot[:, None].astype(ys.dtype))
        return jnp.zeros((tg, d), ys.dtype) \
            .at[jnp.maximum(token_of_slot, 0)] \
            .add(ys * live[:, None].astype(ys.dtype))

    # number of data shards (for group-local routing)
    n_groups = 1
    if not e.expert_parallel and ctx.mesh is not None \
            and ctx.rules.batch is not None:
        sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
        baxes = ctx.rules.batch
        baxes = baxes if isinstance(baxes, tuple) else (baxes,)
        for a in baxes:
            n_groups *= sizes.get(a, 1)
        if n_tok % n_groups:
            n_groups = 1

    if no_drop:
        cap = n_tok // n_groups  # decode: never drop a live request
    else:
        cap = max(1, int(n_tok / n_groups * e.top_k / e.n_experts
                         * e.capacity_factor))

    if n_groups > 1:
        # Replicated-expert path: route each data shard's tokens locally
        # (a data-dependent GLOBAL gather would force GSPMD to replicate
        # the whole expert computation — §Perf P8).  The leading group
        # dim is sharded over the data axes; everything stays shard-local.
        tg = n_tok // n_groups
        xg = ctx.cons(x.reshape(n_groups, tg, d), "batch", None, None)
        idxg = ctx.cons(idx.reshape(n_groups, tg, e.top_k),
                        "batch", None, None)
        gateg = ctx.cons(gate_vals.reshape(n_groups, tg, e.top_k),
                         "batch", None, None)
        out = jax.vmap(lambda a, b, c: route_compute(a, b, c, cap))(
            xg, idxg, gateg).reshape(n_tok, d)
    else:
        out = route_compute(x, idx, gate_vals, cap)

    if e.n_shared_experts and "w_gate" in p:
        out = out + ((act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"])

    # aux losses (Switch-style load balance + router z-loss)
    me = probs.mean(0)                                         # [E]
    ce = jax.nn.one_hot(idx[:, 0], e.n_experts).mean(0)
    lb = e.n_experts * jnp.sum(me * ce) * e.load_balance_loss
    z = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * e.router_z_loss
    return out.reshape(b, s, d).astype(h.dtype), \
        {"moe_lb": lb, "moe_z": z}


# --------------------------------------------------------------- mamba2 SSD
def ssd_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 4)
    return {
        # fused input projection -> [z (d_in), x (d_in), B, C (G*N each), dt (nh)]
        "in_proj": dense_init(ks[0], d,
                              (d, 2 * d_in + 2 * s.n_groups * s.d_state + nh),
                              cfg.pdtype),
        "conv_w": dense_init(ks[1], s.conv_width,
                             (s.conv_width, conv_ch), cfg.pdtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.pdtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(cfg.pdtype),
        "D_skip": jnp.ones((nh,), cfg.pdtype),
        "dt_bias": jnp.zeros((nh,), cfg.pdtype),
        "out_norm": norm_init(d_in, cfg.pdtype),
        "out_proj": dense_init(ks[2], d_in, (d_in, d), cfg.pdtype),
    }


def _causal_conv(x, w, b, state=None, first=None):
    """x [B,S,C]; w [W,C] depthwise causal conv.  Returns (y, new_state)
    where state is the last W-1 inputs (for decode).  ``first`` [B,S] marks
    document starts: taps reaching across a boundary are zeroed so packed
    documents do not leak into each other."""
    width = w.shape[0]
    s = x.shape[1]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    if first is not None:
        nr = jnp.cumsum(first.astype(jnp.int32), axis=1)       # [B,S]
        nrp = jnp.pad(nr, ((0, 0), (width - 1, 0)), constant_values=-1)
        terms = [xp[:, i:i + s, :] * w[i]
                 * (nrp[:, i:i + s] == nr)[..., None].astype(x.dtype)
                 for i in range(width)]
        ys = sum(terms)
    else:
        ys = sum(xp[:, i:i + s, :] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):, :] if width > 1 else None
    return jax.nn.silu(ys + b), new_state


def _ssd_split(p, h, cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    proj = h @ p["in_proj"]
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * gn]
    dt = proj[..., -nh:]
    return z, xbc, dt, d_in, nh, gn


def ssd_apply(p, h, batch, cfg, ctx):
    """Mamba-2 SSD block (chunked scan), packed-document aware: the decay
    is zeroed at document starts so state never crosses documents."""
    s = cfg.ssm
    b, S, _ = h.shape
    seg0 = batch["segment_ids"]
    first0 = jnp.concatenate(
        [jnp.ones((b, 1), bool), seg0[:, 1:] != seg0[:, :-1]], axis=1)
    z, xbc, dt, d_in, nh, gn = _ssd_split(p, h, cfg)
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"], first=first0)
    x = xbc[..., :d_in].reshape(b, S, nh, s.head_dim)
    B_ = xbc[..., d_in:d_in + gn].reshape(b, S, s.n_groups, s.d_state)
    C_ = xbc[..., d_in + gn:].reshape(b, S, s.n_groups, s.d_state)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # [nh] < 0
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # [B,S,nh]
    seg = batch["segment_ids"]
    first = jnp.concatenate(
        [jnp.ones((b, 1), bool), seg[:, 1:] != seg[:, :-1]], axis=1)
    log_a = dt * A                                              # [B,S,nh] <=0

    y = _ssd_chunked(x, dt, log_a, B_, C_, s.chunk_size, first, ctx=ctx)
    y = y + x * p["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, S, d_in)
    y = norm_apply(p["out_norm"], y * jax.nn.silu(z))
    return ctx.cons(y @ p["out_proj"], "batch", "residual_seq", None)


def _ssd_chunked(x, dt, log_a, B_, C_, chunk, first=None, ctx=None):
    """Chunked SSD: y_t = C_t^T ( sum_{j<=t} prod_{i in (j,t]} a_i *
    dt_j B_j x_j^T ).  x [B,S,H,P]; B_/C_ [B,S,G,N]; log_a/dt [B,S,H];
    first [B,S] bool marks document starts (state resets).  Returns
    y [B,S,H,P].

    Document resets are NOT folded into log_a as -inf (the cumsum-difference
    trick would suffer catastrophic cancellation); instead the reset-count
    prefix sum gates which (j -> i) contributions are allowed."""
    b, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0, "seq must divide ssd chunk"
    nc = S // chunk
    if first is None:
        first = jnp.zeros((b, S), bool).at[:, 0].set(True)

    def r(t):  # [B,S,...] -> [B,nc,chunk,...]
        return t.reshape((b, nc, chunk) + t.shape[2:])

    xc, dtc, lac = r(x), r(dt), r(log_a)
    Bc = jnp.repeat(r(B_), rep, axis=3)          # [B,K,c,H,N]
    Cc = jnp.repeat(r(C_), rep, axis=3)
    # a_t at a reset position never multiplies anything that survives the
    # reset-count gates below, so zero its log contribution.
    lac = jnp.where(r(first)[..., None], 0.0, lac)
    nr = jnp.cumsum(r(first).astype(jnp.int32), axis=2)   # resets up to t

    csum = jnp.cumsum(lac, axis=2)               # [B,K,c,H]
    if getattr(ctx, "attn_impl", "") == "pallas":
        # Pallas intra-chunk kernel (kernels/ssd): MXU-tiled scores +
        # decay mask + end-state, one (batch, chunk, head) tile per step
        from repro.kernels.ssd.kernel import ssd_chunk
        y_intra, states = ssd_chunk(
            Cc.astype(jnp.float32), Bc.astype(jnp.float32),
            xc.astype(jnp.float32), dtc, csum, nr.astype(jnp.int32))
        y_intra = y_intra.astype(jnp.float32)
    else:
        # intra-chunk: contribution of input j to output i (j<=i) decays
        # by prod_{t in (j, i]} a_t = exp(csum_i - csum_j); weight dt_j;
        # allowed only when no reset occurred in (j, i] <=> nr_i == nr_j.
        li = csum[:, :, :, None, :]                  # i
        lj = csum[:, :, None, :, :]                  # j
        dec = jnp.exp(jnp.clip(li - lj, -80.0, 0.0))  # [B,K,i,j,H]
        iota = jnp.arange(chunk)
        tri = (iota[:, None] >= iota[None, :])[None, None, :, :, None]
        same_doc = (nr[:, :, :, None] == nr[:, :, None, :])[..., None]
        dec = jnp.where(tri & same_doc, dec, 0.0)
        scores = jnp.einsum("bkihn,bkjhn->bkijh", Cc.astype(jnp.float32),
                            Bc.astype(jnp.float32))
        w = scores * dec * dtc[:, :, None, :, :]
        y_intra = jnp.einsum("bkijh,bkjhp->bkihp", w,
                             xc.astype(jnp.float32))

        # chunk-final states: sum_j exp(csum_end - csum_j) dt_j B_j x_j^T
        # over inputs j with no reset after them (nr_j == nr_last).
        live_end = (nr == nr[:, :, -1:])[..., None]           # [B,K,c,1]
        dec_end = jnp.exp(jnp.clip(csum[:, :, -1:, :] - csum, -80.0, 0.0))
        dec_end = jnp.where(live_end, dec_end, 0.0)
        sB = Bc.astype(jnp.float32) * (dec_end * dtc)[..., None]
        states = jnp.einsum("bkjhn,bkjhp->bkhnp", sB,
                            xc.astype(jnp.float32))
    # carried decay is zero if the chunk contains any reset
    no_reset = (nr[:, :, -1] == 0)[..., None]             # [B,K,1]
    chunk_decay = jnp.exp(jnp.clip(csum[:, :, -1, :], -80.0, 0.0)) \
        * no_reset.astype(jnp.float32)                    # [B,K,H]

    def scan_fn(h_prev, inp):
        st, cd = inp                              # [B,H,N,P], [B,H]
        h_new = h_prev * cd[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    _, h_before = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)  # [B,K,H,N,P] entering state
    # inter-chunk: y_i += C_i^T decay(start..i) h_before, gated on no reset
    # having occurred at or before i within this chunk.
    dec_in = jnp.exp(jnp.clip(csum, -80.0, 0.0)) \
        * (nr == 0).astype(jnp.float32)[..., None]
    y_inter = jnp.einsum("bkihn,bkhnp->bkihp",
                         Cc.astype(jnp.float32) * dec_in[..., None], h_before)
    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ rg-lru
def rglru_init(key, cfg):
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    ks = jax.random.split(key, 6)
    # a initialised so that a = sigmoid(lru_a)^8 is in ~[0.9, 0.999]
    a_init = jnp.log(jnp.expm1(
        jnp.linspace(0.9, 0.999, w) ** (1 / 8.0)) + 1e-8)
    return {
        "w_x": dense_init(ks[0], d, (d, w), cfg.pdtype),       # recurrence in
        "w_gate_br": dense_init(ks[1], d, (d, w), cfg.pdtype),  # gelu branch
        "conv_w": dense_init(ks[2], r.conv_width, (r.conv_width, w), cfg.pdtype),
        "conv_b": jnp.zeros((w,), cfg.pdtype),
        "w_input_gate": dense_init(ks[3], w, (w, w), cfg.pdtype),
        "w_rec_gate": dense_init(ks[4], w, (w, w), cfg.pdtype),
        "lru_a": a_init.astype(cfg.pdtype),
        "w_out": dense_init(ks[5], w, (w, d), cfg.pdtype),
    }


_LRU_C = 8.0


def rglru_apply(p, h, batch, cfg, ctx):
    """Griffin RG-LRU temporal-mixing block with doc-boundary resets."""
    b, S, _ = h.shape
    seg = batch["segment_ids"]
    first = jnp.concatenate(
        [jnp.ones((b, 1), bool), seg[:, 1:] != seg[:, :-1]], axis=1)
    gate_br = jax.nn.gelu(h @ p["w_gate_br"])
    x = h @ p["w_x"]
    x, _ = _causal_conv(x, p["conv_w"], p["conv_b"], first=first)
    y = _rglru_scan(p, x, first, ctx=ctx)
    y = y * gate_br
    return ctx.cons(y @ p["w_out"], "batch", "residual_seq", None)


def _rglru_gates(p, x):
    rg = jax.nn.sigmoid(x @ p["w_rec_gate"]).astype(jnp.float32)
    ig = jax.nn.sigmoid(x @ p["w_input_gate"]).astype(jnp.float32)
    log_a0 = jax.nn.log_sigmoid(p["lru_a"].astype(jnp.float32))
    log_a = _LRU_C * rg * log_a0                       # [B,S,W] (<= 0)
    return log_a, ig


def _rglru_scan(p, x, first, ctx=None):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t x_t), a_t=0 at doc starts.
    Parallelized with associative_scan; with attn_impl="pallas" the
    recurrence runs in the Pallas block-scan kernel (kernels/rglru)."""
    log_a, ig = _rglru_gates(p, x)
    log_a = jnp.where(first[..., None], -1e30, log_a)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0))
    bterm = beta * ig * x.astype(jnp.float32)

    w = x.shape[-1]
    s = x.shape[1]
    if getattr(ctx, "attn_impl", "") == "pallas" and w % 128 == 0 \
            and s % 128 == 0:
        from repro.kernels.rglru.ops import lru_scan
        return lru_scan(a.astype(jnp.float32), bterm).astype(x.dtype)

    def combine(l, r_):
        al, bl = l
        ar, br = r_
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    return h.astype(x.dtype)


def rglru_decode(p, x_t, h_prev, reset=None):
    """Single-step RG-LRU update: x_t [B,1,W] (post-conv), h_prev [B,W];
    reset [B] bool zeroes the decay (document start), matching the packed
    forward's segment-boundary convention."""
    log_a, ig = _rglru_gates(p, x_t)
    a = jnp.exp(log_a[:, 0])
    if reset is not None:
        a = jnp.where(reset[:, None], 0.0, a)
    beta = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0))
    h_new = a * h_prev + beta * ig[:, 0] * x_t[:, 0].astype(jnp.float32)
    return h_new


def ssd_decode(p, h_t, conv_state, ssm_state, cfg):
    """Single-token SSD step.  h_t [B,1,D]; conv_state [B,W-1,C];
    ssm_state [B,H,N,P] (f32).  Returns (out [B,1,D], conv_state, ssm_state)."""
    s = cfg.ssm
    b = h_t.shape[0]
    z, xbc, dt, d_in, nh, gn = _ssd_split(p, h_t, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    x = xbc[:, 0, :d_in].reshape(b, nh, s.head_dim)
    B_ = jnp.repeat(xbc[:, 0, d_in:d_in + gn].reshape(b, s.n_groups, s.d_state),
                    nh // s.n_groups, axis=1)                    # [B,H,N]
    C_ = jnp.repeat(xbc[:, 0, d_in + gn:].reshape(b, s.n_groups, s.d_state),
                    nh // s.n_groups, axis=1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))    # [B,H]
    a = jnp.exp(dtv * A)                                         # [B,H]
    upd = (dtv[..., None] * B_.astype(jnp.float32))[..., None] \
        * x.astype(jnp.float32)[:, :, None, :]                   # [B,H,N,P]
    ssm_state = ssm_state * a[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", C_.astype(jnp.float32), ssm_state)
    y = y + x.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_in).astype(h_t.dtype)
    y = norm_apply(p["out_norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], conv_state, ssm_state
