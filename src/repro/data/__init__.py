from repro.data.packing import BLOCK, PackedChunk, pack_documents
from repro.data.pipeline import PipelineConfig, raw_batches
from repro.data.distributions import sample_lengths
