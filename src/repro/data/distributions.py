"""Synthetic document-length distributions (paper §6.1 "Input data").

"Pretrain": a pretraining-style power-law length distribution with long
documents upsampled by filtering out documents shorter than a threshold
(Fu et al., 2024), exactly as the paper describes.

"ProLong": a long-context-specialized mixture with a higher fraction of
long documents (Gao et al., 2025 train on mixtures where long documents
carry a large token share).
"""
from __future__ import annotations

import numpy as np


def pretrain_lengths(rng: np.random.Generator, n: int, max_len: int,
                     min_len: int = 128, alpha: float = 1.3,
                     upsample_threshold: int = 0,
                     upsample_drop: float = 0.7) -> np.ndarray:
    """Power-law lengths in [min_len, max_len]; optionally upsample long
    docs by dropping a fraction of docs below ``upsample_threshold``."""
    u = rng.random(n)
    lo, hi = float(min_len), float(max_len)
    # inverse-CDF of p(l) ~ l^-alpha on [lo, hi]
    a1 = 1.0 - alpha
    ls = ((lo ** a1) + u * ((hi ** a1) - (lo ** a1))) ** (1.0 / a1)
    ls = np.clip(ls, lo, hi).astype(np.int64)
    if upsample_threshold:
        keep = (ls >= upsample_threshold) | \
            (rng.random(n) > upsample_drop)
        ls = ls[keep]
    return ls


def prolong_lengths(rng: np.random.Generator, n: int,
                    max_len: int) -> np.ndarray:
    """Mixture: 60% short (power law up to 8K), 40% long
    (log-uniform in [max/16, max])."""
    n_long = int(n * 0.4)
    short = pretrain_lengths(rng, n - n_long, min(8192, max_len))
    lo, hi = np.log(max(max_len // 16, 256)), np.log(max_len)
    long_ = np.exp(rng.random(n_long) * (hi - lo) + lo).astype(np.int64)
    ls = np.concatenate([short, np.clip(long_, 256, max_len)])
    rng.shuffle(ls)
    return ls


DISTRIBUTIONS = {"pretrain": pretrain_lengths, "prolong": prolong_lengths}


def sample_lengths(name: str, rng: np.random.Generator, n: int,
                   max_len: int) -> np.ndarray:
    if name == "pretrain":
        return pretrain_lengths(rng, n, max_len,
                                upsample_threshold=max_len // 8)
    if name == "prolong":
        return prolong_lengths(rng, n, max_len)
    raise KeyError(name)
