"""Training data pipeline: samples a length distribution, packs documents
into per-rank chunks, and emits jax-ready batches (+ labels with
in-document next-token shift).

Plan attachment is the :class:`repro.cad.CADSession`'s job
(``session.attach_plans(raw_batches(cfg))`` — asynchronous, prefetched).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.mask import validate_mask_layout
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents


@dataclasses.dataclass
class PipelineConfig:
    distribution: str = "pretrain"     # pretrain | prolong
    max_doc_len: int = 4096
    seq_len: int = 4096                # tokens per row
    global_batch: int = 8              # rows per step
    n_ranks: int = 1                   # data-parallel ranks (CAD servers)
    vocab_size: int = 32000
    seed: int = 0
    strategy: str = "fixed"            # fixed | variable (WLB baseline)


def _labels(tokens, seg):
    nxt = np.roll(tokens, -1, axis=-1)
    nseg = np.roll(seg, -1, axis=-1)
    lab = np.where((seg > 0) & (seg == nseg), nxt, -1)
    return lab.astype(np.int32)


def raw_batches(cfg: PipelineConfig) -> Iterator[dict]:
    """Packed batches without plans — feed through
    ``CADSession.attach_plans`` when CAD is on.

    Fields are host numpy arrays: the plan prefetcher reads
    ``segment_ids`` on its worker thread without touching the device,
    and jit transfers everything once at step time."""
    rng = np.random.default_rng(cfg.seed)
    while True:
        # oversample docs, pack exactly global_batch rows
        need = cfg.global_batch * cfg.seq_len
        lens = []
        while sum(lens) < need * 1.2:
            lens.extend(sample_lengths(cfg.distribution, rng, 64,
                                       cfg.max_doc_len).tolist())
        chunks = pack_documents(lens, cfg.seq_len, cfg.global_batch,
                                rng=rng, strategy=cfg.strategy,
                                vocab_size=cfg.vocab_size)
        toks = np.stack([c.tokens for c in chunks])
        segs = np.stack([c.segment_ids for c in chunks])
        poss = np.stack([c.positions for c in chunks])
        # packed doc boundaries feed the segment mask downstream; a
        # layout violating the doc-pure-block invariant (overlapping or
        # misaligned segments) must fail here, named, not as silent
        # cross-document attention in a fused batch (DESIGN.md §12)
        validate_mask_layout(None, segs, BLOCK)
        yield {
            "tokens": toks,
            "labels": _labels(toks, segs),
            "segment_ids": segs,
            "positions": poss,
        }
