"""Training data pipeline: samples a length distribution, packs documents
into per-rank chunks, emits jax-ready batches (+ labels with in-document
next-token shift), and — when CAD is on — runs the scheduler to attach a
dispatch plan to every batch."""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CommModel
from repro.core.plan import CADConfig, identity_plan, plan_from_schedule
from repro.core.scheduler import schedule
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents


@dataclasses.dataclass
class PipelineConfig:
    distribution: str = "pretrain"     # pretrain | prolong
    max_doc_len: int = 4096
    seq_len: int = 4096                # tokens per row
    global_batch: int = 8              # rows per step
    n_ranks: int = 1                   # data-parallel ranks (CAD servers)
    vocab_size: int = 32000
    seed: int = 0
    strategy: str = "fixed"            # fixed | variable (WLB baseline)
    cad: Optional[CADConfig] = None    # attach plans when set
    tolerance: float = 0.1
    pingpong: bool = False


def _labels(tokens, seg):
    nxt = np.roll(tokens, -1, axis=-1)
    nseg = np.roll(seg, -1, axis=-1)
    lab = np.where((seg > 0) & (seg == nseg), nxt, -1)
    return lab.astype(np.int32)


def batches(cfg: PipelineConfig, n_heads: int, head_dim: int,
            n_kv_heads: int) -> Iterator[dict]:
    rng = np.random.default_rng(cfg.seed)
    rows_per_rank = cfg.global_batch // max(cfg.n_ranks, 1)
    tokens_per_rank = rows_per_rank * cfg.seq_len
    comm = CommModel(n_heads=n_heads, head_dim=head_dim,
                     n_kv_heads=n_kv_heads)
    while True:
        # oversample docs, pack exactly global_batch rows
        need = cfg.global_batch * cfg.seq_len
        lens = []
        while sum(lens) < need * 1.2:
            lens.extend(sample_lengths(cfg.distribution, rng, 64,
                                       cfg.max_doc_len).tolist())
        chunks = pack_documents(lens, cfg.seq_len, cfg.global_batch,
                                rng=rng, strategy=cfg.strategy,
                                vocab_size=cfg.vocab_size)
        toks = np.stack([c.tokens for c in chunks])
        segs = np.stack([c.segment_ids for c in chunks])
        poss = np.stack([c.positions for c in chunks])
        batch = {
            "tokens": jnp.asarray(toks),
            "labels": jnp.asarray(_labels(toks, segs)),
            "segment_ids": jnp.asarray(segs),
            "positions": jnp.asarray(poss),
        }
        if cfg.cad is not None:
            # rank-major fold: rows r*rows_per_rank..(r+1)*rows_per_rank
            segs_rank = segs.reshape(cfg.n_ranks, tokens_per_rank)
            if cfg.pingpong:
                assert rows_per_rank % 2 == 0, \
                    "ping-pong needs an even number of rows per rank"
                half = tokens_per_rank // 2
                assert half % BLOCK == 0
                sub = dataclasses.replace(cfg.cad, nb=half // cfg.cad.blk)
                plans = []
                for i in range(2):
                    seg_i = segs_rank[:, i * half:(i + 1) * half]
                    sch = schedule(seg_i, blk=sub.blk,
                                   n_servers=sub.n_servers, comm=comm,
                                   caps=sub.caps(),
                                   tolerance=cfg.tolerance)
                    plans.append({k: jnp.asarray(v) for k, v in
                                  plan_from_schedule(sub, sch).items()})
                batch["plan"] = tuple(plans)
            else:
                sch = schedule(segs_rank, blk=cfg.cad.blk,
                               n_servers=cfg.cad.n_servers, comm=comm,
                               caps=cfg.cad.caps(), tolerance=cfg.tolerance)
                plan = plan_from_schedule(cfg.cad, sch)
                batch["plan"] = {k: jnp.asarray(v) for k, v in plan.items()}
            batch["schedule_stats"] = {
                "comm_bytes": float(sch.comm_bytes),
                "n_moves": int(sch.n_moves),
                "load_max_over_mean": float(sch.loads.max()
                                            / max(sch.loads.mean(), 1e-9)),
            }
        yield batch
