"""Document packing into fixed-size chunks (paper §1 / §3.1).

Packing strategies:

  fixed_packing        — greedy first-fit into equal-token chunks (the
                         memory-balanced, compute-imbalanced baseline)
  variable_packing     — WLB-LLM-style variable-length chunking: documents
                         are redistributed so per-chunk Σl² (attention
                         FLOPs) is approximately equal, at the price of
                         unequal token counts / activation memory (§3.2)

Both align every document to BLOCK (=128) tokens with segment-0 padding so
q/kv blocks are document-pure — the invariant the CAD scheduler, plan
builder, and kernels rely on (the paper's kernels have the same 128-token
tile constraint, Fig. 5).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

BLOCK = 128


@dataclasses.dataclass
class PackedChunk:
    """One rank's chunk: token ids / segment ids / in-doc positions."""
    tokens: np.ndarray        # [L] int32
    segment_ids: np.ndarray   # [L] int32 (0 = padding)
    positions: np.ndarray     # [L] int32
    doc_lengths: List[int]    # true (unpadded) lengths


def _aligned(l: int, block: int = BLOCK) -> int:
    return ((l + block - 1) // block) * block


def pack_documents(doc_lengths: Sequence[int], chunk_tokens: int,
                   n_chunks: int, *, block: int = BLOCK,
                   rng: Optional[np.random.Generator] = None,
                   strategy: str = "fixed",
                   vocab_size: int = 32000) -> List[PackedChunk]:
    """Pack documents into exactly ``n_chunks`` chunks of ``chunk_tokens``.

    Documents that don't fit are split at block boundaries (the paper's
    sequential placement: "If a device reaches its token threshold before
    a document is fully placed, the remaining portion is put to the next
    device" — we instead truncate-to-fit per chunk and continue the doc as
    a fresh segment, keeping the no-doc-spans-ranks invariant that makes
    the identity plan communication-free; the CAD scheduler re-balances
    across ranks anyway, which is the paper's whole point)."""
    assert chunk_tokens % block == 0
    rng = rng or np.random.default_rng(0)
    if strategy == "fixed":
        order = list(range(len(doc_lengths)))
    elif strategy == "variable":
        order = list(np.argsort(doc_lengths)[::-1])   # longest-first
    else:
        raise KeyError(strategy)

    chunks = [{"docs": [], "used": 0, "cost": 0.0} for _ in range(n_chunks)]

    def fit(c, l):
        return c["used"] + _aligned(l, block) <= chunk_tokens

    for di in order:
        l = int(doc_lengths[di])
        while l > 0:
            if strategy == "variable":
                # least-attention-cost chunk with room (WLB-style Σl² balance)
                cands = [c for c in chunks if c["used"] < chunk_tokens]
                cands.sort(key=lambda c: c["cost"])
            else:
                cands = [c for c in chunks if fit(c, min(l, block))]
            placed = False
            for c in cands:
                room = chunk_tokens - c["used"]
                if room < block:
                    continue
                take = min(_aligned(l, block), room)
                take_real = min(l, take)
                c["docs"].append(take_real)
                c["used"] += _aligned(take_real, block)
                c["cost"] += float(take_real) ** 2
                l -= take_real
                placed = True
                break
            if not placed:
                break  # batch full; drop remainder (sampler oversamples)

    out = []
    seg_counter = 1
    for c in chunks:
        tokens = np.zeros(chunk_tokens, np.int32)
        seg = np.zeros(chunk_tokens, np.int32)
        pos = np.zeros(chunk_tokens, np.int32)
        t = 0
        for dl in c["docs"]:
            al = _aligned(dl, block)
            tokens[t:t + dl] = rng.integers(1, vocab_size,
                                            dl).astype(np.int32)
            seg[t:t + dl] = seg_counter
            pos[t:t + dl] = np.arange(dl)
            seg_counter += 1
            t += al
        out.append(PackedChunk(tokens=tokens, segment_ids=seg,
                               positions=pos, doc_lengths=list(c["docs"])))
    return out


def chunk_attention_cost(chunk: PackedChunk) -> float:
    """Σ l² over documents — the quadratic CA term of §3.1."""
    return float(sum(l * l for l in chunk.doc_lengths))


def chunk_tokens_used(chunk: PackedChunk) -> int:
    return int((chunk.segment_ids > 0).sum())
