"""Pure-jnp oracles for the packed-flash kernels.

Three entry points mirror kernel.py:

  ref_packed_attention    — packed-document self-attention over a chunk
                            (same semantics as core.attention.ref_attention)
  ref_ca_server_attention — the attention-server fused CA-task batch: every
                            task is a (q-block, kv-prefix-range) pair; tasks
                            from any document/rank are batched in one call
                            (paper §3.3 "composability").
  ref_ragged_decode       — the serving cache-attention batch: request-pure
                            q blocks against per-request kv caches with
                            ragged ``kv_len`` (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.attention import NEG_INF, ref_attention
from repro.core.mask import pair_visible

ref_packed_attention = ref_attention


def ref_masked_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv, *,
                         mask=None, blk=128, softcap=0.0, scale=None):
    """Materialized oracle for every mask family (DESIGN.md §12).

    Independent of the kernels and of ``core.attention.mask_fn``: the
    full [B, Sq, Skv] visibility matrix is built inline from segments,
    in-document positions, and the :class:`~repro.core.mask.MaskSpec`
    terms, then run through a plain softmax.  ``blk`` is the block
    granularity the dilated family strides over (the kernel tile size).
    The differential suite checks kernel fwd/bwd against this.
    """
    hq, hkv = q.shape[2], k.shape[2]
    rep = hq // hkv
    if rep > 1:
        b, s, _, dh = k.shape
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (b, s, hkv, rep, dh)).reshape(b, s, hq, dh)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (b, s, hkv, rep, dh)).reshape(b, s, hq, dh)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    pq = pos_q[:, :, None]
    pk = pos_kv[:, None, :]
    m = (seg_q[:, :, None] == seg_kv[:, None, :]) \
        & (seg_q[:, :, None] > 0) & (seg_kv[:, None, :] > 0) \
        & (pq >= pk)
    extra = pair_visible(mask, pq, pk, blk)
    if extra is not None:
        m = m & extra
    logits = jnp.where(m[:, None, :, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(m.any(axis=-1)[:, None, :, None], p, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_ca_server_attention(q_tasks, k_buf, v_buf, kv_start, kv_len,
                            q_pos, kv_pos, *, softcap=0.0, window=0,
                            causal=True, scale=None, mask=None):
    """Oracle for the fused CA-task kernel.

    q_tasks [T, blk, Hq, dh]   query blocks (one per CA-task slot)
    k_buf/v_buf [N, blk, Hkv, dh]  kv blocks resident on this server
    kv_start [T] int32         first kv block index of task t's context
    kv_len  [T] int32          number of kv blocks (0 = padding slot)
    q_pos   [T, blk] int32     in-document position of each query token
                               (-1 = padded query row)
    kv_pos  [N, blk] int32     in-document position of each kv token
                               (-1 = padded kv slot)

    The scheduler guarantees each task's kv range belongs to the task's own
    document, so masking needs positions only.  Returns [T, blk, Hq, dh].
    """
    T, blk, hq, dh = q_tasks.shape
    N = k_buf.shape[0]
    hkv = k_buf.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    jmax = N  # oracle just materializes everything

    # flatten kv buffer to [N*blk, ...]
    kf = k_buf.reshape(N * blk, hkv, dh)
    vf = v_buf.reshape(N * blk, hkv, dh)
    kpf = kv_pos.reshape(N * blk)

    blk_idx = jnp.arange(N)
    in_range = (blk_idx[None, :] >= kv_start[:, None]) & \
               (blk_idx[None, :] < kv_start[:, None] + kv_len[:, None])
    tok_in_range = jnp.repeat(in_range, blk, axis=1)          # [T, N*blk]

    logits = jnp.einsum("tqhd,khd->thqk",
                        q_tasks.astype(jnp.float32),
                        jnp.repeat(kf, rep, axis=1).astype(jnp.float32)
                        ) * scale
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    m = tok_in_range[:, None, None, :]
    m = m & (kpf >= 0)[None, None, None, :]
    m = m & (q_pos >= 0)[:, None, :, None]
    if causal:
        m = m & (q_pos[:, None, :, None] >= kpf[None, None, None, :])
    if window and window > 0:
        m = m & ((q_pos[:, None, :, None] - kpf[None, None, None, :])
                 < window)
    extra = pair_visible(mask, q_pos[:, None, :, None],
                         kpf[None, None, None, :], blk)
    if extra is not None:
        m = m & extra
    logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(m.any(-1)[..., None], p, 0.0)
    out = jnp.einsum("thqk,khd->tqhd", p,
                     jnp.repeat(vf, rep, axis=1).astype(jnp.float32))
    return out.astype(q_tasks.dtype)


def ref_ragged_decode(q_blocks, k_cache, v_cache, block_req, kv_len, q_pos,
                      *, window=0, softcap=0.0, scale=None):
    """Materialized oracle for ``kernel.ragged_decode_fwd``.

    q_blocks [nq, blk_q, Hq, dh]; k_cache/v_cache [R, S, Hkv, dh];
    block_req [nq] (-1 = dead block); kv_len [R]; q_pos [nq, blk_q]
    (-1 = padded row).  Cache slot index == absolute position (the serving
    layout is non-ring); causal always.  Returns [nq, blk_q, Hq, dh].
    """
    nq, blk_q, hq, dh = q_blocks.shape
    R, S, hkv, _ = k_cache.shape
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5

    safe_req = jnp.maximum(block_req, 0)
    kb = jnp.repeat(k_cache, rep, axis=2)[safe_req]    # [nq, S, Hq, dh]
    vb = jnp.repeat(v_cache, rep, axis=2)[safe_req]
    logits = jnp.einsum("nqhd,nshd->nhqs", q_blocks.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    s_pos = jnp.arange(S, dtype=jnp.int32)
    m = (block_req >= 0)[:, None, None]
    m = m & (q_pos >= 0)[:, :, None]
    m = m & (s_pos[None, None, :] < kv_len[safe_req][:, None, None])
    m = m & (q_pos[:, :, None] >= s_pos[None, None, :])
    if window and window > 0:
        m = m & ((q_pos[:, :, None] - s_pos[None, None, :]) < window)
    logits = jnp.where(m[:, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(m[:, None].any(-1)[..., None], p, 0.0)
    out = jnp.einsum("nhqs,nshd->nqhd", p, vb.astype(jnp.float32))
    return out.astype(q_blocks.dtype)
