from repro.kernels.packed_flash.ops import (ca_server_attention,
                                            packed_flash_attention)

__all__ = ["packed_flash_attention", "ca_server_attention"]
