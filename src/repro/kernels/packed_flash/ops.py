"""Jit'd wrappers for the packed-flash kernels with training-ready VJPs.

Forward runs the Pallas kernel (interpret=True on CPU, compiled on TPU)
and saves the flash residuals ``(out, lse)``.  Backward runs the
hand-written Pallas backward kernels (``kernel.flash_bwd`` /
``kernel.ca_server_bwd``) — recompute-free, rebuilding attention weights
from the saved log-sum-exp instead of re-deriving them via ``jax.vjp``
over a forward re-run.

The previous blockwise-jnp recompute backward is kept as an explicit
fallback: pass ``bwd_impl="xla"`` (or set ``REPRO_KERNEL_BWD=xla``) to
select it — e.g. on backends where even interpret-mode Pallas is
undesirable, or to A/B the two in ``benchmarks.kernel_throughput --bwd``.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.core import attention as A
from repro.kernels.packed_flash import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve_bwd(bwd_impl) -> str:
    """"pallas" | "xla"; None defers to $REPRO_KERNEL_BWD (default pallas)."""
    impl = bwd_impl or os.environ.get("REPRO_KERNEL_BWD", "pallas")
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown kernel bwd impl {impl!r}")
    return impl


# ------------------------------------------------------------ packed flash
@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11))
def packed_flash_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                           causal=True, window=0, softcap=0.0, scale=None,
                           bwd_impl=None):
    return K.flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal=causal,
                       window=window, softcap=softcap, scale=scale,
                       interpret=not _on_tpu())


def _pf_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal, window, softcap,
            scale, bwd_impl):
    out, lse = K.flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                           causal=causal, window=window, softcap=softcap,
                           scale=scale, interpret=not _on_tpu(),
                           return_lse=True)
    return out, (q, k, v, seg_q, pos_q, seg_kv, pos_kv, out, lse)


def _pf_bwd(causal, window, softcap, scale, bwd_impl, res, g):
    q, k, v, seg_q, pos_q, seg_kv, pos_kv, out, lse = res
    if _resolve_bwd(bwd_impl) == "pallas":
        dq, dk, dv = K.flash_bwd(q, k, v, out, lse, g, seg_q, pos_q,
                                 seg_kv, pos_kv, causal=causal,
                                 window=window, softcap=softcap,
                                 scale=scale, interpret=not _on_tpu())
        return dq, dk, dv, None, None, None, None
    f = lambda q_, k_, v_: A.xla_flash_attention(
        q_, k_, v_, seg_q, pos_q, seg_kv, pos_kv, causal=causal,
        window=window, softcap=softcap, scale=scale)
    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


packed_flash_attention.defvjp(_pf_fwd, _pf_bwd)


# -------------------------------------------------------------- CA server
@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11, 12))
def ca_server_attention(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                        kv_pos, causal=True, window=0, softcap=0.0,
                        scale=None, jmax=0, bwd_impl=None):
    """Fused CA-task batch on an attention server (paper §4.1).

    ``jmax`` bounds the kv blocks any task may touch (0 -> all of k_buf);
    the scheduler's plan guarantees every ``kv_len`` fits under it."""
    return K.ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                           kv_pos, causal=causal, window=window,
                           softcap=softcap, scale=scale, jmax=jmax or None,
                           interpret=not _on_tpu())


def _ca_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
            causal, window, softcap, scale, jmax, bwd_impl):
    out, lse = K.ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len,
                               q_pos, kv_pos, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               jmax=jmax or None, interpret=not _on_tpu(),
                               return_lse=True)
    return out, (q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
                 out, lse)


def _ca_bwd(causal, window, softcap, scale, jmax, bwd_impl, res, g):
    q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, out, lse = res
    if _resolve_bwd(bwd_impl) == "pallas":
        dq, dk, dv = K.ca_server_bwd(
            q_tasks, k_buf, v_buf, out, lse, g, kv_start, kv_len, q_pos,
            kv_pos, causal=causal, window=window, softcap=softcap,
            scale=scale, jmax=jmax or None, interpret=not _on_tpu())
        return dq, dk, dv, None, None, None, None
    if causal:
        # blockwise-jnp recompute fallback — the attention-server scan
        # path (dispatch._xla_server_bwd); its mask is causal-only
        from repro.core import dispatch as D
        f = lambda q_, k_, v_: D._xla_server(
            q_, k_, v_, kv_start, kv_len, q_pos, kv_pos,
            jmax or k_buf.shape[0], softcap, window, scale)
    else:
        from repro.kernels.packed_flash import ref as R
        f = lambda q_, k_, v_: R.ref_ca_server_attention(
            q_, k_, v_, kv_start, kv_len, q_pos, kv_pos, causal=False,
            window=window, softcap=softcap, scale=scale)
    _, vjp = jax.vjp(f, q_tasks, k_buf, v_buf)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


ca_server_attention.defvjp(_ca_fwd, _ca_bwd)
