"""Jit'd wrappers for the packed-flash kernels with training-ready VJPs.

Forward runs the Pallas kernel (interpret=True on CPU, compiled on TPU).
Backward is flash-style recompute expressed in blockwise jnp — numerically
the same function, so JAX autodiff of the blockwise form is the transpose
of the kernel.  (A hand-written Pallas backward is a recorded §Perf
follow-up; it changes throughput, not semantics.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import attention as A
from repro.kernels.packed_flash import kernel as K
from repro.kernels.packed_flash import ref as R


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def packed_flash_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                           causal=True, window=0, softcap=0.0, scale=None):
    return K.flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal=causal,
                       window=window, softcap=softcap, scale=scale,
                       interpret=not _on_tpu())


def _pf_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal, window, softcap,
            scale):
    out = packed_flash_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                                 causal, window, softcap, scale)
    return out, (q, k, v, seg_q, pos_q, seg_kv, pos_kv)


def _pf_bwd(causal, window, softcap, scale, res, g):
    q, k, v, seg_q, pos_q, seg_kv, pos_kv = res
    f = lambda q_, k_, v_: A.xla_flash_attention(
        q_, k_, v_, seg_q, pos_q, seg_kv, pos_kv, causal=causal,
        window=window, softcap=softcap, scale=scale)
    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


packed_flash_attention.defvjp(_pf_fwd, _pf_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def ca_server_attention(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                        kv_pos, causal=True, window=0, softcap=0.0,
                        scale=None):
    """Fused CA-task batch on an attention server (paper §4.1)."""
    return K.ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                           kv_pos, causal=causal, window=window,
                           softcap=softcap, scale=scale,
                           interpret=not _on_tpu())


def _ca_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
            causal, window, softcap, scale):
    out = ca_server_attention(q_tasks, k_buf, v_buf, kv_start, kv_len,
                              q_pos, kv_pos, causal, window, softcap, scale)
    return out, (q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos)


def _ca_bwd(causal, window, softcap, scale, res, g):
    q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos = res
    f = lambda q_, k_, v_: R.ref_ca_server_attention(
        q_, k_, v_, kv_start, kv_len, q_pos, kv_pos, causal=causal,
        window=window, softcap=softcap, scale=scale)
    _, vjp = jax.vjp(f, q_tasks, k_buf, v_buf)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


ca_server_attention.defvjp(_ca_fwd, _ca_bwd)
