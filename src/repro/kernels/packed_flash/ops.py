"""Jit'd wrappers for the packed-flash kernels with training-ready VJPs.

Forward runs the Pallas kernel (interpret=True on CPU, compiled on TPU)
and saves the flash residuals ``(out, lse)``.  Backward runs the
hand-written Pallas backward kernels (``kernel.flash_bwd`` /
``kernel.ca_server_bwd``) — recompute-free, rebuilding attention weights
from the saved log-sum-exp instead of re-deriving them via ``jax.vjp``
over a forward re-run.

The previous blockwise-jnp recompute backward is kept as an explicit
fallback: pass ``bwd_impl="xla"`` (or set ``REPRO_KERNEL_BWD=xla``) to
select it — e.g. on backends where even interpret-mode Pallas is
undesirable, or to A/B the two in ``benchmarks.kernel_throughput --bwd``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import attention as A
from repro.kernels.packed_flash import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve_bwd(bwd_impl) -> str:
    """"pallas" | "xla"; None defers to $REPRO_KERNEL_BWD (default pallas)."""
    impl = bwd_impl or os.environ.get("REPRO_KERNEL_BWD", "pallas")
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown kernel bwd impl {impl!r}")
    return impl


# ------------------------------------------------------------ packed flash
# ``sink``/``rate`` trail the original args (keeping positional callers
# valid): the unpacked static params of a non-causal MaskSpec
# (DESIGN.md §12) — sliding-sink tokens and dilated block stride.
@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11, 12, 13))
def packed_flash_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                           causal=True, window=0, softcap=0.0, scale=None,
                           bwd_impl=None, sink=0, rate=1):
    return K.flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal=causal,
                       window=window, sink=sink, rate=rate, softcap=softcap,
                       scale=scale, interpret=not _on_tpu())


def _pf_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal, window, softcap,
            scale, bwd_impl, sink, rate):
    out, lse = K.flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                           causal=causal, window=window, sink=sink,
                           rate=rate, softcap=softcap, scale=scale,
                           interpret=not _on_tpu(), return_lse=True)
    return out, (q, k, v, seg_q, pos_q, seg_kv, pos_kv, out, lse)


def _pf_bwd(causal, window, softcap, scale, bwd_impl, sink, rate, res, g):
    q, k, v, seg_q, pos_q, seg_kv, pos_kv, out, lse = res
    if _resolve_bwd(bwd_impl) == "pallas":
        dq, dk, dv = K.flash_bwd(q, k, v, out, lse, g, seg_q, pos_q,
                                 seg_kv, pos_kv, causal=causal,
                                 window=window, sink=sink, rate=rate,
                                 softcap=softcap, scale=scale,
                                 interpret=not _on_tpu())
        return dq, dk, dv, None, None, None, None
    f = lambda q_, k_, v_: A.xla_flash_attention(
        q_, k_, v_, seg_q, pos_q, seg_kv, pos_kv, causal=causal,
        window=window, sink=sink, rate=rate, softcap=softcap, scale=scale)
    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


packed_flash_attention.defvjp(_pf_fwd, _pf_bwd)


# -------------------------------------------------------------- CA server
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(7, 8, 9, 10, 11, 12, 13, 14))
def ca_server_attention(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                        kv_pos, causal=True, window=0, softcap=0.0,
                        scale=None, jmax=0, bwd_impl=None, sink=0, rate=1):
    """Fused CA-task batch on an attention server (paper §4.1).

    ``jmax`` bounds the kv blocks any task may touch (0 -> all of k_buf);
    the scheduler's plan guarantees every ``kv_len`` fits under it.
    ``sink``/``rate`` carry a non-causal MaskSpec (DESIGN.md §12)."""
    return K.ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                           kv_pos, causal=causal, window=window, sink=sink,
                           rate=rate, softcap=softcap, scale=scale,
                           jmax=jmax or None, interpret=not _on_tpu())


def _ca_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
            causal, window, softcap, scale, jmax, bwd_impl, sink, rate):
    out, lse = K.ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len,
                               q_pos, kv_pos, causal=causal, window=window,
                               sink=sink, rate=rate, softcap=softcap,
                               scale=scale, jmax=jmax or None,
                               interpret=not _on_tpu(), return_lse=True)
    return out, (q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
                 out, lse)


def _ca_bwd(causal, window, softcap, scale, jmax, bwd_impl, sink, rate,
            res, g):
    q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, out, lse = res
    if _resolve_bwd(bwd_impl) == "pallas":
        dq, dk, dv = K.ca_server_bwd(
            q_tasks, k_buf, v_buf, out, lse, g, kv_start, kv_len, q_pos,
            kv_pos, causal=causal, window=window, sink=sink, rate=rate,
            softcap=softcap, scale=scale, jmax=jmax or None,
            interpret=not _on_tpu())
        return dq, dk, dv, None, None, None, None
    if causal:
        # blockwise-jnp recompute fallback — the attention-server scan
        # path (dispatch._xla_server_bwd); mask params ride along
        from repro.core import dispatch as D
        f = lambda q_, k_, v_: D._xla_server(
            q_, k_, v_, kv_start, kv_len, q_pos, kv_pos,
            jmax or k_buf.shape[0], softcap, window, scale, sink, rate)
    else:
        from repro.core.mask import spec_from_params
        from repro.kernels.packed_flash import ref as R
        spec = spec_from_params(window, sink, rate)
        w = 0 if (spec is not None and spec.kind == "sliding") else window
        f = lambda q_, k_, v_: R.ref_ca_server_attention(
            q_, k_, v_, kv_start, kv_len, q_pos, kv_pos, causal=False,
            window=w, softcap=softcap, scale=scale, mask=spec)
    _, vjp = jax.vjp(f, q_tasks, k_buf, v_buf)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


ca_server_attention.defvjp(_ca_fwd, _ca_bwd)


# --------------------------------------------- ring partials (DESIGN.md §13)
def _lse_dead(lse):
    """Rows whose partial saw no live kv (``kernel.LSE_DEAD`` marker)."""
    return lse >= K.LSE_DEAD / 2


def _merge_weights(lse_a, lse_b, lse):
    """Softmax merge weights, zeroed on dead partials.  When exactly one
    side is live its weight is ``exp(0) == 1.0`` exactly, so the merge
    below degenerates to a bitwise pass-through of the live side."""
    w_a = jnp.where(_lse_dead(lse_a), 0.0, jnp.exp(lse_a - lse))
    w_b = jnp.where(_lse_dead(lse_b), 0.0, jnp.exp(lse_b - lse))
    return w_a, w_b


def _broadcast_rows(w):
    """[..., hq, blk] row weights -> [..., blk, hq, 1] out broadcast."""
    return jnp.swapaxes(w, -1, -2)[..., None]


@jax.custom_vjp
def merge_softmax_partials(out_a, lse_a, out_b, lse_b):
    """Online-softmax merge of two finalized attention partials.

    ``out_*`` is ``[..., blk, hq, dh]`` (already normalized), ``lse_*``
    the matching ``[..., hq, blk]`` log-sum-exp over each partial's kv
    range; leading batch dims broadcast elementwise, so per-server
    ``[T, ...]`` and stacked-pool ``[D, T, ...]`` layouts merge with the
    identical FP ops (the ring dispatch / single-pool oracle bit-identity
    contract, DESIGN.md §13).  A dead partial (``kernel.LSE_DEAD``: no
    live kv in its range — a causal- or mask-dead ring pass) is a
    *bitwise* no-op: the result is selected, not blended, from the live
    side, the same discipline as ``dispatch.merge_recovered``.  Both
    outputs are differentiable, so merges chain across ring passes and
    gradients flow back into every partial."""
    out, lse = _merge_fwd(out_a, lse_a, out_b, lse_b)[0]
    return out, lse


def _merge_fwd(out_a, lse_a, out_b, lse_b):
    dead_a, dead_b = _lse_dead(lse_a), _lse_dead(lse_b)
    # neutralize dead sentinels before the max-stabilized logaddexp so a
    # dead side can never dominate the stabilizer
    la = jnp.where(dead_a, -K.LSE_DEAD, lse_a)
    lb = jnp.where(dead_b, -K.LSE_DEAD, lse_b)
    m = jnp.maximum(la, lb)
    lse_m = m + jnp.log(jnp.exp(la - m) + jnp.exp(lb - m))
    w_a, w_b = _merge_weights(lse_a, lse_b, lse_m)
    out_m = (_broadcast_rows(w_a) * out_a.astype(jnp.float32)
             + _broadcast_rows(w_b) * out_b.astype(jnp.float32)) \
        .astype(out_a.dtype)
    # bitwise select: a dead partial must not perturb the live side
    # (0.0*x + 1.0*y is not bitwise y when y holds -0.0)
    sel_b = _broadcast_rows(dead_b)
    sel_a = _broadcast_rows(dead_a)
    out = jnp.where(sel_b, out_a, jnp.where(sel_a, out_b, out_m))
    lse = jnp.where(dead_b, lse_a, jnp.where(dead_a, lse_b, lse_m))
    return (out, lse), (out_a, lse_a, out_b, lse_b, out, lse)


def _merge_bwd(res, g):
    out_a, lse_a, out_b, lse_b, out, lse = res
    g_out, g_lse = g
    gf = g_out.astype(jnp.float32)
    of = out.astype(jnp.float32)
    w_a, w_b = _merge_weights(lse_a, lse_b, lse)
    d_out_a = (_broadcast_rows(w_a) * gf).astype(out_a.dtype)
    d_out_b = (_broadcast_rows(w_b) * gf).astype(out_b.dtype)
    # d lse_i = w_i * (sum_dh g_out * (out_i - out) + g_lse): the weight
    # path (out shifts toward out_i as lse_i grows) plus the merged-lse
    # path (d lse / d lse_i == w_i)
    da = jnp.swapaxes(
        (gf * (out_a.astype(jnp.float32) - of)).sum(-1), -1, -2)
    db = jnp.swapaxes(
        (gf * (out_b.astype(jnp.float32) - of)).sum(-1), -1, -2)
    d_lse_a = w_a * (da + g_lse)
    d_lse_b = w_b * (db + g_lse)
    return d_out_a, d_lse_a, d_out_b, d_lse_b


merge_softmax_partials.defvjp(
    lambda oa, la, ob, lb: _merge_fwd(oa, la, ob, lb), _merge_bwd)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(7, 8, 9, 10, 11, 12, 13))
def ca_partial_attention(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                         kv_pos, jmax=0, window=0, softcap=0.0,
                         scale=None, sink=0, rate=1, kernel="xla"):
    """One ring pass of a fused CA-task batch: attention over the pass's
    kv sub-range ``[kv_start, kv_start + kv_len)`` returning the
    finalized ``(out, lse)`` partial — both differentiable, so
    :func:`merge_softmax_partials` chains across passes with gradients
    intact.  ``kv_len == 0`` rows yield a dead partial (zero out,
    ``kernel.LSE_DEAD`` lse) that merges as a bitwise no-op.  ``kernel``
    picks the forward ("pallas" fused kernel / "xla" blockwise scan);
    backward always runs the blockwise recompute extended with the lse
    cotangent (``ds = p * (dp - delta + g_lse)``)."""
    return _partial_fwd_impl(q_tasks, k_buf, v_buf, kv_start, kv_len,
                             q_pos, kv_pos, jmax, window, softcap, scale,
                             sink, rate, kernel)


def _partial_fwd_impl(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                      kv_pos, jmax, window, softcap, scale, sink, rate,
                      kernel):
    if kernel == "pallas":
        return K.ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len,
                               q_pos, kv_pos, causal=True, window=window,
                               sink=sink, rate=rate, softcap=softcap,
                               scale=scale, jmax=jmax or None,
                               interpret=not _on_tpu(), return_lse=True)
    from repro.core import dispatch as D
    return D._xla_server_fwd_impl(q_tasks, k_buf, v_buf, kv_start, kv_len,
                                  q_pos, kv_pos,
                                  jmax or k_buf.shape[-4], softcap,
                                  window, scale, sink, rate)


def _ca_partial_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                    kv_pos, jmax, window, softcap, scale, sink, rate,
                    kernel):
    out, lse = _partial_fwd_impl(q_tasks, k_buf, v_buf, kv_start, kv_len,
                                 q_pos, kv_pos, jmax, window, softcap,
                                 scale, sink, rate, kernel)
    return (out, lse), (q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                        kv_pos, out, lse)


def _ca_partial_bwd(jmax, window, softcap, scale, sink, rate, kernel,
                    res, g):
    g_out, g_lse = g
    from repro.core import dispatch as D
    dq, dk, dv = D._xla_server_bwd_impl(
        res, g_out, g_lse, jmax=jmax or res[1].shape[-4], softcap=softcap,
        window=window, scale=scale, sink=sink, rate=rate)
    return dq, dk, dv, None, None, None, None


ca_partial_attention.defvjp(_ca_partial_fwd, _ca_partial_bwd)


# ---------------------------------------------------- ragged decode (serve)
def _resolve_decode(impl) -> str:
    """"pallas" | "xla"; None defers to $REPRO_KERNEL_DECODE (default
    pallas) — the serving mirror of ``_resolve_bwd``."""
    impl = impl or os.environ.get("REPRO_KERNEL_DECODE", "pallas")
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown kernel decode impl {impl!r}")
    return impl


def _xla_ragged_decode(q_blocks, k_cache, v_cache, block_req, kv_len, q_pos,
                       *, window=0, softcap=0.0, scale=None, blk_k=128):
    """Blockwise-jnp fallback for ``kernel.ragged_decode_fwd``: per q block
    gather that request's cache and run the same online-softmax recurrence
    in plain lax — memory O(S·blk) like the kernel, no [T, S] gather."""
    nq, blk_q, hq, dh = q_blocks.shape
    R, S, hkv, _ = k_cache.shape
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    blk_k = min(blk_k, S)
    assert S % blk_k == 0, "pad cache length to the kv block size"
    nk = S // blk_k

    outs = []
    for i in range(nq):        # static and small: T/128 (prefill) or B (decode)
        req = jnp.maximum(block_req[i], 0)
        qb = q_blocks[i].astype(jnp.float32)                  # [blk_q,hq,dh]
        pos = q_pos[i]
        kb = jnp.repeat(k_cache[req], rep, axis=1).astype(jnp.float32)
        vb = jnp.repeat(v_cache[req], rep, axis=1).astype(jnp.float32)
        kl = kv_len[req]

        def body(carry, j, qb=qb, pos=pos, kb=kb, vb=vb, kl=kl,
                 live_blk=block_req[i] >= 0):
            m_acc, l_acc, o_acc = carry
            k = jax.lax.dynamic_slice_in_dim(kb, j * blk_k, blk_k, 0)
            v = jax.lax.dynamic_slice_in_dim(vb, j * blk_k, blk_k, 0)
            s_pos = j * blk_k + jnp.arange(blk_k, dtype=jnp.int32)
            m = live_blk & (pos[:, None] >= 0) & (s_pos[None, :] < kl) \
                & (pos[:, None] >= s_pos[None, :])
            if window and window > 0:
                m &= (pos[:, None] - s_pos[None, :]) < window
            logits = jnp.einsum("qhd,khd->hqk", qb, k) * scale
            if softcap and softcap > 0:
                logits = jnp.tanh(logits / softcap) * softcap
            logits = jnp.where(m[None], logits, A.NEG_INF)
            m_new = jnp.maximum(m_acc, logits.max(axis=-1))
            p = jnp.where(m[None], jnp.exp(logits - m_new[..., None]), 0.0)
            corr = jnp.exp(m_acc - m_new)
            l_new = l_acc * corr + p.sum(axis=-1)
            contrib = (p[..., None] * v.transpose(1, 0, 2)[:, None]).sum(2)
            o_new = o_acc * corr[..., None] + contrib
            return (m_new, l_new, o_new), None

        carry0 = (jnp.full((hq, blk_q), A.NEG_INF, jnp.float32),
                  jnp.zeros((hq, blk_q), jnp.float32),
                  jnp.zeros((hq, blk_q, dh), jnp.float32))
        (m_acc, l_acc, o_acc), _ = jax.lax.scan(
            body, carry0, jnp.arange(nk, dtype=jnp.int32))
        live = m_acc > A.NEG_INF / 2
        out = o_acc / jnp.maximum(l_acc, 1e-30)[..., None]
        out = jnp.where(live[..., None], out, 0.0)
        outs.append(out.transpose(1, 0, 2).astype(q_blocks.dtype))
    return jnp.stack(outs)


def ragged_decode_attention(q, k_cache, v_cache, block_req, q_pos, kv_len,
                            *, window=0, softcap=0.0, scale=None,
                            impl=None):
    """Fused cache attention over a ragged request batch (DESIGN.md §8).

    The serving hot loop: every q block is request-pure and attends that
    request's cache prefix ``[0, kv_len)`` (slot index == position; the
    serving cache layout is non-ring), in one call for the whole batch —
    blk_q = 1 for decode steps, 128 for chunked prefill.

    q        [T, Hq, dh]  packed query tokens; T % len(block_req) == 0
    k_cache  [R, S, Hkv, dh]  (v_cache alike); S must be a 128 multiple
    block_req [nq] int32  request per q block (-1 = dead block)
    q_pos    [T] int32    absolute positions (-1 = padded row)
    kv_len   [R] int32    visibility bound per request

    Inference-only (no VJP).  ``impl`` mirrors the ``bwd_impl`` pattern:
    "pallas" runs ``kernel.ragged_decode_fwd`` (interpret off-TPU), "xla"
    the blockwise-jnp fallback; None defers to $REPRO_KERNEL_DECODE.
    """
    t, hq, dh = q.shape
    nq = block_req.shape[0]
    assert t % nq == 0, (t, nq)
    blk_q = t // nq
    qb = q.reshape(nq, blk_q, hq, dh)
    qp = q_pos.reshape(nq, blk_q)
    if _resolve_decode(impl) == "pallas":
        out = K.ragged_decode_fwd(qb, k_cache, v_cache, block_req, kv_len,
                                  qp, window=window, softcap=softcap,
                                  scale=scale, interpret=not _on_tpu())
    else:
        out = _xla_ragged_decode(qb, k_cache, v_cache, block_req, kv_len,
                                 qp, window=window, softcap=softcap,
                                 scale=scale)
    return out.reshape(t, hq, dh)
