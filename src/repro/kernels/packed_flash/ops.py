"""Jit'd wrappers for the packed-flash kernels with training-ready VJPs.

Forward runs the Pallas kernel (interpret=True on CPU, compiled on TPU)
and saves the flash residuals ``(out, lse)``.  Backward runs the
hand-written Pallas backward kernels (``kernel.flash_bwd`` /
``kernel.ca_server_bwd``) — recompute-free, rebuilding attention weights
from the saved log-sum-exp instead of re-deriving them via ``jax.vjp``
over a forward re-run.

The previous blockwise-jnp recompute backward is kept as an explicit
fallback: pass ``bwd_impl="xla"`` (or set ``REPRO_KERNEL_BWD=xla``) to
select it — e.g. on backends where even interpret-mode Pallas is
undesirable, or to A/B the two in ``benchmarks.kernel_throughput --bwd``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import attention as A
from repro.kernels.packed_flash import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve_bwd(bwd_impl) -> str:
    """"pallas" | "xla"; None defers to $REPRO_KERNEL_BWD (default pallas)."""
    impl = bwd_impl or os.environ.get("REPRO_KERNEL_BWD", "pallas")
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown kernel bwd impl {impl!r}")
    return impl


# ------------------------------------------------------------ packed flash
# ``sink``/``rate`` trail the original args (keeping positional callers
# valid): the unpacked static params of a non-causal MaskSpec
# (DESIGN.md §12) — sliding-sink tokens and dilated block stride.
@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11, 12, 13))
def packed_flash_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                           causal=True, window=0, softcap=0.0, scale=None,
                           bwd_impl=None, sink=0, rate=1):
    return K.flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal=causal,
                       window=window, sink=sink, rate=rate, softcap=softcap,
                       scale=scale, interpret=not _on_tpu())


def _pf_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal, window, softcap,
            scale, bwd_impl, sink, rate):
    out, lse = K.flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                           causal=causal, window=window, sink=sink,
                           rate=rate, softcap=softcap, scale=scale,
                           interpret=not _on_tpu(), return_lse=True)
    return out, (q, k, v, seg_q, pos_q, seg_kv, pos_kv, out, lse)


def _pf_bwd(causal, window, softcap, scale, bwd_impl, sink, rate, res, g):
    q, k, v, seg_q, pos_q, seg_kv, pos_kv, out, lse = res
    if _resolve_bwd(bwd_impl) == "pallas":
        dq, dk, dv = K.flash_bwd(q, k, v, out, lse, g, seg_q, pos_q,
                                 seg_kv, pos_kv, causal=causal,
                                 window=window, sink=sink, rate=rate,
                                 softcap=softcap, scale=scale,
                                 interpret=not _on_tpu())
        return dq, dk, dv, None, None, None, None
    f = lambda q_, k_, v_: A.xla_flash_attention(
        q_, k_, v_, seg_q, pos_q, seg_kv, pos_kv, causal=causal,
        window=window, sink=sink, rate=rate, softcap=softcap, scale=scale)
    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


packed_flash_attention.defvjp(_pf_fwd, _pf_bwd)


# -------------------------------------------------------------- CA server
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(7, 8, 9, 10, 11, 12, 13, 14))
def ca_server_attention(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                        kv_pos, causal=True, window=0, softcap=0.0,
                        scale=None, jmax=0, bwd_impl=None, sink=0, rate=1):
    """Fused CA-task batch on an attention server (paper §4.1).

    ``jmax`` bounds the kv blocks any task may touch (0 -> all of k_buf);
    the scheduler's plan guarantees every ``kv_len`` fits under it.
    ``sink``/``rate`` carry a non-causal MaskSpec (DESIGN.md §12)."""
    return K.ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                           kv_pos, causal=causal, window=window, sink=sink,
                           rate=rate, softcap=softcap, scale=scale,
                           jmax=jmax or None, interpret=not _on_tpu())


def _ca_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
            causal, window, softcap, scale, jmax, bwd_impl, sink, rate):
    out, lse = K.ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len,
                               q_pos, kv_pos, causal=causal, window=window,
                               sink=sink, rate=rate, softcap=softcap,
                               scale=scale, jmax=jmax or None,
                               interpret=not _on_tpu(), return_lse=True)
    return out, (q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
                 out, lse)


def _ca_bwd(causal, window, softcap, scale, jmax, bwd_impl, sink, rate,
            res, g):
    q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, out, lse = res
    if _resolve_bwd(bwd_impl) == "pallas":
        dq, dk, dv = K.ca_server_bwd(
            q_tasks, k_buf, v_buf, out, lse, g, kv_start, kv_len, q_pos,
            kv_pos, causal=causal, window=window, sink=sink, rate=rate,
            softcap=softcap, scale=scale, jmax=jmax or None,
            interpret=not _on_tpu())
        return dq, dk, dv, None, None, None, None
    if causal:
        # blockwise-jnp recompute fallback — the attention-server scan
        # path (dispatch._xla_server_bwd); mask params ride along
        from repro.core import dispatch as D
        f = lambda q_, k_, v_: D._xla_server(
            q_, k_, v_, kv_start, kv_len, q_pos, kv_pos,
            jmax or k_buf.shape[0], softcap, window, scale, sink, rate)
    else:
        from repro.core.mask import spec_from_params
        from repro.kernels.packed_flash import ref as R
        spec = spec_from_params(window, sink, rate)
        w = 0 if (spec is not None and spec.kind == "sliding") else window
        f = lambda q_, k_, v_: R.ref_ca_server_attention(
            q_, k_, v_, kv_start, kv_len, q_pos, kv_pos, causal=False,
            window=w, softcap=softcap, scale=scale, mask=spec)
    _, vjp = jax.vjp(f, q_tasks, k_buf, v_buf)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


ca_server_attention.defvjp(_ca_fwd, _ca_bwd)


# ---------------------------------------------------- ragged decode (serve)
def _resolve_decode(impl) -> str:
    """"pallas" | "xla"; None defers to $REPRO_KERNEL_DECODE (default
    pallas) — the serving mirror of ``_resolve_bwd``."""
    impl = impl or os.environ.get("REPRO_KERNEL_DECODE", "pallas")
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown kernel decode impl {impl!r}")
    return impl


def _xla_ragged_decode(q_blocks, k_cache, v_cache, block_req, kv_len, q_pos,
                       *, window=0, softcap=0.0, scale=None, blk_k=128):
    """Blockwise-jnp fallback for ``kernel.ragged_decode_fwd``: per q block
    gather that request's cache and run the same online-softmax recurrence
    in plain lax — memory O(S·blk) like the kernel, no [T, S] gather."""
    nq, blk_q, hq, dh = q_blocks.shape
    R, S, hkv, _ = k_cache.shape
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    blk_k = min(blk_k, S)
    assert S % blk_k == 0, "pad cache length to the kv block size"
    nk = S // blk_k

    outs = []
    for i in range(nq):        # static and small: T/128 (prefill) or B (decode)
        req = jnp.maximum(block_req[i], 0)
        qb = q_blocks[i].astype(jnp.float32)                  # [blk_q,hq,dh]
        pos = q_pos[i]
        kb = jnp.repeat(k_cache[req], rep, axis=1).astype(jnp.float32)
        vb = jnp.repeat(v_cache[req], rep, axis=1).astype(jnp.float32)
        kl = kv_len[req]

        def body(carry, j, qb=qb, pos=pos, kb=kb, vb=vb, kl=kl,
                 live_blk=block_req[i] >= 0):
            m_acc, l_acc, o_acc = carry
            k = jax.lax.dynamic_slice_in_dim(kb, j * blk_k, blk_k, 0)
            v = jax.lax.dynamic_slice_in_dim(vb, j * blk_k, blk_k, 0)
            s_pos = j * blk_k + jnp.arange(blk_k, dtype=jnp.int32)
            m = live_blk & (pos[:, None] >= 0) & (s_pos[None, :] < kl) \
                & (pos[:, None] >= s_pos[None, :])
            if window and window > 0:
                m &= (pos[:, None] - s_pos[None, :]) < window
            logits = jnp.einsum("qhd,khd->hqk", qb, k) * scale
            if softcap and softcap > 0:
                logits = jnp.tanh(logits / softcap) * softcap
            logits = jnp.where(m[None], logits, A.NEG_INF)
            m_new = jnp.maximum(m_acc, logits.max(axis=-1))
            p = jnp.where(m[None], jnp.exp(logits - m_new[..., None]), 0.0)
            corr = jnp.exp(m_acc - m_new)
            l_new = l_acc * corr + p.sum(axis=-1)
            contrib = (p[..., None] * v.transpose(1, 0, 2)[:, None]).sum(2)
            o_new = o_acc * corr[..., None] + contrib
            return (m_new, l_new, o_new), None

        carry0 = (jnp.full((hq, blk_q), A.NEG_INF, jnp.float32),
                  jnp.zeros((hq, blk_q), jnp.float32),
                  jnp.zeros((hq, blk_q, dh), jnp.float32))
        (m_acc, l_acc, o_acc), _ = jax.lax.scan(
            body, carry0, jnp.arange(nk, dtype=jnp.int32))
        live = m_acc > A.NEG_INF / 2
        out = o_acc / jnp.maximum(l_acc, 1e-30)[..., None]
        out = jnp.where(live[..., None], out, 0.0)
        outs.append(out.transpose(1, 0, 2).astype(q_blocks.dtype))
    return jnp.stack(outs)


def ragged_decode_attention(q, k_cache, v_cache, block_req, q_pos, kv_len,
                            *, window=0, softcap=0.0, scale=None,
                            impl=None):
    """Fused cache attention over a ragged request batch (DESIGN.md §8).

    The serving hot loop: every q block is request-pure and attends that
    request's cache prefix ``[0, kv_len)`` (slot index == position; the
    serving cache layout is non-ring), in one call for the whole batch —
    blk_q = 1 for decode steps, 128 for chunked prefill.

    q        [T, Hq, dh]  packed query tokens; T % len(block_req) == 0
    k_cache  [R, S, Hkv, dh]  (v_cache alike); S must be a 128 multiple
    block_req [nq] int32  request per q block (-1 = dead block)
    q_pos    [T] int32    absolute positions (-1 = padded row)
    kv_len   [R] int32    visibility bound per request

    Inference-only (no VJP).  ``impl`` mirrors the ``bwd_impl`` pattern:
    "pallas" runs ``kernel.ragged_decode_fwd`` (interpret off-TPU), "xla"
    the blockwise-jnp fallback; None defers to $REPRO_KERNEL_DECODE.
    """
    t, hq, dh = q.shape
    nq = block_req.shape[0]
    assert t % nq == 0, (t, nq)
    blk_q = t // nq
    qb = q.reshape(nq, blk_q, hq, dh)
    qp = q_pos.reshape(nq, blk_q)
    if _resolve_decode(impl) == "pallas":
        out = K.ragged_decode_fwd(qb, k_cache, v_cache, block_req, kv_len,
                                  qp, window=window, softcap=softcap,
                                  scale=scale, interpret=not _on_tpu())
    else:
        out = _xla_ragged_decode(qb, k_cache, v_cache, block_req, kv_len,
                                 qp, window=window, softcap=softcap,
                                 scale=scale)
    return out.reshape(t, hq, dh)
