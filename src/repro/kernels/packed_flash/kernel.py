"""Pallas TPU kernels for packed flash attention.

Two kernels:

1. ``flash_fwd`` — packed-document self-attention over a chunk.  Grid
   (B, Hq, nq, nk) with the kv dimension innermost/sequential; online
   softmax accumulators live in VMEM scratch.  Causal block pruning skips
   (i, j) pairs above the diagonal; window pruning skips pairs entirely
   outside the sliding window.  Blocks are 128-aligned to the MXU —
   exactly the tile constraint the paper leans on (FA2's 128-token tile,
   §3.3 Fig. 5).

2. ``ca_server_fwd`` — the attention-server kernel: a fused batch of
   CA-tasks (q-block, kv-prefix-range), where the kv range of each task is
   looked up through *scalar-prefetch* metadata (kv_start/kv_len), i.e.
   data-dependent BlockSpec index maps.  This is the TPU-native analogue
   of FA2 varlen batching that DistCA's attention servers rely on.

Both are validated in interpret mode against ref.py; on TPU they compile
with explicit VMEM BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


NEG_INF = -2.0 ** 30
DEFAULT_BLOCK = 128


def _mxu_dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


# ----------------------------------------------------------- packed flash
def _flash_kernel(seg_q_ref, pos_q_ref, seg_k_ref, pos_k_ref,
                  q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale, softcap, causal, window, blk_q, blk_k, nk):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level pruning (chunk-order positions; sound for packed docs)
    run = jnp.asarray(True)
    if causal:
        run = run & (j * blk_k < (i + 1) * blk_q)
    if window and window > 0:
        run = run & ((j + 1) * blk_k - 1 >= i * blk_q - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # [blk_q, dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [blk_k, dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        logits = _mxu_dot(q, k.T) * scale              # [blk_q, blk_k]
        if softcap and softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        sq = seg_q_ref[0, :]
        pq = pos_q_ref[0, :]
        sk = seg_k_ref[0, :]
        pk = pos_k_ref[0, :]
        m = (sq[:, None] == sk[None, :]) & (sq[:, None] > 0) \
            & (sk[None, :] > 0)
        if causal:
            m &= pq[:, None] >= pk[None, :]
        if window and window > 0:
            m &= (pq[:, None] - pk[None, :]) < window
        logits = jnp.where(m, logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(m, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] \
            + _mxu_dot(p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((m_scr[...] > NEG_INF / 2)[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, *, causal=True,
              window=0, softcap=0.0, scale=None,
              blk_q=DEFAULT_BLOCK, blk_k=DEFAULT_BLOCK, interpret=True):
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, skv)
    assert sq % blk_q == 0 and skv % blk_k == 0, "pad seq to block size"
    nq, nk = sq // blk_q, skv // blk_k

    grid = (b, hq, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, softcap=softcap, causal=causal,
        window=window, blk_q=blk_q, blk_k=blk_k, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q), lambda b_, h, i, j: (b_, i)),
            pl.BlockSpec((1, blk_q), lambda b_, h, i, j: (b_, i)),
            pl.BlockSpec((1, blk_k), lambda b_, h, i, j: (b_, j)),
            pl.BlockSpec((1, blk_k), lambda b_, h, i, j: (b_, j)),
            pl.BlockSpec((1, blk_q, 1, dh), lambda b_, h, i, j: (b_, i, h, 0)),
            pl.BlockSpec((1, blk_k, 1, dh),
                         lambda b_, h, i, j, r=rep: (b_, j, h // r, 0)),
            pl.BlockSpec((1, blk_k, 1, dh),
                         lambda b_, h, i, j, r=rep: (b_, j, h // r, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, dh),
                               lambda b_, h, i, j: (b_, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(seg_q, pos_q, seg_kv, pos_kv, q, k, v)


# ------------------------------------------------------- CA-server kernel
def _ca_server_kernel(kv_start_ref, kv_len_ref,       # scalar prefetch
                      q_pos_ref, kv_pos_ref, q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr, *,
                      scale, softcap, causal, window, jmax):
    t = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j < kv_len_ref[t])
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        logits = _mxu_dot(q, k.T) * scale
        if softcap and softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        pq = q_pos_ref[0, :]
        pk = kv_pos_ref[0, :]
        m = (pq[:, None] >= 0) & (pk[None, :] >= 0)
        if causal:
            m &= pq[:, None] >= pk[None, :]
        if window and window > 0:
            m &= (pq[:, None] - pk[None, :]) < window
        logits = jnp.where(m, logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(m, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] \
            + _mxu_dot(p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(j == jmax - 1)
    def _finalize():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((m_scr[...] > NEG_INF / 2)[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, *,
                  causal=True, window=0, softcap=0.0, scale=None,
                  jmax=None, interpret=True):
    """Fused CA-task batch (see ref.ref_ca_server_attention for semantics).

    q_tasks [T,blk,Hq,dh]; k_buf/v_buf [N,blk,Hkv,dh]; kv_start/kv_len [T];
    q_pos [T,blk]; kv_pos [N,blk].  ``jmax`` bounds the kv blocks any task
    may touch (defaults to N)."""
    T, blk, hq, dh = q_tasks.shape
    N, _, hkv, _ = k_buf.shape
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    jmax = jmax or N

    def kv_index(t, h, j, starts, lens, r=rep):
        blk_i = jnp.minimum(starts[t] + j, N - 1)
        return (blk_i, 0, h // r, 0)

    def kvpos_index(t, h, j, starts, lens):
        return (jnp.minimum(starts[t] + j, N - 1), 0)

    kernel = functools.partial(
        _ca_server_kernel, scale=scale, softcap=softcap, causal=causal,
        window=window, jmax=jmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, hq, jmax),
        in_specs=[
            pl.BlockSpec((1, blk), lambda t, h, j, st, ln: (t, 0)),
            pl.BlockSpec((1, blk), kvpos_index),
            pl.BlockSpec((1, blk, 1, dh), lambda t, h, j, st, ln: (t, 0, h, 0)),
            pl.BlockSpec((1, blk, 1, dh), kv_index),
            pl.BlockSpec((1, blk, 1, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk, 1, dh),
                               lambda t, h, j, st, ln: (t, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((blk,), jnp.float32),
            pltpu.VMEM((blk,), jnp.float32),
            pltpu.VMEM((blk, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, blk, hq, dh), q_tasks.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_start, kv_len, q_pos, kv_pos, q_tasks, k_buf, v_buf)
