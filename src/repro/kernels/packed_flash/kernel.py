"""Pallas TPU kernels for packed flash attention.

Forward kernels:

1. ``flash_fwd`` — packed-document self-attention over a chunk.  Grid
   (B, Hq, nq, nk) with the kv dimension innermost/sequential; online
   softmax accumulators live in VMEM scratch.  Causal block pruning skips
   (i, j) pairs above the diagonal; window pruning skips pairs entirely
   outside the sliding window.  Blocks are 128-aligned to the MXU —
   exactly the tile constraint the paper leans on (FA2's 128-token tile,
   §3.3 Fig. 5).  With ``return_lse`` the per-row log-sum-exp is written
   as a second output — the residual the backward kernels need.

2. ``ca_server_fwd`` — the attention-server kernel: a fused batch of
   CA-tasks (q-block, kv-prefix-range), where the kv range of each task is
   looked up through *scalar-prefetch* metadata (kv_start/kv_len), i.e.
   data-dependent BlockSpec index maps.  This is the TPU-native analogue
   of FA2 varlen batching that DistCA's attention servers rely on.

Backward kernels (flash-style, recompute-free: ``p`` is rebuilt from the
saved ``(out, lse)`` residuals instead of a second online-softmax pass):

3. ``flash_bwd`` — two grid passes.  dq iterates kv blocks innermost and
   accumulates one q-block's gradient in VMEM scratch; dk/dv iterates
   q blocks innermost and accumulates one kv-block's gradients.  Both
   reuse the forward's causal/window block pruning, so the backward
   touches exactly the forward's (i, j) pairs.

4. ``ca_server_bwd`` — the attention-server backward, honoring the same
   per-task ``kv_start``/``kv_len`` scalar-prefetch layout: dq walks each
   task's kv range; dk/dv inverts the mapping with a (kv-block, task)
   grid whose body is predicated on "task t's range covers block n", a
   scalar-prefetch condition — so servers run balanced bwd tasks in place
   (paper §4 ping-pong symmetry between fwd and bwd tasks).

GQA note: the dk/dv passes emit per-*query*-head gradients; the jnp
wrappers fold the repeat groups back onto kv heads.  That costs rep× the
final dk/dv footprint in f32 intermediates — accumulating the repeat
group in-kernel (q-heads folded into the sequential grid dim) is a
recorded §Perf follow-up; it changes memory, not semantics.

All kernels are validated in interpret mode against ref.py; on TPU they
compile with explicit VMEM BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


NEG_INF = -2.0 ** 30
DEFAULT_BLOCK = 128


def _mxu_dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


LSE_DEAD = 2.0 ** 30   # lse of a fully-masked row: exp(x - LSE_DEAD) == 0


def _capped_masked_logits(q, k, m, scale, softcap):
    """Scaled, softcapped, masked logits — shared by fwd and bwd bodies."""
    logits = _mxu_dot(q, k.T) * scale
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return jnp.where(m, logits, NEG_INF)


def _ds_from_p(p, dp, delta, logits, m, scale, softcap):
    """dL/d(q k^T): softmax bwd + softcap chain rule + scale."""
    ds = p * (dp - delta[:, None])
    if softcap and softcap > 0:
        sc = jnp.where(m, logits / softcap, 0.0)
        ds = ds * (1.0 - sc * sc)
    return ds * scale


# ----------------------------------------------------------- packed flash
def _flash_kernel(seg_q_ref, pos_q_ref, seg_k_ref, pos_k_ref,
                  q_ref, k_ref, v_ref, *rest,
                  scale, softcap, causal, window, sink, rate,
                  blk_q, blk_k, nk, save_lse=False):
    if save_lse:
        o_ref, lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        (o_ref, m_scr, l_scr, acc_scr), lse_ref = rest, None
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # mask-driven live-block pruning (chunk-order block indices; sound
    # for packed docs — see _flash_block_live)
    run = _flash_block_live(i, j, causal, window, sink, rate, blk_q, blk_k)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # [blk_q, dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [blk_k, dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        m = _flash_mask(seg_q_ref[0, :], pos_q_ref[0, :],
                        seg_k_ref[0, :], pos_k_ref[0, :], causal, window,
                        sink, rate, blk_q)
        logits = _capped_masked_logits(q, k, m, scale, softcap)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(m, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] \
            + _mxu_dot(p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        live = m_scr[...] > NEG_INF / 2
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where(live[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)
        if lse_ref is not None:
            lse = m_scr[...] + jnp.log(jnp.maximum(l, 1e-30))
            lse_ref[0, 0, :] = jnp.where(live, lse, LSE_DEAD)


def flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, *, causal=True,
              window=0, sink=0, rate=1, softcap=0.0, scale=None,
              blk_q=DEFAULT_BLOCK, blk_k=DEFAULT_BLOCK, interpret=True,
              return_lse=False):
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, skv)
    assert sq % blk_q == 0 and skv % blk_k == 0, "pad seq to block size"
    if rate > 1:
        assert blk_q == blk_k, "dilated masks need square block tiles"
    nq, nk = sq // blk_q, skv // blk_k

    grid = (b, hq, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, softcap=softcap, causal=causal,
        window=window, sink=sink, rate=rate, blk_q=blk_q, blk_k=blk_k,
        nk=nk, save_lse=return_lse)
    out_shape = jax.ShapeDtypeStruct((b, sq, hq, dh), q.dtype)
    out_specs = pl.BlockSpec((1, blk_q, 1, dh),
                             lambda b_, h, i, j: (b_, i, h, 0))
    if return_lse:
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((b, hq, sq), jnp.float32))
        out_specs = (out_specs,
                     pl.BlockSpec((1, 1, blk_q),
                                  lambda b_, h, i, j: (b_, h, i)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q), lambda b_, h, i, j: (b_, i)),
            pl.BlockSpec((1, blk_q), lambda b_, h, i, j: (b_, i)),
            pl.BlockSpec((1, blk_k), lambda b_, h, i, j: (b_, j)),
            pl.BlockSpec((1, blk_k), lambda b_, h, i, j: (b_, j)),
            pl.BlockSpec((1, blk_q, 1, dh), lambda b_, h, i, j: (b_, i, h, 0)),
            pl.BlockSpec((1, blk_k, 1, dh),
                         lambda b_, h, i, j, r=rep: (b_, j, h // r, 0)),
            pl.BlockSpec((1, blk_k, 1, dh),
                         lambda b_, h, i, j, r=rep: (b_, j, h // r, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(seg_q, pos_q, seg_kv, pos_kv, q, k, v)


# ---------------------------------------------------- packed flash bwd
def _flash_mask(sq, pq, sk, pk, causal, window, sink=0, rate=1, mblk=0):
    """Token-level mask: segments + causal + MaskSpec terms (DESIGN.md §12).

    ``window``/``sink`` are the sliding family's parameters (sink tokens
    are the always-visible document head); ``rate``/``mblk`` the dilated
    family's block stride at granularity ``mblk``.  Positions are
    in-document, so sink and dilation are exact per document."""
    m = (sq[:, None] == sk[None, :]) & (sq[:, None] > 0) & (sk[None, :] > 0)
    if causal:
        m &= pq[:, None] >= pk[None, :]
    if window and window > 0:
        w = (pq[:, None] - pk[None, :]) < window
        if sink and sink > 0:
            w |= pk[None, :] < sink
        m &= w
    if rate and rate > 1:
        m &= ((pq[:, None] // mblk) - (pk[None, :] // mblk)) % rate == 0
    return m


def _flash_block_live(i, j, causal, window, sink, rate, blk_q, blk_k):
    """Block-pruning predicate on chunk-order block indices.

    Sound for packed layouts (documents are block-aligned and contiguous,
    so the document offset cancels in ``i - j``).  When ``sink > 0`` the
    window prune is disabled — sink tokens live at in-document positions
    the global indices can't see — and the token mask alone enforces the
    window; causal pruning still bounds the work."""
    run = jnp.asarray(True)
    if causal:
        run = run & (j * blk_k < (i + 1) * blk_q)
    if window and window > 0 and not sink:
        run = run & ((j + 1) * blk_k - 1 >= i * blk_q - window)
    if rate and rate > 1:
        run = run & ((i - j) % rate == 0)
    return run


def _flash_bwd_dq_kernel(seg_q_ref, pos_q_ref, seg_k_ref, pos_k_ref,
                         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_scr, *, scale, softcap, causal,
                         window, sink, rate, blk_q, blk_k, nk):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    run = _flash_block_live(i, j, causal, window, sink, rate, blk_q, blk_k)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        m = _flash_mask(seg_q_ref[0, :], pos_q_ref[0, :],
                        seg_k_ref[0, :], pos_k_ref[0, :], causal, window,
                        sink, rate, blk_q)
        logits = _capped_masked_logits(q, k, m, scale, softcap)
        lse = lse_ref[0, 0, :]
        p = jnp.where(m, jnp.exp(logits - lse[:, None]), 0.0)
        dp = _mxu_dot(do, v.T)
        ds = _ds_from_p(p, dp, delta_ref[0, 0, :], logits, m, scale,
                        softcap)
        dq_scr[...] += _mxu_dot(ds, k)

    @pl.when(j == nk - 1)
    def _finalize():
        dq_ref[0, :, 0, :] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(seg_q_ref, pos_q_ref, seg_k_ref, pos_k_ref,
                          q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr, *,
                          scale, softcap, causal, window, sink, rate,
                          blk_q, blk_k, nq):
    j = pl.program_id(2)
    i = pl.program_id(3)

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = _flash_block_live(i, j, causal, window, sink, rate, blk_q, blk_k)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        m = _flash_mask(seg_q_ref[0, :], pos_q_ref[0, :],
                        seg_k_ref[0, :], pos_k_ref[0, :], causal, window,
                        sink, rate, blk_q)
        logits = _capped_masked_logits(q, k, m, scale, softcap)
        lse = lse_ref[0, 0, :]
        p = jnp.where(m, jnp.exp(logits - lse[:, None]), 0.0)
        dv_scr[...] += _mxu_dot(p.T, do)
        dp = _mxu_dot(do, v.T)
        ds = _ds_from_p(p, dp, delta_ref[0, 0, :], logits, m, scale,
                        softcap)
        dk_scr[...] += _mxu_dot(ds.T, q)

    @pl.when(i == nq - 1)
    def _finalize():
        dk_ref[0, :, 0, :] = dk_scr[...]
        dv_ref[0, :, 0, :] = dv_scr[...]


def flash_bwd(q, k, v, out, lse, do, seg_q, pos_q, seg_kv, pos_kv, *,
              causal=True, window=0, sink=0, rate=1, softcap=0.0,
              scale=None, blk_q=DEFAULT_BLOCK, blk_k=DEFAULT_BLOCK,
              interpret=True):
    """Hand-written backward for ``flash_fwd`` from saved (out, lse).

    Two passes over the same pruned (i, j) block pairs as the forward:
    a dq pass (kv innermost) and a dk/dv pass (q innermost).  Per-q-head
    dk/dv are folded back onto kv heads here (GQA)."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, skv)
    assert sq % blk_q == 0 and skv % blk_k == 0, "pad seq to block size"
    if rate > 1:
        assert blk_q == blk_k, "dilated masks need square block tiles"
    nq, nk = sq // blk_q, skv // blk_k

    # delta_i = rowsum(do * out) — linear precompute shared by both passes
    delta = jnp.einsum("bqhd,bqhd->bhq", do.astype(jnp.float32),
                       out.astype(jnp.float32))

    seg_spec_q = pl.BlockSpec((1, blk_q), lambda b_, h, i, j: (b_, i))
    seg_spec_k = pl.BlockSpec((1, blk_k), lambda b_, h, i, j: (b_, j))
    q_spec = pl.BlockSpec((1, blk_q, 1, dh),
                          lambda b_, h, i, j: (b_, i, h, 0))
    kv_spec = pl.BlockSpec((1, blk_k, 1, dh),
                           lambda b_, h, i, j, r=rep: (b_, j, h // r, 0))
    row_spec = pl.BlockSpec((1, 1, blk_q), lambda b_, h, i, j: (b_, h, i))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, scale=scale,
                          softcap=softcap, causal=causal, window=window,
                          sink=sink, rate=rate, blk_q=blk_q, blk_k=blk_k,
                          nk=nk),
        grid=(b, hq, nq, nk),
        in_specs=[seg_spec_q, seg_spec_q, seg_spec_k, seg_spec_k,
                  q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((blk_q, dh), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(seg_q, pos_q, seg_kv, pos_kv, q, k, v, do, lse, delta)

    # dk/dv pass: grid transposed, q-block dim innermost/sequential; the
    # index maps see grid ids (b, h, j, i)
    seg_spec_qT = pl.BlockSpec((1, blk_q), lambda b_, h, j, i: (b_, i))
    seg_spec_kT = pl.BlockSpec((1, blk_k), lambda b_, h, j, i: (b_, j))
    q_specT = pl.BlockSpec((1, blk_q, 1, dh),
                           lambda b_, h, j, i: (b_, i, h, 0))
    kv_specT = pl.BlockSpec((1, blk_k, 1, dh),
                            lambda b_, h, j, i, r=rep: (b_, j, h // r, 0))
    kv_out_specT = pl.BlockSpec((1, blk_k, 1, dh),
                                lambda b_, h, j, i: (b_, j, h, 0))
    row_specT = pl.BlockSpec((1, 1, blk_q), lambda b_, h, j, i: (b_, h, i))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, scale=scale,
                          softcap=softcap, causal=causal, window=window,
                          sink=sink, rate=rate, blk_q=blk_q, blk_k=blk_k,
                          nq=nq),
        grid=(b, hq, nk, nq),
        in_specs=[seg_spec_qT, seg_spec_qT, seg_spec_kT, seg_spec_kT,
                  q_specT, kv_specT, kv_specT, q_specT, row_specT,
                  row_specT],
        out_specs=(kv_out_specT, kv_out_specT),
        out_shape=(jax.ShapeDtypeStruct((b, skv, hq, dh), jnp.float32),
                   jax.ShapeDtypeStruct((b, skv, hq, dh), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((blk_k, dh), jnp.float32),
                        pltpu.VMEM((blk_k, dh), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(seg_q, pos_q, seg_kv, pos_kv, q, k, v, do, lse, delta)
    dk = dk_h.reshape(b, skv, hkv, rep, dh).sum(3).astype(k.dtype)
    dv = dv_h.reshape(b, skv, hkv, rep, dh).sum(3).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------- ragged decode (serve)
def _ragged_decode_kernel(block_req_ref, kv_len_ref, qmin_ref,  # prefetch
                          q_pos_ref, q_ref, k_ref, v_ref,
                          o_ref, m_scr, l_scr, acc_scr, *,
                          scale, softcap, window, blk_q, blk_k, nk):
    """One (q-block, kv-block) step of the serving attention (DESIGN.md §8).

    Each q block belongs to exactly one request (``block_req``); its kv
    context is that request's cache rows ``[0, kv_len)`` where slot index
    == absolute position.  Online-softmax accumulators in VMEM scratch,
    kv blocks innermost/sequential — the decode/prefill analogue of
    ``_ca_server_kernel`` with the kv range looked up per request instead
    of per task."""
    i = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    req = block_req_ref[i]
    live = req >= 0
    kv_len = kv_len_ref[jnp.maximum(req, 0)]
    run = live & (j * blk_k < kv_len)
    if window and window > 0:
        # block j's last slot must be inside the oldest live row's window
        run = run & ((j + 1) * blk_k - 1 >= qmin_ref[i] - (window - 1))

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # [blk_q, dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # [blk_k, dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        pos = q_pos_ref[0, :]
        s_pos = j * blk_k + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 1)
        m = (pos[:, None] >= 0) & (s_pos < kv_len) \
            & (pos[:, None] >= s_pos)
        if window and window > 0:
            m &= (pos[:, None] - s_pos) < window
        logits = _capped_masked_logits(q, k, m, scale, softcap)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(m, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] \
            + _mxu_dot(p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        alive = m_scr[...] > NEG_INF / 2
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where(alive[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def ragged_decode_fwd(q_blocks, k_cache, v_cache, block_req, kv_len, q_pos,
                      *, window=0, softcap=0.0, scale=None,
                      blk_k=DEFAULT_BLOCK, interpret=True):
    """Fused ragged-batch cache attention (serving hot loop, DESIGN.md §8).

    q_blocks [nq, blk_q, Hq, dh]   request-pure query blocks (blk_q = 1 for
                                   decode, 128 for chunked prefill)
    k_cache/v_cache [R, S, Hkv, dh] per-request cache, slot index == position
    block_req [nq] int32           request of each q block (-1 = dead block)
    kv_len   [R] int32             live slots per request (visibility bound)
    q_pos    [nq, blk_q] int32     absolute positions (-1 = padded row)

    ``block_req``/``kv_len`` and the per-block min position ride the
    scalar-prefetch channel so the kv BlockSpec index map and the
    per-request block pruning (kv_len upper bound + window lower bound)
    are data-dependent, exactly like ``ca_server_fwd``'s task ranges."""
    nq, blk_q, hq, dh = q_blocks.shape
    R, S, hkv, _ = k_cache.shape
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    assert S % blk_k == 0, "pad cache length to the kv block size"
    nk = S // blk_k

    qmin = jnp.min(jnp.where(q_pos >= 0, q_pos, jnp.int32(2 ** 31 - 1)),
                   axis=1).astype(jnp.int32)

    def kv_index(i, h, j, br, kl, qm, r=rep):
        return (jnp.maximum(br[i], 0), j, h // r, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nq, hq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q), lambda i, h, j, br, kl, qm: (i, 0)),
            pl.BlockSpec((1, blk_q, 1, dh),
                         lambda i, h, j, br, kl, qm: (i, 0, h, 0)),
            pl.BlockSpec((1, blk_k, 1, dh), kv_index),
            pl.BlockSpec((1, blk_k, 1, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, dh),
                               lambda i, h, j, br, kl, qm: (i, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_decode_kernel, scale=scale,
                          softcap=softcap, window=window, blk_q=blk_q,
                          blk_k=blk_k, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nq, blk_q, hq, dh), q_blocks.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_req.astype(jnp.int32), kv_len.astype(jnp.int32), qmin,
      q_pos, q_blocks, k_cache, v_cache)


# ------------------------------------------------------- CA-server kernel
def _ca_mask(pq, pk, causal, window, sink=0, rate=1, mblk=0):
    """Token-level CA-task mask on in-document positions.

    The scheduler guarantees each task's kv range is its own document's
    prefix, so segments are unneeded; sink/dilated terms (DESIGN.md §12)
    work directly on the in-document positions."""
    m = (pq[:, None] >= 0) & (pk[None, :] >= 0)
    if causal:
        m &= pq[:, None] >= pk[None, :]
    if window and window > 0:
        w = (pq[:, None] - pk[None, :]) < window
        if sink and sink > 0:
            w |= pk[None, :] < sink
        m &= w
    if rate and rate > 1:
        m &= ((pq[:, None] // mblk) - (pk[None, :] // mblk)) % rate == 0
    return m


def _ca_live_mask(q_pos_ref, kv_pos_ref, causal, window, sink, rate, blk):
    """(mask, any_live) for the current (task, kv-block) pair, or
    ``(None, None)`` for the trivial dense-causal case.

    The mask-driven live-block predicate is computed from the *actual*
    position vectors (already resident for this grid cell), so it is
    exact for any caller — no reliance on the plan's prefix invariant —
    and skipping a dead block is a bit-exact no-op (its token mask is
    all-False, so the online-softmax carry would pass through
    unchanged).  ``mask.live_block_mask`` prices a conservative superset
    of these blocks (DESIGN.md §12)."""
    if not (window or sink or rate > 1):
        return None, None
    m = _ca_mask(q_pos_ref[0, :], kv_pos_ref[0, :], causal, window,
                 sink, rate, blk)
    return m, jnp.any(m)


def _ca_server_kernel(kv_start_ref, kv_len_ref,       # scalar prefetch
                      q_pos_ref, kv_pos_ref, q_ref, k_ref, v_ref, *rest,
                      scale, softcap, causal, window, sink, rate, blk,
                      jmax, save_lse=False):
    if save_lse:
        o_ref, lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        (o_ref, m_scr, l_scr, acc_scr), lse_ref = rest, None
    t = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    mask, any_live = _ca_live_mask(q_pos_ref, kv_pos_ref, causal, window,
                                   sink, rate, blk)
    live = j < kv_len_ref[t]
    if mask is not None:
        live &= any_live

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        m = mask if mask is not None else _ca_mask(
            q_pos_ref[0, :], kv_pos_ref[0, :], causal, window, sink,
            rate, blk)
        logits = _capped_masked_logits(q, k, m, scale, softcap)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(m, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] \
            + _mxu_dot(p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(j == jmax - 1)
    def _finalize():
        l = l_scr[...]
        live = m_scr[...] > NEG_INF / 2
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where(live[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)
        if lse_ref is not None:
            lse = m_scr[...] + jnp.log(jnp.maximum(l, 1e-30))
            lse_ref[0, 0, :] = jnp.where(live, lse, LSE_DEAD)


def ca_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, *,
                  causal=True, window=0, sink=0, rate=1, softcap=0.0,
                  scale=None, jmax=None, interpret=True, return_lse=False):
    """Fused CA-task batch (see ref.ref_ca_server_attention for semantics).

    q_tasks [T,blk,Hq,dh]; k_buf/v_buf [N,blk,Hkv,dh]; kv_start/kv_len [T];
    q_pos [T,blk]; kv_pos [N,blk].  ``jmax`` bounds the kv blocks any task
    may touch (defaults to N).  window/sink/rate are the MaskSpec terms
    (DESIGN.md §12); kv blocks of a task's prefix that the mask leaves
    fully dead are skipped via ``_ca_live_mask``'s exact predicate."""
    T, blk, hq, dh = q_tasks.shape
    N, _, hkv, _ = k_buf.shape
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    jmax = jmax or N

    def kv_index(t, h, j, starts, lens, r=rep):
        blk_i = jnp.minimum(starts[t] + j, N - 1)
        return (blk_i, 0, h // r, 0)

    def kvpos_index(t, h, j, starts, lens):
        return (jnp.minimum(starts[t] + j, N - 1), 0)

    kernel = functools.partial(
        _ca_server_kernel, scale=scale, softcap=softcap, causal=causal,
        window=window, sink=sink, rate=rate, blk=blk, jmax=jmax,
        save_lse=return_lse)
    out_shape = jax.ShapeDtypeStruct((T, blk, hq, dh), q_tasks.dtype)
    out_specs = pl.BlockSpec((1, blk, 1, dh),
                             lambda t, h, j, st, ln: (t, 0, h, 0))
    if return_lse:
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((T, hq, blk), jnp.float32))
        out_specs = (out_specs,
                     pl.BlockSpec((1, 1, blk),
                                  lambda t, h, j, st, ln: (t, h, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, hq, jmax),
        in_specs=[
            pl.BlockSpec((1, blk), lambda t, h, j, st, ln: (t, 0)),
            pl.BlockSpec((1, blk), kvpos_index),
            pl.BlockSpec((1, blk, 1, dh), lambda t, h, j, st, ln: (t, 0, h, 0)),
            pl.BlockSpec((1, blk, 1, dh), kv_index),
            pl.BlockSpec((1, blk, 1, dh), kv_index),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((blk,), jnp.float32),
            pltpu.VMEM((blk,), jnp.float32),
            pltpu.VMEM((blk, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_start, kv_len, q_pos, kv_pos, q_tasks, k_buf, v_buf)


# --------------------------------------------------- CA-server backward
def _ca_bwd_dq_kernel(kv_start_ref, kv_len_ref,       # scalar prefetch
                      q_pos_ref, kv_pos_ref, q_ref, k_ref, v_ref, do_ref,
                      lse_ref, delta_ref, dq_ref, dq_scr, *,
                      scale, softcap, causal, window, sink, rate, blk,
                      jmax):
    t = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    mask, any_live = _ca_live_mask(q_pos_ref, kv_pos_ref, causal, window,
                                   sink, rate, blk)
    live = j < kv_len_ref[t]
    if mask is not None:
        live &= any_live

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        m = mask if mask is not None else _ca_mask(
            q_pos_ref[0, :], kv_pos_ref[0, :], causal, window, sink,
            rate, blk)
        logits = _capped_masked_logits(q, k, m, scale, softcap)
        lse = lse_ref[0, 0, :]
        p = jnp.where(m, jnp.exp(logits - lse[:, None]), 0.0)
        dp = _mxu_dot(do, v.T)
        ds = _ds_from_p(p, dp, delta_ref[0, 0, :], logits, m, scale,
                        softcap)
        dq_scr[...] += _mxu_dot(ds, k)

    @pl.when(j == jmax - 1)
    def _finalize():
        dq_ref[0, :, 0, :] = dq_scr[...].astype(dq_ref.dtype)


def _ca_bwd_dkv_kernel(kv_start_ref, kv_len_ref,      # scalar prefetch
                       q_pos_ref, kv_pos_ref, q_ref, k_ref, v_ref, do_ref,
                       lse_ref, delta_ref, dk_ref, dv_ref,
                       dk_scr, dv_scr, *,
                       scale, softcap, causal, window, sink, rate, blk,
                       n_tasks):
    n = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    # task t touches kv block n iff its prefix range covers it AND the
    # mask keeps any (q, kv) pair of the block live — untouched
    # (block, task) pairs skip the whole body (the bwd analogue of the
    # fwd's mask-driven live-block iteration)
    jrel = n - kv_start_ref[t]
    covers = (jrel >= 0) & (jrel < kv_len_ref[t])
    mask, any_live = _ca_live_mask(q_pos_ref, kv_pos_ref, causal, window,
                                   sink, rate, blk)
    if mask is not None:
        covers &= any_live

    @pl.when(covers)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        m = mask if mask is not None else _ca_mask(
            q_pos_ref[0, :], kv_pos_ref[0, :], causal, window, sink,
            rate, blk)
        logits = _capped_masked_logits(q, k, m, scale, softcap)
        lse = lse_ref[0, 0, :]
        p = jnp.where(m, jnp.exp(logits - lse[:, None]), 0.0)
        dv_scr[...] += _mxu_dot(p.T, do)
        dp = _mxu_dot(do, v.T)
        ds = _ds_from_p(p, dp, delta_ref[0, 0, :], logits, m, scale,
                        softcap)
        dk_scr[...] += _mxu_dot(ds.T, q)

    @pl.when(t == n_tasks - 1)
    def _finalize():
        dk_ref[0, :, 0, :] = dk_scr[...]
        dv_ref[0, :, 0, :] = dv_scr[...]


def ca_server_bwd(q_tasks, k_buf, v_buf, out, lse, do, kv_start, kv_len,
                  q_pos, kv_pos, *, causal=True, window=0, sink=0,
                  rate=1, softcap=0.0, scale=None, jmax=None,
                  interpret=True):
    """Hand-written backward for ``ca_server_fwd`` from saved (out, lse).

    dq walks each task's kv prefix range exactly like the forward (same
    scalar-prefetch index maps).  dk/dv inverts the task→kv-range mapping
    with an (kv-block, head, task) grid predicated on range coverage, so
    every kv block accumulates only the tasks whose prefix contains it."""
    T, blk, hq, dh = q_tasks.shape
    N, _, hkv, _ = k_buf.shape
    rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    jmax = jmax or N

    delta = jnp.einsum("tqhd,tqhd->thq", do.astype(jnp.float32),
                       out.astype(jnp.float32))

    def kv_index(t, h, j, starts, lens, r=rep):
        return (jnp.minimum(starts[t] + j, N - 1), 0, h // r, 0)

    def kvpos_index(t, h, j, starts, lens):
        return (jnp.minimum(starts[t] + j, N - 1), 0)

    dq_grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, hq, jmax),
        in_specs=[
            pl.BlockSpec((1, blk), lambda t, h, j, st, ln: (t, 0)),
            pl.BlockSpec((1, blk), kvpos_index),
            pl.BlockSpec((1, blk, 1, dh),
                         lambda t, h, j, st, ln: (t, 0, h, 0)),
            pl.BlockSpec((1, blk, 1, dh), kv_index),
            pl.BlockSpec((1, blk, 1, dh), kv_index),
            pl.BlockSpec((1, blk, 1, dh),
                         lambda t, h, j, st, ln: (t, 0, h, 0)),
            pl.BlockSpec((1, 1, blk), lambda t, h, j, st, ln: (t, h, 0)),
            pl.BlockSpec((1, 1, blk), lambda t, h, j, st, ln: (t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk, 1, dh),
                               lambda t, h, j, st, ln: (t, 0, h, 0)),
        scratch_shapes=[pltpu.VMEM((blk, dh), jnp.float32)],
    )
    dq = pl.pallas_call(
        functools.partial(_ca_bwd_dq_kernel, scale=scale, softcap=softcap,
                          causal=causal, window=window, sink=sink,
                          rate=rate, blk=blk, jmax=jmax),
        grid_spec=dq_grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, blk, hq, dh), q_tasks.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_start, kv_len, q_pos, kv_pos, q_tasks, k_buf, v_buf, do, lse,
      delta)

    dkv_grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N, hq, T),
        in_specs=[
            pl.BlockSpec((1, blk), lambda n, h, t, st, ln: (t, 0)),
            pl.BlockSpec((1, blk), lambda n, h, t, st, ln: (n, 0)),
            pl.BlockSpec((1, blk, 1, dh),
                         lambda n, h, t, st, ln: (t, 0, h, 0)),
            pl.BlockSpec((1, blk, 1, dh),
                         lambda n, h, t, st, ln, r=rep: (n, 0, h // r, 0)),
            pl.BlockSpec((1, blk, 1, dh),
                         lambda n, h, t, st, ln, r=rep: (n, 0, h // r, 0)),
            pl.BlockSpec((1, blk, 1, dh),
                         lambda n, h, t, st, ln: (t, 0, h, 0)),
            pl.BlockSpec((1, 1, blk), lambda n, h, t, st, ln: (t, h, 0)),
            pl.BlockSpec((1, 1, blk), lambda n, h, t, st, ln: (t, h, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, blk, 1, dh),
                         lambda n, h, t, st, ln: (n, 0, h, 0)),
            pl.BlockSpec((1, blk, 1, dh),
                         lambda n, h, t, st, ln: (n, 0, h, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((blk, dh), jnp.float32),
                        pltpu.VMEM((blk, dh), jnp.float32)],
    )
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_ca_bwd_dkv_kernel, scale=scale, softcap=softcap,
                          causal=causal, window=window, sink=sink,
                          rate=rate, blk=blk, n_tasks=T),
        grid_spec=dkv_grid_spec,
        out_shape=(jax.ShapeDtypeStruct((N, blk, hq, dh), jnp.float32),
                   jax.ShapeDtypeStruct((N, blk, hq, dh), jnp.float32)),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_start, kv_len, q_pos, kv_pos, q_tasks, k_buf, v_buf, do, lse,
      delta)
    dk = dk_h.reshape(N, blk, hkv, rep, dh).sum(3).astype(k_buf.dtype)
    dv = dv_h.reshape(N, blk, hkv, rep, dh).sum(3).astype(v_buf.dtype)
    return dq, dk, dv
