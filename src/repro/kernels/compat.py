"""jax-version compat for Pallas TPU kernels.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer jax; alias whichever exists so the kernels build on both."""
from jax.experimental.pallas import tpu as _pltpu

try:
    CompilerParams = _pltpu.CompilerParams
except AttributeError:        # pre-rename jax; raises clearly if neither
    CompilerParams = _pltpu.TPUCompilerParams
