"""Pallas TPU kernels for the perf-critical compute layers.

packed_flash/  packed varlen flash attention + the CA-server fused
               CA-task kernel (the paper's attention-server hot loop)
rglru/         RG-LRU linear recurrence (recurrentgemma)
ssd/           Mamba-2 SSD intra-chunk quadratic compute

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with a training-ready VJP), and ref.py (pure-jnp oracle); tests
sweep shapes/dtypes in interpret mode against the oracles.
"""
