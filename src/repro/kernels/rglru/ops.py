"""Jit'd wrapper for the RG-LRU scan with a linear-recurrence backward.

VJP of h_t = a_t h_{t-1} + b_t:
  db_t = g_t + a_{t+1} * db_{t+1}   (reverse recurrence, same kernel on
                                     reversed/shifted inputs)
  da_t = db_t * h_{t-1}
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru import kernel as K


def _on_tpu():
    return jax.default_backend() == "tpu"


@jax.custom_vjp
def lru_scan(a, b):
    return K.lru_scan(a, b, interpret=not _on_tpu())


def _fwd(a, b):
    h = lru_scan(a, b)
    return h, (a, h)


def _bwd(res, g):
    a, h = res
    # reverse-time recurrence: db_t = g_t + a_{t+1} db_{t+1}
    a_next = jnp.concatenate(
        [a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    a_rev = jnp.flip(a_next, axis=1)
    g_rev = jnp.flip(g, axis=1)
    db = jnp.flip(lru_scan(a_rev.astype(g.dtype), g_rev), axis=1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    da = (db.astype(jnp.float32) * h_prev.astype(jnp.float32)) \
        .astype(a.dtype)
    return da, db


lru_scan.defvjp(_fwd, _bwd)
