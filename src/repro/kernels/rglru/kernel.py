"""Pallas TPU kernel for the RG-LRU linear recurrence (Griffin /
RecurrentGemma's temporal-mixing core).

h_t = a_t * h_{t-1} + b_t over the sequence axis, per (batch, channel).

Grid (B, W/tile_w, S/tile_s) with the sequence dimension innermost and
sequential; the running state h lives in VMEM scratch across sequence
tiles.  Within a tile the recurrence is computed with a first-order scan
expressed as a log-depth prefix composition over rows (the recurrence is
associative: (a1,b1)∘(a2,b2) = (a1·a2, b1·a2 + b2)), which keeps the VPU
busy on [tile_s, tile_w] blocks instead of serializing row by row.

Channel tiles are 128-lane aligned; sequence tiles default to 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams



def _lru_kernel(a_ref, b_ref, h_ref, carry, *, tile_s):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        carry[...] = jnp.zeros_like(carry)

    a = a_ref[0].astype(jnp.float32)          # [tile_s, tile_w]
    b = b_ref[0].astype(jnp.float32)

    # log-depth prefix composition over the tile's rows:
    # after the loop, a[t] = prod_{u<=t} a_u ; b[t] = h_t given h_{-1}=0
    k = 1
    while k < tile_s:
        a_sh = jnp.concatenate(
            [jnp.ones((k, a.shape[1]), jnp.float32), a[:-k]], axis=0)
        b_sh = jnp.concatenate(
            [jnp.zeros((k, b.shape[1]), jnp.float32), b[:-k]], axis=0)
        b = b + a * b_sh
        a = a * a_sh
        k *= 2

    h_prev = carry[...]
    h = b + a * h_prev[None, :]
    h_ref[0] = h.astype(h_ref.dtype)
    carry[...] = h[-1]


def lru_scan(a, b, *, tile_s: int = 256, tile_w: int = 128,
             interpret: bool = True):
    """a, b [B, S, W] -> h [B, S, W]."""
    bsz, s, w = a.shape
    tile_s = min(tile_s, s)
    tile_w = min(tile_w, w)
    assert s % tile_s == 0 and w % tile_w == 0, (s, w, tile_s, tile_w)
    grid = (bsz, w // tile_w, s // tile_s)
    kernel = functools.partial(_lru_kernel, tile_s=tile_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_s, tile_w),
                         lambda bb, wi, si: (bb, si, wi)),
            pl.BlockSpec((1, tile_s, tile_w),
                         lambda bb, wi, si: (bb, si, wi)),
        ],
        out_specs=pl.BlockSpec((1, tile_s, tile_w),
                               lambda bb, wi, si: (bb, si, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), b.dtype),
        scratch_shapes=[pltpu.VMEM((tile_w,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
