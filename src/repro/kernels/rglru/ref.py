"""Oracle for the RG-LRU linear-recurrence kernel:
h_t = a_t * h_{t-1} + b_t, h_{-1} = 0 (resets are folded into a=0)."""
import jax
import jax.numpy as jnp


def ref_lru_scan(a, b):
    """a, b [B, S, W] -> h [B, S, W] (f32 accumulation)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    return h.astype(b.dtype)


def ref_lru_scan_sequential(a, b):
    """Literal sequential recurrence (slow; used to validate the oracle)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    h0 = jnp.zeros(a_t.shape[1:], jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1).astype(b.dtype)
