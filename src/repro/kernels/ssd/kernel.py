"""Pallas TPU kernel for the SSD intra-chunk computation (Mamba-2).

The quadratic ('dual attention') part of the chunked SSD algorithm: per
(batch, chunk, head) tile, build the decay-masked score matrix on the
MXU, produce the intra-chunk outputs and the chunk's end-state
contribution. The O(S·N·P) inter-chunk recurrence stays in lax.scan
outside (it is tiny: one [N,P] GEMM per chunk).

Grid (B, K, H); blocks sized [chunk, N] / [chunk, P] live in VMEM —
chunk=256, N=128, P=64 uses ~0.4 MB/operand, MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams



def _ssd_chunk_kernel(C_ref, B_ref, x_ref, dt_ref, csum_ref, nr_ref,
                      y_ref, state_ref, *, chunk):
    C_ = C_ref[0, 0].astype(jnp.float32)           # [c, N]
    B_ = B_ref[0, 0].astype(jnp.float32)
    x = x_ref[0, 0].astype(jnp.float32)            # [c, P]
    dt = dt_ref[0, 0].astype(jnp.float32)          # [c]
    csum = csum_ref[0, 0].astype(jnp.float32)
    nr = nr_ref[0, 0]

    li = csum[:, None]
    lj = csum[None, :]
    dec = jnp.exp(jnp.clip(li - lj, -80.0, 0.0))
    iota = jax.lax.iota(jnp.int32, chunk)
    tri = iota[:, None] >= iota[None, :]
    same = nr[:, None] == nr[None, :]
    dec = jnp.where(tri & same, dec, 0.0)

    scores = jax.lax.dot_general(C_, B_.T, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * dec * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    live = (nr == nr[-1]).astype(jnp.float32)
    dec_end = jnp.exp(jnp.clip(csum[-1] - csum, -80.0, 0.0)) * live
    sB = B_ * (dec_end * dt)[:, None]
    state = jax.lax.dot_general(sB.T, x, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    state_ref[0, 0] = state.astype(state_ref.dtype)


def ssd_chunk(C_, B_, x, dt, csum, nr, *, interpret=True):
    """C_/B_ [Bt,K,c,H,N]; x [Bt,K,c,H,P]; dt/csum [Bt,K,c,H];
    nr [Bt,K,c] int32.  Returns (y [Bt,K,c,H,P], states [Bt,K,H,N,P])."""
    bt, k, c, h, n = C_.shape
    p = x.shape[-1]
    # layout: move head next to (b, k) so each grid step is one 2-D tile
    def mh(t):  # [Bt,K,c,H,...] -> [Bt*H, K, c, ...]
        t = jnp.moveaxis(t, 3, 1)
        return t.reshape((bt * h, t.shape[2], c) + t.shape[4:])
    Cm, Bm, xm, dtm, csm = mh(C_), mh(B_), mh(x), mh(dt), mh(csum)
    nrm = jnp.repeat(nr[:, None], h, axis=1).reshape(bt * h, k, c)

    grid = (bt * h, k)
    kernel = functools.partial(_ssd_chunk_kernel, chunk=c)
    y, states = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, c), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, c), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt * h, k, c, p), x.dtype),
            jax.ShapeDtypeStruct((bt * h, k, n, p), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(Cm, Bm, xm, dtm, csm, nrm)

    def unh(t, tail):  # [Bt*H, K, ...] -> [Bt, K, ..., H, ...]
        t = t.reshape((bt, h, k) + tail)
        return jnp.moveaxis(t, 1, 3)
    y = unh(y, (c, p))                 # [Bt,K,c,H,P]
    states = unh(states, (n, p))       # [Bt,K,N,H->?]
    return y, jnp.moveaxis(states, 3, 2)   # [Bt,K,H,N,P]
