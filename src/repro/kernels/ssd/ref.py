"""Oracle for the SSD intra-chunk kernel (Mamba-2 state-space duality).

Per chunk (the quadratic 'attention-like' part of SSD):
  y[i]    = sum_{j<=i, same-doc} exp(csum[i]-csum[j]) * dt[j]
            * (C[i]·B[j]) * x[j]
  state   = sum_j exp(csum[end]-csum[j]) * dt[j] * B[j] x[j]^T
            (only j with no reset after them)
computed for one (batch, chunk, head) slice:
  C_, B_ [c, N]; x [c, P]; dt, csum [c]; nr [c] (reset prefix counts).
"""
import jax.numpy as jnp


def ref_ssd_chunk(C_, B_, x, dt, csum, nr):
    c = x.shape[0]
    li = csum[:, None]
    lj = csum[None, :]
    dec = jnp.exp(jnp.clip(li - lj, -80.0, 0.0))
    iota = jnp.arange(c)
    tri = iota[:, None] >= iota[None, :]
    same = nr[:, None] == nr[None, :]
    dec = jnp.where(tri & same, dec, 0.0)
    scores = C_ @ B_.T                                   # [c, c]
    w = scores * dec * dt[None, :]
    y = w @ x                                            # [c, P]
    live = (nr == nr[-1]).astype(jnp.float32)
    dec_end = jnp.exp(jnp.clip(csum[-1] - csum, -80.0, 0.0)) * live
    sB = B_ * (dec_end * dt)[:, None]                    # [c, N]
    state = sB.T @ x                                     # [N, P]
    return y, state
