"""Parallelism context: logical-axis sharding rules + helpers.

Logical activation/parameter dims are named; ``ShardingRules`` maps them to
mesh axes (or None).  The rule of thumb (DESIGN.md §4):

  batch   -> ("pod", "data")        tokens/batch dim
  seq     -> None  (long_500k decode: ("pod", "data") context-parallel)
  heads   -> "model"  iff n_heads   divisible by the model-axis size
  kv_heads-> "model"  iff n_kv_heads divisible, else replicated
  ffn     -> "model"
  dmodel  -> "data"   (FSDP; GSPMD all-gathers at use)
  vocab   -> "model"
  experts -> "data"   iff expert_parallel and divisible

Every constraint goes through ``ParallelContext.cons`` so single-device
smoke tests (mesh=None) run the identical code path with no-ops.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    batch: Any = None
    seq: Any = None
    # Megatron-SP analogue: the residual stream's sequence dim is sharded
    # over "model" between attention/FFN blocks; GSPMD inserts the
    # all-gather/reduce-scatter pairs.  Cuts activation memory by the TP
    # degree (decode contexts leave it None: S=1).
    residual_seq: Any = None
    heads: Any = None
    kv_heads: Any = None
    ffn: Any = None
    dmodel: Any = None
    vocab: Any = None
    experts: Any = None
    # axis used for CA head-padding when n_heads doesn't divide "model"
    padded_heads: Any = None
    # data-parallel axis name(s) used by the CAD dispatch shard_map
    cad_axis: Any = None

    def resolve(self, name: Optional[str]):
        if name is None:
            return None
        return getattr(self, name)


def make_rules(mesh: Optional[Mesh], cfg) -> ShardingRules:
    """Divisibility-aware rules for a ("data","model") or
    ("pod","data","model") mesh."""
    if mesh is None:
        return ShardingRules()
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axes.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    data_n = 1
    for a in data_axes:
        data_n *= axes[a]

    def div(n, axis, size):
        return axis if (n and n % size == 0) else None

    heads = div(getattr(cfg, "n_heads", 0), "model", model_n)
    kv_heads = div(getattr(cfg, "n_kv_heads", 0), "model", model_n)
    ffn = div(getattr(cfg, "d_ff", 0), "model", model_n)
    dmodel = div(getattr(cfg, "d_model", 0), data_axes, data_n)
    vocab = div(getattr(cfg, "vocab_size", 0), "model", model_n)
    experts = None
    if getattr(cfg, "moe", None) and cfg.moe.n_experts:
        if cfg.moe.expert_parallel and cfg.moe.n_experts % data_n == 0:
            experts = data_axes
        ffn = div(cfg.moe.d_ff_expert, "model", model_n)
    return ShardingRules(
        batch=data_axes, seq=None,
        residual_seq="model" if model_n > 1 else None,
        heads=heads, kv_heads=kv_heads, ffn=ffn,
        dmodel=dmodel, vocab=vocab, experts=experts,
        padded_heads="model" if model_n > 1 else None,
        cad_axis=data_axes)


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """Static context threaded through model code.

    attn_impl: "ref" | "xla" | "pallas" | "cad"
    attn_bwd:  None (backend default) | "pallas" | "xla" — backward
               implementation for the Pallas kernel paths (the xla choice
               is the blockwise recompute fallback)
    decode_impl: None ($REPRO_KERNEL_DECODE / default pallas) | "pallas" |
               "xla" — the serving ragged-decode kernel selection
               (DESIGN.md §8), mirroring attn_bwd
    """
    mesh: Optional[Mesh] = None
    rules: ShardingRules = ShardingRules()
    attn_impl: str = "ref"
    attn_bwd: Optional[str] = None
    decode_impl: Optional[str] = None
    cad: Any = None          # CADContext (plan + pool config) when attn_impl=="cad"
    pingpong: bool = False
    remat: bool = True
    seq_shard: bool = False  # long_500k: shard the sequence dim (CP layout)

    def cons(self, x, *dims: Optional[str]):
        """with_sharding_constraint by logical dim names (None entries ok).
        Axes that do not evenly divide the dim are dropped (safety net for
        odd sizes like whisper's 1500-frame encoder)."""
        if self.mesh is None or self.mesh.empty:
            return x
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        resolved = []
        for i, d in enumerate(dims):
            ax = self.rules.resolve(d)
            if ax is not None:
                axs = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axs:
                    n *= sizes.get(a, 1)
                if x.shape[i] % n:
                    ax = None
            resolved.append(ax)
        spec = P(*resolved)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def spec(self, *dims: Optional[str]) -> P:
        return P(*(self.rules.resolve(d) for d in dims))

    def sharding(self, *dims: Optional[str]):
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(*dims))


def param_pspecs(cfg, params, rules: ShardingRules,
                 mesh: Optional[Mesh] = None):
    """PartitionSpec tree for a param pytree, by leaf-path naming rules.

    Weight naming conventions (models/init):
      embed            (V, D)        -> (vocab, dmodel)
      wq/wo            (D, H*dh) / (H*dh, D)
      wk/wv            (D, Hkv*dh)
      w_gate/w_up      (D, F) ; w_down (F, D)
      experts_*        (E, D, F) / (E, F, D)
      scale/bias/lru_* 1-D or small -> replicated
    Stacked layer dim (leading, when ndim is one higher than the base
    weight) is always unsharded.
    """
    import jax.tree_util as jtu

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if mesh is not None else {}

    REPLICATED = {"scale", "bias", "lru_a", "conv_b", "conv_w", "A_log",
                  "D_skip", "dt_bias", "xgate", "enc_pos"}

    def leaf_spec(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1] if names else ""

        def wrap(*dims):
            dims = tuple(dims)
            # pad to leaf ndim with None on the left for the stacked dim
            extra = leaf.ndim - len(dims)
            dims = tuple([None] * extra) + dims
            # drop axes that don't divide the dim (safety net)
            fixed = []
            for i, ax in enumerate(dims):
                if ax is None:
                    fixed.append(None)
                    continue
                axs = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axs:
                    n *= axis_sizes.get(a, 1)
                fixed.append(ax if leaf.shape[i] % n == 0 else None)
            return P(*fixed)

        if name in REPLICATED or leaf.ndim <= 1:
            return P(*([None] * leaf.ndim))

        if name in ("embed", "unembed"):
            return wrap(rules.vocab, rules.dmodel)
        if name in ("wq",):
            return wrap(rules.dmodel, rules.heads)
        if name in ("wk", "wv"):
            return wrap(rules.dmodel, rules.kv_heads)
        if name in ("wo",):
            return wrap(rules.heads, rules.dmodel)
        if name in ("w_gate", "w_up", "w_in"):
            return wrap(rules.dmodel, rules.ffn)
        if name in ("w_down", "w_out"):
            return wrap(rules.ffn, rules.dmodel)
        if name in ("experts_gate", "experts_up"):
            # expert-parallel: E over data; dmodel FSDP only when E isn't
            # (a mesh axis may appear once per spec)
            dm = None if rules.experts else rules.dmodel
            return wrap(rules.experts, dm, rules.ffn)
        if name in ("experts_down",):
            dm = None if rules.experts else rules.dmodel
            return wrap(rules.experts, rules.ffn, dm)
        if name in ("router",):
            return wrap(rules.dmodel, None)
        if name in ("in_proj", "xbc_proj"):   # ssm fused projections
            return wrap(rules.dmodel, None)
        if name in ("out_proj",):
            return wrap(None, rules.dmodel)
        if name in ("w_x", "w_gate_br"):      # rg-lru branches (D, W)
            return wrap(rules.dmodel, rules.ffn)
        if name in ("w_input_gate", "w_rec_gate"):   # (W, W)
            return wrap(rules.dmodel, rules.ffn)
        if name in ("w_out",):                # (W, D)
            return wrap(rules.ffn, rules.dmodel)
        if leaf.ndim >= 2:
            return wrap(*([None] * (leaf.ndim - 2)), rules.dmodel, None)
        return P()

    return jtu.tree_map_with_path(leaf_spec, params)


def head_pad(n_heads: int, mesh: Optional[Mesh]) -> int:
    """Heads padded up to a multiple of the model-axis size, used *inside*
    the CA module so CA stays TP-sharded when n_heads is not divisible
    (llama4 40->48, smollm 15->16, whisper 20->32 ... DESIGN.md §4)."""
    if mesh is None or "model" not in mesh.axis_names:
        return n_heads
    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    return ((n_heads + m - 1) // m) * m
