"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427] Griffin / RecurrentGemma. 38L, d_model=4096, 16 q heads
(MQA kv=1, head_dim=256), d_ff=12288, vocab=256000, local window 2048.

Griffin's pattern is (rglru, rglru, local) repeated; 38 is not a multiple
of 3, matching the real model which ends on two recurrent blocks.  We
encode this as a 19-slot period — 6x(rglru, rglru, local) plus one extra
rglru — repeated twice (2 x 19 = 38 layers, 12 local-attn, 26 recurrent).
"""
from .base import ModelConfig, RGLRUConfig, register

_PERIOD = ("rglru", "rglru", "local") * 6 + ("rglru",)

CONFIG = register(ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=_PERIOD,            # 19-slot period, n_layers = 2*19
    window=2048,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4, block_width=256),
    activation="gelu",
    scale_embed=True,
    tie_embeddings=True,
    subquadratic=True,
))
