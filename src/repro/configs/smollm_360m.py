"""smollm-360m [dense] — llama-architecture small model.

[hf:HuggingFaceTB/SmolLM-135M family card] 32L, d_model=960, 15 q heads
(GQA kv=5, head_dim=64), d_ff=2560, vocab=49152.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="smollm-360m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    layer_pattern=("global",),
    tie_embeddings=True,
    subquadratic=False,
))
