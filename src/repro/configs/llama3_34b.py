"""Llama-34B — the paper's own 34B experiment model (Table 2 + Table 5).

48L, d_model=8192, 64 heads, head_dim=128, GQA kv=16 (h_kv=2048),
d_ff=22016, vocab=128256.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="llama3-34b",
    family="dense",
    source="paper Table 2/5",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=16,
    head_dim=128,
    d_ff=22016,
    vocab_size=128256,
    layer_pattern=("global",),
    rope_theta=500000.0,
    subquadratic=False,
))
