"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP.

[arXiv:2402.16819] Nemotron-4. 96L, d_model=18432, 96 q heads (GQA kv=8,
head_dim=192), d_ff=73728 (squared-ReLU, 2-matrix MLP), vocab=256000.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    layer_pattern=("global",),
    activation="relu2",
    gated_mlp=False,
    subquadratic=False,
))
