"""llama4-maverick-400b-a17b [moe] — 128 routed experts, top-1 routing.

[hf:meta-llama/Llama-4-Scout-17B-16E family card] 48L, d_model=5120,
40 q heads (GQA kv=8, head_dim=128), per-expert d_ff=8192, vocab=202048,
MoE 128e top-1, early-fusion multimodal (text backbone here).

Expert parallelism: 128 experts divide the 16-way "data" axis, so this
config exercises the EP all-to-all path (DESIGN.md §4).
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=("global",),
    moe=MoEConfig(n_experts=128, top_k=1, n_shared_experts=1,
                  d_ff_expert=8192, expert_parallel=True),
    rope_theta=500000.0,
    subquadratic=False,
))
