"""whisper-large-v3 [audio] — encoder-decoder, conv frontend stubbed.

[arXiv:2212.04356] Robust speech recognition (Whisper). Backbone: 32
encoder + 32 decoder layers, d_model=1280, 20 heads (MHA, kv=20,
head_dim=64), d_ff=5120 (GELU), vocab=51866, LayerNorm, learned/sinusoidal
positions (no RoPE).  The mel-spectrogram + conv feature extractor is a
stub: ``input_specs()`` supplies precomputed frame embeddings
(B, 1500, 1280) per DESIGN.md §7.  Decoder layers cross-attend encoder
output every layer.
"""
from .base import EncoderConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=32,                      # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    layer_pattern=("cross",),         # every decoder layer cross-attends
    encoder=EncoderConfig(n_layers=32, n_ctx=1500, causal=False),
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    use_rope=False,
    subquadratic=False,
))
