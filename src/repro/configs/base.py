"""Model configuration system.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro/configs``; the registry maps ``--arch <id>`` to it.  A config fully
describes the transformer backbone (the modality frontend for [audio]/[vlm]
archs is a stub per DESIGN.md §7).

Layer patterns
--------------
``layer_pattern`` is a repeating tuple of layer-type strings, e.g.
``("local", "global")`` for gemma2 or ``("rglru", "rglru", "local")`` for
recurrentgemma.  ``n_layers`` must be a multiple of the pattern length; the
model stacks parameters as ``[n_layers // period, ...]`` per slot and scans
over super-blocks, keeping the lowered HLO small even for 96-layer models.

Layer types:
  - ``global``  : full causal self-attention
  - ``local``   : sliding-window causal self-attention (``window``)
  - ``cross``   : self-attention + cross-attention to encoder/vision memory
  - ``ssd``     : Mamba-2 state-space duality block (attention-free)
  - ``rglru``   : RecurrentGemma RG-LRU linear-recurrence block
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 1
    n_shared_experts: int = 0     # always-on experts
    d_ff_expert: int = 0          # per-expert intermediate size
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    expert_parallel: bool = False  # shard experts over the "data" axis


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    chunk_size: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # 0 -> d_model
    conv_width: int = 4
    block_width: int = 256        # scan chunk for the linear recurrence


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) / vision memory for VLM."""
    n_layers: int = 0
    n_ctx: int = 1500             # precomputed frame/patch embeddings length
    d_model: int = 0              # 0 -> same as decoder d_model
    causal: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | ssm | moe | vlm | audio | hybrid
    source: str                   # citation from the assignment table

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab_size: int = 0

    layer_pattern: Tuple[str, ...] = ("global",)
    window: int = 4096            # sliding window for "local" layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    activation: str = "silu"      # silu | gelu | relu2
    gated_mlp: bool = True        # 3-matrix gated MLP vs 2-matrix
    post_norms: bool = False      # gemma2-style post-sublayer norms
    scale_embed: bool = False     # gemma-style sqrt(d_model) embed scaling
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    rope_theta: float = 10000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    qk_norm: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    cross_attn_period: int = 0    # VLM: every Nth layer is "cross"

    # long_500k applicability: True iff decode cost per token is sub-linear
    # in context for *every* layer, or the arch natively uses windowed attn.
    subquadratic: bool = False

    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.layer_pattern) == 0, (
            f"{self.arch_id}: n_layers={self.n_layers} not a multiple of "
            f"pattern {self.layer_pattern}")

    # ---------------------------------------------------------------- util
    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.period

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def has_attention(self) -> bool:
        return any(t in ("global", "local", "cross") for t in self.layer_pattern)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: ≤2 pattern periods,
        d_model≤512, ≤4 experts, small vocab."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) or 0
        head_dim = (d_model // n_heads) if n_heads else 0
        n_kv = min(self.n_kv_heads, n_heads) if self.n_kv_heads else 0
        if n_kv and n_heads % n_kv:
            n_kv = 1
        moe = None
        if self.moe:
            moe = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                d_ff_expert=min(self.moe.d_ff_expert, 128) or 128,
                capacity_factor=8.0,  # no drops in smoke tests
                expert_parallel=False)
        ssm = None
        if self.ssm:
            ssm = dataclasses.replace(self.ssm, d_state=32, head_dim=32,
                                      chunk_size=64)
        rglru = None
        if self.rglru:
            rglru = dataclasses.replace(self.rglru, lru_width=d_model,
                                        block_width=64)
        enc = None
        if self.encoder:
            enc = dataclasses.replace(self.encoder, n_layers=2, n_ctx=24,
                                      d_model=0)
        # Compact long periods (e.g. recurrentgemma's 19-slot pattern) down
        # to the ordered-unique layer types so the smoke variant stays tiny
        # while still covering every layer type of the family.
        pattern = self.layer_pattern
        if len(pattern) > 4:
            pattern = tuple(dict.fromkeys(pattern))
        n_layers = len(pattern) * (2 if len(pattern) == 1 else 1)
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-reduced",
            layer_pattern=pattern,
            n_layers=n_layers,
            d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            window=min(self.window, 64),
            moe=moe, ssm=ssm, rglru=rglru, encoder=enc,
            param_dtype="float32", compute_dtype="float32",
        )

    # ------------------------------------------------------- flops/memory
    def n_params(self) -> int:
        """Total parameter count (analytic)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        per_pattern = 0
        for t in self.layer_pattern:
            per_pattern += self._layer_params(t)
        total += self.n_groups * per_pattern
        if self.encoder:
            ed = self.encoder.d_model or d
            # encoder self-attn + ffn per layer
            hq = self.n_heads * self.head_dim
            hkv = self.n_kv_heads * self.head_dim
            total += self.encoder.n_layers * (
                ed * hq * 2 + ed * hkv * 2 + self._ffn_params())
        return total

    def _ffn_params(self) -> int:
        d = self.d_model
        n_mats = 3 if self.gated_mlp else 2
        if self.moe and self.moe.n_experts:
            e = self.moe
            routed = e.n_experts * n_mats * d * e.d_ff_expert
            shared = e.n_shared_experts * n_mats * d * e.d_ff_expert
            router = d * e.n_experts
            return routed + shared + router
        return n_mats * d * self.d_ff

    def _layer_params(self, layer_type: str) -> int:
        d = self.d_model
        if layer_type == "ssd":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            # in_proj produces [z, x, B, C, dt]
            return d * (2 * d_in + 2 * s.n_groups * s.d_state + nh) + d_in * d
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        if layer_type == "rglru":
            r = self.rglru
            w = r.lru_width or d
            # in/gate branches + input/recurrence gates + out proj + lru a
            blk = 2 * d * w + 2 * w * w + w * d + w
            return blk + self._ffn_params()
        if layer_type == "cross":
            attn *= 2  # self + cross attention
        return attn + self._ffn_params()

    def _ffn_active_flops_per_token(self) -> float:
        """MACs per token through the FFN (active experts only for MoE)."""
        n_mats = 3 if self.gated_mlp else 2
        if self.moe and self.moe.n_experts:
            e = self.moe
            return n_mats * self.d_model * e.d_ff_expert \
                * (e.top_k + e.n_shared_experts)
        return n_mats * self.d_model * self.d_ff

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if not (self.moe and self.moe.n_experts):
            return self.n_params()
        e = self.moe
        n_mats = 3 if self.activation == "silu" else 2
        d = self.d_model
        inactive = (e.n_experts - e.top_k) * n_mats * d * e.d_ff_expert
        n_moe_layers = self.n_layers  # every pattern slot uses same ffn cfg
        return self.n_params() - n_moe_layers * inactive


_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if arch_id.endswith("-reduced"):
        return get_config(arch_id[: -len("-reduced")]).reduced()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (gemma2_2b, mamba2_370m, llama4_maverick, qwen2_moe,  # noqa
                   smollm_360m, llama32_vision, mistral_large,
                   nemotron4_340b, whisper_large_v3, recurrentgemma_9b,
                   llama3_8b, llama3_34b)
