"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118] Gemma 2 technical report. 26L, d_model=2304, 8 q heads
(GQA kv=4, head_dim=256), d_ff=9216 (GeGLU), vocab=256000, sliding window
4096 on local layers, attn softcap 50, final softcap 30.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="gemma2-2b",
    family="dense",
    source="arXiv:2408.00118",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern=("local", "global"),
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    activation="gelu",
    post_norms=True,
    scale_embed=True,
    tie_embeddings=True,
    # half the layers are 4K-window; global layers read the full cache but
    # per-token decode cost is linear -> long_500k runs (DESIGN.md §6).
    subquadratic=True,
))
