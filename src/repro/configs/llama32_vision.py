"""llama-3.2-vision-11b [vlm] — cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision] 40L, d_model=4096, 32 q heads
(GQA kv=8, head_dim=128), d_ff=14336, vocab=128256; every 5th layer adds
cross-attention to projected vision-patch embeddings.  The ViT/projector
frontend is a stub: ``input_specs()`` supplies patch embeddings of shape
(B, n_patches, d_model) per DESIGN.md §7.
"""
from .base import EncoderConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern=("global", "global", "global", "global", "cross"),
    cross_attn_period=5,
    # vision memory: stubbed patch embeddings (e.g. 4 tiles x ~1601 patches)
    encoder=EncoderConfig(n_layers=0, n_ctx=6404, causal=False),
    rope_theta=500000.0,
    subquadratic=False,
))
