"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B] 24L, d_model=2048, 16 q heads (GQA kv=16 ==
MHA), per-expert d_ff=1408, vocab=151936, 60 routed experts top-4 plus 4
always-on shared experts.

60 experts do NOT divide the 16-way "data" axis -> experts stay replicated
on "data" with d_model FSDP-sharded; exercises the dense-dispatch MoE path
(DESIGN.md §4).
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    layer_pattern=("global",),
    moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4,
                  d_ff_expert=1408, expert_parallel=False),
    subquadratic=False,
))
