"""mamba2-370m [ssm] — attention-free SSD (state-space duality).

[arXiv:2405.21060] Transformers are SSMs. 48L, d_model=1024, d_state=128,
expand=2 (d_inner=2048), head_dim=64, vocab=50280.

CAD applicability: NONE — there is no core attention to disaggregate; the
context-dependent op is the SSD chunked scan whose compute is O(l·d_state),
linear in tokens, so packing-induced quadratic imbalance does not arise
(DESIGN.md §5).
"""
from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    arch_id="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("ssd",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                  chunk_size=256, conv_width=4),
    use_rope=False,
    tie_embeddings=True,
    subquadratic=True,
))
