"""Architecture config registry.  ``get_config("<arch-id>")`` or
``get_config("<arch-id>-reduced")`` for smoke-test variants."""
from .base import (EncoderConfig, ModelConfig, MoEConfig, RGLRUConfig,
                   SSMConfig, get_config, list_archs, register)

ASSIGNED_ARCHS = (
    "gemma2-2b",
    "mamba2-370m",
    "llama4-maverick-400b-a17b",
    "qwen2-moe-a2.7b",
    "smollm-360m",
    "llama-3.2-vision-11b",
    "mistral-large-123b",
    "nemotron-4-340b",
    "whisper-large-v3",
    "recurrentgemma-9b",
)

PAPER_ARCHS = ("llama3-8b", "llama3-34b")

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "RGLRUConfig",
           "EncoderConfig", "get_config", "list_archs", "register",
           "ASSIGNED_ARCHS", "PAPER_ARCHS"]
