"""Llama-3-8B — the paper's own 8B experiment model (Table 2).

32L, d_model=4096, 32 heads, head_dim=128, GQA kv=8, d_ff=14336,
vocab=128256.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="llama3-8b",
    family="dense",
    source="paper Table 2 / arXiv:2407.21783",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern=("global",),
    rope_theta=500000.0,
    subquadratic=False,
))
