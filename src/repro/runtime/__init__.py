"""Elastic attention-server runtime (DESIGN.md §9).

The pool of attention servers is a mutable, failure-prone resource —
not a compile-time constant.  Core attention's statelessness (the
paper's key observation) makes that cheap: a lost or slow task is
recomputed anywhere from the q/k/v shards the requester still holds.

  ServerPool          membership with explicit epochs: drain / remove /
                      add mid-training; calibrator speed state carries
                      over, new endpoints restart from the base model
  PoolView            immutable per-epoch membership snapshot
  FaultSchedule       deterministic, seeded fault injection
                      (kill / flap / slow / drain server s at step t)
  build_recovery_plan recovery sub-plans over exactly the lost tasks,
                      built by the primary plan machinery
  ElasticExecutor     fault-tolerant per-server dispatch with
                      exactly-once bit-identical output merging and
                      percentile-deadline straggler speculation
"""
from repro.runtime.executor import ElasticExecutor, StepReport
from repro.runtime.faults import FaultEvent, FaultSchedule
from repro.runtime.pool import (ACTIVE, DEAD, DRAINING,
                                PoolExhaustedError, PoolView, ServerPool)
from repro.runtime.recovery import (RecoveryPlan, assignment_of_plan,
                                    build_recovery_plan, lost_block_mask,
                                    recovery_tasks)

__all__ = [
    "ServerPool", "PoolView", "PoolExhaustedError",
    "ACTIVE", "DRAINING", "DEAD",
    "FaultSchedule", "FaultEvent",
    "RecoveryPlan", "build_recovery_plan", "lost_block_mask",
    "assignment_of_plan", "recovery_tasks",
    "ElasticExecutor", "StepReport",
]
