"""Elastic attention-server pool: explicit membership epochs.

The paper's key structural fact — core attention is *stateless* — means
the server pool does not have to be a compile-time constant: a CA task
can be recomputed anywhere from the (q, k, v) shards its requester
already holds.  :class:`ServerPool` makes membership a first-class,
mutable, *versioned* runtime object:

  * every slot of the dispatch geometry (one per rank — array shapes
    never change, so one compiled executable serves every epoch) holds
    an *endpoint* that is ``active``, ``draining`` or ``dead``;
  * every membership mutation (drain / remove / add) bumps the pool
    **epoch**; planners are re-invoked against the surviving endpoints
    (``PoolView.excluded`` feeds the schedulers' ``exclude``), and
    prefetched plans stamped with an older epoch are re-planned at pull
    (:meth:`repro.cad.CADSession._plan_stale`);
  * :class:`~repro.core.cost_model.GridCalibrator` speed state is
    carried over across epochs: surviving servers keep their measured
    ratios, a same-endpoint rejoin (flap) keeps its calibration, and
    only a *new* endpoint joining at a slot resets that slot to the
    base model (``GridCalibrator.reset_server``).

Killing a server withdraws its attention-*serving* capacity only.  Its
data-rank half stays alive and keeps sending q/k/v shards — the paper's
disaggregated framing, where DP/TP workers own the state and attention
servers own none (DESIGN.md §9).

All methods are thread-safe: the plan-prefetch worker reads ``view()``
while the train loop mutates membership.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterator, List, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

ACTIVE = "active"
DRAINING = "draining"
DEAD = "dead"
_STATUSES = (ACTIVE, DRAINING, DEAD)


class PoolExhaustedError(RuntimeError):
    """A membership change would leave no active attention server."""


@dataclasses.dataclass(frozen=True)
class PoolView:
    """Immutable snapshot of pool membership at one epoch.  Planning a
    step consumes exactly one view, so both ping-pong halves (and every
    recovery sub-plan within the step) see the same membership."""
    epoch: int
    n_slots: int
    active: Tuple[int, ...]       # slots that may receive new tasks
    draining: Tuple[int, ...]     # finishing in-flight work; no new tasks
    dead: Tuple[int, ...]
    endpoints: Tuple[str, ...]    # per-slot endpoint identity

    @property
    def excluded(self) -> Tuple[int, ...]:
        """Slots the planners must not assign tasks to."""
        return tuple(sorted(self.draining + self.dead))

    @property
    def n_active(self) -> int:
        return len(self.active)


@dataclasses.dataclass
class _Member:
    endpoint: str
    status: str
    joined_epoch: int


class ServerPool:
    """Mutable pool membership over a fixed dispatch geometry.

    ``n_slots`` is the dispatch dimension D (== data ranks); it never
    changes.  What changes is which slots currently serve attention.
    ``calibrator`` (optional) receives the carryover hooks described in
    the module docstring.
    """

    def __init__(self, n_slots: int, *, calibrator=None,
                 endpoints: Optional[List[str]] = None):
        if n_slots < 1:
            raise ValueError(f"pool needs >= 1 slot, got {n_slots}")
        if endpoints is not None and len(endpoints) != n_slots:
            raise ValueError(f"endpoints needs {n_slots} entries, got "
                             f"{len(endpoints)}")
        self.n_slots = int(n_slots)
        self.calibrator = calibrator
        self._members = [
            _Member(endpoint=(endpoints[s] if endpoints
                              else f"attn-server/{s}"),
                    status=ACTIVE, joined_epoch=0)
            for s in range(n_slots)]
        self._epoch = 0
        self._lock = threading.Lock()
        self._log: List[Tuple[int, str]] = []

    # ------------------------------------------------------------- views
    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def view(self) -> PoolView:
        with self._lock:
            return self._view_locked()

    def _view_locked(self) -> PoolView:
        by = {st: [] for st in _STATUSES}
        for s, m in enumerate(self._members):
            by[m.status].append(s)
        return PoolView(epoch=self._epoch, n_slots=self.n_slots,
                        active=tuple(by[ACTIVE]),
                        draining=tuple(by[DRAINING]),
                        dead=tuple(by[DEAD]),
                        endpoints=tuple(m.endpoint
                                        for m in self._members))

    def status(self, slot: int) -> str:
        with self._lock:
            return self._members[self._check(slot)].status

    def history(self) -> Tuple[Tuple[int, str], ...]:
        """The (epoch, event) membership log — replayable audit trail."""
        with self._lock:
            return tuple(self._log)

    def __iter__(self) -> Iterator[int]:
        return iter(self.view().active)

    # --------------------------------------------------------- mutations
    def _check(self, slot: int) -> int:
        slot = int(slot)
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} outside pool of "
                             f"{self.n_slots}")
        return slot

    def _bump(self, event: str) -> int:
        self._epoch += 1
        self._log.append((self._epoch, event))
        # narrate the membership change (DESIGN.md §14); the recorder
        # and registry have their own locks and never call back into
        # the pool, so recording under self._lock cannot deadlock
        obs_trace.get_recorder().instant(
            "pool." + event.split(" ", 1)[0], "pool",
            args={"event": event, "epoch": self._epoch})
        obs_metrics.get_registry().gauge(
            "cad_pool_epoch", "pool membership epoch").set(self._epoch)
        obs_metrics.get_registry().counter(
            "cad_pool_events_total", "membership mutations",
            labels=("kind",)).inc(kind=event.split(" ", 1)[0])
        return self._epoch

    def drain(self, slot: int) -> int:
        """Stop routing new tasks to ``slot``; in-flight work finishes.
        Returns the new epoch."""
        with self._lock:
            slot = self._check(slot)
            m = self._members[slot]
            if m.status != ACTIVE:
                raise ValueError(f"cannot drain slot {slot}: {m.status}")
            if sum(x.status == ACTIVE for x in self._members) <= 1:
                raise PoolExhaustedError(
                    f"draining slot {slot} would leave no active "
                    f"attention server")
            m.status = DRAINING
            return self._bump(f"drain {slot} ({m.endpoint})")

    def remove(self, slot: int) -> int:
        """Declare ``slot`` dead (crash, deadline exceeded, operator
        removal).  Its in-flight tasks are lost — the elastic executor
        recovers them onto survivors.  Returns the new epoch."""
        with self._lock:
            slot = self._check(slot)
            m = self._members[slot]
            if m.status == DEAD:
                raise ValueError(f"slot {slot} is already dead")
            others = sum(x.status == ACTIVE for x in self._members
                         if x is not m)
            if others < 1:
                raise PoolExhaustedError(
                    f"removing slot {slot} would leave no active "
                    f"attention server")
            m.status = DEAD
            return self._bump(f"remove {slot} ({m.endpoint})")

    def add(self, slot: int, *, endpoint: Optional[str] = None,
            prior_speed: Optional[float] = None) -> int:
        """(Re)activate ``slot``.  A draining server is simply restored.
        A dead slot rejoins: with ``endpoint=None`` (or the same
        endpoint string) this is a *flap* — the same machine came back,
        so its calibrated speed state stays; with a new ``endpoint`` a
        replacement server joins and the calibrator slot is reset to
        the base model (``prior_speed`` optionally declares its
        relative speed).  Returns the new epoch."""
        with self._lock:
            slot = self._check(slot)
            m = self._members[slot]
            if m.status == ACTIVE:
                raise ValueError(f"slot {slot} is already active")
            was_draining = m.status == DRAINING
            fresh = endpoint is not None and endpoint != m.endpoint
            if fresh:
                m.endpoint = endpoint
                if self.calibrator is not None:
                    self.calibrator.reset_server(slot,
                                                 prior_speed=prior_speed)
            m.status = ACTIVE
            m.joined_epoch = self._epoch + 1
            kind = "join" if fresh else \
                ("undrain" if was_draining else "rejoin")
            return self._bump(f"{kind} {slot} ({m.endpoint})")
