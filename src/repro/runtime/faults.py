"""Deterministic fault injection for the elastic attention runtime.

A :class:`FaultSchedule` is pure data — a sorted tuple of
:class:`FaultEvent` — so every failure path is *replayable*: the same
schedule (parsed from a spec string or generated from a seed) produces
the same kills, slowdowns and rejoins at the same steps, in tests, in
benchmarks and in the training demo alike.  Nothing here consults a
clock or unseeded randomness.

Spec grammar (comma-separated events)::

  kill:S@T        server S dies during step T (tasks lost mid-step;
                  removed from the pool afterwards, forever)
  flap:S@T+K      server S dies during step T and rejoins — same
                  endpoint, calibration kept — before step T+K
  slow:SxF@T-U    server S runs Fx slower during steps [T, U)
                  (U omitted -> forever), e.g. slow:1x4@3-9
  drain:S@T       server S is drained before step T (graceful: no new
                  tasks, nothing lost)

Examples::

  FaultSchedule.parse("kill:2@5")
  FaultSchedule.parse("slow:0x4@3-9,flap:1@4+3")
  FaultSchedule.random(n_servers=8, steps=100, seed=0)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable, List, Tuple

import numpy as np

KINDS = ("kill", "flap", "slow", "drain")


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One injected fault.  ``until`` is the slow end-step (exclusive;
    -1 = forever) or the flap rejoin step; ``factor`` is the slowdown
    multiplier applied to the server's task time."""
    step: int
    kind: str
    server: int
    factor: float = 1.0
    until: int = -1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.step < 0 or self.server < 0:
            raise ValueError(f"step/server must be >= 0: {self}")
        if self.kind == "slow" and self.factor <= 0:
            raise ValueError(f"slow factor must be > 0: {self}")
        if self.kind == "flap" and self.until <= self.step:
            raise ValueError(f"flap rejoin must be after death: {self}")

    def spec(self) -> str:
        if self.kind == "kill" or self.kind == "drain":
            return f"{self.kind}:{self.server}@{self.step}"
        if self.kind == "flap":
            return (f"flap:{self.server}@{self.step}"
                    f"+{self.until - self.step}")
        end = "" if self.until < 0 else f"-{self.until}"
        return f"slow:{self.server}x{self.factor:g}@{self.step}{end}"


_EV_RE = re.compile(
    r"^(?P<kind>kill|flap|slow|drain):(?P<server>\d+)"
    r"(?:x(?P<factor>[0-9.]+))?@(?P<step>\d+)"
    r"(?:\+(?P<dur>\d+))?(?:-(?P<until>\d+))?$")


class FaultSchedule:
    """An ordered, replayable set of :class:`FaultEvent`."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: Tuple[FaultEvent, ...] = tuple(sorted(events))
        seen = set()
        for e in self.events:
            if e.kind in ("kill", "flap", "drain"):
                key = (e.step, e.server)
                if key in seen:
                    raise ValueError(
                        f"conflicting membership events for server "
                        f"{e.server} at step {e.step}")
                seen.add(key)

    # ------------------------------------------------------ constructors
    @classmethod
    def parse(cls, spec: str) -> "FaultSchedule":
        """Parse the comma-separated spec grammar (module docstring)."""
        events: List[FaultEvent] = []
        for raw in filter(None, (p.strip() for p in spec.split(","))):
            m = _EV_RE.match(raw)
            if m is None:
                raise ValueError(f"bad fault spec {raw!r} (grammar: "
                                 f"kill:S@T  flap:S@T+K  slow:SxF@T-U  "
                                 f"drain:S@T)")
            kind = m.group("kind")
            server = int(m.group("server"))
            step = int(m.group("step"))
            if kind == "slow":
                if m.group("factor") is None:
                    raise ValueError(f"slow event needs a factor: {raw!r}")
                if m.group("dur"):
                    raise ValueError(
                        f"slow takes SxF@T-U, not a +K duration: {raw!r}")
                until = int(m.group("until")) if m.group("until") else -1
                events.append(FaultEvent(step, "slow", server,
                                         factor=float(m.group("factor")),
                                         until=until))
            elif kind == "flap":
                if m.group("dur") is None:
                    raise ValueError(f"flap event needs +K steps: {raw!r}")
                if m.group("factor") or m.group("until"):
                    raise ValueError(f"flap takes only S@T+K: {raw!r}")
                events.append(FaultEvent(step, "flap", server,
                                         until=step + int(m.group("dur"))))
            else:
                if m.group("factor") or m.group("dur") or m.group("until"):
                    raise ValueError(f"{kind} takes only S@T: {raw!r}")
                events.append(FaultEvent(step, kind, server))
        return cls(events)

    @classmethod
    def random(cls, n_servers: int, steps: int, seed: int, *,
               p_kill: float = 0.01, p_slow: float = 0.03,
               p_flap: float = 0.01, max_kills: int = 0,
               slow_factors=(2.0, 4.0, 8.0)) -> "FaultSchedule":
        """Seeded random schedule — chaos-monkey input that replays
        bit-identically for the same arguments.  ``max_kills`` caps
        permanent kills (default: at most n_servers - 1 ever die)."""
        rng = np.random.default_rng(seed)
        max_kills = max_kills or n_servers - 1
        kills = 0
        events: List[FaultEvent] = []
        dead_until = {}                      # server -> rejoin step (flap)
        for t in range(steps):
            for s in range(n_servers):
                if dead_until.get(s, -1) > t:
                    continue
                u = rng.random()
                if u < p_kill and kills < max_kills:
                    events.append(FaultEvent(t, "kill", s))
                    kills += 1
                    dead_until[s] = steps          # forever
                elif u < p_kill + p_flap:
                    k = int(rng.integers(1, 4))
                    if t + k < steps:
                        events.append(FaultEvent(t, "flap", s,
                                                 until=t + k))
                        dead_until[s] = t + k
                elif u < p_kill + p_flap + p_slow:
                    f = float(rng.choice(slow_factors))
                    dur = int(rng.integers(1, 6))
                    events.append(FaultEvent(t, "slow", s, factor=f,
                                             until=t + dur))
        return cls(events)

    # ----------------------------------------------------------- queries
    def spec(self) -> str:
        """Round-trips through :meth:`parse` (slow events generated by
        :meth:`random` always carry an end step, so the grammar covers
        them)."""
        return ",".join(e.spec() for e in self.events)

    def failures_at(self, step: int) -> Tuple[FaultEvent, ...]:
        """Kill/flap events striking during ``step`` — these servers
        lose their in-flight tasks mid-step."""
        return tuple(e for e in self.events
                     if e.step == step and e.kind in ("kill", "flap"))

    def drains_at(self, step: int) -> Tuple[int, ...]:
        return tuple(e.server for e in self.events
                     if e.step == step and e.kind == "drain")

    def rejoins_at(self, step: int) -> Tuple[int, ...]:
        """Flapped servers whose rejoin lands before ``step``."""
        return tuple(e.server for e in self.events
                     if e.kind == "flap" and e.until == step)

    # ------------------------------------------------- pool application
    # One implementation of the membership-event semantics, shared by
    # the fused trainer path and the elastic executor so the two can
    # never diverge.  Guards make events idempotent against earlier
    # schedule entries: a rejoin only raises the dead, a drain only
    # drains the active, a kill/flap removes any not-yet-dead server
    # (killing a *draining* server still transitions it to dead, so its
    # flap rejoin can fire later).

    def apply_pre_step(self, pool, step: int) -> List[str]:
        """Apply the membership events that land *before* step ``step``
        plans: flap rejoins and graceful drains.  Returns event log
        lines (empty when nothing applied)."""
        events: List[str] = []
        for s in self.rejoins_at(step):
            if pool.status(s) == "dead":
                pool.add(s)
                events.append(f"rejoin {s}")
        for s in self.drains_at(step):
            if pool.status(s) == "active":
                pool.drain(s)
                events.append(f"drain {s}")
        return events

    def apply_failures(self, pool, step: int) -> List[str]:
        """Apply ``step``'s kill/flap deaths to the pool.  The elastic
        executor calls this *after* executing (the server failed
        mid-step and its tasks were recovered); the fused trainer calls
        it before planning (step-granular membership).  May raise
        :class:`~repro.runtime.pool.PoolExhaustedError`."""
        events: List[str] = []
        for e in self.failures_at(step):
            if pool.status(e.server) != "dead":
                pool.remove(e.server)
                events.append(f"{e.kind} {e.server}")
        return events

    def slow_factor(self, step: int, server: int) -> float:
        """Product of all slowdowns active on ``server`` at ``step``."""
        f = 1.0
        for e in self.events:
            if e.kind == "slow" and e.server == server \
                    and e.step <= step and (e.until < 0 or step < e.until):
                f *= e.factor
        return f

    def __len__(self) -> int:
        return len(self.events)

    def __eq__(self, other) -> bool:
        return isinstance(other, FaultSchedule) \
            and self.events == other.events

    def __hash__(self) -> int:
        return hash(self.events)

    def __repr__(self) -> str:
        return f"FaultSchedule({self.spec()!r})"
