"""Elastic step executor: fault-tolerant dispatch over a mutable pool.

``ElasticExecutor`` is the runtime layer between :class:`CADSession`
(planning, calibration) and ``core.dispatch`` (per-server serve +
scatter).  Each ``run_step``:

  1. applies the step's scheduled membership events (rejoins, drains)
     to the :class:`~repro.runtime.pool.ServerPool`, then plans the
     batch against the surviving endpoints (one epoch view per step);
  2. executes every active server's fused CA-task batch independently
     (``core.dispatch.build_server_inputs`` / ``serve_task_batch``) —
     the decomposition that makes task-level fault handling possible;
  3. on a mid-step failure (injected kill/flap, or a raised exception
     from a real serve) builds a **recovery sub-plan** re-dispatching
     exactly the lost tasks onto survivors, and **speculatively
     re-executes** straggler servers whose time exceeds the
     ``speculate_pct`` percentile deadline from the calibrated cost
     model (when the backup is modeled to finish earlier);
  4. merges outputs exactly-once: every q block's output is *selected*
     bitwise from exactly one execution, so the step output is
     bit-identical to a fault-free run of the same batch
     (DESIGN.md §9);
  5. feeds measured per-server timings back to the session calibrator
     and applies end-of-step membership consequences (kill -> remove,
     flap -> remove + scheduled rejoin).

Timing runs under one of two timers: ``"model"`` — per-server seconds
are predicted by the (calibrated) cost model, scaled by the fault
schedule's slow factors; fully deterministic, the replay/benchmark
default — or ``"wall"`` — real wall-clock serve times (slow factors
still multiply), for live measurements.  Outputs are bit-identical
under either timer; only the reported seconds differ.  Wall reads go
through an injectable :class:`~repro.obs.clock.Clock`, so tests script
time instead of sleeping.

Every step is additionally narrated to the observability layer
(DESIGN.md §14): per-server serve/recovery spans on a cumulative
step timeline (the Perfetto gantt, one track per server), kill /
speculate / merge events, predicted-vs-measured calibration residual
gauges, and step/failure/recovery counters.  Recording is a strict
no-op when the global recorder is disabled and never touches outputs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CommModel, CostModel, MemoryModel
from repro.core.dispatch import (CADContext, assemble_step_outputs,
                                 build_server_inputs, iter_plan_tasks,
                                 merge_recovered, serve_task_batch)
from repro.core.scheduler import (assignment_resident_bytes,
                                  layout_from_segments, streamed_doc_ids)
from repro.obs import MONOTONIC, server_track
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime.faults import FaultSchedule
from repro.runtime.pool import PoolExhaustedError, ServerPool
from repro.runtime.recovery import assignment_of_plan, build_recovery_plan

TIMERS = ("model", "wall")


@dataclasses.dataclass(frozen=True)
class StepReport:
    """What happened during one elastic step — everything a replay must
    reproduce (and a dashboard would chart)."""
    step: int
    epoch: int
    failed: Tuple[int, ...]            # servers that lost tasks mid-step
    speculated: Tuple[int, ...]        # stragglers re-executed on backups
    recovered_blocks: int
    server_seconds: Dict[int, float]   # primary serve time per server
    recovery_seconds: Dict[int, float]  # added backup time per survivor
    step_seconds: float                # modeled/measured step completion
    deadline: float                    # straggler deadline (0 = off)
    plan_stats: Dict[str, float]
    events: Tuple[str, ...]            # membership log entries this step

    def summary(self) -> str:
        bits = [f"step {self.step} epoch {self.epoch} "
                f"t={self.step_seconds * 1e3:.2f}ms"]
        if self.failed:
            bits.append(f"failed={list(self.failed)} "
                        f"recovered={self.recovered_blocks} blocks")
        if self.speculated:
            bits.append(f"speculated={list(self.speculated)}")
        return " | ".join(bits)


@dataclasses.dataclass
class StepState:
    """Everything ``begin_step`` established before execution: the
    membership events applied, the plan and the cost view it was priced
    with, and the per-server task composition/predictions.  The fabric
    executor reads this between planning and execution to admit serve
    traffic into the predicted idle capacity (and may zero
    ``speculate_pct`` to reclaim speculation-eligible capacity —
    mutating the state, never ``self``)."""
    step: int
    q: Any
    k: Any
    v: Any
    pos: Any
    segs: np.ndarray
    events: list
    plan: Any
    stats: Dict[str, float]
    view: Any                          # PoolView for this step
    injected: set                      # servers killed mid-step (sched.)
    tasks_by: Dict[int, list]          # server -> [(q_tok, kv_tok), ...]
    preds: Dict[int, float]            # predicted primary seconds
    cm: CostModel
    speeds: Any
    speculate_pct: float


class ElasticExecutor:
    """Drives elastic steps for one :class:`CADSession` with an
    attached :class:`ServerPool` (``session.with_pool(pool)``).

    ``speculate_pct`` in (0, 1] arms straggler speculation: a server
    whose serve time exceeds ``quantile(predicted, pct) * slack`` is
    re-executed on the least-loaded survivors when the backup is
    modeled to finish earlier.  ``0`` disables speculation (failures
    are still recovered).

    ``run_step`` is ``begin_step`` (membership events, planning, cost
    predictions) followed by ``finish_step`` (execution, speculation,
    recovery, merge, calibration feedback) — split so the multi-tenant
    :class:`repro.fabric.FabricExecutor` can admit serve traffic
    against the predicted per-server loads before execution starts."""

    def __init__(self, session, *, faults: Optional[FaultSchedule] = None,
                 speculate_pct: float = 0.0,
                 speculate_slack: float = 1.5,
                 timer: str = "model",
                 feed_calibrator: bool = True,
                 recorder=None, metrics=None, clock=None):
        if session.pool is None:
            raise ValueError("session has no ServerPool; use "
                             "session.with_pool(ServerPool(...))")
        if session.pingpong:
            raise NotImplementedError(
                "the elastic executor drives single-phase plans; "
                "ping-pong interleaving stays on the fused path")
        if timer not in TIMERS:
            raise ValueError(f"timer must be one of {TIMERS}, got "
                             f"{timer!r}")
        if not 0.0 <= speculate_pct <= 1.0:
            raise ValueError(f"speculate_pct in [0, 1], got "
                             f"{speculate_pct}")
        self.session = session
        self.pool: ServerPool = session.pool
        self.faults = faults or FaultSchedule()
        self.speculate_pct = float(speculate_pct)
        self.speculate_slack = float(speculate_slack)
        self.timer = timer
        self.feed_calibrator = feed_calibrator
        # observability hooks: explicit instances pin the executor to a
        # recorder/registry; None defers to the process-global ones at
        # use time (so launch-flag enabling applies retroactively)
        self._recorder = recorder
        self._metrics = metrics
        self.clock = clock if clock is not None else MONOTONIC
        self._trace_t = 0.0        # cumulative step-timeline origin (s)
        self._cad = CADContext(cfg=session.cfg, kernel=session.kernel,
                               bwd=session.bwd, jmax=session.jmax,
                               mask=session.mask)

    @property
    def recorder(self) -> obs_trace.TraceRecorder:
        return self._recorder if self._recorder is not None \
            else obs_trace.get_recorder()

    @property
    def metrics(self) -> obs_metrics.MetricsRegistry:
        return self._metrics if self._metrics is not None \
            else obs_metrics.get_registry()

    # ------------------------------------------------------------ helpers
    def _cost_view(self):
        """(cost model, speeds) the step's predictions come from: the
        calibrator's current snapshot when attached, else the analytic
        base for the session's head geometry."""
        if self.session.calibrator is not None:
            snap = self.session.calibrator.snapshot()
            return snap.cost_model, snap.speeds_array()
        comm = self.session.comm
        cm = CostModel.analytic(comm.n_heads if comm else 1,
                                comm.head_dim if comm else 8)
        return cm, self.session.cfg.speeds()

    def _predict_server(self, cm: CostModel, speeds, tasks,
                        server: int) -> float:
        if not tasks:
            return 0.0
        t = float(sum(float(cm.predict(qt, kvt)) for qt, kvt in tasks))
        return t / float(speeds[server])

    def _recovery_memory(self, cfg, segs, plan, backups):
        """(MemoryModel, survivor resident bytes) for budget-aware
        recovery destination choice, or (None, None) when the session
        declares no HBM budgets.  The survivors' *primary* resident
        bytes are recovered from the executed plan's dispatch arrays so
        recovery lands on the survivors with genuine headroom
        (DESIGN.md §11)."""
        budgets = cfg.budgets()
        if budgets is None:
            return None, None
        comm = self.session.comm or CommModel(1, 1, 1)
        mem = MemoryModel(comm)
        docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk,
                                                   cfg.n_servers)
        mask = self.session.mask
        streamed = streamed_doc_ids(docs, cfg.blk, mem, budgets,
                                    stream_chunk=cfg.stream_chunk,
                                    allowed=backups, mask=mask)
        res = assignment_resident_bytes(
            assignment_of_plan(cfg, plan), doc_of, bi_of, cfg.blk,
            cfg.n_servers, mem, streamed=streamed,
            stream_chunk=cfg.stream_chunk, mask=mask)
        return mem, {s: float(res[s]) for s in backups}

    # ----------------------------------------------------------- stepping
    def run_step(self, step: int, q, k, v, pos, segment_ids: np.ndarray):
        """Execute one elastic step.  ``q``/``k``/``v`` are the stacked
        rank-major global layout ``[D*Bl, S, H(kv), dh]``, ``pos`` is
        ``[D*Bl, S]`` with -1 on padding, ``segment_ids`` the packed
        [D*Bl, S] (or [D, T]) layout.  Returns ``(out, StepReport)``;
        never raises on an injected fault — lost tasks are recovered
        (only an exhausted pool aborts)."""
        return self.finish_step(self.begin_step(step, q, k, v, pos,
                                                segment_ids))

    def begin_step(self, step: int, q, k, v, pos,
                   segment_ids: np.ndarray) -> StepState:
        """Membership events + planning + cost predictions — everything
        known *before* any server executes."""
        cfg = self.session.cfg

        # 1. scheduled membership: rejoins/drains land before planning
        # (shared semantics with the fused trainer path)
        events = list(self.faults.apply_pre_step(self.pool, step))

        segs = np.asarray(segment_ids).reshape(cfg.n_servers, -1)
        span_args = {"policy": self.session.plan_policy}
        with self.recorder.span("step.plan", "planner", step=step,
                                args=span_args):
            plan, stats = self.session.plan(segs)
            span_args["imbalance"] = stats.get("load_max_over_mean")
        view = self.pool.view()

        injected = {e.server for e in self.faults.failures_at(step)} \
            & set(view.active)
        tasks_by = {s: [] for s in range(cfg.n_servers)}
        # live kv tokens under the session mask: the calibrator keys its
        # grid on live tokens, so rectangle lengths would both mis-price
        # the straggler deadline and feed the wrong cells (DESIGN.md §12)
        for s, _slot, qt, kvt in iter_plan_tasks(cfg, plan,
                                                 self.session.mask):
            tasks_by[s].append((qt, kvt))
        cm, speeds = self._cost_view()
        preds = {s: self._predict_server(cm, speeds, tasks_by[s], s)
                 for s in view.active}
        if preds:
            vals = np.array([preds[s] for s in view.active])
            self.metrics.gauge(
                "cad_predicted_imbalance",
                "predicted per-server serve time max/mean at "
                "schedule time").set(
                float(vals.max() / max(vals.mean(), 1e-30)))
        return StepState(step=step, q=q, k=k, v=v, pos=pos, segs=segs,
                         events=events, plan=plan, stats=stats,
                         view=view, injected=injected, tasks_by=tasks_by,
                         preds=preds, cm=cm, speeds=speeds,
                         speculate_pct=self.speculate_pct)

    def finish_step(self, st: StepState):
        """Execute, speculate, recover and merge the step prepared by
        ``begin_step``.  Returns ``(out, StepReport)``."""
        cfg = self.session.cfg
        step, q, k, v, pos = st.step, st.q, st.k, st.v, st.pos
        events, plan, stats = st.events, st.plan, st.stats
        view, injected = st.view, st.injected
        tasks_by, preds = st.tasks_by, st.preds
        cm, speeds = st.cm, st.speeds
        segs = st.segs

        # 2. primary execution, one fused task batch per active server;
        # injected kills lose their tasks up front, a real serve raising
        # is demoted to a failure the same way (recover, then remove)
        failures = set(injected)
        inputs, plans_r = build_server_inputs(self._cad, plan, q, k, v,
                                              pos)

        outs: Dict[int, Any] = {}
        seconds: Dict[int, float] = {}
        for s in view.active:
            if s in failures:
                continue                      # tasks lost mid-serve
            slow = self.faults.slow_factor(step, s)
            try:
                if self.timer == "wall":
                    t0 = self.clock.monotonic()
                    outs[s] = jax.block_until_ready(
                        serve_task_batch(self._cad, inputs[s],
                                         plans_r[s]))
                    seconds[s] = (self.clock.monotonic() - t0) * slow
                else:
                    outs[s] = serve_task_batch(self._cad, inputs[s],
                                               plans_r[s])
                    seconds[s] = preds[s] * slow
            except Exception as exc:          # real task failure
                failures.add(s)
                outs.pop(s, None)
                seconds.pop(s, None)
                events.append(f"serve-error {s}: {type(exc).__name__}")

        failures = tuple(sorted(failures))
        healthy = [s for s in view.active if s not in failures]
        if not healthy:
            raise PoolExhaustedError(
                f"step {step}: every active server failed {failures}")

        # 3. straggler detection against the cost-model deadline
        # (st.speculate_pct, not self: the fabric zeroes it per-step
        # when serve traffic claims the speculation capacity)
        deadline = 0.0
        speculated: list = []
        if st.speculate_pct > 0 and len(healthy) > 1:
            deadline = float(np.quantile(
                [preds[s] for s in view.active], st.speculate_pct)) \
                * self.speculate_slack
            for s in healthy:
                if seconds[s] <= deadline or not tasks_by[s]:
                    continue
                backups = [x for x in healthy
                           if x != s and seconds[x] <= deadline]
                if not backups:
                    continue
                # speculate only when the backup is modeled to win
                spread = sum(float(cm.predict(qt, kvt))
                             for qt, kvt in tasks_by[s]) \
                    / float(sum(speeds[b] for b in backups))
                if deadline + spread < seconds[s]:
                    speculated.append(s)

        # 4. recovery sub-plan for lost + speculated tasks
        to_recover = tuple(failures) + tuple(speculated)
        rec = None
        rec_secs: Dict[int, float] = {}
        if to_recover:
            backups = [s for s in healthy if s not in speculated]
            if not backups:                    # nobody left to back up
                speculated = []
                to_recover = tuple(failures)
                backups = list(healthy)
            mem, base_res = self._recovery_memory(cfg, segs, plan,
                                                  backups)
            rec = build_recovery_plan(
                cfg, segs, plan, to_recover, allowed=backups,
                base_loads={s: seconds[s] for s in backups},
                cost_model=cm, speeds=speeds, mem_model=mem,
                base_resident=base_res,
                mask=self.session.mask) if to_recover else None
        base = assemble_step_outputs(cfg, plan, outs, q.shape, q.dtype)
        if rec is not None:
            rec_inputs, rec_plans = build_server_inputs(
                self._cad, rec.plan, q, k, v, pos)
            rec_outs = {}
            for s, added in rec.added_time.items():
                slow = self.faults.slow_factor(step, s)
                if self.timer == "wall":
                    t0 = self.clock.monotonic()
                    rec_outs[s] = jax.block_until_ready(serve_task_batch(
                        self._cad, rec_inputs[s], rec_plans[s]))
                    rec_secs[s] = (self.clock.monotonic() - t0) * slow
                else:
                    rec_outs[s] = serve_task_batch(
                        self._cad, rec_inputs[s], rec_plans[s])
                    rec_secs[s] = added * slow
            recovered = assemble_step_outputs(cfg, rec.plan, rec_outs,
                                              q.shape, q.dtype)
            out = merge_recovered(cfg, base, recovered, rec.lost)
        else:
            out = base

        # 5. completion accounting + calibration feedback
        detect = deadline if deadline > 0 else \
            max((seconds[s] for s in seconds), default=0.0)
        done = []
        for s in healthy:
            if s in speculated:
                continue
            t = seconds[s]
            if s in rec_secs:
                t = max(t, detect) + rec_secs[s]
            done.append(t)
        step_seconds = max(done, default=0.0)
        if self.feed_calibrator:
            for s in healthy:
                if tasks_by[s]:
                    self.session.observe_server(s, tasks_by[s],
                                                seconds[s])

        # 6. end-of-step membership consequences (shared semantics with
        # the fused trainer path; also fells draining servers so their
        # flap rejoins can fire later)
        events.extend(self.faults.apply_failures(self.pool, step))
        for s in failures:
            if s not in injected:             # real serve failure
                self.pool.remove(s)
                events.append(f"remove {s} (serve error)")

        report = StepReport(
            step=step, epoch=view.epoch, failed=failures,
            speculated=tuple(speculated),
            recovered_blocks=0 if rec is None else rec.n_blocks,
            server_seconds=dict(seconds), recovery_seconds=rec_secs,
            step_seconds=float(step_seconds), deadline=float(deadline),
            plan_stats=dict(stats), events=tuple(events))
        self._record_step(st, report, detect)
        return out, report

    def _record_step(self, st: StepState, report: StepReport,
                     detect: float) -> None:
        """Narrate one finished step: per-server spans on the cumulative
        step timeline (Perfetto gantt), fault/speculation instants, and
        the step's counters/gauges.  Strictly write-only — outputs are
        already merged by the time this runs (DESIGN.md §14)."""
        rec, mx = self.recorder, self.metrics
        t0, dur = self._trace_t, report.step_seconds
        self._trace_t = t0 + dur
        step = report.step
        if rec.enabled:
            rec.add_span("step", "step", t0, dur, step=step,
                         args={"epoch": report.epoch,
                               "failed": list(report.failed),
                               "speculated": list(report.speculated),
                               "recovered_blocks": report.recovered_blocks})
            for s, sec in sorted(report.server_seconds.items()):
                rec.add_span("serve", server_track(s), t0, sec, step=step,
                             args={"predicted": st.preds.get(s, 0.0),
                                   "n_tasks": len(st.tasks_by.get(s, ()))})
            for s in report.failed:
                name = "kill" if s in st.injected else "serve-error"
                rec.instant(name, server_track(s), ts=t0, step=step)
            for s in report.speculated:
                rec.instant("speculate", server_track(s),
                            ts=t0 + report.deadline, step=step,
                            args={"deadline": report.deadline})
            for s, rs in sorted(report.recovery_seconds.items()):
                start = t0 + max(report.server_seconds.get(s, 0.0),
                                 detect)
                rec.add_span("recover", server_track(s), start, rs,
                             step=step,
                             args={"recovered_from":
                                   list(report.failed)
                                   + list(report.speculated)})
            rec.instant("merge", "step", ts=t0 + dur, step=step,
                        args={"blocks": report.recovered_blocks})
        mx.counter("cad_steps_total", "elastic steps completed").inc()
        mx.counter("cad_failures_total",
                   "servers that lost tasks mid-step").inc(
            len(report.failed))
        mx.counter("cad_speculations_total",
                   "straggler speculative re-executions").inc(
            len(report.speculated))
        mx.counter("cad_recovered_blocks_total",
                   "q blocks re-dispatched by recovery").inc(
            report.recovered_blocks)
        mx.histogram("cad_step_seconds",
                     "modeled/measured step completion seconds").observe(
            report.step_seconds)
        mx.gauge("cad_pool_epoch", "pool membership epoch").set(
            report.epoch)
        resid = mx.gauge(
            "cad_calib_residual",
            "|predicted - measured| / measured serve seconds",
            labels=("server",))
        for s, sec in report.server_seconds.items():
            if st.tasks_by.get(s):
                resid.set(abs(st.preds.get(s, 0.0) - sec)
                          / max(sec, 1e-12), server=s)

    # ------------------------------------------------------ conveniences
    def synth_inputs(self, segment_ids: np.ndarray,
                     positions: np.ndarray, *, seed: int = 0,
                     dtype=jnp.float32):
        """Synthetic q/k/v (+ masked positions) matching the session's
        head geometry for a packed batch — benchmark/demo food."""
        comm = self.session.comm
        nh = comm.n_heads if comm else 1
        dh = comm.head_dim if comm else 8
        hkv = comm.n_kv_heads if comm else nh
        segs = np.asarray(segment_ids)
        rows, s_len = segs.shape
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(kq, (rows, s_len, nh, dh), dtype)
        k = jax.random.normal(kk, (rows, s_len, hkv, dh), dtype)
        v = jax.random.normal(kv, (rows, s_len, hkv, dh), dtype)
        pos = jnp.asarray(np.where(segs > 0, positions, -1)
                          .astype(np.int32))
        return q, k, v, pos
