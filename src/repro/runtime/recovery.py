"""Recovery sub-plans: re-dispatch a failed server's CA tasks.

Core attention is stateless (the paper's central observation): a CA
task is a pure function of the q block and its document's kv prefix,
both of which the *data ranks* still hold when an attention server
dies.  Recovery is therefore just planning again — a **sub-plan** over
exactly the lost q blocks, built by the very same
``plan_from_assignment`` machinery as the primary plan, so every
capacity check, kv-prefix invariant and dispatch-array layout is
shared with the normal path.

Exactly-once + bit-identical merging: a sub-plan's tasks are the lost
blocks and nothing else, so scattering its outputs touches exactly the
blocks the primary scatter left empty; the merge is a bitwise *select*
per block (``core.dispatch.merge_recovered``), never a floating-point
accumulation across executions.  Because every kernel in the path
computes a task identically regardless of which server runs it, the
merged step output is bit-identical to a fault-free run of the same
batch on the reduced pool (DESIGN.md §9; asserted by
``tests/test_elastic.py`` and ``benchmarks/elastic_recovery.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core.cost_model import CommModel, CostModel, MemoryModel
from repro.core.mask import MaskSpec
from repro.core.plan import CADConfig, StepPlan, plan_from_assignment
from repro.core.scheduler import (block_costs, layout_from_segments,
                                  streamed_doc_ids)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def assignment_of_plan(cfg: CADConfig, plan) -> np.ndarray:
    """Recover the per-block server assignment from a plan's dispatch
    arrays — what would *actually execute*, not what a scheduler
    claims.  Blocks not appearing as tasks (padding) keep their home
    rank."""
    d, nb = cfg.n_servers, cfg.nb
    assign = np.arange(d * nb) // nb
    q_send = np.asarray(plan["q_send_idx"])
    for src in range(d):
        for dst in range(d):
            for c in q_send[src, dst]:
                if c >= 0:
                    assign[src * nb + int(c)] = dst
    return assign


def lost_block_mask(cfg: CADConfig, plan, failed: Iterable[int],
                    doc_of: Optional[np.ndarray] = None) -> np.ndarray:
    """Boolean [D*NB]: live q blocks whose serving server failed."""
    assign = assignment_of_plan(cfg, plan)
    failed = set(int(s) for s in failed)
    lost = np.isin(assign, sorted(failed))
    if doc_of is not None:
        lost &= doc_of >= 0
    else:
        # blocks with no task on any server are padding, never lost
        live = np.zeros(cfg.n_servers * cfg.nb, bool)
        kv_len = np.asarray(plan["task_kv_len"])
        q_home = np.asarray(plan["q_home_idx"])
        for s in range(cfg.n_servers):
            for slot in range(kv_len.shape[1]):
                if kv_len[s, slot] > 0:
                    g = _task_q_block(cfg, q_home, plan, s, slot)
                    if g is not None:
                        live[g] = True
        lost &= live
    return lost


def _task_q_block(cfg, q_home, plan, server, slot):
    nb, cq = cfg.nb, cfg.cq
    if slot < nb:
        idx = int(q_home[server, slot])
        return server * nb + idx if idx >= 0 else None
    src, c = divmod(slot - nb, cq)
    idx = int(np.asarray(plan["q_send_idx"])[src, server, c])
    return src * nb + idx if idx >= 0 else None


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    """A recovery sub-plan: the typed StepPlan whose only live tasks
    are the lost blocks, the [D*NB] lost-block mask to merge by, and
    the per-survivor modeled time the recovery adds."""
    plan: StepPlan
    lost: np.ndarray                    # [D*NB] bool
    assign: np.ndarray                  # [G] full assignment (lost only
    #                                     meaningful where ``lost``)
    added_time: Dict[int, float]        # survivor -> modeled seconds

    @property
    def n_blocks(self) -> int:
        return int(self.lost.sum())


def build_recovery_plan(cfg: CADConfig, segment_ids: np.ndarray, plan,
                        failed: Iterable[int], *,
                        allowed: Iterable[int],
                        base_loads: Optional[Dict[int, float]] = None,
                        cost_model: Optional[CostModel] = None,
                        speeds: Optional[np.ndarray] = None,
                        mem_model: Optional[MemoryModel] = None,
                        budgets: Optional[np.ndarray] = None,
                        base_resident: Optional[Dict[int, float]] = None,
                        stream_chunk: Optional[int] = None,
                        mask: Optional[MaskSpec] = None) \
        -> Optional[RecoveryPlan]:
    """Build the sub-plan that recomputes every task lost on ``failed``
    onto ``allowed`` survivors.

    Each maximal contiguous run of lost blocks within one document is
    dealt whole to the survivor with the least (base + already-added)
    modeled time — contiguous runs keep each kv prefix send a single
    range, the comm-minimal granularity of the primary scheduler.
    ``base_loads`` carries the survivors' primary-serve times so
    recovery lands on the least-busy endpoints first.  Returns ``None``
    when the failure lost no live tasks (nothing to recover).

    With ``budgets`` (per-endpoint HBM bytes, defaulting to
    ``cfg.budgets()``; ``base_resident`` carries the survivors'
    primary resident bytes) destination choice is memory-aware:
    survivors whose resident bytes would overflow are skipped while
    any in-budget survivor remains.  When *no* survivor fits — a
    recovery has nowhere cheaper to go — the least-loaded survivor
    takes the run anyway: with ``stream_chunk`` set, dispatch streams
    the kv prefix chunkwise so hardware residency stays bounded; a
    lost task is never dropped for memory (DESIGN.md §11).

    ``mask`` is the session's :class:`~repro.core.mask.MaskSpec`: run
    pricing and the incremental kv view both use *live*-block costs
    (DESIGN.md §12), so doc-masked recovery lands where the real
    compute is cheapest — area pricing would deal deep (area-heavy,
    mask-cheap) runs as if they were expensive and skew the survivor
    balance.  Every elastic pricing path must consume mask-aware costs
    (DESIGN.md §9)."""
    failed = sorted({int(s) for s in failed})
    allowed = sorted({int(s) for s in allowed})
    if not allowed:
        raise ValueError("recovery needs at least one surviving server")
    if set(allowed) & set(failed):
        raise ValueError(f"survivors {allowed} overlap failures {failed}")
    docs, doc_of, bi_of = layout_from_segments(segment_ids, cfg.blk,
                                               cfg.n_servers)
    lost = lost_block_mask(cfg, plan, failed, doc_of)
    if not lost.any():
        return None
    speeds = cfg.speeds() if speeds is None \
        else np.asarray(speeds, np.float64)
    cost = block_costs(doc_of, bi_of, cfg.blk, cost_model, mask)
    loads = {s: float((base_loads or {}).get(s, 0.0)) for s in allowed}
    added = {s: 0.0 for s in allowed}

    if budgets is None:
        budgets = cfg.budgets()
    chunk = cfg.stream_chunk if stream_chunk is None else int(stream_chunk)
    mem = streamed = resident = kv_need = None
    if budgets is not None:
        budgets = np.asarray(budgets, np.float64)
        mem = mem_model or MemoryModel(CommModel(1, 1, 1))
        streamed = set(streamed_doc_ids(docs, cfg.blk, mem, budgets,
                                        stream_chunk=chunk,
                                        allowed=allowed))
        q_unit = mem.q_bytes(cfg.blk) + mem.residual_bytes(cfg.blk)
        resident = {s: float((base_resident or {}).get(s, 0.0))
                    for s in allowed}
        kv_need = {s: {} for s in allowed}

    def mem_add(s: int, dc: int, pref: int, n_q: int) -> float:
        """Incremental resident bytes if survivor ``s`` takes a run of
        ``n_q`` blocks of doc ``dc`` needing kv prefix ``pref`` — the
        ``live_kv_bytes`` view under a mask (prefix-live difference),
        reducing exactly to the dense increment when the mask is
        trivial."""
        p = min(pref, chunk) if dc in streamed else pref
        have = min(kv_need[s].get(dc, 0), p)
        kv = mem.live_kv_bytes(p * cfg.blk, mask, cfg.blk) \
            - mem.live_kv_bytes(have * cfg.blk, mask, cfg.blk)
        return q_unit * n_q + max(0.0, kv)

    assign = np.arange(cfg.n_servers * cfg.nb) // cfg.nb
    masked_doc_of = np.where(lost, doc_of, -1)
    # maximal contiguous lost runs, document-pure, dealt to the least
    # loaded survivor (deterministic tie-break: lowest slot)
    g = 0
    G = cfg.n_servers * cfg.nb
    while g < G:
        if not lost[g]:
            g += 1
            continue
        dc = int(doc_of[g])
        h = g
        while h < G and lost[h] and int(doc_of[h]) == dc:
            h += 1
        run_cost = float(cost[g:h].sum())
        pool = allowed
        if mem is not None:
            pref = int(bi_of[h - 1]) + 1
            fits = [s for s in allowed
                    if resident[s] + mem_add(s, dc, pref, h - g)
                    <= budgets[s]]
            pool = fits or allowed     # never drop a lost task
        dst = min(pool,
                  key=lambda s: (loads[s] + run_cost / speeds[s], s))
        assign[g:h] = dst
        loads[dst] += run_cost / speeds[dst]
        added[dst] += run_cost / speeds[dst]
        if mem is not None:
            resident[dst] += mem_add(dst, dc, pref, h - g)
            p = min(pref, chunk) if dc in streamed else pref
            kv_need[dst][dc] = max(kv_need[dst].get(dc, 0), p)
        g = h
    sub = plan_from_assignment(cfg, assign, masked_doc_of, bi_of, docs)
    out = RecoveryPlan(plan=sub, lost=lost, assign=assign,
                       added_time={s: t for s, t in added.items()
                                   if t > 0})
    # narrate the sub-plan itself (DESIGN.md §14): the executor times
    # and spans its *execution*; this is the planning decision
    obs_trace.get_recorder().instant(
        "recovery.plan", "planner",
        args={"failed": failed, "n_blocks": out.n_blocks,
              "destinations": sorted(out.added_time)})
    reg = obs_metrics.get_registry()
    reg.counter("cad_recovery_plans_total",
                "recovery sub-plans built").inc()
    reg.counter("cad_recovery_blocks_planned_total",
                "lost q blocks routed to survivors").inc(out.n_blocks)
    return out


def recovery_tasks(cfg: CADConfig, rec: RecoveryPlan,
                   mask: Optional[MaskSpec] = None) \
        -> Dict[int, Tuple[Tuple[int, int], ...]]:
    """Per-survivor (q_tokens, kv_tokens) task shapes of a recovery
    sub-plan — calibrator food and modeled-time input.  With ``mask``
    the kv lengths are the tasks' *live* kv tokens, matching the grid
    cells masked primary serves calibrate (DESIGN.md §12)."""
    from repro.core.dispatch import iter_plan_tasks
    out: Dict[int, list] = {}
    for s, _slot, qt, kvt in iter_plan_tasks(cfg, rec.plan, mask):
        out.setdefault(s, []).append((qt, kvt))
    return {s: tuple(v) for s, v in out.items()}
