"""Sharding-aware pytree checkpointing (no external deps).

Layout: one .npz per checkpoint step holding flattened leaves keyed by
their tree path, plus a metadata json.  On restore the arrays are
device_put with the caller's shardings (or left as host arrays).

Runtime-calibration state (the :class:`GridCalibrator` latency grid +
per-server speed ratios, DESIGN.md §3) rides along in the metadata
json: pass ``calibrator=`` to :func:`save` and call
:func:`restore_calibration` after a restart so the measured cost model
survives — a restore from an older checkpoint without calibration
state is a silent no-op (the calibrator simply keeps its base model).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return paths, leaves, treedef


def save(path: str, step: int, params: Any, opt_state: Any = None,
         extra: Optional[dict] = None, calibrator: Any = None) -> str:
    os.makedirs(path, exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    paths, leaves, _ = _flatten(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    np.savez(fname, **arrays)
    extra = dict(extra or {})
    if calibrator is not None:
        extra["calibration"] = calibrator.state_dict()
    meta = {"step": step, "paths": paths,
            "extra": extra}
    with open(fname + ".json", "w") as f:
        json.dump(meta, f)
    return fname


def read_meta(path: str, step: int) -> dict:
    """The metadata json saved alongside a checkpoint step."""
    fname = os.path.join(path, f"ckpt_{step:08d}.npz.json")
    with open(fname) as f:
        return json.load(f)


def restore_calibration(path: str, step: int, calibrator: Any) -> bool:
    """Load a checkpoint's calibration state into ``calibrator``
    (:meth:`GridCalibrator.load_state_dict`).  Returns True when state
    was restored; False — leaving the calibrator untouched — for
    checkpoints written before calibration rode along (older seeds),
    saved without a calibrator, or whose state describes a different
    pool geometry (e.g. a shared ckpt dir reused across runs with a
    different server count)."""
    try:
        meta = read_meta(path, step)
    except FileNotFoundError:
        return False
    state = (meta.get("extra") or {}).get("calibration")
    if not state:
        return False
    try:
        calibrator.load_state_dict(state)
    except ValueError as e:
        print(f"note: ignoring checkpoint calibration state: {e}")
        return False
    return True


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """``like`` provides the target treedef (e.g. init params/opt_state)."""
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    data = np.load(fname)
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, model has {len(leaves)}"
    new_leaves = [data[f"a{i}"] for i in range(len(leaves))]
    for old, new in zip(leaves, new_leaves):
        assert old.shape == new.shape, (old.shape, new.shape)
    restored = jax.tree.unflatten(treedef, new_leaves)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    return restored
