from repro.pipeline_par.pipeline import (pipeline_apply, split_stages,
                                         tick_schedules)

__all__ = ["pipeline_apply", "split_stages", "tick_schedules"]
