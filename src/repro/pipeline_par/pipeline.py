"""Pipeline parallelism with CAD integration (paper §4.1, Figure 8).

GPipe-style schedule expressed as a scan over logical *ticks* inside a
shard_map over the "stage" mesh axis: at tick t, stage s processes
microbatch (t - s); activations move stage-to-stage with ppermute.  All
stages perform the same phase within a tick — the adjustment the paper
makes so devices can switch roles between layer compute and attention
serving.

CAD-PP integration: core attention has no weights, so the CA-tasks of the
microbatches live at *different stages* are indistinguishable; the
scheduler balances them over the whole stage pool per tick.  During
warm-up/drain ticks, idle stages carry zero local load and the scheduler
naturally assigns them other stages' CA-tasks — the paper's "repurpose
idle GPUs as attention servers" falls out of the plan machinery with no
special casing.

The backward pass is jax.grad through the tick scan: ppermute transposes
to the reverse rotation, yielding the mirrored backward pipeline
automatically.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CommModel
from repro.core.plan import CADConfig, empty_plan, plan_from_schedule
from repro.core.scheduler import schedule


def split_stages(block_params, n_stages: int):
    """Stack-split the scan-over-groups params [G, ...] into
    [n_stages, G/n_stages, ...] (leading dim sharded over "stage")."""
    def split(x):
        g = x.shape[0]
        assert g % n_stages == 0, (g, n_stages)
        return x.reshape((n_stages, g // n_stages) + x.shape[1:])
    return jax.tree.map(split, block_params)


def pipeline_apply(stage_params, h_mb, stage_fn: Callable, *,
                   n_stages: int, axis: str = "stage",
                   plans=None):
    """Run the pipeline.  Must be called INSIDE shard_map over ``axis``.

    stage_params: this stage's slice (leading stage dim already consumed)
    h_mb   [n_micro, Bm, S, D] microbatch inputs (replicated; only stage 0
           reads them)
    stage_fn(params, h, mb_index, tick_plan) -> h
    plans  optional per-tick CAD plan rows for THIS stage (leading dim
           n_ticks), passed through to stage_fn

    Returns [n_micro, Bm, S, D] — the last stage's outputs, replicated to
    every stage via a masked psum."""
    sid = jax.lax.axis_index(axis)
    n_micro = h_mb.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    h0 = jnp.zeros_like(h_mb[0])
    outs0 = jnp.zeros_like(h_mb)

    def tick(carry, t):
        h_buf, outs = carry
        m = t - sid
        active = (m >= 0) & (m < n_micro)
        m_c = jnp.clip(m, 0, n_micro - 1)
        h_in = jnp.where(sid == 0, h_mb[m_c], h_buf)
        tick_plan = None if plans is None else \
            jax.tree.map(lambda a: a[t], plans)
        h_out = stage_fn(h_in, m_c, tick_plan)
        h_out = jnp.where(active, h_out, jnp.zeros_like(h_out))
        # collect at the last stage
        take = active & (sid == n_stages - 1)
        outs = outs.at[m_c].set(
            jnp.where(take, h_out, outs[m_c]))
        # rotate activations to the next stage
        h_next = jax.lax.ppermute(h_out, axis, perm)
        return (h_next, outs), None

    (_, outs), _ = jax.lax.scan(tick, (h0, outs0), jnp.arange(n_ticks))
    # replicate the last stage's outputs
    mask = (sid == n_stages - 1).astype(outs.dtype)
    return jax.lax.psum(outs * mask, axis)


def tick_schedules(segs_mb: np.ndarray, n_stages: int, cadcfg: CADConfig,
                   comm: CommModel, tolerance: float = 0.1):
    """Host-side: build one CAD plan per pipeline tick.

    segs_mb [n_micro, tokens_mb]: each microbatch's packed segment ids.
    At tick t, stage s serves microbatch (t - s); inactive stages carry a
    zero chunk (warm-up/drain) and the scheduler offloads CA-tasks of the
    busy stages onto them.  Returns stacked plan arrays with a leading
    n_ticks dim (each plan's own leading dim is the stage/server dim) and
    the per-tick schedule stats."""
    n_micro, tokens = segs_mb.shape
    n_ticks = n_micro + n_stages - 1
    plans: List[Dict[str, np.ndarray]] = []
    stats = []
    for t in range(n_ticks):
        segs_tick = np.zeros((n_stages, tokens), segs_mb.dtype)
        for s in range(n_stages):
            m = t - s
            if 0 <= m < n_micro:
                # offset segment ids so docs of different microbatches
                # stay distinct
                seg = segs_mb[m]
                segs_tick[s] = np.where(seg > 0, seg + m * 100000, 0)
        sch = schedule(segs_tick, blk=cadcfg.blk, n_servers=n_stages,
                       comm=comm, caps=cadcfg.caps(), tolerance=tolerance)
        plans.append(plan_from_schedule(cadcfg, sch))
        stats.append({"tick": t, "moves": sch.n_moves,
                      "comm_bytes": sch.comm_bytes,
                      "loads": sch.loads.copy()})
    stacked = {k: np.stack([p[k] for p in plans])
               for k in plans[0].keys()}
    return stacked, stats
