"""Flight recorder: thread-safe, ring-buffered structured tracing
(DESIGN.md §14).

``TraceRecorder`` collects **spans** (named intervals with a duration)
and **instant events** on named *tracks* — one track per attention
server (``server/0`` …), plus ``planner``, ``prefetch``, ``pool``,
``fabric``, ``serve`` and ``step``.  The buffer is a bounded ring: at
capacity the oldest events are overwritten (``n_dropped`` counts the
overwrites), so a recorder can stay attached to a week-long run
without growing.

Two timestamp sources coexist deliberately:

  * host-side spans (plan build, prefetch, probes, serve rounds) are
    measured with the recorder's injectable :class:`~repro.obs.clock.
    Clock` (``span(...)`` context manager);
  * step-execution spans carry **explicit** timestamps on a synthetic
    per-run timeline (``add_span``): the elastic executor lays each
    step's per-server serve/recovery intervals out in modeled or
    measured seconds from a cumulative origin, so the exported trace
    renders as the paper's per-server gantt regardless of which timer
    produced the numbers.

Export is Chrome-trace/Perfetto JSON (``to_chrome_trace`` / ``save``):
every track becomes one named thread, spans are complete ("X") events,
instants are "i" events, and timestamps are microseconds.  Load the
file in ``ui.perfetto.dev`` or ``chrome://tracing`` as-is.

The disabled recorder is a true no-op: every method returns before
touching the buffer, ``span()`` hands back a shared null context
manager, and — the contract ``benchmarks/obs_overhead.py`` enforces —
enabling tracing never changes a single output bit, only what gets
recorded about producing them.

Process-global wiring: components default to :func:`get_recorder`,
which starts **disabled**.  ``enable_tracing()`` swaps in a live
recorder (``launch/train.py --trace`` / test fixtures);
``disable_tracing()`` restores the no-op.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

from repro.obs.clock import MONOTONIC, Clock

SPAN = "X"          # Chrome-trace complete event
INSTANT = "i"       # Chrome-trace instant event


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded span or instant.  ``ts``/``dur`` are seconds on the
    recorder's timeline; ``track`` names the gantt row; ``step`` (when
    known) groups events for per-step attribution."""
    ph: str                      # SPAN | INSTANT
    name: str
    track: str
    ts: float
    dur: float = 0.0
    step: Optional[int] = None
    args: Optional[Dict[str, Any]] = None


class _NullSpan:
    """Shared no-op context manager for disabled recorders."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    __slots__ = ("_rec", "_name", "_track", "_step", "_args", "_t0")

    def __init__(self, rec: "TraceRecorder", name: str, track: str,
                 step: Optional[int], args: Optional[Dict[str, Any]]):
        self._rec = rec
        self._name, self._track = name, track
        self._step, self._args = step, args

    def __enter__(self):
        self._t0 = self._rec.clock.monotonic()
        return self

    def __exit__(self, *exc):
        t1 = self._rec.clock.monotonic()
        self._rec.add_span(self._name, self._track, self._t0,
                           t1 - self._t0, step=self._step,
                           args=self._args)
        return False


class TraceRecorder:
    """Bounded, thread-safe event ring.

    ``capacity`` bounds the retained event count; older events are
    overwritten once full.  ``enabled=False`` builds the permanent
    no-op recorder (no buffer is ever touched).
    """

    def __init__(self, capacity: int = 65536, *, enabled: bool = True,
                 clock: Optional[Clock] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.clock: Clock = clock if clock is not None else MONOTONIC
        self._lock = threading.Lock()
        self._ring: list = [None] * self.capacity
        self._head = 0               # next write index
        self._count = 0              # live events (<= capacity)
        self._dropped = 0            # overwrites

    # ------------------------------------------------------------ record
    def span(self, name: str, track: str, *, step: Optional[int] = None,
             args: Optional[Dict[str, Any]] = None):
        """Context manager measuring a host-side span with the clock."""
        if not self.enabled:
            return _NULL_SPAN
        return _LiveSpan(self, name, track, step, args)

    def add_span(self, name: str, track: str, ts: float, dur: float, *,
                 step: Optional[int] = None,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Record a span with explicit timestamps (synthetic or modeled
        timelines — the executor's per-server serve intervals)."""
        if not self.enabled:
            return
        self._push(TraceEvent(SPAN, name, track, float(ts),
                              max(0.0, float(dur)), step=step, args=args))

    def instant(self, name: str, track: str, *,
                ts: Optional[float] = None, step: Optional[int] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        """Record a point event (kill, epoch bump, admission round)."""
        if not self.enabled:
            return
        t = self.clock.monotonic() if ts is None else float(ts)
        self._push(TraceEvent(INSTANT, name, track, t, step=step,
                              args=args))

    def _push(self, ev: TraceEvent) -> None:
        with self._lock:
            if self._count == self.capacity:
                self._dropped += 1
            else:
                self._count += 1
            self._ring[self._head] = ev
            self._head = (self._head + 1) % self.capacity

    # ------------------------------------------------------------- views
    @property
    def n_dropped(self) -> int:
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return self._count

    def events(self) -> Tuple[TraceEvent, ...]:
        """Snapshot in record order (oldest retained first)."""
        with self._lock:
            if self._count < self.capacity:
                return tuple(self._ring[:self._count])
            h = self._head
            return tuple(self._ring[h:] + self._ring[:h])

    def clear(self) -> None:
        with self._lock:
            self._ring = [None] * self.capacity
            self._head = self._count = self._dropped = 0

    # ------------------------------------------------------------ export
    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome-trace/Perfetto JSON object: one named thread per
        track, microsecond timestamps, args carried through (plus the
        step for per-step attribution)."""
        evs = self.events()
        tracks = sorted({e.track for e in evs})
        tid = {t: i + 1 for i, t in enumerate(tracks)}
        out = [{"ph": "M", "pid": 1, "tid": tid[t], "name": "thread_name",
                "args": {"name": t}} for t in tracks]
        for e in evs:
            args = {k: _jsonable(v) for k, v in (e.args or {}).items()}
            if e.step is not None:
                args["step"] = int(e.step)
            rec = {"ph": e.ph, "name": e.name, "pid": 1,
                   "tid": tid[e.track], "ts": e.ts * 1e6, "args": args}
            if e.ph == SPAN:
                rec["dur"] = e.dur * 1e6
            else:
                rec["s"] = "t"      # instant scope: thread
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.n_dropped}}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=None,
                      separators=(",", ":"))

    # ----------------------------------------------------------- queries
    def iter_steps(self) -> Iterator[int]:
        seen = []
        for e in self.events():
            if e.step is not None and e.step not in seen:
                seen.append(e.step)
        return iter(seen)


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    try:
        return float(v)            # numpy scalars
    except (TypeError, ValueError):
        return str(v)


# ------------------------------------------------------------ global hook
_NULL_RECORDER = TraceRecorder(capacity=1, enabled=False)
_default: TraceRecorder = _NULL_RECORDER
_default_lock = threading.Lock()


def get_recorder() -> TraceRecorder:
    """The process-global recorder components default to.  Starts as
    the disabled no-op; ``enable_tracing()`` swaps in a live one."""
    return _default


def set_recorder(rec: Optional[TraceRecorder]) -> TraceRecorder:
    """Install ``rec`` as the global recorder (None restores the
    no-op).  Returns the recorder now installed."""
    global _default
    with _default_lock:
        _default = rec if rec is not None else _NULL_RECORDER
        return _default


def enable_tracing(capacity: int = 65536, *,
                   clock: Optional[Clock] = None) -> TraceRecorder:
    """Install and return a fresh live global recorder."""
    return set_recorder(TraceRecorder(capacity, clock=clock))


def disable_tracing() -> None:
    """Restore the disabled no-op global recorder."""
    set_recorder(None)
