"""Labeled metrics registry: counters, gauges, histograms
(DESIGN.md §14).

The quantitative half of the flight recorder: where the
:class:`~repro.obs.trace.TraceRecorder` answers "what happened when",
the registry answers "how much, how often, how big" — cumulative
counters (steps, failures, recovered blocks, admissions), point-in-time
gauges (pool epoch, calibration version, predicted imbalance, per-server
calibration residuals) and bucketed histograms (step seconds, queue
waits).

Design mirrors the Prometheus client model, stdlib-only:

  * a metric *family* has a name, a kind, help text and a fixed label
    name tuple; each distinct label-value combination is one series;
  * ``inc``/``set``/``observe`` take the label values as keyword args
    (``reg.counter("cad_failures_total", labels=("server",))
    .inc(server="2")``);
  * export is Prometheus text exposition (``to_text`` — what the serve
    daemon's ``GET /metrics`` returns) and a JSON-able dict
    (``to_dict``/``from_dict`` round-trip exactly — artifact files).

All mutation is lock-protected; reads snapshot under the same lock.
Metric updates never feed back into planning or execution — the
registry is write-only from the runtime's point of view, so recording
can never perturb outputs.

A process-global default registry (``get_registry``) is always live:
single float/dict updates are cheap enough to leave on
unconditionally, unlike tracing.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, Optional, Tuple

COUNTER, GAUGE, HISTOGRAM = "counter", "gauge", "histogram"

#: Default histogram buckets (seconds-flavored, powers of ~4).
DEFAULT_BUCKETS = (1e-4, 4e-4, 1.6e-3, 6.4e-3, 2.56e-2, 0.1024,
                   0.4096, 1.6384, 6.5536)


class _Hist:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets       # per-bucket (non-cumulative)
        self.sum = 0.0
        self.count = 0


class MetricFamily:
    """One named metric and all its labeled series."""

    def __init__(self, registry: "MetricsRegistry", name: str, kind: str,
                 help: str, labelnames: Tuple[str, ...],
                 buckets: Tuple[float, ...] = ()):
        self.registry = registry
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = labelnames
        self.buckets = buckets
        self._series: Dict[Tuple[str, ...], Any] = {}

    # ------------------------------------------------------------ series
    def _key(self, labels: Dict[str, Any]) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if self.kind != COUNTER:
            raise TypeError(f"{self.name} is a {self.kind}, not a counter")
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up "
                             f"(got {amount})")
        key = self._key(labels)
        with self.registry._lock:
            self._series[key] = self._series.get(key, 0.0) + float(amount)

    def set(self, value: float, **labels: Any) -> None:
        if self.kind != GAUGE:
            raise TypeError(f"{self.name} is a {self.kind}, not a gauge")
        key = self._key(labels)
        with self.registry._lock:
            self._series[key] = float(value)

    def observe(self, value: float, **labels: Any) -> None:
        if self.kind != HISTOGRAM:
            raise TypeError(f"{self.name} is a {self.kind}, not a "
                            f"histogram")
        key = self._key(labels)
        v = float(value)
        with self.registry._lock:
            h = self._series.get(key)
            if h is None:
                h = self._series[key] = _Hist(len(self.buckets) + 1)
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            h.counts[i] += 1
            h.sum += v
            h.count += 1

    # ------------------------------------------------------------- reads
    def value(self, **labels: Any) -> Optional[float]:
        """Current value of one series (histograms: the sum)."""
        key = self._key(labels)
        with self.registry._lock:
            v = self._series.get(key)
        if isinstance(v, _Hist):
            return v.sum
        return v

    def series(self) -> Dict[Tuple[str, ...], Any]:
        with self.registry._lock:
            return dict(self._series)


class MetricsRegistry:
    """All metric families for one process (or one test)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, MetricFamily] = {}

    # ------------------------------------------------------- registration
    def _family(self, name: str, kind: str, help: str,
                labels: Iterable[str],
                buckets: Tuple[float, ...] = ()) -> MetricFamily:
        labelnames = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name} re-registered as {kind}"
                        f"{labelnames}, was {fam.kind}{fam.labelnames}")
                return fam
            fam = MetricFamily(self, name, kind, help, labelnames,
                               buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()) -> MetricFamily:
        return self._family(name, COUNTER, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Iterable[str] = ()) -> MetricFamily:
        return self._family(name, GAUGE, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = (),
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) \
            -> MetricFamily:
        return self._family(name, HISTOGRAM, help, labels,
                            tuple(float(b) for b in buckets))

    def families(self) -> Tuple[MetricFamily, ...]:
        with self._lock:
            return tuple(self._families[k]
                         for k in sorted(self._families))

    # ------------------------------------------------------------- export
    def to_text(self) -> str:
        """Prometheus text exposition format (``GET /metrics``)."""
        lines = []
        for fam in self.families():
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, val in sorted(fam.series().items()):
                lbl = ",".join(f'{n}="{v}"'
                               for n, v in zip(fam.labelnames, key))
                if fam.kind != HISTOGRAM:
                    lines.append(f"{fam.name}{{{lbl}}} {val:g}" if lbl
                                 else f"{fam.name} {val:g}")
                    continue
                cum = 0
                edges = [f"{b:g}" for b in fam.buckets] + ["+Inf"]
                for i, le in enumerate(edges):
                    cum += val.counts[i]
                    sep = "," if lbl else ""
                    lines.append(
                        f'{fam.name}_bucket{{{lbl}{sep}le="{le}"}} {cum}')
                base = f"{{{lbl}}}" if lbl else ""
                lines.append(f"{fam.name}_sum{base} {val.sum:g}")
                lines.append(f"{fam.name}_count{base} {val.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_dict(self) -> Dict[str, Any]:
        """JSON-able snapshot; ``from_dict`` round-trips it exactly."""
        out: Dict[str, Any] = {}
        for fam in self.families():
            samples = []
            for key, val in sorted(fam.series().items()):
                labels = dict(zip(fam.labelnames, key))
                if fam.kind == HISTOGRAM:
                    samples.append({"labels": labels,
                                    "buckets": list(val.counts),
                                    "sum": val.sum, "count": val.count})
                else:
                    samples.append({"labels": labels, "value": val})
            out[fam.name] = {"kind": fam.kind, "help": fam.help,
                             "labels": list(fam.labelnames),
                             "buckets": list(fam.buckets),
                             "samples": samples}
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MetricsRegistry":
        reg = cls()
        for name, fd in d.items():
            fam = reg._family(name, fd["kind"], fd.get("help", ""),
                              fd.get("labels", ()),
                              tuple(fd.get("buckets", ())))
            for s in fd.get("samples", ()):
                key = fam._key(s.get("labels", {}))
                if fam.kind == HISTOGRAM:
                    h = _Hist(len(fam.buckets) + 1)
                    h.counts = list(s["buckets"])
                    h.sum, h.count = float(s["sum"]), int(s["count"])
                    fam._series[key] = h
                else:
                    fam._series[key] = float(s["value"])
        return reg


# ------------------------------------------------------------ global hook
_default = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-global registry the runtime records into."""
    return _default


def set_registry(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Install ``reg`` (None installs a fresh empty registry)."""
    global _default
    with _default_lock:
        _default = reg if reg is not None else MetricsRegistry()
        return _default
