"""Injectable monotonic clocks (DESIGN.md §14).

Every timer read in the runtime — the elastic executor's ``"wall"``
serve timings, trace span boundaries, serve-round latencies — goes
through a :class:`Clock` rather than calling ``time.perf_counter()``
directly.  Production code uses :data:`MONOTONIC` (a thin
``perf_counter`` wrapper); timing-dependent tests install a
:class:`FakeClock` and *script* the passage of time instead of
sleeping, so "this server took 3x longer" is a deterministic fixture,
not a flaky race.

``FakeClock.tick`` is the auto-advance: each ``monotonic()`` read
moves the clock forward by a fixed amount, which makes paired
start/stop reads measure exactly ``tick`` seconds — enough to drive
the executor's wall timer through a whole fault-injected run with
reproducible per-server seconds.
"""
from __future__ import annotations

import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """Anything with a monotonic ``monotonic() -> float`` (seconds)."""

    def monotonic(self) -> float: ...


class MonotonicClock:
    """The real thing: ``time.perf_counter``."""

    def monotonic(self) -> float:
        return time.perf_counter()


class FakeClock:
    """A scripted clock for deterministic timing tests.

    ``tick`` auto-advances the clock by that many seconds on every
    ``monotonic()`` read; ``advance()`` moves it explicitly.  Reads are
    monotone non-decreasing by construction.
    """

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        if tick < 0:
            raise ValueError(f"tick must be >= 0, got {tick}")
        self._now = float(start)
        self.tick = float(tick)
        self.reads = 0

    def monotonic(self) -> float:
        t = self._now
        self._now += self.tick
        self.reads += 1
        return t

    def advance(self, seconds: float) -> float:
        """Move time forward; returns the new now."""
        if seconds < 0:
            raise ValueError(f"cannot advance by {seconds} (monotonic)")
        self._now += float(seconds)
        return self._now


#: Process-wide default — the real monotonic clock.
MONOTONIC = MonotonicClock()
