"""Observability: structured step tracing + metrics (DESIGN.md §14).

The flight recorder for the CAD runtime — the in-flight counterpart of
the offline benchmarks.  Three pieces:

  * :mod:`repro.obs.clock` — injectable monotonic clocks; production
    timer reads route through these so tests script time instead of
    sleeping;
  * :mod:`repro.obs.trace` — :class:`TraceRecorder`, a thread-safe
    ring-buffered span/event recorder with Chrome-trace/Perfetto
    export (one track per attention server); a true no-op when
    disabled;
  * :mod:`repro.obs.metrics` — :class:`MetricsRegistry`, labeled
    counters/gauges/histograms with Prometheus-text and JSON export
    (the serve daemon's ``GET /metrics``).

``server_track(s)`` is the one naming convention every producer and
consumer (``launch/trace_report.py``) shares: per-server events land
on ``server/<slot>``.
"""
from repro.obs.clock import MONOTONIC, Clock, FakeClock, MonotonicClock
from repro.obs.metrics import (DEFAULT_BUCKETS, MetricFamily,
                               MetricsRegistry, get_registry,
                               set_registry)
from repro.obs.trace import (INSTANT, SPAN, TraceEvent, TraceRecorder,
                             disable_tracing, enable_tracing,
                             get_recorder, set_recorder)


def server_track(slot: int) -> str:
    """Canonical trace-track name for attention server ``slot``."""
    return f"server/{int(slot)}"


__all__ = [
    "MONOTONIC", "Clock", "FakeClock", "MonotonicClock",
    "DEFAULT_BUCKETS", "MetricFamily", "MetricsRegistry",
    "get_registry", "set_registry",
    "INSTANT", "SPAN", "TraceEvent", "TraceRecorder",
    "disable_tracing", "enable_tracing", "get_recorder", "set_recorder",
    "server_track",
]
