"""Per-op-category breakdown of a compiled module (the dry-run 'profiler'
— §Perf iterations reason from this, since there is no wall-clock TPU).

Groups trip-weighted dot FLOPs and collective bytes by the jax op_name
metadata (e.g. attention einsums vs FFN matmuls vs dispatch gathers).
"""
from __future__ import annotations

import collections
import re
from typing import Dict, Tuple

from repro.launch import hlo_analysis as H

_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _bucket(op_name: str) -> str:
    s = op_name
    if "bqhd,bkhd" in s or "bhqk,bkhd" in s or "tqhd,tkhd" in s \
            or "thqk,tkhd" in s:
        return "attention"
    if "transpose" in s and ("bqhd" in s or "bhqk" in s or "tqhd" in s):
        return "attention_bwd"
    if "ecd,edf" in s or "ecf,efd" in s:
        return "moe_experts"
    if "bsd,vd" in s or "unembed" in s:
        return "unembed"
    if "all_to_all" in s or "ppermute" in s:
        return "dispatch"
    if "transpose(jvp" in s:
        return "bwd_other"
    return "fwd_other"


def flops_breakdown(hlo_text: str) -> Dict[str, float]:
    comps, entry = H.parse_hlo(hlo_text)
    acc: Dict[str, float] = collections.defaultdict(float)

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            if op.opcode == "while":
                bm = H._BODY_RE.search(op.tail)
                cm = H._COND_RE.search(op.tail)
                t = H._while_trip_count(comps, cm.group(1)) if cm else 1
                if bm:
                    walk(bm.group(1), mult * t)
            elif op.opcode in ("fusion", "call", "custom-call", "reduce",
                               "scatter", "sort", "map", "reduce-window"):
                cm = H._CALLS_RE.search(op.tail)
                if cm:
                    walk(cm.group(1), mult)
            elif op.opcode in ("dot", "convolution"):
                f = H._dot_flops(comp, op) * mult
                m = _OPNAME_RE.search(op.tail)
                acc[_bucket(m.group(1) if m else "?")] += f
    walk(entry, 1.0)
    return dict(acc)


def collective_breakdown_by_name(hlo_text: str) -> Dict[str, float]:
    comps, entry = H.parse_hlo(hlo_text)
    acc: Dict[str, float] = collections.defaultdict(float)

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            if op.opcode == "while":
                bm = H._BODY_RE.search(op.tail)
                cm = H._COND_RE.search(op.tail)
                t = H._while_trip_count(comps, cm.group(1)) if cm else 1
                if bm:
                    walk(bm.group(1), mult * t)
            elif op.opcode in ("fusion", "call"):
                cm = H._CALLS_RE.search(op.tail)
                if cm:
                    walk(cm.group(1), mult)
            else:
                base = op.opcode.replace("-start", "")
                if base in H.COLLECTIVES and not op.opcode.endswith("-done"):
                    m = _OPNAME_RE.search(op.tail)
                    key = (m.group(1)[-70:] if m else "?")
                    acc[f"{base} | {key}"] += H.shape_bytes(op.shape) * mult
    walk(entry, 1.0)
    return dict(acc)


def report(hlo_text: str, top: int = 15) -> str:
    lines = ["-- flops by bucket (per device) --"]
    fb = flops_breakdown(hlo_text)
    tot = sum(fb.values()) or 1.0
    for k, v in sorted(fb.items(), key=lambda kv: -kv[1]):
        lines.append(f"  {k:16s} {v:12.4e}  {v/tot*100:5.1f}%")
    lines.append("-- collective bytes by op_name (per device) --")
    cb = collective_breakdown_by_name(hlo_text)
    for k, v in sorted(cb.items(), key=lambda kv: -kv[1])[:top]:
        lines.append(f"  {v/2**20:10.1f} MiB  {k}")
    return "\n".join(lines)
