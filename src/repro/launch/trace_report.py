"""Straggler-attribution report over a saved Chrome trace.

Reads the Perfetto/Chrome-trace JSON written by ``--trace`` (or
``TraceRecorder.save``) and prints, per training step, the paper's
straggler story in one line: which attention server bounded the step,
how far above the mean it ran, how well the planner predicted it, and
how much of its time was recovery work re-dispatched from a failed or
speculated peer (DESIGN.md §14).

  PYTHONPATH=src python -m repro.launch.trace_report run.trace.json

Columns:

  step      the training step
  max_s     the bounding (slowest) server's total seconds
            (serve + recovery + backfill on that server)
  mean_s    mean total seconds over servers that served this step
  server    which server was the straggler
  pred_s    the cost model's predicted serve seconds for that server
  rec%      recovery share of the straggler's time (0% = fault-free)
  events    kill / serve-error / speculate markers this step

The report consumes only the public trace schema — span names
``serve`` / ``recover`` / ``serve.backfill`` on ``server/<slot>``
tracks, ``kill`` / ``serve-error`` / ``speculate`` instants, and the
``step`` + ``predicted`` args the executor attaches — so any trace a
:class:`repro.obs.TraceRecorder` saved is reportable.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

SERVE_SPANS = ("serve", "serve.backfill")
MARKER_EVENTS = ("kill", "serve-error", "speculate")


def _track_of(ev: Dict[str, Any], names: Dict[int, str]) -> str:
    return names.get(ev.get("tid", -1), f"tid/{ev.get('tid')}")


def _server_of(track: str) -> Optional[int]:
    if track.startswith("server/"):
        return int(track.split("/", 1)[1])
    return None


def load_steps(trace: Dict[str, Any]) -> Dict[int, Dict[int, dict]]:
    """{step: {server: {"serve": s, "recover": s, "predicted": s,
    "events": [name, ...]}}} from a Chrome-trace object."""
    names: Dict[int, str] = {}
    for ev in trace.get("traceEvents", ()):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[ev["tid"]] = ev["args"]["name"]
    steps: Dict[int, Dict[int, dict]] = {}
    for ev in trace.get("traceEvents", ()):
        args = ev.get("args") or {}
        step = args.get("step")
        if ev.get("ph") == "M" or step is None:
            continue
        server = _server_of(_track_of(ev, names))
        if server is None:
            continue
        rec = steps.setdefault(int(step), {}).setdefault(
            server, {"serve": 0.0, "recover": 0.0, "predicted": 0.0,
                     "events": []})
        name = ev.get("name", "")
        if ev.get("ph") == "X" and name in SERVE_SPANS:
            rec["serve"] += float(ev.get("dur", 0.0)) / 1e6
            rec["predicted"] += float(args.get("predicted", 0.0))
        elif ev.get("ph") == "X" and name == "recover":
            rec["recover"] += float(ev.get("dur", 0.0)) / 1e6
        elif ev.get("ph") == "i" and name in MARKER_EVENTS:
            rec["events"].append(name)
    return steps


def attribute_step(servers: Dict[int, dict]) -> Dict[str, Any]:
    """The straggler attribution for one step: who bounded it and why."""
    totals = {s: d["serve"] + d["recover"] for s, d in servers.items()}
    served = {s: t for s, t in totals.items() if t > 0.0} or totals
    straggler = max(sorted(served), key=lambda s: served[s])
    mean = sum(served.values()) / len(served)
    d = servers[straggler]
    total = totals[straggler]
    return {"server": straggler,
            "max_seconds": total,
            "mean_seconds": mean,
            "predicted_seconds": d["predicted"],
            "recovery_share": (d["recover"] / total) if total > 0 else 0.0,
            "events": sorted(ev for s in servers.values()
                             for ev in s["events"])}


def report_lines(trace: Dict[str, Any]) -> List[str]:
    steps = load_steps(trace)
    lines = [f"{'step':>6} {'max_s':>12} {'mean_s':>12} {'server':>6} "
             f"{'pred_s':>12} {'rec%':>6}  events"]
    for step in sorted(steps):
        a = attribute_step(steps[step])
        evs = ",".join(a["events"]) or "-"
        lines.append(
            f"{step:>6} {a['max_seconds']:>12.6g} "
            f"{a['mean_seconds']:>12.6g} {a['server']:>6} "
            f"{a['predicted_seconds']:>12.6g} "
            f"{a['recovery_share'] * 100:>5.1f}%  {evs}")
    if not steps:
        lines.append("(no per-step server events in trace)")
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="per-step straggler attribution from a --trace file")
    ap.add_argument("trace", help="Chrome-trace JSON (from --trace or "
                                  "TraceRecorder.save)")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable attribution instead of "
                         "the table")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    if args.json:
        steps = load_steps(trace)
        print(json.dumps({str(k): attribute_step(v)
                          for k, v in sorted(steps.items())}, indent=2))
        return
    for line in report_lines(trace):
        print(line)


if __name__ == "__main__":
    main()
