"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so any model
that scans over layers (ours all do) under-reports FLOPs/bytes/collective
traffic by the trip count.  This module parses the compiled HLO text,
walks the call graph (entry -> fusions/calls/whiles/conditionals), infers
while trip counts from the loop-condition constant, and accumulates:

  * flops               — dot/convolution MAC*2, trip-weighted
  * collective bytes    — output-shape bytes of all-gather / all-reduce /
                          reduce-scatter / all-to-all / collective-permute
                          (+ their async -start forms), trip-weighted
  * hbm bytes           — sum of operand+output bytes of compute ops
                          (fusions, dots, copies, collectives): an
                          approximation of HBM traffic that, unlike
                          cost_analysis, scales with loop trip counts

The parser is deliberately text-based (no xla_client bindings needed) and
validated against known matmul/scan modules in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "c64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
                "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(x) for x in dims.split(",")] if dims else []))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        total += _DTYPE_BYTES[dt] * math.prod(dims)
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    tail: str          # rest of the line after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    by_name: Dict[str, Op]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        if not ls or ls.startswith("//") or ls.startswith("HloModule"):
            continue
        if ls == "}":
            cur = None
            continue
        if cur is None:
            m = _COMP_RE.match(ls)
            if m and ls.endswith("{"):
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if ls.startswith("ENTRY"):
                    entry = cur.name
            continue
        m = _DEF_RE.match(ls)
        if not m:
            continue
        name, shape, opcode, tail = m.groups()
        op = Op(name=name, shape=shape, opcode=opcode, tail=tail)
        cur.ops.append(op)
        cur.by_name[name] = op
    if entry is None:  # fall back: computation named like main/entry
        for n in comps:
            if "main" in n or "entry" in n.lower():
                entry = n
        if entry is None and comps:
            entry = list(comps)[-1]
    return comps, entry


_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _while_trip_count(comps, cond_name: str) -> int:
    """Largest s32/u32/s64 constant in the condition computation — for
    scan-lowered loops this is the trip bound (ind_var < N)."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for op in comp.ops:
        if op.opcode == "constant":
            m = re.match(r"([\-0-9]+)\)?", op.tail)
            if m:
                try:
                    best = max(best, int(m.group(1)))
                except ValueError:
                    pass
        if op.opcode == "fusion":
            cm = _CALLS_RE.search(op.tail)
            if cm:
                best = max(best, _while_trip_count(comps, cm.group(1)))
    return best


COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems = sum(math.prod(d) for _, d in _shape_dims(op.shape))
    mc = _CONTRACT_RE.search(op.tail)
    contract_dims = [int(x) for x in mc.group(1).split(",")] if (
        mc and mc.group(1)) else []
    # lhs operand shape
    ops_named = _OPERAND_RE.findall(op.tail.split(")")[0])
    csize = 1
    if ops_named:
        lhs = comp.by_name.get(ops_named[0])
        if lhs is not None:
            dims = _shape_dims(lhs.shape)
            if dims:
                _, d = dims[0]
                for ci in contract_dims:
                    if ci < len(d):
                        csize *= d[ci]
    return 2.0 * out_elems * csize


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] = \
                self.collective_breakdown.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = \
                self.collective_counts.get(k, 0.0) + v * mult


def _operand_bytes(comp: Computation, op: Op) -> int:
    total = 0
    head = op.tail.split("),")[0]
    for name in _OPERAND_RE.findall(head):
        d = comp.by_name.get(name)
        if d is not None:
            total += shape_bytes(d.shape)
    return total


def analyze(text: str) -> HloCost:
    comps, entry = parse_hlo(text)
    memo: Dict[Tuple[str, bool], HloCost] = {}

    def comp_cost(name: str, at_hbm: bool) -> HloCost:
        """``at_hbm``: ops in this computation materialize buffers (entry,
        while bodies).  Inside fusions only the fusion *boundary* touches
        HBM — internals live in VMEM/registers — so nested ops contribute
        flops/collectives but no bytes."""
        key = (name, at_hbm)
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        c = HloCost()
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                bm = _BODY_RE.search(op.tail)
                cm = _COND_RE.search(op.tail)
                trips = _while_trip_count(comps, cm.group(1)) if cm else 1
                if bm:
                    c.add(comp_cost(bm.group(1), at_hbm), trips)
                continue
            if oc == "conditional":
                bm = _BRANCHES_RE.search(op.tail)
                if bm:
                    branches = [b.strip().lstrip("%") for b in
                                bm.group(1).split(",") if b.strip()]
                    if branches:   # average the branches
                        sub = HloCost()
                        for b in branches:
                            sub.add(comp_cost(b, at_hbm),
                                    1.0 / len(branches))
                        c.add(sub)
                continue
            if oc in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "sort", "scatter"):
                cm = _CALLS_RE.search(op.tail)
                if cm:
                    inner_at_hbm = at_hbm and oc == "call"
                    c.add(comp_cost(cm.group(1), inner_at_hbm))
                if at_hbm:
                    c.hbm_bytes += shape_bytes(op.shape) \
                        + _operand_bytes(comp, op)
                continue
            base = oc.replace("-start", "")
            if base in COLLECTIVES and not oc.endswith("-done"):
                b = shape_bytes(op.shape)
                c.collective_bytes += b
                c.collective_breakdown[base] = \
                    c.collective_breakdown.get(base, 0.0) + b
                c.collective_counts[base] = \
                    c.collective_counts.get(base, 0.0) + 1
                if at_hbm:
                    c.hbm_bytes += b
                continue
            if oc in ("dot", "convolution"):
                c.flops += _dot_flops(comp, op)
                if at_hbm:
                    c.hbm_bytes += shape_bytes(op.shape) \
                        + _operand_bytes(comp, op)
                continue
            if at_hbm and oc in (
                    "copy", "transpose", "broadcast", "add", "multiply",
                    "dynamic-update-slice", "dynamic-slice", "gather",
                    "concatenate", "reshape", "select", "exponential",
                    "tanh", "divide", "subtract", "maximum", "minimum"):
                c.hbm_bytes += shape_bytes(op.shape)
        memo[key] = c
        return c

    return comp_cost(entry, True)
