"""Production training launcher.

On real TPU hardware this runs the full mesh; on CPU it runs reduced
configs (the mesh flags are for the dry-run, see dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m-reduced \
      --steps 50 --seq 512 --batch 4 --ranks 2 --cad

Flags mirror the paper's system knobs: --cad (core attention
disaggregation on/off), --plan-policy (identity | per_doc_cp |
balanced | ring — the last is the DISTFLASHATTN-style context-parallel
baseline layout, DESIGN.md §13), --pingpong (nano-batch overlap),
--tolerance (scheduler
imbalance budget), --prefetch (async plan look-ahead; 0 = synchronous),
--strategy fixed|variable (packing baseline), --server-speeds
(heterogeneous pool: comma-separated per-rank speed factors, e.g.
"1,0.5" gives rank 1 half the FLOPs), --calibrate (runtime cost-model
calibration: per-server kernel timings are probed every
--calibrate-every steps and fed back so later batches are planned from
measured costs), --mask (attention task shape beyond dense causal:
"sliding:window=256,sink=16" or "dilated:rate=4" — planning prices
tasks by live blocks and the kernels apply the matching in-block mask,
DESIGN.md §12), --fault-schedule (elastic pool membership: a
deterministic FaultSchedule spec like "kill:1@5" or "flap:0@3+2,
slow:2x4@4-8" — killed/drained servers are excluded from subsequent
plans and flapped servers rejoin, DESIGN.md §9), --speculate-pct
(straggler-speculation percentile for the elastic executor paths).
"""
import argparse
import json

from repro.cad import CADSession, available_policies
from repro.configs import get_config
from repro.data.pipeline import PipelineConfig
from repro.obs import enable_tracing, get_recorder, get_registry
from repro.parallel import ParallelContext
from repro.train.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--max-doc", type=int, default=0)
    ap.add_argument("--dist", default="pretrain",
                    choices=["pretrain", "prolong"])
    ap.add_argument("--strategy", default="fixed",
                    choices=["fixed", "variable"])
    ap.add_argument("--cad", action="store_true")
    ap.add_argument("--plan-policy", default="balanced",
                    choices=list(available_policies()))
    ap.add_argument("--pingpong", action="store_true")
    ap.add_argument("--tolerance", type=float, default=0.1)
    ap.add_argument("--prefetch", type=int, default=2,
                    help="plan look-ahead depth (0 = synchronous)")
    ap.add_argument("--server-speeds", default="",
                    help="comma-separated per-rank speed factors "
                         "(heterogeneous pool), e.g. '1,0.5'")
    ap.add_argument("--server-hbm", default="",
                    help="comma-separated per-rank HBM budgets in "
                         "bytes; planning then treats endpoint memory "
                         "as a constraint next to modeled time")
    ap.add_argument("--stream-chunk", type=int, default=0,
                    help="kv blocks resident per streamed chunk; "
                         "lets dispatch serve tasks whose kv prefix "
                         "exceeds every --server-hbm budget (0 = off)")
    ap.add_argument("--mask", default="",
                    help="attention task shape (DESIGN.md §12): "
                         "'causal' (default), "
                         "'sliding:window=N[,sink=M]', "
                         "'dilated:rate=R'; live-block planning + "
                         "masked kernels")
    ap.add_argument("--calibrate", action="store_true",
                    help="runtime cost-model calibration: probe "
                         "per-server CA timings and replan from them")
    ap.add_argument("--calibrate-every", type=int, default=5,
                    help="steps between calibration probes")
    ap.add_argument("--fault-schedule", default="",
                    help="deterministic fault injection spec, e.g. "
                         "'kill:1@5' or 'flap:0@3+2,slow:2x4@4-8' "
                         "(elastic pool membership, DESIGN.md §9)")
    ap.add_argument("--speculate-pct", type=float, default=0.0,
                    help="straggler-speculation deadline percentile "
                         "(0 = off; task-level speculation runs in the "
                         "elastic executor)")
    ap.add_argument("--kernel", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "to this path (one track per attention server; "
                         "load in ui.perfetto.dev — DESIGN.md §14)")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="trace ring-buffer capacity (oldest events "
                         "are overwritten past it)")
    ap.add_argument("--metrics", default="",
                    help="write the metrics-registry JSON snapshot "
                         "(counters/gauges/histograms) to this path "
                         "at exit")
    args = ap.parse_args()

    if args.trace:
        enable_tracing(capacity=args.trace_capacity)

    cfg = get_config(args.arch)
    print(f"arch={cfg.arch_id} params={cfg.n_params()/1e6:.1f}M "
          f"family={cfg.family}")
    pipe = PipelineConfig(
        distribution=args.dist, max_doc_len=args.max_doc or args.seq,
        seq_len=args.seq, global_batch=args.batch, n_ranks=args.ranks,
        vocab_size=cfg.vocab_size, strategy=args.strategy)
    speeds = None
    if args.server_speeds:
        speeds = tuple(float(s) for s in args.server_speeds.split(","))
        if len(speeds) != args.ranks:
            raise SystemExit(f"--server-speeds needs {args.ranks} "
                             f"entries, got {len(speeds)}")
    hbm = None
    if args.server_hbm:
        hbm = tuple(float(s) for s in args.server_hbm.split(","))
        if len(hbm) != args.ranks:
            raise SystemExit(f"--server-hbm needs {args.ranks} "
                             f"entries, got {len(hbm)}")
    session = None
    if args.cad and cfg.has_attention():
        session = CADSession.for_pipeline(
            cfg, pipe, kernel=args.kernel, pingpong=args.pingpong,
            tolerance=args.tolerance, plan_policy=args.plan_policy,
            prefetch=args.prefetch, server_speeds=speeds,
            server_hbm=hbm, stream_chunk=args.stream_chunk,
            calibrate=args.calibrate, mask=args.mask or None)
        ctx = None
    else:
        if args.cad:
            print(f"note: {cfg.arch_id} is attention-free; CAD is "
                  f"inapplicable (DESIGN.md §5) — training without it")
        if args.calibrate or speeds or args.fault_schedule or args.mask:
            print("note: --calibrate/--server-speeds/--fault-schedule/"
                  "--mask only apply to the CAD attention service — "
                  "ignored")
        ctx = ParallelContext(attn_impl="xla", remat=True)
    tc = TrainConfig(steps=args.steps, peak_lr=args.lr,
                     warmup=max(1, args.steps // 10),
                     log_every=max(1, args.steps // 20),
                     ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir or "/tmp/repro_ckpt",
                     calibrate_every=args.calibrate_every
                     if args.calibrate else 0,
                     fault_schedule=args.fault_schedule
                     if session is not None else "",
                     speculate_pct=args.speculate_pct)
    res = train(cfg, pipe, tc, ctx=ctx, session=session)
    h = res["history"]
    print(f"done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}")
    if args.trace:
        rec = get_recorder()
        rec.save(args.trace)
        print(f"trace: {len(rec)} events -> {args.trace} "
              f"({rec.n_dropped} dropped)")
    if args.metrics:
        with open(args.metrics, "w") as f:
            json.dump(get_registry().to_dict(), f, indent=2)
        print(f"metrics: -> {args.metrics}")


if __name__ == "__main__":
    main()
