"""Thin HTTP front-end over the continuous-batching serve engine.

One daemon thread owns the engine and steps ``Engine.serve_round`` —
the exact state machine ``Engine.serve`` loops over, so daemon-driven
and batch serving share one code path.  HTTP handler threads only
submit requests and read per-request token queues; the scheduler and
kv cache are touched under a single lock.

Endpoints:

  * ``POST /generate`` — body ``{"prompt": [int, ...],
    "max_new_tokens": N?, "stream": true?}``.  Non-streaming waits for
    completion and returns ``{"rid", "tokens"}``; streaming responds
    with NDJSON lines ``{"token": t, "done": false}`` as tokens are
    sampled, closing with ``{"rid", "tokens", "done": true}``.
  * ``GET /health`` — ``{"status": "ok"|"draining"|"drained",
    "active", "waiting", "done", "rounds", "pool_epoch",
    "calib_version", "queue_depth"}`` (the last three read from the
    same metrics registry ``GET /metrics`` exports).
  * ``GET /metrics`` — Prometheus text exposition of the process
    metrics registry (DESIGN.md §14).
  * ``POST /drain`` — stop admitting new work; in-flight requests run
    to completion (503 for later ``/generate`` calls).

``--admission cost`` prices admission with the analytic CAD cost
model; adding ``--calibrate`` re-prices it live from measured decode
round latencies (a ``GridCalibrator`` fed by the daemon, exposed to
the scheduler as a snapshot provider — the same one-snapshot-per-round
discipline the training planner follows).

Run: PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
         --port 8080
Try: curl -d '{"prompt": [3, 14, 15, 92]}' localhost:8080/generate
"""
from __future__ import annotations

import argparse
import itertools
import json
import queue
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import jax
import numpy as np

from repro.configs import get_config
from repro.core.cost_model import GridCalibrator
from repro.models import model as M
from repro.obs import metrics as obs_metrics
from repro.parallel import ParallelContext
from repro.serve import Engine, ServeConfig
from repro.serve.scheduler import DECODE, Request


class EngineDaemon:
    """Owns the engine + one ContinuousScheduler; a background thread
    steps serve rounds while handler threads submit and stream."""

    def __init__(self, engine: Engine, *, calibrate: bool = False):
        self.engine = engine
        self.calibrator = GridCalibrator(engine._cost_model(), 1) \
            if calibrate else None
        self.sched = engine.make_scheduler(
            snapshot_provider=self.calibrator.snapshot
            if self.calibrator else None)
        self.cond = threading.Condition()
        self.draining = False
        self.stopped = False
        self.rounds = 0
        self._rids = itertools.count()
        self._out = {}       # rid -> [token, ...] (grows as sampled)
        self._done = {}      # rid -> threading.Event
        self._streams = {}   # rid -> queue.Queue[(token|None, done)]
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ client API
    def submit(self, prompt, max_new_tokens=None, stream=False) -> int:
        """Enqueue one request; raises RuntimeError when draining."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size < 1:
            raise ValueError("prompt must be a non-empty int list")
        if prompt.size > self.engine.scfg.max_seq:
            raise ValueError(f"prompt length {prompt.size} exceeds "
                             f"max_seq {self.engine.scfg.max_seq}")
        mn = self.engine.scfg.max_new_tokens if max_new_tokens is None \
            else int(max_new_tokens)
        with self.cond:
            if self.draining or self.stopped:
                raise RuntimeError("daemon is draining")
            rid = next(self._rids)
            self._out[rid] = []
            self._done[rid] = threading.Event()
            if stream:
                self._streams[rid] = queue.Queue()
            self.sched.submit(Request(rid=rid, prompt=prompt,
                                      max_new_tokens=mn))
            self.cond.notify_all()
        return rid

    def wait(self, rid: int, timeout=None):
        """Block until ``rid`` finishes; returns its token list."""
        if not self._done[rid].wait(timeout):
            raise TimeoutError(f"request {rid} still running")
        return list(self._out[rid])

    def stream(self, rid: int):
        """Yield ``(token, done)`` as request ``rid`` produces them."""
        q = self._streams[rid]
        while True:
            tok, done = q.get()
            yield tok, done
            if done:
                return

    def drain(self):
        with self.cond:
            self.draining = True
            in_flight = len(self.sched.active) + len(self.sched.waiting)
            self.cond.notify_all()
        return in_flight

    def stop(self):
        with self.cond:
            self.stopped = True
            self.cond.notify_all()
        self._thread.join(timeout=5)

    def stats(self):
        with self.cond:
            active = len(self.sched.active)
            waiting = len(self.sched.waiting)
            done = len(self.sched.done)
            if not self.draining:
                status = "ok"
            else:
                status = "drained" if active + waiting == 0 else "draining"
            # pool_epoch / calib_version / queue_depth come from the
            # same metrics registry GET /metrics serves, so the two
            # endpoints can never disagree (DESIGN.md §14)
            reg = obs_metrics.get_registry()

            def gval(name, default):
                v = reg.gauge(name).value()
                return default if v is None else v
            return {"status": status, "active": active, "waiting": waiting,
                    "done": done, "rounds": self.rounds,
                    "pool_epoch": int(gval("cad_pool_epoch", 0)),
                    "calib_version": int(gval("serve_calib_version", -1)),
                    "queue_depth": int(gval("serve_queue_depth",
                                            waiting))}

    # ------------------------------------------------------------ the worker
    def _on_token(self, rid, token, done):
        if token is not None:
            self._out[rid].append(int(token))
        q = self._streams.get(rid)
        if q is not None:
            q.put((None if token is None else int(token), done))
        if done:
            self._done[rid].set()

    def _loop(self):
        while True:
            with self.cond:
                while not self.stopped and not self.sched.has_work():
                    self.cond.wait(0.1)
                if self.stopped:
                    return
                decode_shapes = None
                if self.calibrator is not None \
                        and not self.sched.has_prefill():
                    decode_shapes = [
                        (1, int(self.sched.kv_len[s]) + 1)
                        for s, r in self.sched.active.items()
                        if r.state == DECODE]
                t0 = time.perf_counter()
                progressed = self.engine.serve_round(
                    self.sched, on_token=self._on_token)
                if progressed:
                    self.rounds += 1
                    if decode_shapes:
                        self.calibrator.observe_tasks(
                            decode_shapes, time.perf_counter() - t0,
                            server=0)


# ------------------------------------------------------------------- HTTP
def make_handler(daemon: EngineDaemon):
    class Handler(BaseHTTPRequestHandler):
        # HTTP/1.0: streaming responses end at connection close, no
        # chunked framing needed
        protocol_version = "HTTP/1.0"

        def log_message(self, fmt, *args):     # quiet by default
            pass

        def _json(self, code, obj):
            body = (json.dumps(obj) + "\n").encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path == "/metrics":
                body = obs_metrics.get_registry().to_text().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
                return
            if self.path != "/health":
                return self._json(404, {"error": "unknown path"})
            self._json(200, daemon.stats())

        def do_POST(self):
            if self.path == "/drain":
                return self._json(200, {"draining": True,
                                        "in_flight": daemon.drain()})
            if self.path != "/generate":
                return self._json(404, {"error": "unknown path"})
            try:
                n = int(self.headers.get("Content-Length", 0))
                req = json.loads(self.rfile.read(n) or b"{}")
                prompt = req["prompt"]
                stream = bool(req.get("stream", False))
                rid = daemon.submit(prompt, req.get("max_new_tokens"),
                                    stream=stream)
            except RuntimeError as e:
                return self._json(503, {"error": str(e)})
            except (KeyError, ValueError, TypeError,
                    json.JSONDecodeError) as e:
                return self._json(400, {"error": str(e)})
            if not stream:
                return self._json(200, {"rid": rid,
                                        "tokens": daemon.wait(rid)})
            self.send_response(200)
            self.send_header("Content-Type", "application/x-ndjson")
            self.end_headers()
            for tok, done in daemon.stream(rid):
                if done:
                    line = {"rid": rid, "tokens": list(daemon._out[rid]),
                            "done": True}
                else:
                    line = {"token": tok, "done": False}
                self.wfile.write((json.dumps(line) + "\n").encode())
                self.wfile.flush()

    return Handler


def make_server(daemon: EngineDaemon, host: str, port: int) \
        -> ThreadingHTTPServer:
    return ThreadingHTTPServer((host, port), make_handler(daemon))


# ------------------------------------------------------------------ launch
def build_engine(args) -> Engine:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init(jax.random.PRNGKey(args.seed), cfg)
    scfg = ServeConfig(max_seq=args.max_seq,
                       max_new_tokens=args.max_new,
                       chunk_tokens=args.chunk_tokens,
                       prefill=args.prefill,
                       admission=args.admission,
                       token_budget=args.token_budget,
                       step_cost_budget=args.step_cost_budget)
    ctx = ParallelContext(attn_impl="ref", remat=False)
    return Engine(cfg, params, ctx, scfg, batch_size=args.slots)


def parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--arch", default="gemma2-2b")
    p.add_argument("--reduced", action="store_true", default=True,
                   help="use the reduced config (default; random init)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8080)
    p.add_argument("--slots", type=int, default=4,
                   help="cache slots = max concurrent requests on device")
    p.add_argument("--max-seq", type=int, default=256)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--chunk-tokens", type=int, default=128)
    p.add_argument("--prefill", choices=("fused", "loop"), default="fused")
    p.add_argument("--admission", choices=("fcfs", "cost"), default="fcfs")
    p.add_argument("--token-budget", type=int, default=None,
                   help="continuous-batching kv budget (tokens)")
    p.add_argument("--step-cost-budget", type=float, default=0.0,
                   help="predicted CA seconds per decode step (0 = off)")
    p.add_argument("--calibrate", action="store_true",
                   help="re-price cost admission from measured decode "
                        "round latencies")
    p.add_argument("--seed", type=int, default=0)
    return p.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    daemon = EngineDaemon(build_engine(args), calibrate=args.calibrate)
    srv = make_server(daemon, args.host, args.port)
    print(f"serving {args.arch} on http://{args.host}:{srv.server_port} "
          f"({args.slots} slots, admission={args.admission}"
          f"{', calibrated' if args.calibrate else ''})")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        daemon.stop()
        srv.server_close()


if __name__ == "__main__":
    main()
