import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape), lower + compile the appropriate
step function on the production mesh and print memory/cost/collective
analysis.  Results are appended as JSON lines.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all                # single-pod 16x16
  python -m repro.launch.dryrun --all --multi-pod    # 2x16x16 (512 chips)
  python -m repro.launch.dryrun --arch ... --cad     # CAD dispatch mode
"""
import argparse
import json
import sys
import traceback

from repro.configs import ASSIGNED_ARCHS
from repro.launch.dryrun_lib import INPUT_SHAPES, run_dryrun
from repro.launch.mesh import make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None,
                    choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--cad", action="store_true",
                    help="lower the CAD dispatch path (train shapes)")
    ap.add_argument("--pingpong", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    args = ap.parse_args(argv)

    archs = args.arch or list(ASSIGNED_ARCHS)
    shapes = args.shape or list(INPUT_SHAPES)
    if not args.all and args.arch is None and args.shape is None:
        ap.error("pass --all or --arch/--shape")

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    failures = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch} x {shape} mesh={list(mesh.devices.shape)}" \
                      + (" CAD" if args.cad else "")
                try:
                    r = run_dryrun(arch, shape, mesh, cad=args.cad,
                                   pingpong=args.pingpong)
                except Exception as e:  # a failure here is a system bug
                    failures += 1
                    r = {"arch": arch, "shape": shape, "cad": args.cad,
                         "mesh": list(mesh.devices.shape), "error":
                         f"{type(e).__name__}: {e}"}
                    traceback.print_exc()
                f.write(json.dumps(r) + "\n")
                f.flush()
                if r.get("skipped"):
                    print(f"[skip] {tag}: {r['reason']}")
                elif "error" in r:
                    print(f"[FAIL] {tag}: {r['error'][:200]}")
                else:
                    print(f"[ ok ] {tag}: compile={r['compile_s']}s "
                          f"peak={r['peak_bytes']/2**30:.2f}GiB/dev "
                          f"flops={r['hlo_flops_per_device']:.3e} "
                          f"coll={r['collective_bytes_per_device']/2**20:.1f}"
                          f"MiB")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
