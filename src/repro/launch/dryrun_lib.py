"""Dry-run library: build sharded ShapeDtypeStruct inputs for every
(architecture × input shape), lower + compile the right step function on
the production mesh, and extract memory/cost/collective statistics.

No real allocation happens: everything is ShapeDtypeStruct + AOT
lower/compile.
"""
from __future__ import annotations

import dataclasses
import math
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.dispatch import CADContext
from repro.core.plan import CADConfig
from repro.models import model as M
from repro.optim.adamw import AdamW
from repro.parallel import (ParallelContext, ShardingRules, make_rules,
                            param_pspecs)
from repro.train.step import make_serve_step, make_train_step

INPUT_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, long=True),
}


def applicable(cfg, shape_name: str) -> Tuple[bool, str]:
    info = INPUT_SHAPES[shape_name]
    if info.get("long") and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500K decode requires a "
                       "sub-quadratic/windowed variant (DESIGN.md §6)")
    return True, ""


# ------------------------------------------------------------------ specs
def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _shard_tree(tree_shapes, pspecs, mesh):
    return jax.tree.map(
        lambda s, p: _sds(s.shape, s.dtype, mesh, p), tree_shapes, pspecs)


def params_sds(cfg, mesh, rules):
    shapes = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(cfg, shapes, rules, mesh)
    return _shard_tree(shapes, specs, mesh), specs


def opt_sds(cfg, p_sds, p_specs, mesh):
    opt = AdamW()
    shapes = jax.eval_shape(opt.init, p_sds)
    from repro.optim.adamw import AdamWState
    specs = AdamWState(step=P(), mu=p_specs, nu=p_specs)
    return _shard_tree(shapes, specs, mesh)


def train_batch_sds(cfg, mesh, rules, seq, batch, with_memory):
    bspec = P(rules.batch, None)
    out = {
        "tokens": _sds((batch, seq), jnp.int32, mesh, bspec),
        "labels": _sds((batch, seq), jnp.int32, mesh, bspec),
        "segment_ids": _sds((batch, seq), jnp.int32, mesh, bspec),
        "positions": _sds((batch, seq), jnp.int32, mesh, bspec),
    }
    if with_memory:
        m = cfg.encoder.n_ctx if cfg.encoder else 1601
        out["memory"] = _sds((batch, m, cfg.d_model), cfg.cdtype, mesh,
                             P(rules.batch, None, None))
    return out


def _cache_pspecs(cfg, cache_shapes, rules):
    def leaf_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        bspec = rules.batch
        sspec = rules.seq
        if name in ("k", "v"):
            return P(None, bspec, sspec, rules.kv_heads, None)
        if name == "kv_pos":
            return P(None, bspec, sspec)
        if name in ("xk", "xv"):
            return P(None, bspec, None, rules.kv_heads, None)
        if name == "state":      # [G,B,H,N,P]
            return P(None, bspec, None, None, None)
        if name == "conv":
            return P(None, bspec, None, None)
        if name == "h":          # [G,B,W]
            return P(None, bspec, None)
        return P(*([None] * leaf.ndim))
    import jax.tree_util as jtu
    return jtu.tree_map_with_path(leaf_spec, cache_shapes)


def cache_sds(cfg, mesh, rules, batch, max_seq, p_sds, with_memory):
    mem = None
    if with_memory:
        m = cfg.encoder.n_ctx if cfg.encoder else 1601
        mem = jax.ShapeDtypeStruct((batch, m, cfg.d_model), cfg.cdtype)
    ctx = ParallelContext(mesh=None, rules=ShardingRules(), attn_impl="xla",
                          remat=False)
    shapes = jax.eval_shape(
        lambda p, mm: M.init_cache(p, cfg, batch, max_seq, memory=mm,
                                   ctx=ctx), p_sds, mem)
    specs = _cache_pspecs(cfg, shapes, rules)
    return _shard_tree(shapes, specs, mesh)


# ----------------------------------------------------------- CAD plumbing
def cad_setup(cfg, mesh, rules, seq, batch, pingpong=False):
    """CADConfig + plan SDS for the production mesh."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d = 1
    for a in ("pod", "data"):
        d *= axes.get(a, 1)
    tokens_per_rank = batch * seq // d
    if pingpong:
        tokens_per_rank //= 2   # per nano-batch
    blk = 128
    # capacity rule (§Perf P10): per-pair caps >= max-doc blocks so long
    # document tails stay schedulable; docs never span a row -> max doc =
    # one row of `seq` tokens
    cadcfg = CADConfig.default(d, tokens_per_rank, blk=blk,
                               max_doc_tokens=seq)
    jmax = max(1, seq // blk)   # docs never exceed one row
    from repro.core.plan import StepPlan
    plan_np = StepPlan.empty(cadcfg)
    cspec = rules.cad_axis
    plan = jax.tree.map(
        lambda v: _sds(v.shape, jnp.int32, mesh,
                       P(cspec, *([None] * (v.ndim - 1)))), plan_np)
    return cadcfg, plan, jmax


# ------------------------------------------------------------- the lower
def build_step(cfg, mesh, shape_name: str, *, cad: bool = False,
               pingpong: bool = False, attn_impl: str = "xla"):
    """Returns (fn, example_args_sds, ctx)."""
    info = INPUT_SHAPES[shape_name]
    rules = make_rules(mesh, cfg)
    if info.get("long"):
        # batch=1: context-parallel layout — shard the sequence over every
        # axis (data for CP + model: the KV cache is the footprint)
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        seq_axes = axes + (("model",) if "model" in mesh.axis_names
                           else ())
        rules = dataclasses.replace(rules, batch=None, seq=seq_axes,
                                    residual_seq=None)
    elif info["kind"] == "decode":
        # batch over data; cache sequence over model (kv heads rarely
        # divide the model axis — S always does; a mesh axis may appear
        # only once per spec, so kv_heads yields to seq)
        has_model = "model" in mesh.axis_names
        rules = dataclasses.replace(
            rules, seq="model" if has_model else None,
            kv_heads=None if has_model else rules.kv_heads,
            residual_seq=None)
    with_memory = cfg.family in ("vlm", "audio")
    ctx = ParallelContext(mesh=mesh, rules=rules, attn_impl=attn_impl,
                          remat=True)
    p_sds, p_specs = params_sds(cfg, mesh, rules)

    if info["kind"] == "train":
        cadctx = None
        if cad:
            cadcfg, plan_sds, jmax = cad_setup(cfg, mesh, rules,
                                               info["seq"], info["batch"],
                                               pingpong=pingpong)
            cadctx = CADContext(cfg=cadcfg, kernel="xla", jmax=jmax,
                                pingpong=pingpong)
            ctx = dataclasses.replace(ctx, attn_impl="cad", cad=cadctx)
        opt = AdamW()
        o_sds = opt_sds(cfg, p_sds, p_specs, mesh)
        b_sds = train_batch_sds(cfg, mesh, rules, info["seq"],
                                info["batch"], with_memory)
        if cad:
            from repro.core.plan import PingPongPlan
            b_sds["plan"] = PingPongPlan(plan_sds, plan_sds) if pingpong \
                else plan_sds
        fn = make_train_step(cfg, ctx, opt)
        return fn, (p_sds, o_sds, b_sds), ctx

    if info["kind"] == "prefill":
        b_sds = train_batch_sds(cfg, mesh, rules, info["seq"],
                                info["batch"], with_memory)
        b_sds.pop("labels")

        def prefill_step(params, batch):
            logits, _ = M.forward(params, cfg, batch, ctx)
            return logits[:, -1:, :]
        return prefill_step, (p_sds, b_sds), ctx

    # decode
    b = info["batch"]
    c_sds = cache_sds(cfg, mesh, rules, b, info["seq"], p_sds, with_memory)
    tok = _sds((b, 1), jnp.int32, mesh, P(rules.batch, None))
    pos = _sds((b,), jnp.int32, mesh, P(rules.batch))
    fn = make_serve_step(cfg, ctx)
    return fn, (p_sds, c_sds, tok, pos), ctx


HLO_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in the (stable-)HLO /
    HLO text.  Per-device bytes (shapes in the compiled module are local)."""
    out = {k: 0.0 for k in HLO_COLLECTIVES}
    count = {k: 0 for k in HLO_COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if "start" in ls.split("(")[0] and f"{op}-start" in ls:
            pass  # async start carries the shape; done is pass-through
        if f"{op}-done" in ls:
            continue
        out[op] += _shape_bytes(shape_str)
        count[op] += 1
    return {"bytes": out, "count": count,
            "total_bytes": sum(out.values())}


def run_dryrun(arch: str, shape_name: str, mesh, *, cad=False,
               pingpong=False) -> Dict[str, Any]:
    """Lower + compile one combo; return stats dict."""
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}
    t0 = time.time()
    fn, args, ctx = build_step(cfg, mesh, shape_name, cad=cad,
                               pingpong=pingpong)
    lowered = jax.jit(fn).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    from repro.launch.hlo_analysis import analyze
    hc = analyze(txt)   # trip-count-aware (XLA counts loop bodies once)
    n_dev = mesh.devices.size

    def g(obj, name, default=0.0):
        try:
            v = getattr(obj, name, None)
            if v is None and isinstance(obj, dict):
                v = obj.get(name, default)
            return float(v if v is not None else default)
        except Exception:
            return float(default)

    result = {
        "arch": arch, "shape": shape_name, "cad": cad,
        "pingpong": pingpong, "skipped": False,
        "n_devices": int(n_dev),
        "mesh": list(mesh.devices.shape),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # memory_analysis numbers are per-device
        "argument_bytes": g(mem, "argument_size_in_bytes"),
        "output_bytes": g(mem, "output_size_in_bytes"),
        "temp_bytes": g(mem, "temp_size_in_bytes"),
        "peak_bytes": (g(mem, "argument_size_in_bytes")
                       + g(mem, "temp_size_in_bytes")
                       + g(mem, "output_size_in_bytes")),
        # trip-count-aware per-device analysis of the compiled module
        "hlo_flops_per_device": hc.flops,
        "hlo_bytes_per_device": hc.hbm_bytes,
        "collective_bytes_per_device": hc.collective_bytes,
        "collective_counts": hc.collective_counts,
        "collective_breakdown": hc.collective_breakdown,
        # XLA's own (loop-body-once) numbers kept for reference
        "xla_flops_per_device": g(cost, "flops"),
        "xla_bytes_per_device": g(cost, "bytes accessed"),
    }
    return result
