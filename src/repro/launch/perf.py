import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ before any jax import (see dryrun.py)

"""Perf-iteration driver: lower+compile one (arch × shape) combo and print
the roofline terms plus the per-bucket flops / per-op collective
breakdown — the 'profile' each §Perf hypothesis is tested against.

  PYTHONPATH=src python -m repro.launch.perf --arch gemma2-2b \
      --shape train_4k [--cad] [--pingpong] [--multi-pod]
"""
import argparse

import jax

from repro.launch.breakdown import report
from repro.launch.dryrun_lib import build_step, run_dryrun
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--cad", action="store_true")
    ap.add_argument("--pingpong", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rec = run_dryrun(args.arch, args.shape, mesh, cad=args.cad,
                     pingpong=args.pingpong)
    if rec.get("skipped") or rec.get("error"):
        print(rec)
        return
    row = roofline_row(rec)
    print(f"== {args.arch} x {args.shape} mesh={rec['mesh']} "
          f"cad={args.cad} pingpong={args.pingpong}")
    print(f"compute   {row['compute_s']:.4f} s")
    print(f"memory    {row['memory_s']:.4f} s")
    print(f"collective{row['collective_s']:.4f} s")
    print(f"dominant  {row['dominant']}   useful={row['useful_ratio']:.2f} "
          f"peak={row['peak_gib_per_dev']:.1f} GiB/dev")
    # re-lower for the breakdown (run_dryrun doesn't return the text)
    from repro.configs import get_config
    fn, a, ctx = build_step(get_config(args.arch), mesh, args.shape,
                            cad=args.cad, pingpong=args.pingpong)
    txt = jax.jit(fn).lower(*a).compile().as_text()
    print(report(txt, top=args.top))


if __name__ == "__main__":
    main()
