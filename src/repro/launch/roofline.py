"""Roofline analysis (deliverable g).

Consumes dryrun JSONL records and derives the three roofline terms per
(arch × shape × mesh):

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

All dryrun numbers are already per-device (the compiled module is the
per-device program), so the per-chip terms divide by nothing further:
term = per_device_quantity / per_chip_rate.

MODEL_FLOPS uses 6·N·D (dense train), 6·N_active·D (MoE), 2·N·D for a
forward-only shape, and 2·N_active per generated token for decode.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core.cost_model import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.dryrun_lib import INPUT_SHAPES


def model_flops(arch: str, shape_name: str) -> float:
    """Useful (paper-accounting) FLOPs for the whole step, global."""
    cfg = get_config(arch)
    info = INPUT_SHAPES[shape_name]
    n_active = cfg.n_active_params()
    tokens = info["batch"] * (info["seq"] if info["kind"] != "decode"
                              else 1)
    if info["kind"] == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("skipped") or rec.get("error"):
        return None
    n = rec["n_devices"]
    t_compute = rec["hlo_flops_per_device"] / PEAK_FLOPS_BF16
    t_memory = rec["hlo_bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["hlo_flops_per_device"] * n
    row = {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "x".join(str(x) for x in rec["mesh"]),
        "cad": rec.get("cad", False),
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "peak_gib_per_dev": rec["peak_bytes"] / 2 ** 30,
        "fits_hbm16": rec["peak_bytes"] < 16 * 2 ** 30,
    }
    # one-line "what would move the dominant term down"
    hints = {
        "compute": "shard replicated CA heads / cut remat recompute",
        "memory": "larger fused blocks; fewer materialized intermediates; "
                  "rematerialize less-reused tensors only",
        "collective": "reduce FSDP all-gather volume (cache weights), "
                      "overlap A2A with serve compute (ping-pong), "
                      "shard kv instead of MHA-izing",
    }
    row["hint"] = hints[dom]
    return row


def load_rows(paths: List[str]) -> List[Dict]:
    rows = []
    for p in paths:
        with open(p) as f:
            for line in f:
                rec = json.loads(line)
                r = roofline_row(rec)
                if r:
                    r["_rec"] = rec
                    rows.append(r)
    return rows


def fmt_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | CAD | compute_s | memory_s | "
           "collective_s | dominant | MODEL/HLO | peak GiB/dev |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{'Y' if r['cad'] else '-'} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['peak_gib_per_dev']:.1f} |")
    return "\n".join(lines)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", nargs="+")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    rows = load_rows(args.jsonl)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if args.markdown:
        print(fmt_table(rows))
    else:
        for r in rows:
            print(f"{r['arch']:28s} {r['shape']:12s} {r['mesh']:9s} "
                  f"C={r['compute_s']:.4f}s M={r['memory_s']:.4f}s "
                  f"X={r['collective_s']:.4f}s dom={r['dominant']:10s} "
                  f"useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    main()
