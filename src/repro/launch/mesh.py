"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  The dry-run forces 512 host devices via
XLA_FLAGS *before* any jax import (see dryrun.py); smoke tests and
benchmarks see the normal single device.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return make_mesh(shape, axes, devices=devs[:n])
