"""Packed-LM loss: next-token cross-entropy within documents."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits, labels, segment_ids):
    """logits [B,S,V] f32, labels [B,S] (-1 = ignore), segment_ids [B,S].

    Loss counts position t iff label t is valid AND t is not padding.
    The data pipeline pre-shifts labels so labels[t] = tokens[t+1] within
    the same document and -1 at document tails/padding.
    """
    valid = (labels >= 0) & (segment_ids > 0)
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    n = jnp.maximum(valid.sum(), 1)
    return nll.sum() / n, {"n_tokens": n, "nll_sum": nll.sum()}
