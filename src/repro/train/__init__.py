from repro.train.loss import lm_loss
from repro.train.step import (make_eval_step, make_serve_chunk_step,
                              make_serve_step, make_train_step)
from repro.train.trainer import TrainConfig, train
