"""train_step / eval_step factories (the functions the launcher jits)."""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.train.loss import lm_loss


def make_train_step(cfg, ctx, optimizer):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` may carry CAD plan arrays under 'plan' — they are
    data, consumed by the dispatch layer via ctx."""

    def loss_fn(params, batch):
        if ctx.cad is not None and "plan" in batch:
            local_ctx = ctx.cad.bind_plan(ctx, batch["plan"])
        else:
            local_ctx = ctx
        logits, aux = M.forward(params, cfg, batch, local_ctx)
        loss, stats = lm_loss(logits, batch["labels"], batch["segment_ids"])
        total = loss
        for v in aux.values():
            total = total + v
        return total, (loss, stats, aux)

    def train_step(params, opt_state, batch):
        (total, (loss, stats, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "total_loss": total, "grad_norm": gnorm,
                   "n_tokens": stats["n_tokens"]}
        metrics.update({k: v for k, v in aux.items()})
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg, ctx):
    def eval_step(params, batch):
        logits, _ = M.forward(params, cfg, batch, ctx)
        loss, stats = lm_loss(logits, batch["labels"], batch["segment_ids"])
        return {"loss": loss, "n_tokens": stats["n_tokens"]}
    return eval_step


def make_serve_step(cfg, ctx):
    """decode_32k / long_500k shapes: one new token against a KV cache
    (the legacy dense-batch decode path; the serving engine's ragged
    batches use ``make_serve_chunk_step``)."""
    def serve_step(params, cache, tokens, pos):
        logits, cache = M.decode_step(params, cfg, cache, tokens, pos, ctx)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits, cache
    return serve_step


def make_serve_chunk_step(cfg, ctx):
    """Packed-prefill / ragged-decode serving step (DESIGN.md §8).

    One jitted function serves both engine phases against a
    ``layout="serve"`` cache: a fused chunked-prefill call (blk_q = 128
    request-pure q blocks packed cu_seqlens-style into ``tokens [T]``)
    and a batched decode step (blk_q = 1, one token per request slot).
    The two phases trace to different shapes, so each gets its own
    executable under one ``jax.jit``.
    """
    def chunk_step(params, cache, tokens, pos, block_req, kv_len_next):
        return M.serve_chunk_step(params, cfg, cache, tokens, pos,
                                  block_req, kv_len_next, ctx)
    return chunk_step
