"""Trainer: the host loop that owns the data pipeline, the CAD scheduler
(plan per step — the paper's "scheduler prefetches the upcoming batch"),
jit compilation, checkpointing, and metrics."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core.dispatch import CADContext
from repro.core.plan import CADConfig
from repro.data.pipeline import PipelineConfig, batches
from repro.models import model as M
from repro.optim.adamw import AdamW, cosine_schedule
from repro.parallel import ParallelContext
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    peak_lr: float = 3e-4
    warmup: int = 20
    weight_decay: float = 0.1
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


def train(cfg, pipe_cfg: PipelineConfig, train_cfg: TrainConfig,
          ctx: Optional[ParallelContext] = None,
          params=None) -> Dict[str, Any]:
    """Train ``cfg`` (a ModelConfig); returns final params + history."""
    ctx = ctx or ParallelContext(attn_impl="xla", remat=True)
    key = jax.random.PRNGKey(train_cfg.seed)
    if params is None:
        params = M.init(key, cfg)
    opt = AdamW(lr=cosine_schedule(train_cfg.peak_lr, train_cfg.warmup,
                                   train_cfg.steps),
                weight_decay=train_cfg.weight_decay)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, ctx, opt))

    gen = batches(pipe_cfg, cfg.n_heads or 1, cfg.head_dim or 1,
                  cfg.n_kv_heads or 1)
    history = []
    t0 = time.time()
    for step in range(train_cfg.steps):
        batch = next(gen)
        stats = batch.pop("schedule_stats", None)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % train_cfg.log_every == 0 or step == train_cfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            if stats:
                m.update({f"sched_{k}": v for k, v in stats.items()})
            history.append(m)
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} ({m['wall_s']:.1f}s)")
        if train_cfg.ckpt_every and step and \
                step % train_cfg.ckpt_every == 0:
            ckpt.save(train_cfg.ckpt_dir, step, params, opt_state)
    return {"params": params, "opt_state": opt_state, "history": history}


def make_cad_context(cfg, pipe_cfg: PipelineConfig, *, kernel="xla",
                     pingpong=False, mesh=None, rules=None,
                     tolerance=0.1) -> ParallelContext:
    """Build a ParallelContext with CAD enabled and the pipeline configured
    to attach plans (single-host: global-sim pool; mesh: shard_map)."""
    from repro.parallel import ShardingRules
    n = pipe_cfg.n_ranks
    rows_per_rank = pipe_cfg.global_batch // n
    tokens_per_rank = rows_per_rank * pipe_cfg.seq_len
    if pingpong:
        tokens_per_rank //= 2
    cadcfg = CADConfig.default(n, tokens_per_rank,
                               max_doc_tokens=pipe_cfg.max_doc_len)
    pipe_cfg.cad = cadcfg
    pipe_cfg.tolerance = tolerance
    pipe_cfg.pingpong = pingpong
    jmax = max(1, pipe_cfg.max_doc_len // cadcfg.blk)
    cad = CADContext(cfg=cadcfg, kernel=kernel, jmax=jmax,
                     pingpong=pingpong)
    return ParallelContext(mesh=mesh, rules=rules or ShardingRules(),
                           attn_impl="cad", cad=cad, remat=True,
                           pingpong=pingpong)
