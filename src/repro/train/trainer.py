"""Trainer: the host loop that owns the data pipeline, the CAD attention
service (plans prefetched asynchronously one step ahead — the paper's
"scheduler prefetches the upcoming batch"), jit compilation,
checkpointing, and metrics."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.cad import CADSession
from repro.checkpoint import ckpt
from repro.data.pipeline import PipelineConfig, raw_batches
from repro.models import model as M
from repro.optim.adamw import AdamW, cosine_schedule
from repro.parallel import ParallelContext
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    peak_lr: float = 3e-4
    warmup: int = 20
    weight_decay: float = 0.1
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    calibrate_every: int = 0      # probe + feed CA timings every N steps
                                  # (0 = off; needs a session calibrator)
    fault_schedule: str = ""      # FaultSchedule spec applied to the
                                  # session's ServerPool (one is attached
                                  # if missing): membership events take
                                  # effect at step granularity here —
                                  # a killed server is excluded from the
                                  # next plan; prefetched plans from the
                                  # dead epoch re-plan at pull
    speculate_pct: float = 0.0    # straggler-speculation percentile;
                                  # consumed by the task-level elastic
                                  # executor (benchmarks/examples) — the
                                  # fused jit path only records it


def train(cfg, pipe_cfg: PipelineConfig, train_cfg: TrainConfig,
          ctx: Optional[ParallelContext] = None, params=None,
          session: Optional[CADSession] = None) -> Dict[str, Any]:
    """Train ``cfg`` (a ModelConfig); returns final params + history.

    Pass ``session`` (a :class:`repro.cad.CADSession`) to train with the
    attention service: the session provides the ParallelContext and
    attaches prefetched plans to every batch.  Without a session the
    loop trains on raw packed batches with a plain (or caller-supplied)
    ``ctx``."""
    faults = pool = None
    if session is not None:
        if train_cfg.fault_schedule:
            from repro.runtime import FaultSchedule, ServerPool
            faults = FaultSchedule.parse(train_cfg.fault_schedule)
            if session.pool is None:
                session = session.with_pool(ServerPool(
                    session.cfg.n_servers,
                    calibrator=session.calibrator))
            if train_cfg.speculate_pct > 0:
                print("note: --speculate-pct drives task-level "
                      "speculation in the elastic executor "
                      "(benchmarks/elastic_recovery.py); the fused "
                      "train step applies membership events only")
        pool = session.pool
        ctx = session.context()
        gen = session.attach_plans(raw_batches(pipe_cfg))
    else:
        ctx = ctx or ParallelContext(attn_impl="xla", remat=True)
        gen = raw_batches(pipe_cfg)
    key = jax.random.PRNGKey(train_cfg.seed)
    if params is None:
        params = M.init(key, cfg)
    opt = AdamW(lr=cosine_schedule(train_cfg.peak_lr, train_cfg.warmup,
                                   train_cfg.steps),
                weight_decay=train_cfg.weight_decay)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, ctx, opt))

    calibrating = (session is not None
                   and session.calibrator is not None
                   and train_cfg.calibrate_every > 0)
    if session is not None and session.calibrator is not None \
            and train_cfg.ckpt_every:
        # calibration survives restarts: pick up the measured grid from
        # the newest checkpoint (no-op when none carries calibration)
        last = ckpt.latest_step(train_cfg.ckpt_dir)
        if last is not None and ckpt.restore_calibration(
                train_cfg.ckpt_dir, last, session.calibrator):
            print(f"restored calibration state from step {last}")
    history = []
    t0 = time.time()
    try:
        for step in range(train_cfg.steps):
            pool_events = []
            if faults is not None:
                # membership events land at step granularity on the
                # fused path: the planner is re-invoked against the
                # survivors and stale prefetched plans re-plan at pull
                # (kills apply before the step — the jitted path cannot
                # lose a server mid-flight; same shared semantics as
                # the elastic executor)
                pool_events = faults.apply_pre_step(pool, step) \
                    + faults.apply_failures(pool, step)
                if pool_events:
                    print(f"step {step:5d} pool: "
                          f"{', '.join(pool_events)} "
                          f"(epoch {pool.epoch})")
            batch = next(gen)
            stats = batch.pop("schedule_stats", None)
            plan = batch.get("plan") if calibrating else None
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if calibrating and plan is not None \
                    and step % train_cfg.calibrate_every == 0:
                # measure → fit: per-server kernel timings feed the
                # calibrator, so the (prefetched) plan for a later batch
                # is built from these measured costs (DESIGN.md §3)
                session.observe_probe(plan, seed=train_cfg.seed + step)
            if step % train_cfg.log_every == 0 \
                    or step == train_cfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.time() - t0
                if stats:
                    m.update({f"sched_{k}": v for k, v in stats.items()})
                if pool_events:
                    m["pool_events"] = ";".join(pool_events)
                history.append(m)
                print(f"step {step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} ({m['wall_s']:.1f}s)")
            if train_cfg.ckpt_every and step and \
                    step % train_cfg.ckpt_every == 0:
                ckpt.save(train_cfg.ckpt_dir, step, params, opt_state,
                          calibrator=None if session is None
                          else session.calibrator)
    finally:
        gen.close()      # stops the plan-prefetch worker, if any
    return {"params": params, "opt_state": opt_state, "history": history}
