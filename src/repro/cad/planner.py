"""Pluggable plan policies for the attention service.

A *planner* maps a packed batch's document layout to a
:class:`~repro.core.plan.StepPlan` plus scheduling statistics.  Policies
are registered by name so every plan-building site — the data pipeline,
benchmarks, launch dry-runs, examples — selects behavior with a single
``plan_policy="..."`` string:

  identity    every block served at home (no disaggregation; the
              fixed-packing baseline expressed as a CAD plan)
  per_doc_cp  head-tail per-document context parallelism (paper §2.2,
              DISTFLASHATTN-style) as a registered policy
  balanced    the communication-aware greedy scheduler (paper §4.2)
  ring        DISTFLASHATTN-style ring / context parallelism: each
              endpoint owns the p-th contiguous kv shard of every
              document (DESIGN.md §13) — the external baseline CAD's
              planners are measured against in benchmarks/cad_vs_ring

All planners build their dispatch arrays through the same
``plan_from_assignment``, so two policies that produce the same
assignment produce bit-identical plans.

``comm`` calibrates comm-volume accounting (and, for ``balanced``, the
scheduler's bytes-per-FLOP scoring) to the model's head geometry; with
``comm=None`` reported ``comm_bytes`` is 0 and ``balanced`` falls back
to a unit-size byte model — pass the real ``CommModel`` whenever stats
are compared across call sites.  ``build_plan=False`` skips the
dispatch-array construction (and its capacity checks) for
analysis-only callers that never dispatch.

Heterogeneous pools and runtime calibration (DESIGN.md §3): every
policy accepts ``cost_model`` (a measured/calibrated latency grid;
``None`` = relative FLOPs) and ``speeds`` (per-server speed factors;
``None`` = ``cfg.speeds()``).  Reported ``loads`` are per-server
modeled *time* — assigned cost over speed — so stats stay comparable
across policies on a heterogeneous pool; ``balanced`` additionally
balances against per-server capacity, giving a 0.5x server half the
FLOPs.

Mask-structured tasks (DESIGN.md §12): every policy accepts ``mask`` —
a :class:`~repro.core.mask.MaskSpec` that reprices q-blocks by their
*live* kv blocks.  ``balanced`` then splits documents along the mask
structure (per-server live-block time balances instead of rectangle
area); the fixed layouts report honestly-masked loads so policy
comparisons under sparse masks stay meaningful.

Elastic membership (DESIGN.md §9): every policy accepts ``exclude`` — a
set of servers (drained or dead pool members) that must not hold CA
tasks.  Documents homed on an excluded server are evacuated to the
survivors; the dispatch geometry (array shapes) never changes, so one
compiled executable serves every membership epoch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core.cost_model import CommModel, CostModel, MemoryModel
from repro.core.mask import MaskSpec
from repro.core.plan import CADConfig, PlanMemoryError, StepPlan, \
    head_tail_assignment, identity_assignment, plan_from_assignment, \
    ring_assignment
from repro.core.scheduler import assignment_resident_bytes, block_costs, \
    check_exclude, layout_from_segments, schedule, streamed_doc_ids


@dataclasses.dataclass(frozen=True)
class PlanResult:
    """A planner's output: the typed plan, the raw per-block assignment
    (for analysis/benchmarks), per-server loads, and summary stats.
    ``plan`` is None when the planner ran with ``build_plan=False``
    (analysis-only callers that never dispatch).  ``loads`` is modeled
    per-server time (cost / speed); with the homogeneous default and no
    cost model it equals relative FLOPs.  ``resident_bytes`` is the
    per-server modeled HBM working set (populated whenever a memory
    model was in play — always when ``cfg.server_hbm`` is set);
    ``streamed`` names docs whose kv streams in chunks (DESIGN.md
    §11)."""
    plan: Optional[StepPlan]
    assign: np.ndarray            # [G] server per global q-block
    loads: np.ndarray             # [S] per-server modeled time
    stats: Dict[str, float]       # comm_bytes, n_moves, load_max_over_mean
    resident_bytes: Optional[np.ndarray] = None   # [S] modeled HBM bytes
    streamed: Tuple[int, ...] = ()                # doc ids streaming kv


# planner signature:
#   (cfg, segment_ids, *, comm, tolerance, build_plan, cost_model,
#    speeds, exclude, mem_model, budgets, stream_chunk, mask)
#   -> PlanResult
Planner = Callable[..., PlanResult]

_PLANNERS: Dict[str, Planner] = {}


def register_planner(name: str) -> Callable[[Planner], Planner]:
    """Decorator: register ``fn`` under ``name`` in the policy registry."""
    def deco(fn: Planner) -> Planner:
        _PLANNERS[name] = fn
        return fn
    return deco


def get_planner(name: str) -> Planner:
    try:
        return _PLANNERS[name]
    except KeyError:
        raise KeyError(f"unknown plan policy {name!r}; registered: "
                       f"{sorted(_PLANNERS)}") from None


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_PLANNERS))


def _resolve_speeds(cfg: CADConfig, speeds) -> np.ndarray:
    return cfg.speeds() if speeds is None \
        else np.asarray(speeds, np.float64)


def _evacuate_whole_docs(assign: np.ndarray, docs,
                         exclude: Tuple[int, ...],
                         allowed: Tuple[int, ...]) -> np.ndarray:
    """Deterministic fallback evacuation for the fixed-layout policies
    (identity / per_doc_cp): whole documents homed on an excluded server
    are dealt round-robin over the survivors, in document order."""
    i = 0
    for d in docs:
        if d.home in exclude:
            assign[d.g0:d.g0 + d.n_blocks] = allowed[i % len(allowed)]
            i += 1
    return assign


def _loads_of(assign: np.ndarray, doc_of: np.ndarray, bi_of: np.ndarray,
              blk: int, n_servers: int,
              cost_model: Optional[CostModel] = None,
              speeds: Optional[np.ndarray] = None,
              mask: Optional[MaskSpec] = None) -> np.ndarray:
    cost = block_costs(doc_of, bi_of, blk, cost_model, mask)
    loads = np.zeros(n_servers)
    live = doc_of >= 0
    np.add.at(loads, assign[live].astype(np.int64), cost[live])
    return loads if speeds is None else loads / speeds


def _migration_bytes(cfg: CADConfig, assign: np.ndarray, docs,
                     doc_of: np.ndarray, bi_of: np.ndarray,
                     comm: Optional[CommModel]) -> float:
    """Comm volume implied by an assignment (one layer, forward
    direction): offloaded q blocks + the deduplicated kv prefixes each
    server must receive — the same counting the dispatch send slots
    realize, without building the plan arrays."""
    if comm is None:
        return 0.0
    d, nb = cfg.n_servers, cfg.nb
    home = identity_assignment(cfg)
    live = doc_of >= 0
    n_q = int((assign[live] != home[live]).sum())
    needs: list = [dict() for _ in range(d)]
    for g in np.nonzero(live)[0]:
        s = int(assign[g])
        dc = int(doc_of[g])
        needs[s][dc] = max(needs[s].get(dc, 0), int(bi_of[g]) + 1)
    n_kv = 0
    for s in range(d):
        for dc, pref in needs[s].items():
            g0 = docs[dc].g0
            n_kv += sum(1 for g in range(g0, g0 + pref) if g // nb != s)
    return float(comm.migration_bytes(n_q * cfg.blk, n_kv * cfg.blk))


def _stats(loads: np.ndarray, comm_bytes: float, n_moves: int,
           resident: Optional[np.ndarray] = None,
           allowed: Optional[Tuple[int, ...]] = None) -> Dict[str, float]:
    st = {"comm_bytes": float(comm_bytes), "n_moves": int(n_moves),
          "load_max_over_mean": float(loads.max()
                                      / max(loads.mean(), 1e-9))}
    if resident is not None:
        r = resident if allowed is None else resident[list(allowed)]
        st["peak_resident_bytes"] = float(r.max())
        st["resident_max_over_mean"] = float(r.max()
                                             / max(r.mean(), 1e-9))
    return st


def _mem_setup(cfg: CADConfig, comm: Optional[CommModel], mem_model,
               budgets, stream_chunk):
    """Resolve the memory-planning inputs: explicit kwargs win, else the
    config's ``server_hbm``/``stream_chunk``.  Returns (mem, budgets,
    chunk) with ``mem`` None only when memory is wholly unconstrained
    AND no model was requested (resident stats are then skipped)."""
    budgets = cfg.budgets() if budgets is None \
        else np.asarray(budgets, np.float64)
    chunk = cfg.stream_chunk if stream_chunk is None else int(stream_chunk)
    if mem_model is None and budgets is None:
        return None, None, chunk
    mem = mem_model if mem_model is not None else MemoryModel(
        comm if comm is not None
        else CommModel(n_heads=1, head_dim=1, n_kv_heads=1))
    return mem, budgets, chunk


def _check_fixed_layout_memory(policy: str, cfg: CADConfig, assign, docs,
                               doc_of, bi_of, mem, budgets, chunk,
                               allowed: Tuple[int, ...]):
    """Memory accounting for the fixed-layout policies (identity /
    per_doc_cp).  Their assignments are not re-splittable by
    construction, so a budget overflow is immediately terminal:
    :class:`PlanMemoryError` — the caller should pick ``balanced``
    (which re-splits) or raise the budget."""
    if mem is None:
        return None, ()
    streamed = () if budgets is None else streamed_doc_ids(
        docs, cfg.blk, mem, budgets, stream_chunk=chunk, allowed=allowed)
    resident = assignment_resident_bytes(
        assign, doc_of, bi_of, cfg.blk, cfg.n_servers, mem,
        streamed=streamed, stream_chunk=chunk)
    if budgets is not None:
        for s in allowed:
            if resident[s] > budgets[s]:
                raise PlanMemoryError(
                    s, float(resident[s]), float(budgets[s]),
                    detail=f"{policy} is a fixed layout and cannot "
                           f"re-split; use plan_policy='balanced'")
    return resident, streamed


@register_planner("identity")
def identity_planner(cfg: CADConfig, segment_ids: np.ndarray, *,
                     comm: Optional[CommModel] = None,
                     tolerance: float = 0.0,
                     build_plan: bool = True,
                     cost_model: Optional[CostModel] = None,
                     speeds: Optional[np.ndarray] = None,
                     exclude: Optional[Iterable[int]] = None,
                     mem_model: Optional[MemoryModel] = None,
                     budgets: Optional[np.ndarray] = None,
                     stream_chunk: Optional[int] = None,
                     mask: Optional[MaskSpec] = None) -> PlanResult:
    docs, doc_of, bi_of = layout_from_segments(segment_ids, cfg.blk,
                                               cfg.n_servers)
    exclude = check_exclude(exclude, cfg.n_servers)
    allowed = tuple(s for s in range(cfg.n_servers) if s not in exclude)
    assign = identity_assignment(cfg)
    n_moves = 0
    if exclude:
        assign = _evacuate_whole_docs(assign, docs, exclude, allowed)
        home = identity_assignment(cfg)
        live = doc_of >= 0
        n_moves = int((assign[live] != home[live]).sum())
    mem, budgets, chunk = _mem_setup(cfg, comm, mem_model, budgets,
                                     stream_chunk)
    resident, streamed = _check_fixed_layout_memory(
        "identity", cfg, assign, docs, doc_of, bi_of, mem, budgets,
        chunk, allowed)
    plan = plan_from_assignment(cfg, assign, doc_of, bi_of, docs) \
        if build_plan else None
    loads = _loads_of(assign, doc_of, bi_of, cfg.blk, cfg.n_servers,
                      cost_model, _resolve_speeds(cfg, speeds), mask)
    return PlanResult(plan=plan, assign=assign, loads=loads,
                      stats=_stats(loads, _migration_bytes(
                          cfg, assign, docs, doc_of, bi_of, comm)
                          if exclude else 0.0, n_moves,
                          resident, allowed),
                      resident_bytes=resident, streamed=streamed)


@register_planner("per_doc_cp")
def per_doc_cp_planner(cfg: CADConfig, segment_ids: np.ndarray, *,
                       comm: Optional[CommModel] = None,
                       tolerance: float = 0.0,
                       build_plan: bool = True,
                       cost_model: Optional[CostModel] = None,
                       speeds: Optional[np.ndarray] = None,
                       exclude: Optional[Iterable[int]] = None,
                       mem_model: Optional[MemoryModel] = None,
                       budgets: Optional[np.ndarray] = None,
                       stream_chunk: Optional[int] = None,
                       mask: Optional[MaskSpec] = None) \
        -> PlanResult:
    """Head-tail per-document CP (paper §2.2 as a special-case plan).
    The dealing order is the paper's fixed head-tail pairing — speed-
    oblivious by construction — but loads/stats are still reported in
    modeled time so heterogeneous-pool comparisons stay honest.  With
    ``exclude`` the head-tail deal runs over the surviving servers."""
    docs, doc_of, bi_of = layout_from_segments(segment_ids, cfg.blk,
                                               cfg.n_servers)
    exclude = check_exclude(exclude, cfg.n_servers)
    allowed = tuple(s for s in range(cfg.n_servers) if s not in exclude)
    servers = allowed if exclude else None
    assign = head_tail_assignment(cfg, docs, servers)
    mem, budgets, chunk = _mem_setup(cfg, comm, mem_model, budgets,
                                     stream_chunk)
    resident, streamed = _check_fixed_layout_memory(
        "per_doc_cp", cfg, assign, docs, doc_of, bi_of, mem, budgets,
        chunk, allowed)
    plan = plan_from_assignment(cfg, assign, doc_of, bi_of, docs) \
        if build_plan else None
    loads = _loads_of(assign, doc_of, bi_of, cfg.blk, cfg.n_servers,
                      cost_model, _resolve_speeds(cfg, speeds), mask)
    n_moves = int((assign != identity_assignment(cfg)).sum())
    return PlanResult(
        plan=plan, assign=assign, loads=loads,
        stats=_stats(loads, _migration_bytes(cfg, assign, docs, doc_of,
                                             bi_of, comm), n_moves,
                     resident, allowed),
        resident_bytes=resident, streamed=streamed)


@register_planner("ring")
def ring_planner(cfg: CADConfig, segment_ids: np.ndarray, *,
                 comm: Optional[CommModel] = None,
                 tolerance: float = 0.0,
                 build_plan: bool = True,
                 cost_model: Optional[CostModel] = None,
                 speeds: Optional[np.ndarray] = None,
                 exclude: Optional[Iterable[int]] = None,
                 mem_model: Optional[MemoryModel] = None,
                 budgets: Optional[np.ndarray] = None,
                 stream_chunk: Optional[int] = None,
                 mask: Optional[MaskSpec] = None) -> PlanResult:
    """Ring / context-parallel attention (DISTFLASHATTN, DESIGN.md §13)
    as a registered policy: every document is cut into P contiguous kv
    shards and shard ``p`` is owned by the ``p``-th allowed server, so
    q blocks rotate through P ring passes at execution time
    (``dispatch.ring_attention``).  Sequence-contiguous and
    workload-oblivious by construction — under causal attention the
    tail-shard endpoints carry quadratically more compute, the
    imbalance CAD's ``balanced`` planner is quantified against in
    ``benchmarks/cad_vs_ring.py``.  Loads/stats are reported in modeled
    time with mask-aware live-block pricing like every other policy,
    so the comparison measures what the kernels execute.  With
    ``exclude`` the ring shrinks to the surviving servers."""
    docs, doc_of, bi_of = layout_from_segments(segment_ids, cfg.blk,
                                               cfg.n_servers)
    exclude = check_exclude(exclude, cfg.n_servers)
    allowed = tuple(s for s in range(cfg.n_servers) if s not in exclude)
    servers = allowed if exclude else None
    assign = ring_assignment(cfg, docs, servers)
    mem, budgets, chunk = _mem_setup(cfg, comm, mem_model, budgets,
                                     stream_chunk)
    resident, streamed = _check_fixed_layout_memory(
        "ring", cfg, assign, docs, doc_of, bi_of, mem, budgets,
        chunk, allowed)
    plan = plan_from_assignment(cfg, assign, doc_of, bi_of, docs) \
        if build_plan else None
    loads = _loads_of(assign, doc_of, bi_of, cfg.blk, cfg.n_servers,
                      cost_model, _resolve_speeds(cfg, speeds), mask)
    n_moves = int((assign != identity_assignment(cfg)).sum())
    return PlanResult(
        plan=plan, assign=assign, loads=loads,
        stats=_stats(loads, _migration_bytes(cfg, assign, docs, doc_of,
                                             bi_of, comm), n_moves,
                     resident, allowed),
        resident_bytes=resident, streamed=streamed)


@register_planner("balanced")
def balanced_planner(cfg: CADConfig, segment_ids: np.ndarray, *,
                     comm: Optional[CommModel] = None,
                     tolerance: float = 0.1,
                     build_plan: bool = True,
                     cost_model: Optional[CostModel] = None,
                     speeds: Optional[np.ndarray] = None,
                     exclude: Optional[Iterable[int]] = None,
                     mem_model: Optional[MemoryModel] = None,
                     budgets: Optional[np.ndarray] = None,
                     stream_chunk: Optional[int] = None,
                     mask: Optional[MaskSpec] = None) \
        -> PlanResult:
    """The paper's communication-aware greedy scheduler (§4.2), balancing
    modeled time across per-server capacities (calibrated cost model +
    speed factors) when provided; ``exclude`` withdraws drained/dead
    pool members from the balance (DESIGN.md §9).  With HBM budgets
    (``cfg.server_hbm`` or explicit ``budgets``) assignments are
    re-split until every endpoint's resident bytes fit (DESIGN.md §11),
    raising :class:`PlanMemoryError` only when no feasible split
    exists."""
    if comm is None:
        comm = CommModel(n_heads=1, head_dim=1, n_kv_heads=1)
    mem, budgets, chunk = _mem_setup(cfg, comm, mem_model, budgets,
                                     stream_chunk)
    sch = schedule(segment_ids, blk=cfg.blk, n_servers=cfg.n_servers,
                   comm=comm, caps=cfg.caps(), tolerance=tolerance,
                   speeds=_resolve_speeds(cfg, speeds),
                   cost_model=cost_model, exclude=exclude,
                   mem_model=mem, budgets=budgets, stream_chunk=chunk,
                   mask=mask)
    plan = plan_from_assignment(cfg, sch.assign, sch.doc_of_block,
                                sch.bi_of_block, sch.docs) \
        if build_plan else None
    allowed = tuple(s for s in range(cfg.n_servers)
                    if s not in set(sch.exclude))
    return PlanResult(plan=plan, assign=sch.assign, loads=sch.loads,
                      stats=_stats(sch.loads, sch.comm_bytes, sch.n_moves,
                                   sch.resident_bytes, allowed),
                      resident_bytes=sch.resident_bytes,
                      streamed=sch.streamed)
