"""Core attention disaggregation as a service (paper §4; DistCA).

This package is the single entry point for CAD:

  CADSession          owns pool config, kernel, ping-pong, tolerance,
                      plan policy; builds contexts and plans; feeds
                      measured timings back to the calibrator
  StepPlan            one step's dispatch plan, a typed JAX pytree
  PingPongPlan        the two nano-batch plans of a ping-pong step
  register_planner /  string-keyed plan-policy registry
  get_planner         ("identity" | "per_doc_cp" | "balanced")
  PlanPrefetcher      async host-side plan prefetch (bounded queue,
                      stale-plan refresh under calibration)
  PlanCapacityError   static-capacity overflow diagnostics
  PlanMemoryError     no feasible split fits the HBM budgets
  GridCalibrator      runtime (q_len, kv_len) latency-grid profiler with
                      per-server speed estimation (DESIGN.md §3)

All CAD use goes through :class:`CADSession`; the PR-1 era shims
(``make_cad_context``, dict-plan ``batches()``) have been removed.
"""
from repro.cad.planner import (PlanResult, Planner, available_policies,
                               get_planner, register_planner)
from repro.cad.prefetch import PlanPrefetcher
from repro.cad.session import CADSession
from repro.core.cost_model import CalibrationSnapshot, GridCalibrator
from repro.core.plan import (CADConfig, PingPongPlan, PlanCapacityError,
                             PlanMemoryError, StepPlan)

__all__ = [
    "CADSession", "StepPlan", "PingPongPlan", "CADConfig",
    "PlanCapacityError", "PlanMemoryError", "Planner",
    "PlanResult", "register_planner",
    "get_planner", "available_policies", "PlanPrefetcher",
    "GridCalibrator", "CalibrationSnapshot",
]
