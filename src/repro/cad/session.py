"""`CADSession` — the single entry point for core-attention
disaggregation.

A session owns everything that used to be scattered across
``PipelineConfig`` / ``CADContext`` / ``ParallelContext`` side channels:
the pool geometry (:class:`CADConfig`), the server kernel choice, the
ping-pong flag, the scheduler tolerance, and the plan policy.  From one
session you derive:

  session.context()              the ParallelContext the model jits with
  session.plan(segs)             one step's StepPlan (or PingPongPlan)
  session.attach_plans(batches)  a batch stream with plans attached,
                                 planned asynchronously one step ahead
                                 (the paper's scheduler prefetch)

DESIGN.md §1 places the session in the data → planner → dispatch →
kernels architecture; §3 explains the static capacities it configures.

Construction::

  session = CADSession.for_pipeline(model_cfg, pipe_cfg,
                                    plan_policy="balanced")
  ctx = session.context()
  for batch in session.attach_plans(raw_batches(pipe_cfg)):
      params, opt_state, metrics = step(params, opt_state, batch)

Unlike the deprecated ``make_cad_context``, ``for_pipeline`` never
mutates the pipeline config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Iterator, Optional, Tuple, Union

import numpy as np

from repro.cad.planner import get_planner
from repro.cad.prefetch import PlanPrefetcher
from repro.core.cost_model import CommModel
from repro.core.dispatch import CADContext
from repro.core.plan import CADConfig, PingPongPlan, StepPlan
from repro.parallel import ParallelContext, ShardingRules

Plan = Union[StepPlan, PingPongPlan]


@dataclasses.dataclass(frozen=True)
class CADSession:
    """Immutable description of the attention service for one run."""
    cfg: CADConfig
    kernel: str = "xla"            # "xla" | "pallas" server implementation
    bwd: Optional[str] = None      # None (default) | "pallas" | "xla"
    pingpong: bool = False
    tolerance: float = 0.1
    plan_policy: str = "balanced"
    jmax: int = 0                  # max kv blocks per task (0 -> cfg.nkv)
    comm: Optional[CommModel] = None
    mesh: Any = None
    rules: Any = None
    prefetch: int = 2              # plan look-ahead depth; 0 = synchronous

    # ------------------------------------------------------- constructors
    @classmethod
    def for_pipeline(cls, model_cfg, pipe_cfg, *, kernel: str = "xla",
                     pingpong: bool = False, tolerance: float = 0.1,
                     plan_policy: str = "balanced", mesh=None, rules=None,
                     prefetch: int = 2) -> "CADSession":
        """Size the attention-server pool for a training pipeline.

        ``pipe_cfg`` needs ``n_ranks``, ``global_batch``, ``seq_len`` and
        ``max_doc_len``; it is read, never mutated."""
        n = pipe_cfg.n_ranks
        rows_per_rank = pipe_cfg.global_batch // n
        tokens_per_rank = rows_per_rank * pipe_cfg.seq_len
        if pingpong:
            if rows_per_rank % 2:
                raise ValueError("ping-pong needs an even number of rows "
                                 f"per rank, got {rows_per_rank}")
            tokens_per_rank //= 2          # pool sized per nano-batch
        cadcfg = CADConfig.default(n, tokens_per_rank,
                                   max_doc_tokens=pipe_cfg.max_doc_len)
        comm = CommModel(n_heads=getattr(model_cfg, "n_heads", 1) or 1,
                         head_dim=getattr(model_cfg, "head_dim", 1) or 1,
                         n_kv_heads=getattr(model_cfg, "n_kv_heads", 1)
                         or 1)
        jmax = max(1, pipe_cfg.max_doc_len // cadcfg.blk)
        return cls(cfg=cadcfg, kernel=kernel, pingpong=pingpong,
                   tolerance=tolerance, plan_policy=plan_policy,
                   jmax=jmax, comm=comm, mesh=mesh, rules=rules,
                   prefetch=prefetch)

    # ------------------------------------------------------------ context
    def context(self, *, remat: bool = True) -> ParallelContext:
        """The ParallelContext consumers jit against.  Plans are bound per
        step by the train step (``CADContext.bind_plan``)."""
        cad = CADContext(cfg=self.cfg, kernel=self.kernel, bwd=self.bwd,
                         jmax=self.jmax, pingpong=self.pingpong)
        return ParallelContext(mesh=self.mesh,
                               rules=self.rules or ShardingRules(),
                               attn_impl="cad", cad=cad, remat=remat,
                               pingpong=self.pingpong)

    # ----------------------------------------------------------- planning
    def plan(self, segment_ids: np.ndarray) \
            -> Tuple[Plan, Dict[str, float]]:
        """Plan one step.  ``segment_ids`` is the rank-major [D, T] packed
        layout (T = tokens per rank; 2·nb·blk when ping-pong is on)."""
        segs = np.asarray(segment_ids)
        planner = get_planner(self.plan_policy)
        if not self.pingpong:
            res = planner(self.cfg, segs, comm=self.comm,
                          tolerance=self.tolerance)
            return res.plan, dict(res.stats)
        half = segs.shape[1] // 2
        if half % self.cfg.blk:
            raise ValueError(
                f"ping-pong nano-batch of {half} tokens is not a "
                f"multiple of blk={self.cfg.blk}")
        # a cfg sized for the full step (legacy callers) is re-sized to
        # the nano-batch, matching the old pipeline behavior
        cfg = self.cfg if half == self.cfg.nb * self.cfg.blk \
            else dataclasses.replace(self.cfg, nb=half // self.cfg.blk)
        halves = []
        stats: Dict[str, float] = {"comm_bytes": 0.0, "n_moves": 0,
                                   "load_max_over_mean": 0.0}
        for i in range(2):
            res = planner(cfg, segs[:, i * half:(i + 1) * half],
                          comm=self.comm, tolerance=self.tolerance)
            halves.append(res.plan)
            stats["comm_bytes"] += res.stats["comm_bytes"]
            stats["n_moves"] += res.stats["n_moves"]
            stats["load_max_over_mean"] = max(
                stats["load_max_over_mean"],
                res.stats["load_max_over_mean"])
        return PingPongPlan(*halves), stats

    def plan_batch(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        """Attach ``plan`` + ``schedule_stats`` to one pipeline batch
        (rows are rank-major: rank r owns rows [r·rpr, (r+1)·rpr))."""
        segs = np.asarray(batch["segment_ids"])
        if self.pingpong:
            rpr = segs.shape[0] // self.cfg.n_servers
            if rpr % 2:
                # the dispatch nano-split is by rows; a mid-row token
                # split would fail opaquely deep inside cad_attention
                raise ValueError("ping-pong needs an even number of rows "
                                 f"per rank, got {rpr}")
        segs_rank = segs.reshape(self.cfg.n_servers, -1)
        plan, stats = self.plan(segs_rank)
        out = dict(batch)
        out["plan"] = plan
        out["schedule_stats"] = stats
        return out

    def attach_plans(self, batch_iter: Iterable[Dict[str, Any]], *,
                     prefetch: Optional[int] = None) \
            -> Iterator[Dict[str, Any]]:
        """Yield batches with plans attached.  With ``prefetch >= 1`` a
        background worker plans batch *i+1* while the caller's device
        computes batch *i* (bounded queue, order-preserving); with
        ``prefetch=0`` planning happens inline."""
        depth = self.prefetch if prefetch is None else prefetch
        if depth <= 0:
            for batch in batch_iter:
                yield self.plan_batch(batch)
            return
        pf = PlanPrefetcher(batch_iter, self.plan_batch, depth=depth)
        try:
            yield from pf
        finally:
            pf.close()

    # ------------------------------------------------------------- legacy
    @classmethod
    def from_legacy(cls, cad_cfg: CADConfig, *, kernel: str = "xla",
                    pingpong: bool = False, tolerance: float = 0.1,
                    plan_policy: str = "balanced",
                    comm: Optional[CommModel] = None,
                    jmax: int = 0) -> "CADSession":
        """Wrap pre-session state (a bare CADConfig + loose knobs) — used
        by the deprecated ``make_cad_context``/dict-plan pipeline path."""
        return cls(cfg=cad_cfg, kernel=kernel, pingpong=pingpong,
                   tolerance=tolerance, plan_policy=plan_policy, comm=comm,
                   jmax=jmax or max(1, cad_cfg.nkv), prefetch=0)
