"""`CADSession` — the single entry point for core-attention
disaggregation.

A session owns everything that used to be scattered across
``PipelineConfig`` / ``CADContext`` / ``ParallelContext`` side channels:
the pool geometry (:class:`CADConfig`), the server kernel choice, the
ping-pong flag, the scheduler tolerance, and the plan policy.  From one
session you derive:

  session.context()              the ParallelContext the model jits with
  session.plan(segs)             one step's StepPlan (or PingPongPlan)
  session.attach_plans(batches)  a batch stream with plans attached,
                                 planned asynchronously one step ahead
                                 (the paper's scheduler prefetch)
  session.observe*(...)          measured CA-task timings fed back into
                                 the runtime calibrator, so batch i+1
                                 is planned from batch i's costs

DESIGN.md §1 places the session in the data → planner → dispatch →
kernels architecture; §3 explains the static capacities it configures
and the measure → fit → replan calibration loop.

Construction::

  session = CADSession.for_pipeline(model_cfg, pipe_cfg,
                                    plan_policy="balanced",
                                    server_speeds=(1.0, 0.5),
                                    calibrate=True)
  ctx = session.context()
  for batch in session.attach_plans(raw_batches(pipe_cfg)):
      params, opt_state, metrics = step(params, opt_state, batch)
      session.observe_probe(batch["plan"])    # feed measured timings

``for_pipeline`` never mutates the pipeline config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Iterator, Optional, Tuple, Union

import numpy as np

from repro.cad.planner import get_planner
from repro.cad.prefetch import PlanPrefetcher
from repro.core.cost_model import (CalibrationSnapshot, CommModel,
                                   CostModel, GridCalibrator)
from repro.core.dispatch import CADContext, iter_plan_tasks, \
    probe_plan_times
from repro.core.mask import MaskSpec, parse_mask, validate_mask_layout
from repro.core.plan import CADConfig, PingPongPlan, StepPlan
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.parallel import ParallelContext, ShardingRules

Plan = Union[StepPlan, PingPongPlan]


@dataclasses.dataclass(frozen=True)
class CADSession:
    """Immutable description of the attention service for one run.

    ``calibrator`` (optional) owns the runtime measure → fit → replan
    loop: every ``plan()`` call consumes one immutable calibration
    snapshot (cost model + per-server speeds) and records its version
    in the schedule stats; ``observe*`` feeds measured timings back.
    The calibrator object itself is mutable shared state — the one
    deliberate exception to the session's immutability."""
    cfg: CADConfig
    kernel: str = "xla"            # "xla" | "pallas" server implementation
    bwd: Optional[str] = None      # None (default) | "pallas" | "xla"
    pingpong: bool = False
    tolerance: float = 0.1
    plan_policy: str = "balanced"  # registry name: identity | per_doc_cp
                                   # | balanced | ring (DESIGN.md §13)
    jmax: int = 0                  # max kv blocks per task (0 -> cfg.nkv)
    comm: Optional[CommModel] = None
    mesh: Any = None
    rules: Any = None
    prefetch: int = 2              # plan look-ahead depth; 0 = synchronous
    calibrator: Optional[GridCalibrator] = None
    recalib_threshold: float = 0.05   # speed drift that re-plans a
                                      # prefetched (stale) plan at pull
    pool: Any = None               # ServerPool: elastic membership; like
                                   # the calibrator, mutable shared state
    mask: Optional[MaskSpec] = None   # task shape beyond dense causal
                                      # (DESIGN.md §12); None = causal

    # ------------------------------------------------------- constructors
    @classmethod
    def for_pipeline(cls, model_cfg, pipe_cfg, *, kernel: str = "xla",
                     pingpong: bool = False, tolerance: float = 0.1,
                     plan_policy: str = "balanced", mesh=None, rules=None,
                     prefetch: int = 2, server_speeds=None,
                     server_hbm=None, stream_chunk: int = 0,
                     calibrate: bool = False,
                     calib_ema: float = 0.5,
                     mask: Union[MaskSpec, str, None] = None) \
            -> "CADSession":
        """Size the attention-server pool for a training pipeline.

        ``pipe_cfg`` needs ``n_ranks``, ``global_batch``, ``seq_len`` and
        ``max_doc_len``; it is read, never mutated.  ``server_speeds``
        declares known pool heterogeneity (a 0.5 entry = half-speed
        server); ``calibrate=True`` additionally attaches a
        :class:`GridCalibrator` (seeded with the analytic model and the
        declared speeds as prior) so measured timings keep refining
        both the latency grid and the speed estimates.

        ``server_hbm`` declares per-endpoint HBM budgets in bytes
        (DESIGN.md §11): planning then treats memory as a second
        constraint next to modeled time, and ``stream_chunk`` (kv
        blocks) lets dispatch serve tasks whose kv prefix exceeds
        every budget by streaming the prefix chunkwise.

        ``mask`` names the step's task shape beyond dense causal
        (DESIGN.md §12) — a :class:`~repro.core.mask.MaskSpec` or a
        ``--mask`` flag string (``sliding:window=256,sink=16``,
        ``dilated:rate=4``); planning prices tasks by live blocks and
        the dispatch kernels apply the matching in-block mask."""
        n = pipe_cfg.n_ranks
        rows_per_rank = pipe_cfg.global_batch // n
        tokens_per_rank = rows_per_rank * pipe_cfg.seq_len
        if pingpong:
            if rows_per_rank % 2:
                raise ValueError("ping-pong needs an even number of rows "
                                 f"per rank, got {rows_per_rank}")
            tokens_per_rank //= 2          # pool sized per nano-batch
        cadcfg = CADConfig.default(n, tokens_per_rank,
                                   max_doc_tokens=pipe_cfg.max_doc_len,
                                   server_speeds=server_speeds,
                                   server_hbm=server_hbm,
                                   stream_chunk=stream_chunk)
        n_heads = getattr(model_cfg, "n_heads", 1) or 1
        head_dim = getattr(model_cfg, "head_dim", 1) or 1
        comm = CommModel(n_heads=n_heads, head_dim=head_dim,
                         n_kv_heads=getattr(model_cfg, "n_kv_heads", 1)
                         or 1)
        calibrator = None
        if calibrate:
            calibrator = GridCalibrator(
                CostModel.analytic(n_heads, head_dim), n,
                ema=calib_ema, prior_speeds=cadcfg.speeds())
        jmax = max(1, pipe_cfg.max_doc_len // cadcfg.blk)
        if isinstance(mask, str):
            mask = parse_mask(mask)
        if mask is not None and mask.trivial:
            mask = None
        return cls(cfg=cadcfg, kernel=kernel, pingpong=pingpong,
                   tolerance=tolerance, plan_policy=plan_policy,
                   jmax=jmax, comm=comm, mesh=mesh, rules=rules,
                   prefetch=prefetch, calibrator=calibrator, mask=mask)

    # ------------------------------------------------------------ context
    def context(self, *, remat: bool = True) -> ParallelContext:
        """The ParallelContext consumers jit against.  Plans are bound per
        step by the train step (``CADContext.bind_plan``)."""
        cad = CADContext(cfg=self.cfg, kernel=self.kernel, bwd=self.bwd,
                         jmax=self.jmax, pingpong=self.pingpong,
                         mask=self.mask)
        return ParallelContext(mesh=self.mesh,
                               rules=self.rules or ShardingRules(),
                               attn_impl="cad", cad=cad, remat=remat,
                               pingpong=self.pingpong)

    # --------------------------------------------------------- elasticity
    def with_pool(self, pool) -> "CADSession":
        """Attach a :class:`repro.runtime.ServerPool`: planning then
        runs against the pool's surviving members only, every plan's
        stats record the membership epoch it was built from, and
        prefetched plans from a superseded epoch are re-planned at pull
        (DESIGN.md §9)."""
        if pool is not None and pool.n_slots != self.cfg.n_servers:
            raise ValueError(
                f"pool has {pool.n_slots} slots, session pool geometry "
                f"is {self.cfg.n_servers} servers")
        return dataclasses.replace(self, pool=pool)

    def _pool_view(self):
        return None if self.pool is None else self.pool.view()

    # ------------------------------------------------------- calibration
    def _snapshot(self) -> Optional[CalibrationSnapshot]:
        return None if self.calibrator is None \
            else self.calibrator.snapshot()

    def admission_view(self) \
            -> Tuple[CalibrationSnapshot, Optional[Any]]:
        """One atomic (calibration snapshot, pool view) pair — the
        pricing basis for one fabric admission round (DESIGN.md §10:
        every round consumes exactly one snapshot and one
        ``pool_epoch``-stamped membership view, the same discipline
        ``plan()`` follows).  Without a calibrator the snapshot wraps
        the analytic model and declared speeds at version -1; without
        a pool the view is None."""
        snap = self._snapshot()
        if snap is None:
            comm = self.comm
            cm = CostModel.analytic(comm.n_heads if comm else 1,
                                    comm.head_dim if comm else 8)
            snap = CalibrationSnapshot(
                version=-1, cost_model=cm,
                speeds=tuple(float(s) for s in self.cfg.speeds()))
        return snap, self._pool_view()

    def snapshot_provider(self):
        """A ``() -> CalibrationSnapshot`` callable for the serve
        scheduler's ``SchedulerConfig.snapshot_provider``: admission
        then prices each round from the same calibrated snapshot the
        planner plans from."""
        return lambda: self.admission_view()[0]

    def _planner_kwargs(self, snap: Optional[CalibrationSnapshot]) \
            -> Dict[str, Any]:
        if snap is None:
            return {}
        return {"cost_model": snap.cost_model,
                "speeds": snap.speeds_array()}

    def _annotate(self, stats: Dict[str, float],
                  snap: Optional[CalibrationSnapshot],
                  view=None) -> Dict[str, float]:
        if snap is not None:
            stats["calib_version"] = float(snap.version)
            for s, sp in enumerate(snap.speeds):
                stats[f"calib_speed_{s}"] = float(sp)
        if view is not None:
            stats["pool_epoch"] = float(view.epoch)
            stats["pool_active"] = float(len(view.active))
        return stats

    def _plan_stale(self, batch: Dict[str, Any]) -> bool:
        """True when a prefetched batch's plan was built from a
        superseded pool-membership epoch (it may still assign tasks to a
        drained or dead server — never executable) or from speeds that
        have since drifted beyond ``recalib_threshold`` — checked (and
        re-planned) on the consumer thread at pull time."""
        st = batch.get("schedule_stats") or {}
        view = self._pool_view()
        if view is not None \
                and int(st.get("pool_epoch", -1)) != view.epoch:
            return True
        snap = self._snapshot()
        if snap is None or "calib_version" not in st:
            return False
        if int(st["calib_version"]) == snap.version:
            return False
        drift = max(abs(st.get(f"calib_speed_{s}", 1.0) - snap.speeds[s])
                    for s in range(self.cfg.n_servers))
        return drift > self.recalib_threshold

    def observe(self, q_tokens: int, kv_tokens: int, seconds: float,
                server: Optional[int] = None) -> None:
        """Feed one measured CA-task timing into the calibrator."""
        if self.calibrator is not None:
            self.calibrator.observe(q_tokens, kv_tokens, seconds,
                                    server=server)

    def observe_server(self, server: int, tasks, seconds: float) -> None:
        """Feed one per-server fused-batch timing (``tasks`` is the
        server's [(q_tokens, kv_tokens), ...] composition)."""
        if self.calibrator is not None:
            self.calibrator.observe_tasks(tasks, seconds, server=server)

    def observe_plan(self, plan, per_server_seconds) -> None:
        """Feed measured per-server serve times for one executed plan;
        task shapes are recovered from the plan's dispatch arrays.  A
        ping-pong step's timing covers both nano-batch halves, so a
        :class:`PingPongPlan` contributes the tasks of both."""
        if self.calibrator is None:
            return
        halves = list(plan) if isinstance(plan, (tuple, list,
                                                 PingPongPlan)) \
            else [plan]
        by_server: Dict[int, list] = {}
        for p in halves:
            # masked tasks key the calibrator by *live* kv tokens, the
            # same unit the planners price them in (DESIGN.md §12)
            for s, _slot, qt, kvt in iter_plan_tasks(self.cfg, p,
                                                     mask=self.mask):
                by_server.setdefault(s, []).append((qt, kvt))
        if not isinstance(per_server_seconds, dict):
            per_server_seconds = dict(enumerate(per_server_seconds))
        for s, seconds in per_server_seconds.items():
            if s in by_server:
                self.calibrator.observe_tasks(by_server[s], float(seconds),
                                              server=s)

    def observe_probe(self, plan, *, repeats: int = 1,
                      seed: int = 0) -> None:
        """Measure per-server serve time for ``plan`` with the eager
        synthetic-tensor probe (``core.dispatch.probe_plan_times``) and
        feed the timings back — the trainer's ``calibrate_every`` hook.
        Ping-pong plans probe both nano-batch halves."""
        if self.calibrator is None:
            return
        comm = self.comm or CommModel(1, 1, 1)
        plans = list(plan) if isinstance(plan, (tuple, list, PingPongPlan)) \
            else [plan]
        for i, p in enumerate(plans):
            # ping-pong halves may have been planned with a nano-batch
            # re-sized config; recover the geometry from the arrays
            nb = np.asarray(p["q_home_idx"]).shape[1]
            cfg = self.cfg if nb == self.cfg.nb \
                else dataclasses.replace(self.cfg, nb=nb)
            cad = CADContext(cfg=cfg, kernel=self.kernel, bwd=self.bwd,
                             jmax=self.jmax, mask=self.mask)
            label = "probe" if len(plans) == 1 else f"probe/half{i}"
            for s, tasks, seconds in probe_plan_times(
                    cad, p, n_heads=comm.n_heads, head_dim=comm.head_dim,
                    n_kv_heads=comm.n_kv_heads, seed=seed,
                    repeats=repeats, trace_label=label):
                self.calibrator.observe_tasks(tasks, seconds, server=s)

    # ----------------------------------------------------------- planning
    def plan(self, segment_ids: np.ndarray) \
            -> Tuple[Plan, Dict[str, float]]:
        """Plan one step.  ``segment_ids`` is the rank-major [D, T] packed
        layout (T = tokens per rank; 2·nb·blk when ping-pong is on).
        With a calibrator attached, the whole step — both ping-pong
        halves — plans from ONE calibration snapshot, recorded in the
        stats as ``calib_version`` (+ the per-server speeds used).

        Each call is narrated to the observability layer (DESIGN.md
        §14): a ``plan.build`` span on the ``planner`` track and the
        plan-quality gauges — both no-ops unless tracing is enabled /
        read."""
        with obs_trace.get_recorder().span("plan.build", "planner",
                                           args={"policy":
                                                 self.plan_policy}):
            plan, stats = self._plan_impl(segment_ids)
        reg = obs_metrics.get_registry()
        reg.gauge("cad_plan_load_max_over_mean",
                  "planned per-server load max/mean").set(
            stats.get("load_max_over_mean", 0.0))
        if "calib_version" in stats:
            reg.gauge("cad_calib_version",
                      "calibration snapshot version planned from").set(
                stats["calib_version"])
        if "pool_epoch" in stats:
            reg.gauge("cad_pool_epoch", "pool membership epoch").set(
                stats["pool_epoch"])
        return plan, stats

    def _plan_impl(self, segment_ids: np.ndarray) \
            -> Tuple[Plan, Dict[str, float]]:
        segs = np.asarray(segment_ids)
        planner = get_planner(self.plan_policy)
        if self.mask is not None:
            # fail at planning time with the offending segment/task
            # named (MaskSpecError), not as a shape error in a kernel
            validate_mask_layout(self.mask, segs, self.cfg.blk)
        snap = self._snapshot()
        view = self._pool_view()
        kw = self._planner_kwargs(snap)
        if self.mask is not None:
            kw["mask"] = self.mask
        if view is not None:
            # ONE membership view per step: both ping-pong halves plan
            # against the same surviving-endpoint set, and the epoch is
            # recorded so prefetched plans invalidate on change
            kw["exclude"] = view.excluded
        if not self.pingpong:
            res = planner(self.cfg, segs, comm=self.comm,
                          tolerance=self.tolerance, **kw)
            return res.plan, self._annotate(dict(res.stats), snap, view)
        half = segs.shape[1] // 2
        if half % self.cfg.blk:
            raise ValueError(
                f"ping-pong nano-batch of {half} tokens is not a "
                f"multiple of blk={self.cfg.blk}")
        # a cfg sized for the full step (legacy callers) is re-sized to
        # the nano-batch, matching the old pipeline behavior
        cfg = self.cfg if half == self.cfg.nb * self.cfg.blk \
            else dataclasses.replace(self.cfg, nb=half // self.cfg.blk)
        halves = []
        stats: Dict[str, float] = {"comm_bytes": 0.0, "n_moves": 0,
                                   "load_max_over_mean": 0.0}
        for i in range(2):
            res = planner(cfg, segs[:, i * half:(i + 1) * half],
                          comm=self.comm, tolerance=self.tolerance, **kw)
            halves.append(res.plan)
            stats["comm_bytes"] += res.stats["comm_bytes"]
            stats["n_moves"] += res.stats["n_moves"]
            stats["load_max_over_mean"] = max(
                stats["load_max_over_mean"],
                res.stats["load_max_over_mean"])
        return PingPongPlan(*halves), self._annotate(stats, snap, view)

    def plan_batch(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        """Attach ``plan`` + ``schedule_stats`` to one pipeline batch
        (rows are rank-major: rank r owns rows [r·rpr, (r+1)·rpr))."""
        segs = np.asarray(batch["segment_ids"])
        if self.pingpong:
            rpr = segs.shape[0] // self.cfg.n_servers
            if rpr % 2:
                # the dispatch nano-split is by rows; a mid-row token
                # split would fail opaquely deep inside cad_attention
                raise ValueError("ping-pong needs an even number of rows "
                                 f"per rank, got {rpr}")
        segs_rank = segs.reshape(self.cfg.n_servers, -1)
        plan, stats = self.plan(segs_rank)
        out = dict(batch)
        out["plan"] = plan
        out["schedule_stats"] = stats
        return out

    def attach_plans(self, batch_iter: Iterable[Dict[str, Any]], *,
                     prefetch: Optional[int] = None) \
            -> Iterator[Dict[str, Any]]:
        """Yield batches with plans attached.  With ``prefetch >= 1`` a
        background worker plans batch *i+1* while the caller's device
        computes batch *i* (bounded queue, order-preserving); with
        ``prefetch=0`` planning happens inline.

        With a calibrator attached, prefetched plans whose speed
        estimates have drifted past ``recalib_threshold`` are re-planned
        synchronously at pull time (consumer thread), so calibration
        feedback is never more than one *materially different* snapshot
        behind despite the look-ahead — and after the estimates
        converge, no pull pays the re-plan.  With a pool attached, a
        plan prefetched under a superseded membership epoch is *always*
        re-planned at pull — a plan that routes tasks to a dead server
        must never reach the dispatch."""
        depth = self.prefetch if prefetch is None else prefetch
        if depth <= 0:
            for batch in batch_iter:
                yield self.plan_batch(batch)
            return
        stale = self._plan_stale if (self.calibrator is not None
                                     or self.pool is not None) else None
        pf = PlanPrefetcher(batch_iter, self.plan_batch, depth=depth,
                            is_stale=stale)
        try:
            yield from pf
        finally:
            pf.close()

    # ---------------------------------------------------------- from parts
    @classmethod
    def from_legacy(cls, cad_cfg: CADConfig, *, kernel: str = "xla",
                    pingpong: bool = False, tolerance: float = 0.1,
                    plan_policy: str = "balanced",
                    comm: Optional[CommModel] = None,
                    jmax: int = 0,
                    mask: Union[MaskSpec, str, None] = None) \
            -> "CADSession":
        """Wrap a bare CADConfig + loose knobs into a session — for
        callers that size the pool geometry themselves rather than
        deriving it from a pipeline config."""
        if isinstance(mask, str):
            mask = parse_mask(mask)
        if mask is not None and mask.trivial:
            mask = None
        return cls(cfg=cad_cfg, kernel=kernel, pingpong=pingpong,
                   tolerance=tolerance, plan_policy=plan_policy, comm=comm,
                   jmax=jmax or max(1, cad_cfg.nkv), prefetch=0,
                   mask=mask)
