"""Async plan prefetch: overlap host-side scheduling with device compute.

The paper's scheduler "prefetches the upcoming batch": while the device
executes step *i*, the (numpy, host-side) scheduler plans batch *i+1* so
planning never sits on the critical path.  ``PlanPrefetcher`` implements
that as a background worker thread feeding a bounded queue; the numpy
scheduler and XLA both release the GIL for their heavy parts, so host
planning genuinely overlaps device compute.

If the worker dies, its exception is re-raised at the consumer's next
pull — a failed plan is never silently swallowed.  ``CADSession`` falls
back to fully synchronous planning when ``prefetch=0``.

Runtime calibration crosses this thread boundary (DESIGN.md §3): the
worker plans ahead with whatever calibration snapshot is current *when
it plans*, so a prefetched plan can be up to ``depth`` steps stale by
the time the consumer pulls it.  ``is_stale``/``refresh`` close the
loop deterministically: the staleness check and the synchronous re-plan
both run on the *consumer* thread at pull time, so which snapshot a
yielded plan was built from is a pure function of the pull sequence —
never of worker-thread timing — and replay stays deterministic (each
plan records its ``calib_version``).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterable, Iterator, Optional

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

_DONE = object()


class PlanPrefetcher:
    """Iterate ``fn(item) for item in source`` with a bounded look-ahead.

    The worker thread pulls from ``source`` and plans at most ``depth``
    items beyond what the consumer has taken.  Order is preserved (single
    worker, FIFO queue).  ``close()`` — also invoked by ``with`` exit and
    generator teardown — stops the worker and joins it.

    ``is_stale`` (optional) is evaluated against each planned item on
    the consumer thread at pull time; when it returns True the item is
    re-planned synchronously with ``refresh`` (default: ``fn``) before
    being yielded — the calibration feedback path.  ``stale_refreshes``
    counts how many pulls re-planned.
    """

    def __init__(self, source: Iterable[Any], fn: Callable[[Any], Any],
                 depth: int = 2, *,
                 is_stale: Optional[Callable[[Any], bool]] = None,
                 refresh: Optional[Callable[[Any], Any]] = None):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._source = iter(source)
        self._fn = fn
        self._is_stale = is_stale
        self._refresh = refresh if refresh is not None else fn
        self.stale_refreshes = 0
        self._queue: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exc: BaseException | None = None
        self._thread = threading.Thread(target=self._work, daemon=True,
                                        name="cad-plan-prefetch")
        self._thread.start()

    # ------------------------------------------------------------- worker
    def _put(self, item: Any) -> bool:
        """Blocking put that stays responsive to ``close()``."""
        while not self._stop.is_set():
            try:
                self._queue.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _work(self) -> None:
        rec = obs_trace.get_recorder()
        try:
            for raw in self._source:
                if self._stop.is_set():
                    return
                with rec.span("prefetch.plan", "prefetch"):
                    item = self._fn(raw)
                if not self._put(item):
                    return
                self._depth_gauge()
        except BaseException as e:           # surfaced at the next pull
            self._exc = e
        finally:
            self._put(_DONE)

    def _depth_gauge(self) -> None:
        """Publish the current look-ahead occupancy (queue depth is
        approximate by nature — a gauge, not an invariant)."""
        obs_metrics.get_registry().gauge(
            "cad_prefetch_queue_depth",
            "planned batches waiting in the prefetch queue").set(
            self._queue.qsize())

    # ----------------------------------------------------------- consumer
    def __iter__(self) -> Iterator[Any]:
        return self

    def __next__(self) -> Any:
        # timed get so a close() from another thread (which drains the
        # queue, possibly eating the sentinel) cannot strand us
        while True:
            if self._stop.is_set():
                raise StopIteration
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is _DONE:
                self.close()
                if self._exc is not None:
                    raise self._exc
                raise StopIteration
            if self._stop.is_set():
                # close() raced the get: the item was planned for a
                # world that no longer exists (a dead pool epoch, a
                # torn-down session) — drop it, never deliver it
                raise StopIteration
            self._depth_gauge()
            if self._is_stale is not None and self._is_stale(item):
                with obs_trace.get_recorder().span("prefetch.replan",
                                                   "prefetch"):
                    item = self._refresh(item)
                self.stale_refreshes += 1
                obs_metrics.get_registry().counter(
                    "cad_prefetch_stale_refreshes_total",
                    "prefetched plans re-planned at pull "
                    "(stale epoch or drifted speeds)").inc()
            return item

    def close(self) -> None:
        """Stop the worker and drain the queue; idempotent."""
        self._stop.set()
        while True:
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "PlanPrefetcher":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    def __del__(self):
        try:
            self._stop.set()
        except Exception:
            pass
