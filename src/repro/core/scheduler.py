"""Communication-aware greedy CA-task scheduler (paper §4.2 + App. B).

Host-side, numpy.  Input: the packed batch's document layout (one packed
chunk per data rank; documents are 128-block aligned by the data pipeline
and never span ranks).  Output: an assignment of every 128-token q-block
to an attention server, which ``plan.build_plan`` turns into static-shape
dispatch arrays.

Algorithm (faithful to the paper):
  1. ideal per-server load  F̄ = Σ FLOPs / n_servers; servers split into
     surplus (> F̄) and deficit (< F̄); the worst deficit is served first.
  2. for each deficit destination: evaluate candidate Items (doc-shard
     ranges resident on surplus servers), ΔF_max = min(F_item, surplus,
     deficit); the shard moved is the Item's *latest* blocks (suffix) —
     under the causal mask these carry the most FLOPs per byte of kv
     prefix, the comm-minimal choice of App. B at block granularity;
     score E = ΔF_max / V_comm, pick the best candidate.
  3. stop when every load is within (1±ε)·F̄ or no move improves.

Capacities (per-pair q/kv send slots, per-server kv buffer slots) mirror
the static shapes of the compiled dispatch; moves that would overflow a
capacity are rejected (TPU adaptation — see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.cost_model import CommModel


@dataclasses.dataclass
class Doc:
    """A document in the packed global stream, 128-aligned, single-rank."""
    doc_id: int
    home: int            # rank holding it
    g0: int              # first global block index
    n_blocks: int

    def blocks(self):
        return range(self.g0, self.g0 + self.n_blocks)


@dataclasses.dataclass(frozen=True)
class Caps:
    cq: int              # q blocks per (src, dst) pair
    ckv: int             # kv blocks per (src, dst) pair
    nkv: int             # dense kv buffer blocks per server (incl. local)


@dataclasses.dataclass
class Schedule:
    """Scheduler output: per-block server assignment + stats."""
    assign: np.ndarray           # [G] server per global q-block
    docs: List[Doc]
    doc_of_block: np.ndarray     # [G] doc index (-1 = padding block)
    bi_of_block: np.ndarray      # [G] block-in-doc index
    n_servers: int
    nb: int                      # blocks per rank
    blk: int
    loads: np.ndarray            # [S] final per-server cost (rel. FLOPs)
    comm_bytes: float
    n_moves: int


def layout_from_segments(segment_ids: np.ndarray, blk: int,
                         n_servers: int) -> Tuple[List[Doc], np.ndarray,
                                                  np.ndarray]:
    """Derive the Doc table from [R, L] per-rank packed segment ids.
    Blocks are document-pure by pipeline construction (trailing padding
    inside a doc's last block carries segment 0 and is handled by -1
    positions downstream)."""
    r, l = segment_ids.shape
    assert r == n_servers, (r, n_servers)
    assert l % blk == 0
    nb = l // blk
    seg_b = segment_ids.reshape(r, nb, blk)
    lead = seg_b[:, :, 0]
    docs: List[Doc] = []
    doc_of = -np.ones(r * nb, np.int64)
    bi_of = np.zeros(r * nb, np.int64)
    for rank in range(r):
        prev = None
        for i in range(nb):
            s = int(lead[rank, i])
            g = rank * nb + i
            if s == 0:
                prev = None
                continue
            nz = seg_b[rank, i][seg_b[rank, i] != 0]
            assert (nz == s).all(), \
                "blocks must be document-pure (pipeline aligns docs)"
            if prev != s:
                docs.append(Doc(len(docs), rank, g, 1))
                prev = s
            else:
                docs[-1].n_blocks += 1
            doc_of[g] = docs[-1].doc_id
            bi_of[g] = g - docs[-1].g0
    return docs, doc_of, bi_of


def block_costs(doc_of: np.ndarray, bi_of: np.ndarray,
                blk: int) -> np.ndarray:
    """Relative CA FLOPs per q-block: (bi+1)·blk² for live blocks, 0 for
    padding.  The single cost formula shared by the scheduler and the
    plan-policy load accounting (repro.cad.planner)."""
    return np.where(doc_of >= 0, (bi_of + 1) * float(blk * blk), 0.0)


def _range_cost(blk: int, lo: int, hi: int) -> float:
    """Sum of per-block CA cost over block-in-doc range [lo, hi):
    cost(bi) = (bi+1)·blk² (relative FLOPs; H·dh factors cancel)."""
    n = hi - lo
    return float(blk * blk) * n * (lo + hi + 1) / 2.0


def schedule(segment_ids: np.ndarray, *, blk: int, n_servers: int,
             comm: CommModel, caps: Caps, tolerance: float = 0.1,
             max_moves: int = 100000) -> Schedule:
    docs, doc_of, bi_of = layout_from_segments(segment_ids, blk, n_servers)
    nb = segment_ids.shape[1] // blk
    G = n_servers * nb
    assign = (np.arange(G) // nb).astype(np.int64)     # home assignment

    cost_of = block_costs(doc_of, bi_of, blk)
    loads = np.array([cost_of[s * nb:(s + 1) * nb].sum()
                      for s in range(n_servers)])
    fbar = loads.sum() / n_servers

    # items[s][doc_id] -> sorted list of disjoint (lo, hi) block ranges
    items: List[Dict[int, List[Tuple[int, int]]]] = \
        [dict() for _ in range(n_servers)]
    for d in docs:
        items[d.home][d.doc_id] = [(0, d.n_blocks)]
    # kv prefix length (blocks) already available on each server per doc
    sent_kv: List[Dict[int, int]] = [dict() for _ in range(n_servers)]
    q_used = np.zeros((n_servers, n_servers), np.int64)
    kv_used = np.zeros((n_servers, n_servers), np.int64)
    nkv_used = np.full(n_servers, nb, np.int64)        # local blocks

    comm_bytes = 0.0
    n_moves = 0

    def suffix_take(lo: int, hi: int, budget: float) -> int:
        """Largest t in [lo, hi) such that cost of [t, hi) <= budget, but
        always at least one block if a single block fits 1.5x the budget
        (avoids stalling on coarse granularity)."""
        t = hi
        acc = 0.0
        while t > lo:
            c = float(blk * blk) * t          # block (t-1) has cost t·blk²
            if acc + c > budget:
                break
            acc += c
            t -= 1
        if t == hi and hi - lo >= 1:
            c = float(blk * blk) * hi
            if c <= 1.5 * budget:
                t = hi - 1
        return t

    while n_moves < max_moves:
        order = np.argsort(loads)
        dst = int(order[0])
        deficit = fbar - loads[dst]
        if deficit <= tolerance * fbar:
            break
        best = None  # (E, src, doc_id, ridx, t, hi, dF, vbytes, need_kv)
        for src in order[::-1]:
            src = int(src)
            surplus = loads[src] - fbar
            if surplus <= 0:
                break
            if src == dst:
                continue
            budget = min(surplus, deficit)
            for doc_id, ranges in items[src].items():
                d = docs[doc_id]
                # only the latest range's suffix migrates (comm-minimal)
                for ridx in range(len(ranges) - 1, -1, -1):
                    lo, hi = ranges[ridx]
                    t = suffix_take(lo, hi, budget)
                    if t >= hi:
                        continue
                    n_q = hi - t
                    if q_used[d.home, dst] + n_q > caps.cq:
                        continue
                    if d.home == dst:
                        need_kv = 0
                    else:
                        have = sent_kv[dst].get(doc_id, 0)
                        need_kv = max(0, hi - have)
                        if kv_used[d.home, dst] + need_kv > caps.ckv:
                            continue
                        if nkv_used[dst] + need_kv > caps.nkv:
                            continue
                    df = _range_cost(blk, t, hi)
                    vbytes = comm.migration_bytes(n_q * blk, need_kv * blk)
                    e_score = df / max(vbytes, 1.0)
                    if best is None or e_score > best[0]:
                        best = (e_score, src, doc_id, ridx, t, hi, df,
                                vbytes, need_kv)
                    break    # deeper ranges cost strictly more comm
        if best is None:
            break
        _, src, doc_id, ridx, t, hi, df, vbytes, need_kv = best
        d = docs[doc_id]
        ranges = items[src][doc_id]
        lo, _hi = ranges[ridx]
        assert _hi == hi
        if t == lo:
            ranges.pop(ridx)
            if not ranges:
                del items[src][doc_id]
        else:
            ranges[ridx] = (lo, t)
        # insert into dst with adjacency merge
        dst_ranges = items[dst].setdefault(doc_id, [])
        dst_ranges.append((t, hi))
        dst_ranges.sort()
        merged = [dst_ranges[0]]
        for a, b in dst_ranges[1:]:
            if a == merged[-1][1]:
                merged[-1] = (merged[-1][0], b)
            else:
                merged.append((a, b))
        items[dst][doc_id] = merged

        assign[d.g0 + t: d.g0 + hi] = dst
        loads[src] -= df
        loads[dst] += df
        q_used[d.home, dst] += hi - t
        if d.home != dst:
            kv_used[d.home, dst] += need_kv
            nkv_used[dst] += need_kv
            sent_kv[dst][doc_id] = max(sent_kv[dst].get(doc_id, 0), hi)
        comm_bytes += vbytes
        n_moves += 1

    return Schedule(assign=assign, docs=docs, doc_of_block=doc_of,
                    bi_of_block=bi_of, n_servers=n_servers, nb=nb, blk=blk,
                    loads=loads, comm_bytes=comm_bytes, n_moves=n_moves)


def imbalance(loads: np.ndarray) -> float:
    """max/mean - 1 (the straggler overhang)."""
    m = loads.mean()
    return float(loads.max() / max(m, 1e-9) - 1.0)
