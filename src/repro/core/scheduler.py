"""Communication-aware greedy CA-task scheduler (paper §4.2 + App. B).

Host-side, numpy.  Input: the packed batch's document layout (one packed
chunk per data rank; documents are 128-block aligned by the data pipeline
and never span ranks).  Output: an assignment of every 128-token q-block
to an attention server, which ``plan.build_plan`` turns into static-shape
dispatch arrays.

Algorithm (faithful to the paper):
  1. ideal per-server load  F̄ = Σ FLOPs / n_servers; servers split into
     surplus (> F̄) and deficit (< F̄); the worst deficit is served first.
  2. for each deficit destination: evaluate candidate Items (doc-shard
     ranges resident on surplus servers), ΔF_max = min(F_item, surplus,
     deficit); the shard moved is the Item's *latest* blocks (suffix) —
     under the causal mask these carry the most FLOPs per byte of kv
     prefix, the comm-minimal choice of App. B at block granularity;
     score E = ΔF_max / V_comm, pick the best candidate.
  3. stop when every load is within (1±ε)·F̄ or no move improves.

Heterogeneous pools and measured costs (DESIGN.md §3): ``speeds`` gives
per-server relative speed factors and ``cost_model`` a (runtime-
calibrated) latency model; balancing then runs in *time* units — each
server's load is its assigned cost divided by its speed, and the ideal
target is equal time, i.e. FLOPs proportional to speed (a 0.5x server
receives half the work).  With both left at their defaults the
arithmetic reduces exactly to the homogeneous relative-FLOPs balance.

Mask-structured tasks (DESIGN.md §12): an optional ``mask``
(:class:`~repro.core.mask.MaskSpec`) reprices every q-block by its
*live* kv blocks (``live_block_table``) instead of its dense causal
prefix; the same greedy suffix loop then splits documents along the
mask structure — under a sliding window the deep-suffix blocks stop
dominating, under dilation only every ``rate``-th kv block is paid for
— so per-server *live-block time* balances rather than rectangle area.

Capacities (per-pair q/kv send slots, per-server kv buffer slots) mirror
the static shapes of the compiled dispatch; moves that would overflow a
capacity are rejected (TPU adaptation — see DESIGN.md §3).

Memory budgets (DESIGN.md §11): ``budgets`` gives each endpoint an HBM
budget in bytes and makes memory a second constraint next to time — a
destination is only eligible while its modeled resident working set
(q/o + residuals per held block, plus each needed doc kv prefix once)
stays within budget, and a post-balance repair phase moves doc-range
suffixes off servers born over budget by their own home layout.
Documents whose final task fits no endpoint (the kv prefix alone
overflows every budget) are marked ``streamed``: the dispatch layer
consumes their kv in ``stream_chunk``-block chunks, so their planned
kv residency is one chunk.  ``PlanMemoryError`` is raised only when no
feasible split exists.

Elastic pools (DESIGN.md §9): ``exclude`` names servers that must not
hold CA tasks this step — drained or dead members of an elastic pool.
Core attention is stateless, so excluding a server never loses data:
its *data-rank* half keeps holding (and sending) q/k/v shards; only its
attention-serving capacity is withdrawn.  Documents homed on an
excluded server are dealt whole to the least-loaded surviving server
first (whole docs keep the kv prefix send contiguous and cap-checked),
then the ordinary greedy loop balances among the survivors.  The
dispatch geometry — array shapes keyed by ``n_servers`` — never
changes, so one compiled executable serves every membership epoch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import CommModel, CostModel, MemoryModel
from repro.core.mask import MaskSpec, live_block_mask, live_block_table
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class Doc:
    """A document in the packed global stream, 128-aligned, single-rank."""
    doc_id: int
    home: int            # rank holding it
    g0: int              # first global block index
    n_blocks: int

    def blocks(self):
        return range(self.g0, self.g0 + self.n_blocks)


@dataclasses.dataclass(frozen=True)
class Caps:
    cq: int              # q blocks per (src, dst) pair
    ckv: int             # kv blocks per (src, dst) pair
    nkv: int             # dense kv buffer blocks per server (incl. local)


@dataclasses.dataclass
class Schedule:
    """Scheduler output: per-block server assignment + stats.
    ``loads`` is per-server modeled *time*: assigned cost (relative
    FLOPs, or seconds under a calibrated cost model) divided by the
    server's speed factor — identical to relative FLOPs for the
    homogeneous default.  ``resident_bytes`` is per-server modeled HBM
    working set (DESIGN.md §11), populated whenever a memory model was
    in play; ``streamed`` names documents whose kv must be consumed in
    ``stream_chunk``-block chunks because their final task fits no
    single endpoint's budget."""
    assign: np.ndarray           # [G] server per global q-block
    docs: List[Doc]
    doc_of_block: np.ndarray     # [G] doc index (-1 = padding block)
    bi_of_block: np.ndarray      # [G] block-in-doc index
    n_servers: int
    nb: int                      # blocks per rank
    blk: int
    loads: np.ndarray            # [S] final per-server modeled time
    comm_bytes: float
    n_moves: int
    speeds: Optional[np.ndarray] = None   # [S] speed factors (None = 1)
    exclude: Tuple[int, ...] = ()         # servers barred from tasks
    resident_bytes: Optional[np.ndarray] = None  # [S] modeled HBM bytes
    budgets: Optional[np.ndarray] = None         # [S] HBM budgets, bytes
    streamed: Tuple[int, ...] = ()               # doc ids streaming kv


def layout_from_segments(segment_ids: np.ndarray, blk: int,
                         n_servers: int) -> Tuple[List[Doc], np.ndarray,
                                                  np.ndarray]:
    """Derive the Doc table from [R, L] per-rank packed segment ids.
    Blocks are document-pure by pipeline construction (trailing padding
    inside a doc's last block carries segment 0 and is handled by -1
    positions downstream)."""
    r, l = segment_ids.shape
    assert r == n_servers, (r, n_servers)
    assert l % blk == 0
    nb = l // blk
    seg_b = segment_ids.reshape(r, nb, blk)
    lead = seg_b[:, :, 0]
    docs: List[Doc] = []
    doc_of = -np.ones(r * nb, np.int64)
    bi_of = np.zeros(r * nb, np.int64)
    for rank in range(r):
        prev = None
        for i in range(nb):
            s = int(lead[rank, i])
            g = rank * nb + i
            if s == 0:
                prev = None
                continue
            nz = seg_b[rank, i][seg_b[rank, i] != 0]
            assert (nz == s).all(), \
                "blocks must be document-pure (pipeline aligns docs)"
            if prev != s:
                docs.append(Doc(len(docs), rank, g, 1))
                prev = s
            else:
                docs[-1].n_blocks += 1
            doc_of[g] = docs[-1].doc_id
            bi_of[g] = g - docs[-1].g0
    return docs, doc_of, bi_of


def block_costs(doc_of: np.ndarray, bi_of: np.ndarray, blk: int,
                cost_model: Optional[CostModel] = None,
                mask: Optional[MaskSpec] = None) -> np.ndarray:
    """Per-q-block CA cost for live blocks, 0 for padding.  Default:
    relative FLOPs ``live_blocks(bi)·blk²`` — for the dense-causal mask
    ``live_blocks(bi) == bi + 1`` and this reduces to the historic
    (bi+1)·blk².  A non-trivial ``mask`` prices the block by its *live*
    kv blocks only (DESIGN.md §12): a sliding-window or dilated task
    costs what its kernel actually iterates, not its rectangle area.
    With a (runtime-calibrated) ``cost_model``: predicted seconds for a
    blk-token shard against its live context.  The single cost formula
    shared by the scheduler and the plan-policy load accounting
    (repro.cad.planner)."""
    live = doc_of >= 0
    max_blocks = int(bi_of[live].max()) + 1 if live.any() else 1
    tbl = live_block_table(mask, max_blocks, blk)   # live kv blocks per bi
    if cost_model is None:
        out = np.zeros(len(doc_of))
        out[live] = tbl[bi_of[live]] * float(blk * blk)
        return out
    out = np.zeros(len(doc_of))
    out[live] = cost_model.predict(blk, tbl[bi_of[live]] * blk)
    return out


def ring_shard_size(n_blocks: int, n_ring: int) -> int:
    """Contiguous kv-shard length (in blocks) of a DISTFLASHATTN-style
    ring split of an ``n_blocks``-long document over ``n_ring``
    endpoints: shard ``p`` covers in-doc blocks ``[p*L, (p+1)*L)``
    clipped to the document.  Shared by the ring planner
    (``repro.cad.planner``), the ring pass geometry (``core.dispatch``)
    and :func:`ring_pass_costs` so all three agree on shard
    boundaries."""
    return -(-max(int(n_blocks), 1) // max(int(n_ring), 1))


def ring_pass_costs(docs: List[Doc], blk: int, n_servers: int, *,
                    servers: Optional[Iterable[int]] = None,
                    cost_model: Optional[CostModel] = None,
                    mask: Optional[MaskSpec] = None) -> np.ndarray:
    """Per-(ring pass, endpoint) modeled compute of the DISTFLASHATTN
    ring schedule (DESIGN.md §13): ``costs[t, s]`` is what endpoint
    ``s`` executes during synchronous ring pass ``t``.

    Each document is cut into ``P`` contiguous kv shards of
    :func:`~repro.core.plan.ring_shard_size` blocks; a q block in shard
    ``i`` consumes kv shard ``(i - t) % P`` at pass ``t``.  Causal-dead
    and mask-dead (q block, kv shard) pairs cost zero — the pass is
    skipped exactly, mirroring ``dispatch.ring_pass_geometry`` — and
    live work is priced per live kv block like :func:`block_costs`, so
    ``costs.sum(0)`` equals the ring assignment's per-endpoint loads.

    Because the ring barriers between passes, the schedule's modeled
    step time is ``sum_t max_s costs[t, s] / speed[s]`` — the quantity
    ``benchmarks/cad_vs_ring.py`` compares against CAD's
    ``max_s sum_t`` (no inner barrier)."""
    allowed = tuple(range(n_servers)) if servers is None \
        else tuple(servers)
    P = len(allowed)
    costs = np.zeros((P, n_servers))
    for d in docs:
        n = d.n_blocks
        L = ring_shard_size(n, P)
        lbm = live_block_mask(mask, n, n, blk)          # [n, n] bool
        pad = P * L - n
        counts = np.pad(lbm, ((0, 0), (0, pad))) \
            .reshape(n, P, L).sum(-1)                   # [n, P] live blocks
        shard_q = np.arange(n) // L                     # q shard per row
        owner = np.asarray(allowed)[shard_q]            # endpoint per row
        for t in range(P):
            j = (shard_q - t) % P
            live = np.take_along_axis(counts, j[:, None], 1)[:, 0]
            if cost_model is None:
                c = live * float(blk * blk)
            else:
                c = np.where(live > 0,
                             cost_model.predict(blk, live * blk), 0.0)
            np.add.at(costs[t], owner, c)
    return costs


def _bi_cost_table(blk: int, max_blocks: int,
                   cost_model: Optional[CostModel],
                   mask: Optional[MaskSpec] = None) -> np.ndarray:
    """cost of block-in-doc index bi, for bi in [0, max_blocks)."""
    ctx = live_block_table(mask, max_blocks, blk)   # live kv blocks per bi
    if cost_model is None:
        return (ctx * (blk * blk)).astype(np.float64)
    return np.asarray(cost_model.predict(blk, ctx * blk), np.float64)


def check_exclude(exclude: Optional[Iterable[int]],
                  n_servers: int) -> Tuple[int, ...]:
    """Validate an excluded-server set; returns it sorted.  At least one
    server must survive — an empty pool cannot serve attention."""
    ex = tuple(sorted({int(s) for s in (exclude or ())}))
    for s in ex:
        if not 0 <= s < n_servers:
            raise ValueError(f"excluded server {s} outside pool of "
                             f"{n_servers}")
    if len(ex) >= n_servers:
        raise ValueError(
            f"cannot exclude all {n_servers} servers — the attention "
            f"pool needs at least one surviving endpoint")
    return ex


def streamed_doc_ids(docs: List[Doc], blk: int, mem: MemoryModel,
                     budgets: np.ndarray, *, stream_chunk: int,
                     allowed: Optional[Iterable[int]] = None,
                     mask: Optional[MaskSpec] = None) \
        -> Tuple[int, ...]:
    """Documents that must stream their kv: the doc's *final* task (one
    q block against the full causal prefix) overflows EVERY allowed
    endpoint's HBM budget, so no re-split can help — causal attention
    needs the whole prefix resident for that task unless it is consumed
    in chunks (DESIGN.md §11).  With streaming disabled such a doc is
    unplannable: :class:`~repro.core.plan.PlanMemoryError` at planning
    time, not an OOM at step time.

    ``mask`` switches the final task's pricing to the ``live_kv_bytes``
    view (DESIGN.md §12) — the elastic pricing paths pass the session's
    MaskSpec here; planners keep the default dense-prefix ledger."""
    idx = list(range(len(budgets))) if allowed is None else list(allowed)
    cap = float(budgets[idx].max())
    cap_srv = int(idx[int(np.argmax(budgets[idx]))])
    out = []
    for d in docs:
        need = mem.task_bytes(blk, d.n_blocks * blk, mask, blk)
        if need > cap:
            if stream_chunk <= 0:
                from repro.core.plan import PlanMemoryError  # circular-safe
                raise PlanMemoryError(
                    cap_srv, need, cap,
                    detail=f"doc {d.doc_id} final task needs its full "
                           f"{d.n_blocks}-block kv prefix resident and "
                           f"streaming is off")
            out.append(d.doc_id)
    return tuple(out)


def assignment_resident_bytes(assign: np.ndarray, doc_of: np.ndarray,
                              bi_of: np.ndarray, blk: int, n_servers: int,
                              mem: MemoryModel, *,
                              streamed: Iterable[int] = (),
                              stream_chunk: int = 0,
                              mask: Optional[MaskSpec] = None) \
        -> np.ndarray:
    """Per-server modeled HBM working set of an assignment: every live
    q block contributes its q/o shard plus backward residuals, and each
    (server, doc) pair contributes the doc's needed kv prefix exactly
    once — the same deduplicated counting ``plan_from_assignment``'s
    kv-gather buffer realizes.  Streamed docs' kv residency is bounded
    by one ``stream_chunk`` of blocks.

    ``mask`` switches kv pricing to the ``live_kv_bytes`` view
    (DESIGN.md §12): planners leave it unset — the dense prefix remains
    the residency ledger's unit because the dispatch gather buffer
    realizes the contiguous range — while the elastic pricing paths
    (``executor._recovery_memory``) pass the session's MaskSpec so
    recovery destinations are weighed by live bandwidth (DESIGN.md §9).
    """
    streamed = set(streamed)
    res = np.zeros(n_servers)
    q_unit = mem.q_bytes(blk) + mem.residual_bytes(blk)
    needs: List[Dict[int, int]] = [dict() for _ in range(n_servers)]
    for g in np.nonzero(doc_of >= 0)[0]:
        s = int(assign[g])
        dc = int(doc_of[g])
        res[s] += q_unit
        needs[s][dc] = max(needs[s].get(dc, 0), int(bi_of[g]) + 1)
    for s in range(n_servers):
        for dc, pref in needs[s].items():
            if dc in streamed and stream_chunk > 0:
                pref = min(pref, stream_chunk)
            res[s] += mem.live_kv_bytes(pref * blk, mask, blk)
    return res


def schedule(segment_ids: np.ndarray, *, blk: int, n_servers: int,
             comm: CommModel, caps: Caps, tolerance: float = 0.1,
             max_moves: int = 100000,
             speeds: Optional[np.ndarray] = None,
             cost_model: Optional[CostModel] = None,
             exclude: Optional[Iterable[int]] = None,
             mem_model: Optional[MemoryModel] = None,
             budgets: Optional[np.ndarray] = None,
             stream_chunk: int = 0,
             mask: Optional[MaskSpec] = None) -> Schedule:
    docs, doc_of, bi_of = layout_from_segments(segment_ids, blk, n_servers)
    nb = segment_ids.shape[1] // blk
    G = n_servers * nb
    assign = (np.arange(G) // nb).astype(np.int64)     # home assignment

    exclude = check_exclude(exclude, n_servers)
    excluded = set(exclude)
    speeds = np.ones(n_servers) if speeds is None \
        else np.asarray(speeds, np.float64)
    if speeds.shape != (n_servers,):
        raise ValueError(f"speeds needs {n_servers} entries, got "
                         f"{speeds.shape}")
    if (speeds <= 0).any():
        raise ValueError(f"server speeds must be > 0, got {speeds}")
    cost_of = block_costs(doc_of, bi_of, blk, cost_model, mask)
    max_blocks = int(bi_of.max()) + 1 if len(bi_of) else 1
    bi_cost = _bi_cost_table(blk, max_blocks, cost_model, mask)
    bi_csum = np.concatenate([[0.0], np.cumsum(bi_cost)])

    def range_cost(lo: int, hi: int) -> float:
        """Sum of per-block CA cost over block-in-doc range [lo, hi)."""
        return float(bi_csum[hi] - bi_csum[lo])

    # loads are modeled *time*: assigned base cost / server speed.
    # Excluded servers contribute no capacity: the ideal per-server time
    # spreads the whole batch over the survivors' speeds only.
    allowed = [s for s in range(n_servers) if s not in excluded]
    loads_base = np.array([cost_of[s * nb:(s + 1) * nb].sum()
                           for s in range(n_servers)])
    loads = loads_base / speeds
    fbar = loads_base.sum() / speeds[allowed].sum()

    # items[s][doc_id] -> sorted list of disjoint (lo, hi) block ranges
    items: List[Dict[int, List[Tuple[int, int]]]] = \
        [dict() for _ in range(n_servers)]
    for d in docs:
        items[d.home][d.doc_id] = [(0, d.n_blocks)]
    # kv prefix length (blocks) already available on each server per doc
    sent_kv: List[Dict[int, int]] = [dict() for _ in range(n_servers)]
    q_used = np.zeros((n_servers, n_servers), np.int64)
    kv_used = np.zeros((n_servers, n_servers), np.int64)
    nkv_used = np.full(n_servers, nb, np.int64)        # local blocks

    comm_bytes = 0.0
    n_moves = 0

    # ---- memory constraint state (DESIGN.md §11).  ``resident`` and
    # ``kv_need`` mirror the assignment incrementally: per-server q/o +
    # residual bytes for every held block, plus each needed doc kv
    # prefix once (deduplicated — the same counting the kv-gather
    # buffer realizes).  Streamed docs' kv is clamped to one chunk.
    mem_on = budgets is not None
    mem = mem_model if mem_model is not None \
        else (MemoryModel(comm) if mem_on else None)
    if mem_on:
        budgets = np.asarray(budgets, np.float64)
        if budgets.shape != (n_servers,):
            raise ValueError(f"budgets needs {n_servers} entries, got "
                             f"{budgets.shape}")
        if not (budgets > 0).all():
            bad = int(np.argmin(budgets > 0))
            raise ValueError(f"budgets[{bad}] must be > 0, got "
                             f"{budgets[bad]} for endpoint {bad}")
    streamed: set = set(streamed_doc_ids(
        docs, blk, mem, budgets, stream_chunk=stream_chunk,
        allowed=allowed)) if mem_on else set()
    q_unit = (mem.q_bytes(blk) + mem.residual_bytes(blk)) if mem else 0.0
    kv_unit = mem.kv_bytes(blk) if mem else 0.0

    def kv_clamp(dc: int, pref: int) -> int:
        if dc in streamed and stream_chunk > 0:
            return min(pref, stream_chunk)
        return pref

    resident = np.zeros(n_servers)
    kv_need: List[Dict[int, int]] = [dict() for _ in range(n_servers)]
    if mem is not None:
        for d in docs:
            resident[d.home] += d.n_blocks * q_unit \
                + kv_clamp(d.doc_id, d.n_blocks) * kv_unit
            kv_need[d.home][d.doc_id] = d.n_blocks

    def mem_delta_dst(dst: int, dc: int, hi: int, n_q: int) -> float:
        """Resident bytes dst gains when n_q blocks of doc dc (prefix
        end hi) land on it."""
        old = kv_need[dst].get(dc, 0)
        return n_q * q_unit \
            + (kv_clamp(dc, max(old, hi)) - kv_clamp(dc, old)) * kv_unit

    def mem_fits(dst: int, dc: int, hi: int, n_q: int) -> bool:
        return not mem_on or resident[dst] \
            + mem_delta_dst(dst, dc, hi, n_q) <= budgets[dst]

    def mem_move(src: int, dst: int, dc: int, hi: int, n_q: int) -> None:
        """Memory bookkeeping for a src->dst move; call AFTER
        ``items[src]`` was updated (the remaining ranges determine the
        source's surviving kv need)."""
        if mem is None:
            return
        resident[dst] += mem_delta_dst(dst, dc, hi, n_q)
        kv_need[dst][dc] = max(kv_need[dst].get(dc, 0), hi)
        old_s = kv_need[src].pop(dc, 0)
        rng = items[src].get(dc)
        new_s = rng[-1][1] if rng else 0
        if rng:
            kv_need[src][dc] = new_s
        resident[src] -= n_q * q_unit \
            + (kv_clamp(dc, old_s) - kv_clamp(dc, new_s)) * kv_unit

    if excluded:
        from repro.core.plan import PlanCapacityError  # circular-safe

        def _deal_fit(home: int, dst: int, n_bl: int):
            """None when the whole doc fits on dst, else the failing
            (capacity, needed, available) triple."""
            if q_used[home, dst] + n_bl > caps.cq:
                return "CQ", int(q_used[home, dst]) + n_bl, caps.cq
            if kv_used[home, dst] + n_bl > caps.ckv:
                return "CKV", int(kv_used[home, dst]) + n_bl, caps.ckv
            if nkv_used[dst] + n_bl > caps.nkv:
                return "NKV", int(nkv_used[dst]) + n_bl, caps.nkv
            return None

        # Evacuation: docs homed on excluded servers are dealt whole to
        # the least-loaded survivor with capacity (whole docs keep each
        # kv prefix send a single contiguous range); the greedy loop
        # below then rebalances among survivors as usual.
        for d in docs:
            if d.home not in excluded:
                continue
            n_bl = d.n_blocks
            cand = sorted(allowed, key=lambda s: (loads[s], s))
            cap_ok = [s for s in cand
                      if _deal_fit(d.home, s, n_bl) is None]
            if not cap_ok:
                cap, needed, avail = _deal_fit(d.home, cand[0], n_bl)
                raise PlanCapacityError(cap, d.home, cand[0], needed,
                                        avail)
            dst = next((s for s in cap_ok
                        if mem_fits(s, d.doc_id, n_bl, n_bl)), None)
            if dst is None:
                from repro.core.plan import PlanMemoryError
                s0 = cap_ok[0]
                raise PlanMemoryError(
                    s0, resident[s0] + mem_delta_dst(s0, d.doc_id, n_bl,
                                                     n_bl),
                    float(budgets[s0]),
                    detail=f"evacuating doc {d.doc_id} whole from "
                           f"excluded server {d.home}")
            df = range_cost(0, n_bl)
            del items[d.home][d.doc_id]
            items[dst][d.doc_id] = [(0, n_bl)]
            assign[d.g0:d.g0 + n_bl] = dst
            loads[d.home] -= df / speeds[d.home]
            loads[dst] += df / speeds[dst]
            q_used[d.home, dst] += n_bl
            kv_used[d.home, dst] += n_bl
            nkv_used[dst] += n_bl
            sent_kv[dst][d.doc_id] = n_bl
            mem_move(d.home, dst, d.doc_id, n_bl, n_bl)
            comm_bytes += comm.migration_bytes(n_bl * blk, n_bl * blk)
            n_moves += 1
        loads[list(excluded)] = 0.0      # evacuated exactly

    def suffix_take(lo: int, hi: int, budget: float) -> int:
        """Largest t in [lo, hi) such that cost of [t, hi) <= budget, but
        always at least one block if a single block fits 1.5x the budget
        (avoids stalling on coarse granularity).  ``budget`` is in base
        cost units (the destination's time budget times its speed)."""
        t = hi
        acc = 0.0
        while t > lo:
            c = float(bi_cost[t - 1])         # cost of block (t-1)
            if acc + c > budget:
                break
            acc += c
            t -= 1
        if t == hi and hi - lo >= 1:
            c = float(bi_cost[hi - 1])
            if c <= 1.5 * budget:
                t = hi - 1
        return t

    while n_moves < max_moves:
        order = np.argsort(loads)
        # destination: the least-loaded *surviving* server (an excluded
        # server sits at load 0 but must never receive tasks)
        dst = next(int(s) for s in order if int(s) not in excluded)
        deficit = fbar - loads[dst]
        if deficit <= tolerance * fbar:
            break
        best = None  # (E, src, doc_id, ridx, t, hi, dF, vbytes, need_kv)
        for src in order[::-1]:
            src = int(src)
            surplus = loads[src] - fbar
            if surplus <= 0:
                break
            if src == dst:
                continue
            # time budgets converted to base cost units per endpoint
            budget = min(surplus * speeds[src], deficit * speeds[dst])
            for doc_id, ranges in items[src].items():
                d = docs[doc_id]
                # only the latest range's suffix migrates (comm-minimal)
                for ridx in range(len(ranges) - 1, -1, -1):
                    lo, hi = ranges[ridx]
                    t = suffix_take(lo, hi, budget)
                    if t >= hi:
                        continue
                    n_q = hi - t
                    if q_used[d.home, dst] + n_q > caps.cq:
                        continue
                    if d.home == dst:
                        need_kv = 0
                    else:
                        have = sent_kv[dst].get(doc_id, 0)
                        need_kv = max(0, hi - have)
                        if kv_used[d.home, dst] + need_kv > caps.ckv:
                            continue
                        if nkv_used[dst] + need_kv > caps.nkv:
                            continue
                    if not mem_fits(dst, doc_id, hi, n_q):
                        continue
                    df = range_cost(t, hi)
                    vbytes = comm.migration_bytes(n_q * blk, need_kv * blk)
                    # time gained by the deficit server per byte moved
                    e_score = df / speeds[dst] / max(vbytes, 1.0)
                    if best is None or e_score > best[0]:
                        best = (e_score, src, doc_id, ridx, t, hi, df,
                                vbytes, need_kv)
                    break    # deeper ranges cost strictly more comm
        if best is None:
            break
        _, src, doc_id, ridx, t, hi, df, vbytes, need_kv = best
        d = docs[doc_id]
        ranges = items[src][doc_id]
        lo, _hi = ranges[ridx]
        assert _hi == hi
        if t == lo:
            ranges.pop(ridx)
            if not ranges:
                del items[src][doc_id]
        else:
            ranges[ridx] = (lo, t)
        # insert into dst with adjacency merge
        dst_ranges = items[dst].setdefault(doc_id, [])
        dst_ranges.append((t, hi))
        dst_ranges.sort()
        merged = [dst_ranges[0]]
        for a, b in dst_ranges[1:]:
            if a == merged[-1][1]:
                merged[-1] = (merged[-1][0], b)
            else:
                merged.append((a, b))
        items[dst][doc_id] = merged

        assign[d.g0 + t: d.g0 + hi] = dst
        loads[src] -= df / speeds[src]
        loads[dst] += df / speeds[dst]
        q_used[d.home, dst] += hi - t
        if d.home != dst:
            kv_used[d.home, dst] += need_kv
            nkv_used[dst] += need_kv
            sent_kv[dst][doc_id] = max(sent_kv[dst].get(doc_id, 0), hi)
        mem_move(src, dst, doc_id, hi, hi - t)
        comm_bytes += vbytes
        n_moves += 1

    # ---- memory repair (DESIGN.md §11).  Time balancing never ADDS
    # bytes past a destination's budget (mem_fits above), but servers
    # can be born over budget by their own home layout.  Repair moves
    # doc-range suffixes off over-budget servers to the least-loaded
    # destination with room — the deepest-prefix doc first, since its
    # kv dominates the working set.  Every move is capacity-checked
    # like any other; when no move exists, no feasible split does.
    if mem_on:
        from repro.core.plan import PlanMemoryError  # circular-safe

        while n_moves < max_moves:
            over = [s for s in allowed if resident[s] > budgets[s]]
            if not over:
                break
            s = max(over, key=lambda x: (resident[x] - budgets[x], -x))
            move = None   # (dst, doc_id, ridx, t, hi, need_kv)
            by_depth = sorted(items[s].items(),
                              key=lambda kv: (-kv_need[s][kv[0]], kv[0]))
            for doc_id, ranges in by_depth:
                d = docs[doc_id]
                ridx = len(ranges) - 1
                lo, hi = ranges[ridx]
                for dst in sorted(allowed, key=lambda x: (loads[x], x)):
                    if dst == s:
                        continue
                    # capacity ceiling on the suffix length
                    take = min(hi - lo, caps.cq - int(q_used[d.home,
                                                             dst]))
                    if take <= 0:
                        continue
                    if dst == d.home:
                        need_kv = 0
                    else:
                        need_kv = max(0, hi - sent_kv[dst].get(doc_id, 0))
                        if kv_used[d.home, dst] + need_kv > caps.ckv:
                            continue
                        if nkv_used[dst] + need_kv > caps.nkv:
                            continue
                    # budget ceiling: dst pays the full hi-prefix kv
                    # (causal duplication) plus q/o bytes per block
                    head = budgets[dst] - resident[dst] \
                        - mem_delta_dst(dst, doc_id, hi, 0)
                    if q_unit > 0:
                        take = min(take, int(head // q_unit))
                    elif head < 0:
                        continue
                    if take <= 0:
                        continue
                    move = (dst, doc_id, ridx, max(lo, hi - take), hi,
                            need_kv)
                    break
                if move is not None:
                    break
            if move is None:
                raise PlanMemoryError(
                    s, float(resident[s]), float(budgets[s]),
                    detail=f"{len(items[s])} docs resident after "
                           f"{n_moves} moves; no destination has room")
            dst, doc_id, ridx, t, hi, need_kv = move
            d = docs[doc_id]
            ranges = items[s][doc_id]
            lo, _hi = ranges[ridx]
            if t == lo:
                ranges.pop(ridx)
                if not ranges:
                    del items[s][doc_id]
            else:
                ranges[ridx] = (lo, t)
            dst_ranges = items[dst].setdefault(doc_id, [])
            dst_ranges.append((t, hi))
            dst_ranges.sort()
            merged = [dst_ranges[0]]
            for a, b in dst_ranges[1:]:
                if a == merged[-1][1]:
                    merged[-1] = (merged[-1][0], b)
                else:
                    merged.append((a, b))
            items[dst][doc_id] = merged
            assign[d.g0 + t: d.g0 + hi] = dst
            df = range_cost(t, hi)
            loads[s] -= df / speeds[s]
            loads[dst] += df / speeds[dst]
            q_used[d.home, dst] += hi - t
            if d.home != dst:
                kv_used[d.home, dst] += need_kv
                nkv_used[dst] += need_kv
                sent_kv[dst][doc_id] = max(sent_kv[dst].get(doc_id, 0),
                                           hi)
            mem_move(s, dst, doc_id, hi, hi - t)
            comm_bytes += comm.migration_bytes((hi - t) * blk,
                                               need_kv * blk)
            n_moves += 1

    final_resident = None
    if mem is not None:
        # authoritative recompute from the final assignment — the same
        # helper tests and planners use, so the reported working set can
        # never drift from the incremental bookkeeping above
        final_resident = assignment_resident_bytes(
            assign, doc_of, bi_of, blk, n_servers, mem,
            streamed=streamed, stream_chunk=stream_chunk)
    # narrate the schedule-time prediction (DESIGN.md §14): the
    # imbalance gauge is the planner's own claim about the step it just
    # built — trace_report compares it against measured serve times
    obs_metrics.get_registry().gauge(
        "cad_schedule_imbalance",
        "scheduled per-server load max/mean - 1 (straggler "
        "overhang)").set(imbalance(loads))
    rec = obs_trace.get_recorder()
    if rec.enabled:
        rec.instant("schedule", "planner",
                    args={"imbalance": imbalance(loads),
                          "n_moves": n_moves,
                          "comm_bytes": float(comm_bytes),
                          "excluded": sorted(exclude)})
    return Schedule(assign=assign, docs=docs, doc_of_block=doc_of,
                    bi_of_block=bi_of, n_servers=n_servers, nb=nb, blk=blk,
                    loads=loads, comm_bytes=comm_bytes, n_moves=n_moves,
                    speeds=speeds, exclude=exclude,
                    resident_bytes=final_resident, budgets=budgets,
                    streamed=tuple(sorted(streamed)))


def imbalance(loads: np.ndarray) -> float:
    """max/mean - 1 (the straggler overhang)."""
    m = loads.mean()
    return float(loads.max() / max(m, 1e-9) - 1.0)
