"""Communication-aware greedy CA-task scheduler (paper §4.2 + App. B).

Host-side, numpy.  Input: the packed batch's document layout (one packed
chunk per data rank; documents are 128-block aligned by the data pipeline
and never span ranks).  Output: an assignment of every 128-token q-block
to an attention server, which ``plan.build_plan`` turns into static-shape
dispatch arrays.

Algorithm (faithful to the paper):
  1. ideal per-server load  F̄ = Σ FLOPs / n_servers; servers split into
     surplus (> F̄) and deficit (< F̄); the worst deficit is served first.
  2. for each deficit destination: evaluate candidate Items (doc-shard
     ranges resident on surplus servers), ΔF_max = min(F_item, surplus,
     deficit); the shard moved is the Item's *latest* blocks (suffix) —
     under the causal mask these carry the most FLOPs per byte of kv
     prefix, the comm-minimal choice of App. B at block granularity;
     score E = ΔF_max / V_comm, pick the best candidate.
  3. stop when every load is within (1±ε)·F̄ or no move improves.

Heterogeneous pools and measured costs (DESIGN.md §3): ``speeds`` gives
per-server relative speed factors and ``cost_model`` a (runtime-
calibrated) latency model; balancing then runs in *time* units — each
server's load is its assigned cost divided by its speed, and the ideal
target is equal time, i.e. FLOPs proportional to speed (a 0.5x server
receives half the work).  With both left at their defaults the
arithmetic reduces exactly to the homogeneous relative-FLOPs balance.

Capacities (per-pair q/kv send slots, per-server kv buffer slots) mirror
the static shapes of the compiled dispatch; moves that would overflow a
capacity are rejected (TPU adaptation — see DESIGN.md §3).

Elastic pools (DESIGN.md §9): ``exclude`` names servers that must not
hold CA tasks this step — drained or dead members of an elastic pool.
Core attention is stateless, so excluding a server never loses data:
its *data-rank* half keeps holding (and sending) q/k/v shards; only its
attention-serving capacity is withdrawn.  Documents homed on an
excluded server are dealt whole to the least-loaded surviving server
first (whole docs keep the kv prefix send contiguous and cap-checked),
then the ordinary greedy loop balances among the survivors.  The
dispatch geometry — array shapes keyed by ``n_servers`` — never
changes, so one compiled executable serves every membership epoch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import CommModel, CostModel


@dataclasses.dataclass
class Doc:
    """A document in the packed global stream, 128-aligned, single-rank."""
    doc_id: int
    home: int            # rank holding it
    g0: int              # first global block index
    n_blocks: int

    def blocks(self):
        return range(self.g0, self.g0 + self.n_blocks)


@dataclasses.dataclass(frozen=True)
class Caps:
    cq: int              # q blocks per (src, dst) pair
    ckv: int             # kv blocks per (src, dst) pair
    nkv: int             # dense kv buffer blocks per server (incl. local)


@dataclasses.dataclass
class Schedule:
    """Scheduler output: per-block server assignment + stats.
    ``loads`` is per-server modeled *time*: assigned cost (relative
    FLOPs, or seconds under a calibrated cost model) divided by the
    server's speed factor — identical to relative FLOPs for the
    homogeneous default."""
    assign: np.ndarray           # [G] server per global q-block
    docs: List[Doc]
    doc_of_block: np.ndarray     # [G] doc index (-1 = padding block)
    bi_of_block: np.ndarray      # [G] block-in-doc index
    n_servers: int
    nb: int                      # blocks per rank
    blk: int
    loads: np.ndarray            # [S] final per-server modeled time
    comm_bytes: float
    n_moves: int
    speeds: Optional[np.ndarray] = None   # [S] speed factors (None = 1)
    exclude: Tuple[int, ...] = ()         # servers barred from tasks


def layout_from_segments(segment_ids: np.ndarray, blk: int,
                         n_servers: int) -> Tuple[List[Doc], np.ndarray,
                                                  np.ndarray]:
    """Derive the Doc table from [R, L] per-rank packed segment ids.
    Blocks are document-pure by pipeline construction (trailing padding
    inside a doc's last block carries segment 0 and is handled by -1
    positions downstream)."""
    r, l = segment_ids.shape
    assert r == n_servers, (r, n_servers)
    assert l % blk == 0
    nb = l // blk
    seg_b = segment_ids.reshape(r, nb, blk)
    lead = seg_b[:, :, 0]
    docs: List[Doc] = []
    doc_of = -np.ones(r * nb, np.int64)
    bi_of = np.zeros(r * nb, np.int64)
    for rank in range(r):
        prev = None
        for i in range(nb):
            s = int(lead[rank, i])
            g = rank * nb + i
            if s == 0:
                prev = None
                continue
            nz = seg_b[rank, i][seg_b[rank, i] != 0]
            assert (nz == s).all(), \
                "blocks must be document-pure (pipeline aligns docs)"
            if prev != s:
                docs.append(Doc(len(docs), rank, g, 1))
                prev = s
            else:
                docs[-1].n_blocks += 1
            doc_of[g] = docs[-1].doc_id
            bi_of[g] = g - docs[-1].g0
    return docs, doc_of, bi_of


def block_costs(doc_of: np.ndarray, bi_of: np.ndarray, blk: int,
                cost_model: Optional[CostModel] = None) -> np.ndarray:
    """Per-q-block CA cost for live blocks, 0 for padding.  Default:
    relative FLOPs (bi+1)·blk².  With a (runtime-calibrated)
    ``cost_model``: predicted seconds for a blk-token shard against its
    (bi+1)·blk context.  The single cost formula shared by the scheduler
    and the plan-policy load accounting (repro.cad.planner)."""
    if cost_model is None:
        return np.where(doc_of >= 0, (bi_of + 1) * float(blk * blk), 0.0)
    out = np.zeros(len(doc_of))
    live = doc_of >= 0
    out[live] = cost_model.predict(blk, (bi_of[live] + 1) * blk)
    return out


def _bi_cost_table(blk: int, max_blocks: int,
                   cost_model: Optional[CostModel]) -> np.ndarray:
    """cost of block-in-doc index bi, for bi in [0, max_blocks)."""
    ctx = (np.arange(max_blocks, dtype=np.int64) + 1)
    if cost_model is None:
        return (ctx * (blk * blk)).astype(np.float64)
    return np.asarray(cost_model.predict(blk, ctx * blk), np.float64)


def check_exclude(exclude: Optional[Iterable[int]],
                  n_servers: int) -> Tuple[int, ...]:
    """Validate an excluded-server set; returns it sorted.  At least one
    server must survive — an empty pool cannot serve attention."""
    ex = tuple(sorted({int(s) for s in (exclude or ())}))
    for s in ex:
        if not 0 <= s < n_servers:
            raise ValueError(f"excluded server {s} outside pool of "
                             f"{n_servers}")
    if len(ex) >= n_servers:
        raise ValueError(
            f"cannot exclude all {n_servers} servers — the attention "
            f"pool needs at least one surviving endpoint")
    return ex


def schedule(segment_ids: np.ndarray, *, blk: int, n_servers: int,
             comm: CommModel, caps: Caps, tolerance: float = 0.1,
             max_moves: int = 100000,
             speeds: Optional[np.ndarray] = None,
             cost_model: Optional[CostModel] = None,
             exclude: Optional[Iterable[int]] = None) -> Schedule:
    docs, doc_of, bi_of = layout_from_segments(segment_ids, blk, n_servers)
    nb = segment_ids.shape[1] // blk
    G = n_servers * nb
    assign = (np.arange(G) // nb).astype(np.int64)     # home assignment

    exclude = check_exclude(exclude, n_servers)
    excluded = set(exclude)
    speeds = np.ones(n_servers) if speeds is None \
        else np.asarray(speeds, np.float64)
    if speeds.shape != (n_servers,):
        raise ValueError(f"speeds needs {n_servers} entries, got "
                         f"{speeds.shape}")
    if (speeds <= 0).any():
        raise ValueError(f"server speeds must be > 0, got {speeds}")
    cost_of = block_costs(doc_of, bi_of, blk, cost_model)
    max_blocks = int(bi_of.max()) + 1 if len(bi_of) else 1
    bi_cost = _bi_cost_table(blk, max_blocks, cost_model)
    bi_csum = np.concatenate([[0.0], np.cumsum(bi_cost)])

    def range_cost(lo: int, hi: int) -> float:
        """Sum of per-block CA cost over block-in-doc range [lo, hi)."""
        return float(bi_csum[hi] - bi_csum[lo])

    # loads are modeled *time*: assigned base cost / server speed.
    # Excluded servers contribute no capacity: the ideal per-server time
    # spreads the whole batch over the survivors' speeds only.
    allowed = [s for s in range(n_servers) if s not in excluded]
    loads_base = np.array([cost_of[s * nb:(s + 1) * nb].sum()
                           for s in range(n_servers)])
    loads = loads_base / speeds
    fbar = loads_base.sum() / speeds[allowed].sum()

    # items[s][doc_id] -> sorted list of disjoint (lo, hi) block ranges
    items: List[Dict[int, List[Tuple[int, int]]]] = \
        [dict() for _ in range(n_servers)]
    for d in docs:
        items[d.home][d.doc_id] = [(0, d.n_blocks)]
    # kv prefix length (blocks) already available on each server per doc
    sent_kv: List[Dict[int, int]] = [dict() for _ in range(n_servers)]
    q_used = np.zeros((n_servers, n_servers), np.int64)
    kv_used = np.zeros((n_servers, n_servers), np.int64)
    nkv_used = np.full(n_servers, nb, np.int64)        # local blocks

    comm_bytes = 0.0
    n_moves = 0

    if excluded:
        from repro.core.plan import PlanCapacityError  # circular-safe

        def _deal_fit(home: int, dst: int, n_bl: int):
            """None when the whole doc fits on dst, else the failing
            (capacity, needed, available) triple."""
            if q_used[home, dst] + n_bl > caps.cq:
                return "CQ", int(q_used[home, dst]) + n_bl, caps.cq
            if kv_used[home, dst] + n_bl > caps.ckv:
                return "CKV", int(kv_used[home, dst]) + n_bl, caps.ckv
            if nkv_used[dst] + n_bl > caps.nkv:
                return "NKV", int(nkv_used[dst]) + n_bl, caps.nkv
            return None

        # Evacuation: docs homed on excluded servers are dealt whole to
        # the least-loaded survivor with capacity (whole docs keep each
        # kv prefix send a single contiguous range); the greedy loop
        # below then rebalances among survivors as usual.
        for d in docs:
            if d.home not in excluded:
                continue
            n_bl = d.n_blocks
            cand = sorted(allowed, key=lambda s: (loads[s], s))
            dst = next((s for s in cand
                        if _deal_fit(d.home, s, n_bl) is None), None)
            if dst is None:
                cap, needed, avail = _deal_fit(d.home, cand[0], n_bl)
                raise PlanCapacityError(cap, d.home, cand[0], needed,
                                        avail)
            df = range_cost(0, n_bl)
            del items[d.home][d.doc_id]
            items[dst][d.doc_id] = [(0, n_bl)]
            assign[d.g0:d.g0 + n_bl] = dst
            loads[d.home] -= df / speeds[d.home]
            loads[dst] += df / speeds[dst]
            q_used[d.home, dst] += n_bl
            kv_used[d.home, dst] += n_bl
            nkv_used[dst] += n_bl
            sent_kv[dst][d.doc_id] = n_bl
            comm_bytes += comm.migration_bytes(n_bl * blk, n_bl * blk)
            n_moves += 1
        loads[list(excluded)] = 0.0      # evacuated exactly

    def suffix_take(lo: int, hi: int, budget: float) -> int:
        """Largest t in [lo, hi) such that cost of [t, hi) <= budget, but
        always at least one block if a single block fits 1.5x the budget
        (avoids stalling on coarse granularity).  ``budget`` is in base
        cost units (the destination's time budget times its speed)."""
        t = hi
        acc = 0.0
        while t > lo:
            c = float(bi_cost[t - 1])         # cost of block (t-1)
            if acc + c > budget:
                break
            acc += c
            t -= 1
        if t == hi and hi - lo >= 1:
            c = float(bi_cost[hi - 1])
            if c <= 1.5 * budget:
                t = hi - 1
        return t

    while n_moves < max_moves:
        order = np.argsort(loads)
        # destination: the least-loaded *surviving* server (an excluded
        # server sits at load 0 but must never receive tasks)
        dst = next(int(s) for s in order if int(s) not in excluded)
        deficit = fbar - loads[dst]
        if deficit <= tolerance * fbar:
            break
        best = None  # (E, src, doc_id, ridx, t, hi, dF, vbytes, need_kv)
        for src in order[::-1]:
            src = int(src)
            surplus = loads[src] - fbar
            if surplus <= 0:
                break
            if src == dst:
                continue
            # time budgets converted to base cost units per endpoint
            budget = min(surplus * speeds[src], deficit * speeds[dst])
            for doc_id, ranges in items[src].items():
                d = docs[doc_id]
                # only the latest range's suffix migrates (comm-minimal)
                for ridx in range(len(ranges) - 1, -1, -1):
                    lo, hi = ranges[ridx]
                    t = suffix_take(lo, hi, budget)
                    if t >= hi:
                        continue
                    n_q = hi - t
                    if q_used[d.home, dst] + n_q > caps.cq:
                        continue
                    if d.home == dst:
                        need_kv = 0
                    else:
                        have = sent_kv[dst].get(doc_id, 0)
                        need_kv = max(0, hi - have)
                        if kv_used[d.home, dst] + need_kv > caps.ckv:
                            continue
                        if nkv_used[dst] + need_kv > caps.nkv:
                            continue
                    df = range_cost(t, hi)
                    vbytes = comm.migration_bytes(n_q * blk, need_kv * blk)
                    # time gained by the deficit server per byte moved
                    e_score = df / speeds[dst] / max(vbytes, 1.0)
                    if best is None or e_score > best[0]:
                        best = (e_score, src, doc_id, ridx, t, hi, df,
                                vbytes, need_kv)
                    break    # deeper ranges cost strictly more comm
        if best is None:
            break
        _, src, doc_id, ridx, t, hi, df, vbytes, need_kv = best
        d = docs[doc_id]
        ranges = items[src][doc_id]
        lo, _hi = ranges[ridx]
        assert _hi == hi
        if t == lo:
            ranges.pop(ridx)
            if not ranges:
                del items[src][doc_id]
        else:
            ranges[ridx] = (lo, t)
        # insert into dst with adjacency merge
        dst_ranges = items[dst].setdefault(doc_id, [])
        dst_ranges.append((t, hi))
        dst_ranges.sort()
        merged = [dst_ranges[0]]
        for a, b in dst_ranges[1:]:
            if a == merged[-1][1]:
                merged[-1] = (merged[-1][0], b)
            else:
                merged.append((a, b))
        items[dst][doc_id] = merged

        assign[d.g0 + t: d.g0 + hi] = dst
        loads[src] -= df / speeds[src]
        loads[dst] += df / speeds[dst]
        q_used[d.home, dst] += hi - t
        if d.home != dst:
            kv_used[d.home, dst] += need_kv
            nkv_used[dst] += need_kv
            sent_kv[dst][doc_id] = max(sent_kv[dst].get(doc_id, 0), hi)
        comm_bytes += vbytes
        n_moves += 1

    return Schedule(assign=assign, docs=docs, doc_of_block=doc_of,
                    bi_of_block=bi_of, n_servers=n_servers, nb=nb, blk=blk,
                    loads=loads, comm_bytes=comm_bytes, n_moves=n_moves,
                    speeds=speeds, exclude=exclude)


def imbalance(loads: np.ndarray) -> float:
    """max/mean - 1 (the straggler overhang)."""
    m = loads.mean()
    return float(loads.max() / max(m, 1e-9) - 1.0)
