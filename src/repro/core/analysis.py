"""Analytical bounds from the paper's appendices.

Appendix A: the maximum number of shards a document can be split into
while the CAD communication still hides under the context-independent
compute:  s <= 2(t·B - h_q) / h_kv - 1,  where t is the per-token
context-independent compute time, B the interconnect bandwidth, and
h_q/h_kv the query / key-value hidden byte sizes.

The paper evaluates this for Llama-34B on H200+InfiniBand and gets
s ≈ 31 (reproduced in tests/test_analysis.py); ``max_partition_size``
generalizes it to any config and link bandwidth (ICI for us).
"""
from __future__ import annotations

from repro.core.cost_model import BYTES_PER_EL, ICI_BW, PEAK_FLOPS_BF16


def context_independent_time_per_token(cfg, *, peak_flops: float,
                                       mfu: float = 0.5) -> float:
    """App. A: t = 2h(2h + h_kv + 3i) / (mfu·peak) — generalized via the
    config's own layer structure (single layer, as in the paper)."""
    from repro.core.cost_model import linear_flops_per_token
    per_layer = linear_flops_per_token(cfg) / cfg.n_layers
    return per_layer / (mfu * peak_flops)


def max_partition_size(cfg, *, bandwidth: float = ICI_BW,
                       peak_flops: float = PEAK_FLOPS_BF16,
                       mfu: float = 0.5) -> float:
    """s <= 2(tB - size_q) / size_kv - 1 (paper App. A)."""
    t = context_independent_time_per_token(cfg, peak_flops=peak_flops,
                                           mfu=mfu)
    size_q = cfg.n_heads * cfg.head_dim * BYTES_PER_EL
    size_kv = 2 * cfg.n_kv_heads * cfg.head_dim * BYTES_PER_EL  # K and V
    return 2.0 * (t * bandwidth - size_q) / size_kv - 1.0
