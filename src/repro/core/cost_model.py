"""Cost model for CA-tasks and communication (paper §4.2 "Profiler" +
Appendix A/B).

On real hardware the paper benchmarks a (q_len, kv_len) latency grid and
bilinearly interpolates.  We keep exactly that interface (``from_grid``)
but default to an analytic roofline-calibrated model, since this container
has no TPU to measure.  Everything downstream (scheduler, benchmarks,
e2e simulator) consumes only this interface, so a measured grid drops in.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# TPU v5e hardware constants (per chip) — single source of truth, also used
# by launch/roofline.py
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
BYTES_PER_EL = 2                  # bf16


def ca_flops(q_tokens: int | np.ndarray, kv_tokens: int | np.ndarray,
             n_heads: int, head_dim: int) -> np.ndarray:
    """FLOPs of core attention for q tokens against kv context:
    2·q·kv·H·dh (QK^T) + 2·q·kv·H·dh (PV)."""
    return 4.0 * np.asarray(q_tokens, np.float64) * kv_tokens \
        * n_heads * head_dim


def causal_doc_flops(doc_len: int | np.ndarray, n_heads: int,
                     head_dim: int) -> np.ndarray:
    """Total CA FLOPs of a causal document: sum_t 4·t·H·dh ≈ 2·l²·H·dh."""
    l = np.asarray(doc_len, np.float64)
    return 2.0 * l * (l + 1) * n_heads * head_dim


def linear_flops_per_token(cfg) -> float:
    """FLOPs per token of the context-independent layers (App. A formula:
    2·h·(2h + h_kv + 3i) per layer, adapted per arch)."""
    d = cfg.d_model
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim
    total = 0.0
    for t in cfg.layer_pattern:
        if t in ("global", "local", "cross", "enc"):
            attn = 2 * (d * hq * 2 + d * hkv * 2)
            if t == "cross":
                attn *= 2
            total += attn + 2 * cfg._ffn_active_flops_per_token()
        elif t == "rglru":
            w = cfg.rglru.lru_width or d
            total += 2 * (2 * d * w + 2 * w * w + w * d) \
                + 2 * cfg._ffn_active_flops_per_token()
        elif t == "ssd":
            s = cfg.ssm
            d_in = s.expand * d
            total += 2 * d * (2 * d_in + 2 * s.n_groups * s.d_state
                              + d_in // s.head_dim) + 2 * d_in * d
    return total * cfg.n_layers / len(cfg.layer_pattern)


@dataclasses.dataclass(frozen=True)
class CommModel:
    """Bytes of moving CA-task inputs/outputs (App. B)."""
    n_heads: int
    head_dim: int
    n_kv_heads: int
    bytes_per_el: int = BYTES_PER_EL

    @property
    def size_q(self) -> int:          # bytes per q token (q + returned o)
        return 2 * self.n_heads * self.head_dim * self.bytes_per_el

    @property
    def size_kv(self) -> int:         # bytes per kv token (k and v)
        return 2 * self.n_kv_heads * self.head_dim * self.bytes_per_el

    def migration_bytes(self, n_q_tokens: int, n_kv_tokens: int) -> float:
        return n_q_tokens * self.size_q + n_kv_tokens * self.size_kv


class CostModel:
    """Predicts CA-task execution time.  Bilinear interpolation over a
    (q_len, kv_len) grid — the paper's profiler — with an analytic default
    grid derived from the roofline constants."""

    def __init__(self, q_grid: np.ndarray, kv_grid: np.ndarray,
                 time_grid: np.ndarray, n_heads: int, head_dim: int,
                 peak_flops: float = PEAK_FLOPS_BF16):
        self.q_grid = np.asarray(q_grid, np.float64)
        self.kv_grid = np.asarray(kv_grid, np.float64)
        self.time_grid = np.asarray(time_grid, np.float64)
        self.n_heads, self.head_dim = n_heads, head_dim
        self.peak_flops = peak_flops

    # -------------------------------------------------------- constructors
    @classmethod
    def analytic(cls, n_heads: int, head_dim: int,
                 peak_flops: float = PEAK_FLOPS_BF16,
                 mfu_saturated: float = 0.4, tile: int = 128):
        """Latency = flops / (mfu(q)·peak); small shards (< tile) waste
        their thread block — the Fig. 5 throughput cliff.

        mfu_saturated defaults to 0.4: masked varlen flash attention runs
        well below GEMM efficiency (FA2-class kernels reach ~35-45% of
        peak on packed variable-length batches; cf. the paper's Fig. 5 and
        our benchmarks/kernel_throughput.py reproduction).  GEMM-dominated
        linear layers use MFU_LINEAR=0.5 in the simulators."""
        q_grid = np.array([16, 32, 64, 128, 256, 512, 1024, 4096, 32768])
        kv_grid = np.array([128, 512, 2048, 8192, 32768, 131072, 524288])
        tg = np.zeros((len(q_grid), len(kv_grid)))
        for i, q in enumerate(q_grid):
            # sub-tile shards are padded to the tile -> mfu ∝ q/tile
            eff = mfu_saturated * min(1.0, q / tile)
            for j, kv in enumerate(kv_grid):
                f = ca_flops(q, kv, n_heads, head_dim)
                tg[i, j] = f / (eff * peak_flops)
        return cls(q_grid, kv_grid, tg, n_heads, head_dim, peak_flops)

    @classmethod
    def from_grid(cls, q_grid, kv_grid, time_grid, n_heads, head_dim):
        """Drop-in for a measured profiler grid."""
        return cls(q_grid, kv_grid, time_grid, n_heads, head_dim)

    # ------------------------------------------------------------- predict
    def predict(self, q_len, kv_len) -> np.ndarray:
        """Bilinear interpolation; saturation region falls back to peak
        throughput (paper §4.2)."""
        q = np.clip(np.asarray(q_len, np.float64), self.q_grid[0],
                    self.q_grid[-1])
        kv = np.clip(np.asarray(kv_len, np.float64), self.kv_grid[0],
                     self.kv_grid[-1])
        qi = np.clip(np.searchsorted(self.q_grid, q) - 1, 0,
                     len(self.q_grid) - 2)
        ki = np.clip(np.searchsorted(self.kv_grid, kv) - 1, 0,
                     len(self.kv_grid) - 2)
        q0, q1 = self.q_grid[qi], self.q_grid[qi + 1]
        k0, k1 = self.kv_grid[ki], self.kv_grid[ki + 1]
        tq = (q - q0) / (q1 - q0)
        tk = (kv - k0) / (k1 - k0)
        t00 = self.time_grid[qi, ki]
        t01 = self.time_grid[qi, ki + 1]
        t10 = self.time_grid[qi + 1, ki]
        t11 = self.time_grid[qi + 1, ki + 1]
        interp = (t00 * (1 - tq) * (1 - tk) + t01 * (1 - tq) * tk
                  + t10 * tq * (1 - tk) + t11 * tq * tk)
        # saturation: never below peak-throughput time
        floor = ca_flops(q, kv, self.n_heads, self.head_dim) \
            / self.peak_flops
        return np.maximum(interp, floor)
