"""Cost model for CA-tasks and communication (paper §4.2 "Profiler" +
Appendix A/B).

On real hardware the paper benchmarks a (q_len, kv_len) latency grid and
bilinearly interpolates.  We keep exactly that interface (``from_grid``)
and default to an analytic roofline-calibrated model; at runtime
:class:`GridCalibrator` populates the grid from *measured* per-task
timings (EMA per cell, unobserved cells fall back to the analytic
prediction) and estimates per-server speed factors, so the planners
replan batch *i+1* from batch *i*'s measured costs (DESIGN.md §3).
Everything downstream (scheduler, benchmarks, e2e simulator) consumes
only the ``CostModel`` interface, so a measured grid drops in.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

# TPU v5e hardware constants (per chip) — single source of truth, also used
# by launch/roofline.py
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
BYTES_PER_EL = 2                  # bf16


def ca_flops(q_tokens: int | np.ndarray, kv_tokens: int | np.ndarray,
             n_heads: int, head_dim: int) -> np.ndarray:
    """FLOPs of core attention for q tokens against kv context:
    2·q·kv·H·dh (QK^T) + 2·q·kv·H·dh (PV)."""
    return 4.0 * np.asarray(q_tokens, np.float64) * kv_tokens \
        * n_heads * head_dim


def causal_doc_flops(doc_len: int | np.ndarray, n_heads: int,
                     head_dim: int) -> np.ndarray:
    """Total CA FLOPs of a causal document: sum_t 4·t·H·dh ≈ 2·l²·H·dh."""
    l = np.asarray(doc_len, np.float64)
    return 2.0 * l * (l + 1) * n_heads * head_dim


def linear_flops_per_token(cfg) -> float:
    """FLOPs per token of the context-independent layers (App. A formula:
    2·h·(2h + h_kv + 3i) per layer, adapted per arch)."""
    d = cfg.d_model
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim
    total = 0.0
    for t in cfg.layer_pattern:
        if t in ("global", "local", "cross", "enc"):
            attn = 2 * (d * hq * 2 + d * hkv * 2)
            if t == "cross":
                attn *= 2
            total += attn + 2 * cfg._ffn_active_flops_per_token()
        elif t == "rglru":
            w = cfg.rglru.lru_width or d
            total += 2 * (2 * d * w + 2 * w * w + w * d) \
                + 2 * cfg._ffn_active_flops_per_token()
        elif t == "ssd":
            s = cfg.ssm
            d_in = s.expand * d
            total += 2 * d * (2 * d_in + 2 * s.n_groups * s.d_state
                              + d_in // s.head_dim) + 2 * d_in * d
    return total * cfg.n_layers / len(cfg.layer_pattern)


@dataclasses.dataclass(frozen=True)
class CommModel:
    """Bytes of moving CA-task inputs/outputs (App. B)."""
    n_heads: int
    head_dim: int
    n_kv_heads: int
    bytes_per_el: int = BYTES_PER_EL

    @property
    def size_q(self) -> int:          # bytes per q token (q + returned o)
        return 2 * self.n_heads * self.head_dim * self.bytes_per_el

    @property
    def size_kv(self) -> int:         # bytes per kv token (k and v)
        return 2 * self.n_kv_heads * self.head_dim * self.bytes_per_el

    def migration_bytes(self, n_q_tokens: int, n_kv_tokens: int) -> float:
        return n_q_tokens * self.size_q + n_kv_tokens * self.size_kv


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """HBM bytes a CA task pins on its attention server while resident
    (DESIGN.md §11).

    A task is one q block against a causal kv prefix; its working set
    is the q shard plus the returned o (``CommModel.size_q``), the k/v
    context (``CommModel.size_kv``) and the f32 per-(q token, head)
    lse residual the flash backward saves.  Reusing the CommModel byte
    accessors keeps the planner's resident-bytes and comm-bytes ledgers
    on one byte accounting that cannot drift apart.
    """
    comm: CommModel
    lse_bytes: int = 4                # f32 per (q token, head)

    def q_bytes(self, q_tokens) -> float:
        """q shard + returned o resident for the task's q side."""
        return float(q_tokens) * self.comm.size_q

    def kv_bytes(self, kv_tokens) -> float:
        """k and v context bytes for a ``kv_tokens``-token prefix."""
        return float(kv_tokens) * self.comm.size_kv

    def residual_bytes(self, q_tokens) -> float:
        """Backward-saved softmax statistics (lse) for the q shard."""
        return float(q_tokens) * self.comm.n_heads * self.lse_bytes

    def live_kv_bytes(self, kv_tokens, mask=None, blk: int = 128) -> float:
        """kv bytes a task actually *touches* under ``mask`` — the
        live-block pricing of DESIGN.md §12.  The dense prefix
        (:meth:`kv_bytes`) remains the residency ledger's unit because
        the kv gather buffer realizes the contiguous range; live pricing
        is the compute/bandwidth view planners and benchmarks weigh
        masked tasks by."""
        if mask is None or getattr(mask, "trivial", True):
            return self.kv_bytes(kv_tokens)
        from repro.core.mask import live_kv_len  # local: avoid cycle
        nb = -(-int(kv_tokens) // blk)
        return self.kv_bytes(min(int(kv_tokens),
                                 live_kv_len(mask, nb, blk)))

    def task_bytes(self, q_len, kv_len, mask=None, blk: int = 128) -> float:
        """Full resident footprint of one (q_len, kv_len) CA task.  With
        a non-trivial ``mask`` the kv term is priced at the task's live
        kv tokens (:meth:`live_kv_bytes`) — rectangle area otherwise."""
        return self.q_bytes(q_len) + self.residual_bytes(q_len) \
            + self.live_kv_bytes(kv_len, mask, blk)


class CostModel:
    """Predicts CA-task execution time.  Bilinear interpolation over a
    (q_len, kv_len) grid — the paper's profiler — with an analytic default
    grid derived from the roofline constants."""

    def __init__(self, q_grid: np.ndarray, kv_grid: np.ndarray,
                 time_grid: np.ndarray, n_heads: int, head_dim: int,
                 peak_flops: float = PEAK_FLOPS_BF16):
        self.q_grid = np.asarray(q_grid, np.float64)
        self.kv_grid = np.asarray(kv_grid, np.float64)
        self.time_grid = np.asarray(time_grid, np.float64)
        self.n_heads, self.head_dim = n_heads, head_dim
        self.peak_flops = peak_flops

    # -------------------------------------------------------- constructors
    @classmethod
    def analytic(cls, n_heads: int, head_dim: int,
                 peak_flops: float = PEAK_FLOPS_BF16,
                 mfu_saturated: float = 0.4, tile: int = 128):
        """Latency = flops / (mfu(q)·peak); small shards (< tile) waste
        their thread block — the Fig. 5 throughput cliff.

        mfu_saturated defaults to 0.4: masked varlen flash attention runs
        well below GEMM efficiency (FA2-class kernels reach ~35-45% of
        peak on packed variable-length batches; cf. the paper's Fig. 5 and
        our benchmarks/kernel_throughput.py reproduction).  GEMM-dominated
        linear layers use MFU_LINEAR=0.5 in the simulators."""
        q_grid = np.array([16, 32, 64, 128, 256, 512, 1024, 4096, 32768])
        kv_grid = np.array([128, 512, 2048, 8192, 32768, 131072, 524288])
        tg = np.zeros((len(q_grid), len(kv_grid)))
        for i, q in enumerate(q_grid):
            # sub-tile shards are padded to the tile -> mfu ∝ q/tile
            eff = mfu_saturated * min(1.0, q / tile)
            for j, kv in enumerate(kv_grid):
                f = ca_flops(q, kv, n_heads, head_dim)
                tg[i, j] = f / (eff * peak_flops)
        return cls(q_grid, kv_grid, tg, n_heads, head_dim, peak_flops)

    @classmethod
    def from_grid(cls, q_grid, kv_grid, time_grid, n_heads, head_dim,
                  peak_flops: float = PEAK_FLOPS_BF16):
        """Drop-in for a measured profiler grid."""
        return cls(q_grid, kv_grid, time_grid, n_heads, head_dim,
                   peak_flops)

    # -------------------------------------------------------- serialization
    def to_dict(self) -> Dict:
        """JSON-serializable state (measured grids survive restarts)."""
        return {
            "q_grid": self.q_grid.tolist(),
            "kv_grid": self.kv_grid.tolist(),
            "time_grid": self.time_grid.tolist(),
            "n_heads": int(self.n_heads),
            "head_dim": int(self.head_dim),
            "peak_flops": float(self.peak_flops),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "CostModel":
        return cls(np.asarray(d["q_grid"]), np.asarray(d["kv_grid"]),
                   np.asarray(d["time_grid"]), int(d["n_heads"]),
                   int(d["head_dim"]), float(d["peak_flops"]))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)

    @classmethod
    def load(cls, path: str) -> "CostModel":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def scaled(self, factor: float) -> "CostModel":
        """A model whose predictions are ``factor``x slower — e.g. the
        per-server view of a 1/factor-speed server."""
        return CostModel(self.q_grid, self.kv_grid,
                         self.time_grid * float(factor), self.n_heads,
                         self.head_dim, self.peak_flops / float(factor))

    # ------------------------------------------------------------- predict
    def predict(self, q_len, kv_len) -> np.ndarray:
        """Bilinear interpolation; saturation region falls back to peak
        throughput (paper §4.2)."""
        q = np.clip(np.asarray(q_len, np.float64), self.q_grid[0],
                    self.q_grid[-1])
        kv = np.clip(np.asarray(kv_len, np.float64), self.kv_grid[0],
                     self.kv_grid[-1])
        qi = np.clip(np.searchsorted(self.q_grid, q) - 1, 0,
                     len(self.q_grid) - 2)
        ki = np.clip(np.searchsorted(self.kv_grid, kv) - 1, 0,
                     len(self.kv_grid) - 2)
        q0, q1 = self.q_grid[qi], self.q_grid[qi + 1]
        k0, k1 = self.kv_grid[ki], self.kv_grid[ki + 1]
        tq = (q - q0) / (q1 - q0)
        tk = (kv - k0) / (k1 - k0)
        t00 = self.time_grid[qi, ki]
        t01 = self.time_grid[qi, ki + 1]
        t10 = self.time_grid[qi + 1, ki]
        t11 = self.time_grid[qi + 1, ki + 1]
        interp = (t00 * (1 - tq) * (1 - tk) + t01 * (1 - tq) * tk
                  + t10 * tq * (1 - tk) + t11 * tq * tk)
        # saturation: never below peak-throughput time
        floor = ca_flops(q, kv, self.n_heads, self.head_dim) \
            / self.peak_flops
        return np.maximum(interp, floor)


# ===================================================================
# Runtime calibration (paper §4.2 "Profiler", online)
# ===================================================================

@dataclasses.dataclass(frozen=True)
class CalibrationSnapshot:
    """An immutable view of the calibrator at one version: the cost
    model every planner call in flight uses, plus normalized per-server
    speed factors (fastest server == 1.0).  Plans record the version
    they were built from (``stats["calib_version"]``), which is what
    keeps async-prefetched planning deterministic for replay: planning
    is a pure function of (batch, snapshot)."""
    version: int
    cost_model: CostModel
    speeds: Tuple[float, ...]

    def speeds_array(self) -> np.ndarray:
        return np.asarray(self.speeds, np.float64)


class GridCalibrator:
    """Online (q_len, kv_len) latency-grid profiler with per-server
    speed estimation.

    ``observe(q_len, kv_len, seconds, server=...)`` feeds one measured
    CA-task timing.  Under a non-trivial mask the caller keys the
    observation by the task's *live* kv tokens —
    ``repro.core.dispatch.iter_plan_tasks`` emits exactly that — so a
    sliding-window or dilated task calibrates the grid cell of the
    context it actually iterated, and predictions stay consistent with
    the live-block pricing the planners use (DESIGN.md §12).  Each
    sample updates

    * the EMA of its (log-nearest) grid cell, normalized to the current
      fastest-server reference, and
    * the server's speed ratio EMA — *base*-model prediction over
      measured time.  The fixed base is the yardstick on purpose: a
      0.5x server measures 2x the base prediction of a 1x server for
      the same shape, so ratios converge to (base/hardware scale)·speed
      and their normalization to relative speeds — without coupling to
      the moving calibrated cells (which would let cell drift and speed
      drift chase each other).

    ``snapshot()`` returns an immutable :class:`CalibrationSnapshot`
    whose grid falls back to the ``base`` model for unobserved cells and
    whose speeds are normalized so the fastest server is 1.0.  All
    methods are thread-safe: the plan-prefetch worker snapshots while
    the train loop observes (DESIGN.md §3).
    """

    def __init__(self, base: CostModel, n_servers: int, *,
                 ema: float = 0.5,
                 prior_speeds: Optional[Iterable[float]] = None,
                 q_grid: Optional[np.ndarray] = None,
                 kv_grid: Optional[np.ndarray] = None):
        if not 0.0 < ema <= 1.0:
            raise ValueError(f"ema must be in (0, 1], got {ema}")
        self.base = base
        self.n_servers = int(n_servers)
        self.ema = float(ema)
        self.q_grid = np.asarray(base.q_grid if q_grid is None else q_grid,
                                 np.float64)
        if kv_grid is None:
            # denser than the analytic default: samples snap to their
            # log-nearest cell, and mixing octaves into one cell leaves
            # an interpolation bias the planner then balances against
            kv0, kv1 = float(base.kv_grid[0]), float(base.kv_grid[-1])
            n_oct = int(np.ceil(np.log2(kv1 / kv0))) + 1
            kv_grid = kv0 * 2.0 ** np.arange(n_oct)
        self.kv_grid = np.asarray(kv_grid, np.float64)
        self._cells = np.full((len(self.q_grid), len(self.kv_grid)),
                              np.nan)
        if prior_speeds is None:
            self._prior = np.ones(self.n_servers)
        else:
            self._prior = np.asarray(list(prior_speeds), np.float64)
            if self._prior.shape != (self.n_servers,):
                raise ValueError(
                    f"prior_speeds needs {self.n_servers} entries, got "
                    f"{self._prior.shape}")
        self._ratio = np.full(self.n_servers, np.nan)
        self._n_obs = 0
        self._version = 0
        self._lock = threading.Lock()
        self._snap: Optional[CalibrationSnapshot] = None

    # ------------------------------------------------------------ internals
    def _cell_idx(self, q_len: float, kv_len: float) -> Tuple[int, int]:
        """Log-nearest grid cell for one measured task shape."""
        lq = np.log(max(float(q_len), 1.0))
        lk = np.log(max(float(kv_len), 1.0))
        qi = int(np.argmin(np.abs(np.log(self.q_grid) - lq)))
        ki = int(np.argmin(np.abs(np.log(self.kv_grid) - lk)))
        return qi, ki

    def _speeds_locked(self) -> np.ndarray:
        """Normalized speeds under the held lock, fastest == 1.

        Observed ratios carry the base-model/hardware scale; priors are
        *relative* speeds on scale 1.  Mixing them raw would make any
        not-yet-observed server look arbitrarily fast or slow whenever
        the hardware differs from the analytic model, so unobserved
        servers get their prior anchored to the mean observed
        ratio-per-prior — i.e. "assume it behaves like the servers we
        have measured, at its declared relative speed"."""
        obs = ~np.isnan(self._ratio)
        if not obs.any():
            s = self._prior.copy()
        else:
            scale = float((self._ratio[obs] / self._prior[obs]).mean())
            s = np.where(obs, self._ratio, self._prior * scale)
        top = s.max()
        return s / top if top > 0 else np.ones_like(s)

    def _predict_ref_locked(self, q_len: float, kv_len: float) -> float:
        """Reference (fastest-server) prediction from the current cells,
        falling back to the base model for unobserved cells."""
        qi, ki = self._cell_idx(q_len, kv_len)
        c = self._cells[qi, ki]
        if np.isnan(c):
            return float(self.base.predict(q_len, kv_len))
        return float(c)

    # -------------------------------------------------------------- observe
    def observe(self, q_len: int, kv_len: int, seconds: float,
                server: Optional[int] = None) -> None:
        """Record one measured CA-task timing.  ``server=None`` means
        the measurement came from a reference (speed-1) server."""
        if seconds <= 0 or kv_len <= 0 or q_len <= 0:
            return
        with self._lock:
            if server is not None:
                pred = float(self.base.predict(q_len, kv_len))
                ratio = pred / float(seconds)
                old = self._ratio[server]
                self._ratio[server] = ratio if np.isnan(old) \
                    else (1 - self.ema) * old + self.ema * ratio
                speed = self._speeds_locked()[server]
            else:
                speed = 1.0
            ref = float(seconds) * speed     # time on the fastest server
            qi, ki = self._cell_idx(q_len, kv_len)
            old = self._cells[qi, ki]
            self._cells[qi, ki] = ref if np.isnan(old) \
                else (1 - self.ema) * old + self.ema * ref
            self._n_obs += 1
            self._version += 1

    def observe_tasks(self, tasks: Iterable[Tuple[int, int]],
                      seconds: float,
                      server: Optional[int] = None) -> None:
        """Record one measured timing for a *fused batch* of tasks
        (what a per-server timer sees): ``seconds`` is split across the
        tasks proportionally to the current snapshot's predictions —
        the per-server total drives the scale and speed estimates, the
        model keeps the relative cell structure."""
        tasks = [(int(q), int(kv)) for q, kv in tasks if q > 0 and kv > 0]
        if not tasks or seconds <= 0:
            return
        with self._lock:
            preds = np.array([self._predict_ref_locked(q, kv)
                              for q, kv in tasks])
        total = preds.sum()
        if total <= 0:
            return
        for (q, kv), p in zip(tasks, preds):
            self.observe(q, kv, float(seconds) * float(p / total),
                         server=server)

    # ------------------------------------------------------------ snapshots
    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    @property
    def n_observations(self) -> int:
        with self._lock:
            return self._n_obs

    def speeds(self) -> np.ndarray:
        with self._lock:
            return self._speeds_locked()

    def snapshot(self) -> CalibrationSnapshot:
        """Immutable (version, cost model, speeds) triple; cached per
        version so prefetch-thread planning is cheap."""
        with self._lock:
            if self._snap is not None \
                    and self._snap.version == self._version:
                return self._snap
            tg = np.empty_like(self._cells)
            for i, q in enumerate(self.q_grid):
                for j, kv in enumerate(self.kv_grid):
                    c = self._cells[i, j]
                    tg[i, j] = self.base.predict(q, kv) if np.isnan(c) \
                        else c
            cm = CostModel.from_grid(self.q_grid, self.kv_grid, tg,
                                     self.base.n_heads,
                                     self.base.head_dim,
                                     peak_flops=self.base.peak_flops)
            self._snap = CalibrationSnapshot(
                version=self._version, cost_model=cm,
                speeds=tuple(float(s) for s in self._speeds_locked()))
            return self._snap

    # ------------------------------------------------------- pool elasticity
    def reset_server(self, server: int,
                     prior_speed: Optional[float] = None) -> None:
        """Forget one server's measured speed ratio — the elastic-pool
        carryover hook (DESIGN.md §9): when a *new* endpoint joins at a
        dispatch slot, its predecessor's speed estimate must not leak
        onto it, so the slot restarts from the base model (and
        ``prior_speed`` if declared).  Surviving servers keep their
        state untouched; a same-endpoint rejoin (flap) should NOT call
        this — its calibration is still valid."""
        with self._lock:
            if not 0 <= server < self.n_servers:
                raise ValueError(f"server {server} outside pool of "
                                 f"{self.n_servers}")
            self._ratio[server] = np.nan
            if prior_speed is not None:
                if prior_speed <= 0:
                    raise ValueError(
                        f"prior_speed must be > 0, got {prior_speed}")
                self._prior[server] = float(prior_speed)
            self._version += 1
            self._snap = None

    # -------------------------------------------------------- serialization
    def state_dict(self) -> Dict:
        with self._lock:
            return {
                "q_grid": self.q_grid.tolist(),
                "kv_grid": self.kv_grid.tolist(),
                "cells": self._cells.tolist(),
                "ratio": self._ratio.tolist(),
                "prior": self._prior.tolist(),
                "ema": self.ema,
                "n_obs": self._n_obs,
                "version": self._version,
            }

    def load_state_dict(self, d: Dict) -> None:
        """Restore saved calibration.  The state must describe the same
        pool size this calibrator was built for — silently adopting a
        differently-sized ``ratio``/``prior`` would hand the planners a
        wrong-length speeds array (or mis-index servers)."""
        ratio = np.asarray(d["ratio"], np.float64)
        prior = np.asarray(d["prior"], np.float64)
        if ratio.shape != (self.n_servers,) \
                or prior.shape != (self.n_servers,):
            raise ValueError(
                f"calibration state is for a {ratio.shape[0]}-server "
                f"pool, this calibrator has {self.n_servers} servers")
        cells = np.asarray(d["cells"], np.float64)
        q_grid = np.asarray(d["q_grid"], np.float64)
        kv_grid = np.asarray(d["kv_grid"], np.float64)
        if cells.shape != (len(q_grid), len(kv_grid)):
            raise ValueError(
                f"calibration grid {cells.shape} does not match its "
                f"axes ({len(q_grid)}, {len(kv_grid)})")
        with self._lock:
            self.q_grid = q_grid
            self.kv_grid = kv_grid
            self._cells = cells
            self._ratio = ratio
            self._prior = prior
            self.ema = float(d["ema"])
            self._n_obs = int(d["n_obs"])
            self._version = int(d["version"])
            self._snap = None
