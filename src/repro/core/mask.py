"""First-class attention mask families (DESIGN.md §12).

A :class:`MaskSpec` names the *shape* of core attention beyond the packed
segment/causal baseline: which kv positions of a document each query
position may see.  Three families are supported, all causal subsets:

  causal   — dense lower triangle per document (the default; every prior
             scenario in this repo)
  sliding  — ``window`` trailing tokens, plus an optional ``sink`` of
             always-visible leading tokens (StreamingLLM-style)
  dilated  — block-strided sparsity at the kernel tile granularity: a
             query block with in-document index ``i`` sees kv blocks ``j``
             with ``(i - j) % rate == 0`` (causal within the block pair)

Everything downstream consumes the spec through three views that are kept
mutually consistent (the property suite in ``tests/test_block_mask.py``
asserts it):

  * :func:`pair_visible` — token-level predicate on in-document positions,
    usable from numpy and jnp; the oracle, the XLA fallbacks, and the
    Pallas kernels' in-block masks all add this same term.
  * :func:`live_block_mask` / :func:`live_block_table` — block-level
    liveness mirroring the kernels' *pruning* predicates (a conservative
    superset of token visibility: a pruned-in block may still be fully
    masked at the token level, but it is iterated and therefore costed).
  * cost/planning — ``core/scheduler.py`` prices a task at in-document
    q-block ``bi`` by ``live_block_table(...)[bi] * blk`` live kv tokens
    instead of the dense prefix ``(bi + 1) * blk`` (DESIGN.md §12).

Malformed specs raise :class:`MaskSpecError` naming the offending
parameter, task, or segment instead of failing as a shape error deep in a
kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

MASK_KINDS = ("causal", "sliding", "dilated")


class MaskSpecError(ValueError):
    """A mask spec (or spec × layout combination) is malformed.

    Carries the offending ``segment`` / ``task`` when the failure is tied
    to a specific document or q-block so callers (and error messages) can
    point at data, not just at the spec string.
    """

    def __init__(self, detail: str, *, segment=None, task=None):
        self.segment = segment
        self.task = task
        msg = detail
        if segment is not None:
            msg += f" (segment {segment})"
        if task is not None:
            msg += f" (task {task})"
        super().__init__(msg)


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """One attention mask family with its parameters (DESIGN.md §12).

    window / sink are in tokens; rate is in kernel blocks.  The spec is
    hashable and is threaded as a static argument into kernels, planner
    kwargs, and :class:`~repro.core.dispatch.CADContext`.
    """
    kind: str = "causal"
    window: int = 0
    sink: int = 0
    rate: int = 1

    def __post_init__(self):
        if self.kind not in MASK_KINDS:
            raise MaskSpecError(
                f"unknown mask kind {self.kind!r} (choose from "
                f"{', '.join(MASK_KINDS)})")
        if self.kind == "causal":
            if self.window or self.sink or self.rate != 1:
                raise MaskSpecError(
                    "causal mask takes no window/sink/rate parameters")
        elif self.kind == "sliding":
            if self.window <= 0:
                raise MaskSpecError(
                    "zero-live-block mask: sliding needs window > 0 "
                    f"(got {self.window})")
            if self.sink < 0:
                raise MaskSpecError(f"sink must be >= 0 (got {self.sink})")
            if self.rate != 1:
                raise MaskSpecError("sliding mask does not take rate")
        else:  # dilated
            if self.rate < 1:
                raise MaskSpecError(
                    "zero-live-block mask: dilated needs rate >= 1 "
                    f"(got {self.rate})")
            if self.window or self.sink:
                raise MaskSpecError(
                    "dilated mask does not take window/sink")

    @property
    def trivial(self) -> bool:
        """True when the spec is plain dense-causal (no extra terms)."""
        return self.kind == "causal"

    def describe(self) -> str:
        if self.kind == "causal":
            return "causal"
        if self.kind == "sliding":
            s = f"sliding:window={self.window}"
            return s + (f",sink={self.sink}" if self.sink else "")
        return f"dilated:rate={self.rate}"


def parse_mask(text: Optional[str]) -> MaskSpec:
    """Parse a ``--mask`` flag value into a :class:`MaskSpec`.

    Grammar: ``kind[:key=int,...]`` — e.g. ``causal``,
    ``sliding:window=256,sink=16``, ``dilated:rate=4``.
    """
    if not text:
        return MaskSpec()
    kind, _, rest = text.strip().partition(":")
    kw = {}
    if rest:
        for part in rest.split(","):
            key, eq, val = part.partition("=")
            key = key.strip()
            if not eq or key not in ("window", "sink", "rate"):
                raise MaskSpecError(
                    f"bad mask parameter {part!r} in {text!r} "
                    "(expected window=/sink=/rate=)")
            try:
                kw[key] = int(val)
            except ValueError:
                raise MaskSpecError(
                    f"mask parameter {key}={val!r} is not an integer")
    return MaskSpec(kind=kind.strip(), **kw)


def mask_params(spec: Optional[MaskSpec], window: int = 0):
    """Unpack a spec into the ``(window, sink, rate)`` static ints the
    kernels take.  A trivial/absent spec passes the caller's own
    ``window`` through unchanged (the pre-mask layer-local sliding
    window); a non-trivial spec overrides it."""
    if spec is None or spec.trivial:
        return window, 0, 1
    if spec.kind == "sliding":
        return spec.window, spec.sink, 1
    return 0, 0, spec.rate


def spec_from_params(window: int = 0, sink: int = 0,
                     rate: int = 1) -> Optional[MaskSpec]:
    """Reconstruct the non-trivial :class:`MaskSpec` encoded by unpacked
    kernel params (``window``/``sink``/``rate`` static ints), or None when
    they encode plain causal or causal+window — both of which the original
    ``window`` code paths already handle without a spec."""
    if rate and rate > 1:
        return MaskSpec(kind="dilated", rate=rate)
    if sink and sink > 0:
        return MaskSpec(kind="sliding", window=window, sink=sink)
    return None


# ----------------------------------------------------------- token level
def pair_visible(spec: Optional[MaskSpec], pq, pk, blk: int):
    """Extra visibility term beyond segment + causal, or None if trivial.

    ``pq`` / ``pk`` are broadcast-compatible *in-document* position arrays
    (numpy or jnp — only operators are used).  ``blk`` is the block
    granularity the dilated family strides over.  The caller ANDs the
    result into its segment/causal/validity mask; causal specs contribute
    nothing (return None) so trivial paths stay byte-identical to the
    pre-mask code.
    """
    if spec is None or spec.trivial:
        return None
    if spec.kind == "sliding":
        m = (pq - pk) < spec.window
        if spec.sink:
            m = m | (pk < spec.sink)
        return m
    # dilated: block-strided on in-document block indices
    return ((pq // blk) - (pk // blk)) % spec.rate == 0


# ----------------------------------------------------------- block level
def live_block_mask(spec: Optional[MaskSpec], nq_blocks: int,
                    nkv_blocks: int, blk: int) -> np.ndarray:
    """[nq, nkv] bool: kv block ``j`` is priced live for q block ``i``.

    Mirrors the packed kernel's block-pruning predicates; the CA-server
    kernels prune with an exact any-pair-visible test on the actual
    position vectors, which is a subset of this table — so the cost
    model's live count is a tight conservative upper bound on the blocks
    a kernel executes (it can over-count a sliding-window boundary block
    by at most one per row, never under-count).  Sliding keeps block
    ``j`` when its last token could fall inside the window of q block
    ``i``'s first token (``(j+1)*blk - 1 >= i*blk - window``).
    """
    i = np.arange(nq_blocks, dtype=np.int64)[:, None]
    j = np.arange(nkv_blocks, dtype=np.int64)[None, :]
    live = j <= i
    if spec is None or spec.trivial:
        return live
    if spec.kind == "sliding":
        w = (j + 1) * blk - 1 >= i * blk - spec.window
        if spec.sink:
            w = w | (j * blk < spec.sink)
        return live & w
    return live & (((i - j) % spec.rate) == 0)


def live_block_table(spec: Optional[MaskSpec], max_blocks: int,
                     blk: int) -> np.ndarray:
    """[max_blocks] int64: live kv blocks for in-doc q-block index bi.

    ``table[bi] * blk`` is the live kv token count the cost model prices a
    task by; for the causal spec this reduces to the dense ``bi + 1``
    prefix (DESIGN.md §12).
    """
    if max_blocks <= 0:
        return np.zeros(0, np.int64)
    return live_block_mask(spec, max_blocks, max_blocks, blk).sum(axis=1)


def live_kv_len(spec: Optional[MaskSpec], kv_blocks: int, blk: int) -> int:
    """Live kv tokens for a CA task whose kv prefix is ``kv_blocks`` long.

    Uses the plan invariant that a task's q block has in-document index
    ``kv_blocks - 1`` (its kv range is its document's exact causal
    prefix), so the task's live work is ``table[kv_blocks - 1]`` blocks.
    """
    if kv_blocks <= 0:
        return 0
    if spec is None or spec.trivial:
        return kv_blocks * blk
    return int(live_block_table(spec, kv_blocks, blk)[kv_blocks - 1]) * blk


# ------------------------------------------------------------ validation
def validate_mask_layout(spec: Optional[MaskSpec], segment_ids,
                         blk: int) -> None:
    """Check a spec against a packed layout before planning/kernels.

    ``segment_ids``: [L] or [R, L] int array (0 = padding).  Raises
    :class:`MaskSpecError` naming the offending segment/task for:

      * overlapping segments — a nonzero id that is non-contiguous within
        a row or spans rows (the doc-pure-block invariant every kernel
        index map relies on);
      * segments not aligned to ``blk`` block boundaries;
      * window larger than kv — a sliding window wider than the longest
        document degenerates to dense causal, which is always a config
        mistake (the flag's unit is tokens);
      * zero-live-block tasks — any q block the spec leaves with no live
        kv block (defensive; reachable through hand-built live tables).
    """
    seg = np.asarray(segment_ids)
    if seg.ndim == 1:
        seg = seg[None, :]
    seen_rows = {}
    max_doc_tokens = 0
    for r in range(seg.shape[0]):
        row = seg[r]
        ids = row[row > 0]
        if ids.size == 0:
            continue
        # contiguity: each id must occupy exactly one run within one row
        change = np.flatnonzero(np.diff(row) != 0)
        starts = np.concatenate([[0], change + 1])
        run_ids = row[starts]
        nz = run_ids[run_ids > 0]
        uniq, counts = np.unique(nz, return_counts=True)
        for sid, cnt in zip(uniq.tolist(), counts.tolist()):
            if cnt > 1:
                raise MaskSpecError(
                    "overlapping segments: id occupies multiple runs "
                    f"in row {r}", segment=sid)
            prev = seen_rows.get(sid)
            if prev is not None:
                raise MaskSpecError(
                    f"overlapping segments: id spans rows {prev} and {r}",
                    segment=sid)
            seen_rows[sid] = r
        for s0, sid in zip(starts.tolist(), run_ids.tolist()):
            if sid > 0 and s0 % blk != 0:
                raise MaskSpecError(
                    f"segment start {s0} is not aligned to blk={blk}",
                    segment=sid)
        for sid in uniq.tolist():
            max_doc_tokens = max(max_doc_tokens, int((row == sid).sum()))
    if spec is None or spec.trivial:
        return
    if spec.kind == "sliding" and max_doc_tokens \
            and spec.window > max_doc_tokens:
        longest = max(seen_rows, key=lambda s: int((seg == s).sum()))
        raise MaskSpecError(
            f"window {spec.window} larger than kv: longest document has "
            f"{max_doc_tokens} tokens, the mask degenerates to causal",
            segment=longest)
    nb = max(1, -(-max_doc_tokens // blk))
    tbl = live_block_table(spec, nb, blk)
    dead = np.flatnonzero(tbl == 0)
    if dead.size:
        raise MaskSpecError(
            "zero-live-block task: q block has no live kv blocks",
            task=int(dead[0]))
