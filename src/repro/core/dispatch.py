"""CAD runtime: dispatch CA-tasks to the attention-server pool.

Dataflow per transformer layer (paper §4.1, Figure 2):

  local q/k/v blocks --gather--> per-destination send buffers
      --all_to_all--> attention servers (in-place: same devices)
      --fused CA kernel over the task batch--> outputs
      --all_to_all (transposed)--> home ranks --scatter--> local layout

Everything is linear except the CA kernel, so JAX transposes the backward
pass to the mirror-image communication automatically (the paper's
"backward reuses the schedule" property holds by construction).
DESIGN.md §1-§2 diagram the dataflow and the ping-pong overlap.

Two execution paths with identical math (shared helpers):
  * shard_map over the mesh's data axes with lax.all_to_all — the real
    distributed path (dry-run / TPU).
  * a "global simulation" on a single device where the exchange is a
    transpose on stacked [D, ...] arrays — used by tests & CPU examples;
    it IS the same per-rank code vmapped.

Ping-pong (paper §4.1): the layer's rows are split into two nano-batches
whose dispatch/compute phases are interleaved so XLA's async collectives
can overlap the A2A of one with the CA compute of the other.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.attention import NEG_INF, xla_flash_attention
from repro.core.mask import live_block_mask, live_kv_len, mask_params
from repro.core.plan import CADConfig, PingPongPlan
from repro.obs import server_track
from repro.obs import trace as obs_trace

from repro.compat import shard_map as _shard_map


@dataclasses.dataclass(frozen=True)
class CADContext:
    """Static CAD pool description + the (traced) plan for this step.

    ``plan`` is a :class:`repro.core.plan.StepPlan` (or
    :class:`PingPongPlan` when ping-pong is on).  Legacy dict plans and
    (ping, pong) tuples are still accepted for one release."""
    cfg: CADConfig
    plan: Any = None          # StepPlan | PingPongPlan | legacy dict/tuple
    kernel: str = "pallas"    # "pallas" | "xla" server implementation
    bwd: Any = None           # None (backend default) | "pallas" | "xla"
    jmax: int = 0             # max kv blocks any task touches (0 -> nkv)
    pingpong: bool = False
    mask: Any = None          # Optional[MaskSpec] — the step's task shape
                              # (DESIGN.md §12); None = dense causal

    def bind_plan(self, ctx, plan):
        new_cad = dataclasses.replace(self, plan=plan)
        return dataclasses.replace(ctx, cad=new_cad)


# ------------------------------------------------------------ helpers
def _to_blocks(x, blk):
    """[Bl, S, ...] -> [NB, blk, ...] (row-major token stream)."""
    bl, s = x.shape[:2]
    nb = bl * s // blk
    return x.reshape((nb, blk) + x.shape[2:])


def _gather_blocks(xb, idx, fill=0.0):
    """xb [NB, ...]; idx [...] with -1 padding -> gathered, pad = fill."""
    safe = jnp.maximum(idx, 0)
    out = xb[safe]
    mask = (idx >= 0).reshape(idx.shape + (1,) * (xb.ndim - 1))
    return jnp.where(mask, out, fill)


def _make_sends(qb, kb, vb, posb, plan):
    """Per-rank send buffers.  plan rows are this rank's (as src)."""
    q_send = _gather_blocks(qb, plan["q_send_idx"])      # [D, CQ, blk, H, dh]
    qpos_send = _gather_blocks(posb, plan["q_send_idx"], fill=-1)
    k_send = _gather_blocks(kb, plan["kv_send_idx"])     # [D, CKV, blk, Hk, dh]
    v_send = _gather_blocks(vb, plan["kv_send_idx"])
    kpos_send = _gather_blocks(posb, plan["kv_send_idx"], fill=-1)
    return q_send, qpos_send, k_send, v_send, kpos_send


def _server_tasks(qb, kb, vb, posb, recv, plan, cfg: CADConfig):
    """Assemble the fused CA-task batch on this server."""
    q_recv, qpos_recv, k_recv, v_recv, kpos_recv = recv
    d, cq, ckv = cfg.n_servers, cfg.cq, cfg.ckv
    # task list: home tasks then received tasks
    q_home = _gather_blocks(qb, plan["q_home_idx"])
    qpos_home = _gather_blocks(posb, plan["q_home_idx"], fill=-1)
    q_tasks = jnp.concatenate(
        [q_home, q_recv.reshape((d * cq,) + q_recv.shape[2:])], axis=0)
    qpos_tasks = jnp.concatenate(
        [qpos_home, qpos_recv.reshape(d * cq, -1)], axis=0)
    # dense kv buffer: concat(local blocks, received slots), then gather
    k_all = jnp.concatenate(
        [kb, k_recv.reshape((d * ckv,) + k_recv.shape[2:])], axis=0)
    v_all = jnp.concatenate(
        [vb, v_recv.reshape((d * ckv,) + v_recv.shape[2:])], axis=0)
    kpos_all = jnp.concatenate(
        [posb, kpos_recv.reshape(d * ckv, -1)], axis=0)
    k_buf = _gather_blocks(k_all, plan["kv_gather"])
    v_buf = _gather_blocks(v_all, plan["kv_gather"])
    kpos_buf = _gather_blocks(kpos_all, plan["kv_gather"], fill=-1)
    return q_tasks, qpos_tasks, k_buf, v_buf, kpos_buf


def _server_pair(qf, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, j, *,
                 softcap, window, scale, rep, n, sink=0, rate=1):
    """logits/mask/value block for relative kv index j of every task.

    ``sink``/``rate`` are the unpacked MaskSpec params (DESIGN.md §12);
    both default to the pre-mask no-op so dense-causal traces stay
    byte-identical.  Positions are in-document, so the dilated stride
    operates on in-doc block indices at the task blk granularity."""
    idx = jnp.clip(kv_start + j, 0, n - 1)                  # [T]
    kj = k_buf[idx]                                         # [T, blk, Hkv, dh]
    vj = v_buf[idx]
    pkj = kv_pos[idx]                                       # [T, blk]
    if rep > 1:
        kj = jnp.repeat(kj, rep, axis=2)
        vj = jnp.repeat(vj, rep, axis=2)
    logits = jnp.einsum("tqhd,tkhd->thqk", qf,
                        kj.astype(jnp.float32)) * scale
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    live = (j < kv_len)[:, None, None, None]
    msk = (q_pos[:, None, :, None] >= pkj[:, None, None, :]) \
        & (q_pos[:, None, :, None] >= 0) \
        & (pkj[:, None, None, :] >= 0) & live
    if window and window > 0:
        w = (q_pos[:, None, :, None] - pkj[:, None, None, :]) < window
        if sink and sink > 0:
            w = w | (pkj[:, None, None, :] < sink)
        msk &= w
    if rate and rate > 1:
        blk = qf.shape[1]
        msk &= ((q_pos[:, None, :, None] // blk)
                - (pkj[:, None, None, :] // blk)) % rate == 0
    return jnp.where(msk, logits, NEG_INF), msk, kj, vj, idx


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11, 12))
def _xla_server(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
                jmax, softcap, window, scale, sink=0, rate=1):
    out, _ = _xla_server_fwd_impl(q_tasks, k_buf, v_buf, kv_start, kv_len,
                                  q_pos, kv_pos, jmax, softcap, window,
                                  scale, sink, rate)
    return out


def _accum_init(T, hq, blk, dh):
    """Fresh running (m, l, acc) flash-accumulation carry."""
    return (jnp.full((T, hq, blk), NEG_INF, jnp.float32),
            jnp.zeros((T, hq, blk), jnp.float32),
            jnp.zeros((T, hq, blk, dh), jnp.float32))


def _accum_body(qf, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, *,
                softcap, window, scale, rep, n, sink=0, rate=1):
    """One flash-accumulation scan step over relative kv-block index j.
    Shared — same closure, same op sequence — by the full serve scan and
    the chunked KV-streaming scans, which is what makes streamed output
    bit-identical to the unstreamed path (DESIGN.md §11): splitting a
    scan into chunked sub-scans with the carry threaded across chunks
    performs the identical FP operations in the identical order.
    Iterations past a task's kv_len are exact no-ops (masked logits are
    NEG_INF, so m/l/acc are multiplied by exp(0) == 1 and incremented
    by 0), which also covers a ragged final chunk."""
    def body(carry, j):
        m_acc, l_acc, acc = carry
        logits, msk, kj, vj, _ = _server_pair(
            qf, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, j,
            softcap=softcap, window=window, scale=scale, rep=rep, n=n,
            sink=sink, rate=rate)
        m_new = jnp.maximum(m_acc, logits.max(-1))
        p = jnp.where(msk, jnp.exp(logits - m_new[..., None]), 0.0)
        corr = jnp.exp(m_acc - m_new)
        l_new = l_acc * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "thqk,tkhd->thqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc), None
    return body


def _accum_finalize(m_acc, l_acc, acc, dtype):
    """Normalize a finished flash carry into (out, lse)."""
    out = acc / jnp.maximum(l_acc, 1e-30)[..., None]
    live = m_acc > NEG_INF / 2
    out = jnp.where(live[..., None], out, 0.0)
    lse = jnp.where(live, m_acc + jnp.log(jnp.maximum(l_acc, 1e-30)),
                    jnp.float32(2.0 ** 30))
    return out.transpose(0, 2, 1, 3).astype(dtype), lse


def _xla_server_fwd_impl(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos,
                         kv_pos, jmax, softcap, window, scale, sink=0,
                         rate=1):
    """Blockwise jnp attention-server (the compile/dry-run path): scan over
    relative kv-block index j, gathering each task's j-th context block."""
    T, blk, hq, dh = q_tasks.shape
    n = k_buf.shape[0]
    rep = hq // k_buf.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    qf = q_tasks.astype(jnp.float32)
    body = _accum_body(qf, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
                       softcap=softcap, window=window, scale=scale,
                       rep=rep, n=n, sink=sink, rate=rate)
    carry, _ = jax.lax.scan(body, _accum_init(T, hq, blk, dh),
                            jnp.arange(jmax))
    return _accum_finalize(*carry, q_tasks.dtype)


def _xla_server_fwd(q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
                    jmax, softcap, window, scale, sink=0, rate=1):
    out, lse = _xla_server_fwd_impl(q_tasks, k_buf, v_buf, kv_start,
                                    kv_len, q_pos, kv_pos, jmax, softcap,
                                    window, scale, sink, rate)
    return out, (q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos,
                 out, lse)


def _xla_server_bwd(jmax, softcap, window, scale, sink, rate, res, g):
    """Flash-style recompute backward: nothing quadratic is saved."""
    return _xla_server_bwd_impl(res, g, None, jmax=jmax, softcap=softcap,
                                window=window, scale=scale, sink=sink,
                                rate=rate) + (None, None, None, None)


def _xla_server_bwd_impl(res, g, g_lse, *, jmax, softcap, window, scale,
                         sink, rate):
    """Blockwise recompute backward body, shared by the full serve's vjp
    and the ring partial op (``ops.ca_partial_attention``).  ``g_lse``
    is the cotangent of the partial's log-sum-exp output (None for the
    out-only full serve — the original expression is kept verbatim so
    pre-ring traces stay byte-identical); since ``d lse / d logits`` is
    the softmax itself, it joins the score gradient as
    ``ds = p * (dp - delta + g_lse)``.  Returns ``(dq, dk, dv)``."""
    q_tasks, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, out, lse = res
    T, blk, hq, dh = q_tasks.shape
    n = k_buf.shape[0]
    hkv = k_buf.shape[2]
    rep = hq // hkv
    scale_v = scale if scale is not None else dh ** -0.5
    qf = q_tasks.astype(jnp.float32)
    gf = g.astype(jnp.float32)                              # [T,blk,hq,dh]
    of = out.astype(jnp.float32)
    delta = jnp.einsum("tqhd,tqhd->thq", gf, of)            # [T,hq,blk]

    dq0 = jnp.zeros((T, blk, hq, dh), jnp.float32)
    dk0 = jnp.zeros((n, blk, hkv, dh), jnp.float32)
    dv0 = jnp.zeros((n, blk, hkv, dh), jnp.float32)

    def body(carry, j):
        dq_acc, dk_acc, dv_acc = carry
        logits, msk, kj, vj, idx = _server_pair(
            qf, k_buf, v_buf, kv_start, kv_len, q_pos, kv_pos, j,
            softcap=softcap, window=window, scale=scale_v, rep=rep, n=n,
            sink=sink, rate=rate)
        p = jnp.where(msk, jnp.exp(logits - lse[..., None]), 0.0)
        dvj = jnp.einsum("thqk,tqhd->tkhd", p, gf)          # [T,blk,hq,dh]
        dp = jnp.einsum("tqhd,tkhd->thqk", gf, vj.astype(jnp.float32))
        if g_lse is None:
            ds = p * (dp - delta[..., None])
        else:
            ds = p * (dp - (delta - g_lse.astype(jnp.float32))[..., None])
        if softcap and softcap > 0:
            sc = jnp.where(msk, logits / softcap, 0.0)
            ds = ds * (1.0 - sc * sc)
        ds = ds * scale_v
        dq_acc = dq_acc + jnp.einsum("thqk,tkhd->tqhd", ds,
                                     kj.astype(jnp.float32))
        dkj = jnp.einsum("thqk,tqhd->tkhd", ds, qf)
        # fold GQA repeats, scatter-add into kv buffer rows
        dkj = dkj.reshape(T, blk, hkv, rep, dh).sum(3)
        dvj = dvj.reshape(T, blk, hkv, rep, dh).sum(3)
        live = (j < kv_len).astype(jnp.float32)[:, None, None, None]
        dk_acc = dk_acc.at[idx].add(dkj * live)
        dv_acc = dv_acc.at[idx].add(dvj * live)
        return (dq_acc, dk_acc, dv_acc), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0),
                                   jnp.arange(jmax))
    return (dq.astype(q_tasks.dtype), dk.astype(k_buf.dtype),
            dv.astype(v_buf.dtype))


_xla_server.defvjp(_xla_server_fwd, _xla_server_bwd)


def _serve(q_tasks, qpos_tasks, k_buf, v_buf, kpos_buf, plan, cad,
           softcap, window, scale):
    jmax = cad.jmax or cad.cfg.nkv
    window, sink, rate = mask_params(cad.mask, window)
    if cad.kernel == "pallas":
        from repro.kernels.packed_flash.ops import ca_server_attention
        return ca_server_attention(
            q_tasks, k_buf, v_buf, plan["task_kv_start"],
            plan["task_kv_len"], qpos_tasks, kpos_buf,
            True, window, softcap, scale, jmax, cad.bwd, sink, rate)
    return _xla_server(q_tasks, k_buf, v_buf, plan["task_kv_start"],
                       plan["task_kv_len"], qpos_tasks, kpos_buf,
                       jmax, softcap, window, scale, sink, rate)


def _scatter_outputs(out_tasks, ret_recv, plan, cfg: CADConfig, nb, blk,
                     hq, dh, dtype):
    """Home-rank reassembly: home task slots + returned remote outputs."""
    out = jnp.zeros((nb, blk, hq, dh), jnp.float32)
    # home tasks: slot i corresponds to local block q_home_idx[i]
    idx_home = plan["q_home_idx"]
    safe = jnp.maximum(idx_home, 0)
    contrib = jnp.where((idx_home >= 0)[:, None, None, None],
                        out_tasks[:nb].astype(jnp.float32), 0.0)
    out = out.at[safe].add(contrib)
    # remote returns: ret_recv [D, CQ, blk, H, dh]; slot (s, c) is the
    # output of local block q_send_idx[s, c] (this rank's row as src)
    idx_rem = plan["q_send_idx"]                          # [D, CQ]
    safe_r = jnp.maximum(idx_rem, 0)
    contrib_r = jnp.where((idx_rem >= 0)[:, :, None, None, None],
                          ret_recv.astype(jnp.float32), 0.0)
    out = out.at[safe_r.reshape(-1)].add(
        contrib_r.reshape((-1,) + contrib_r.shape[2:]))
    return out.astype(dtype)


# ------------------------------------------------------- execution paths
def _rank_fn(q, k, v, pos, plan, cad, softcap, scale, axis_names):
    """Body run per rank inside shard_map.  q/k/v [Bl, S, H(l), dh]."""
    cfg = cad.cfg
    blk = cfg.blk
    qb = _to_blocks(q, blk)
    kb = _to_blocks(k, blk)
    vb = _to_blocks(v, blk)
    posb = _to_blocks(pos, blk)
    nb = qb.shape[0]

    sends = _make_sends(qb, kb, vb, posb, plan)
    a2a = functools.partial(jax.lax.all_to_all, axis_name=axis_names,
                            split_axis=0, concat_axis=0)
    recv = tuple(a2a(s) for s in sends)
    q_tasks, qpos_tasks, k_buf, v_buf, kpos_buf = _server_tasks(
        qb, kb, vb, posb, recv, plan, cfg)
    out_tasks = _serve(q_tasks, qpos_tasks, k_buf, v_buf, kpos_buf, plan,
                       cad, softcap, 0, scale)
    ret_send = out_tasks[nb:].reshape((cfg.n_servers, cfg.cq)
                                      + out_tasks.shape[1:])
    ret_recv = a2a(ret_send)
    out = _scatter_outputs(out_tasks, ret_recv, plan, cfg, nb, blk,
                           q.shape[2], q.shape[3], q.dtype)
    return out.reshape(q.shape)


def _sim_exchange(x):
    """Global-simulation all_to_all: [D_src, D_dst, C, ...] ->
    [D_dst, D_src, C, ...]."""
    return jnp.swapaxes(x, 0, 1)


def _global_sim(q, k, v, pos, plan, cad, softcap, scale):
    """Single-device semantics-equivalent execution over stacked ranks.
    q [D*Bl, S, H, dh] with rank-major rows."""
    cfg = cad.cfg
    d = cfg.n_servers
    blk = cfg.blk

    def stack_ranks(x):
        return x.reshape((d, x.shape[0] // d) + x.shape[1:])

    qs, ks, vs, ps = map(stack_ranks, (q, k, v, pos))
    qb = jax.vmap(lambda t: _to_blocks(t, blk))(qs)
    kb = jax.vmap(lambda t: _to_blocks(t, blk))(ks)
    vb = jax.vmap(lambda t: _to_blocks(t, blk))(vs)
    posb = jax.vmap(lambda t: _to_blocks(t, blk))(ps)
    nb = qb.shape[1]

    sends = jax.vmap(_make_sends)(qb, kb, vb, posb, plan)
    recv = tuple(_sim_exchange(s) for s in sends)
    q_tasks, qpos_tasks, k_buf, v_buf, kpos_buf = jax.vmap(
        lambda a, b, c, dd, r, pr: _server_tasks(a, b, c, dd, r, pr, cfg)
    )(qb, kb, vb, posb, recv, plan)
    out_tasks = jax.vmap(
        lambda a, b, c, dd, e, pr: _serve(a, b, c, dd, e, pr, cad, softcap,
                                          0, scale)
    )(q_tasks, qpos_tasks, k_buf, v_buf, kpos_buf, plan)
    ret_send = out_tasks[:, nb:].reshape((d, d, cfg.cq)
                                         + out_tasks.shape[2:])
    ret_recv = _sim_exchange(ret_send)
    out = jax.vmap(
        lambda ot, rr, pr: _scatter_outputs(ot, rr, pr, cfg, nb, blk,
                                            q.shape[2], q.shape[3], q.dtype)
    )(out_tasks, ret_recv, plan)
    return out.reshape(q.shape)


# ----------------------------------------------------- calibration probes
def iter_plan_tasks(cfg: CADConfig, plan, mask=None) \
        -> "list[Tuple[int, int, int, int]]":
    """Host-side: the (server, task_slot, q_tokens, kv_tokens) list of
    every live CA task in a :class:`StepPlan` (or legacy dict plan).
    Every task is one q block against a (kv_len · blk)-token context —
    the shapes the runtime calibrator's grid cells are keyed by.  Task
    count comes from the plan arrays themselves, so nano-batch plans
    built from a re-sized ping-pong config iterate correctly.

    With a non-trivial ``mask`` (:class:`~repro.core.mask.MaskSpec`),
    ``kv_tokens`` is the task's *live* kv length — the blocks the masked
    kernel actually iterates (DESIGN.md §12) — so calibration grid cells
    key on work done rather than rectangle area."""
    kv_len = np.asarray(plan["task_kv_len"])
    d, n_tasks = kv_len.shape
    out = []
    for s in range(d):
        for slot in range(n_tasks):
            kvl = int(kv_len[s, slot])
            if kvl > 0:
                out.append((s, slot, cfg.blk,
                            live_kv_len(mask, kvl, cfg.blk)))
    return out


@functools.lru_cache(maxsize=16)
def _probe_serve_fn(cfg: CADConfig, kernel: str, bwd, jmax: int,
                    softcap: float = 0.0, scale=None, mask=None):
    """One jitted serve per pool geometry — probes recur every
    ``calibrate_every`` steps and must not pay a re-trace each time
    (jit caches per argument shape under the returned callable)."""
    cad = CADContext(cfg=cfg, kernel=kernel, bwd=bwd, jmax=jmax, mask=mask)
    return jax.jit(lambda qt, qp, kb_, vb_, kp, st, ln: _serve(
        qt, qp, kb_, vb_, kp,
        {"task_kv_start": st, "task_kv_len": ln}, cad, softcap, 0, scale))


def build_server_inputs(cad: CADContext, plan, q, k, v, pos):
    """Host-side decomposed dispatch: assemble every server's fused
    CA-task inputs for one plan, without the collective exchange.

    ``q``/``k``/``v`` are the stacked rank-major global layout
    (``[D*Bl, S, H(kv), dh]``, as fed to ``cad_attention``'s global
    simulation), ``pos`` is ``[D*Bl, S]`` with -1 marking padding.
    Returns ``(inputs, plans_r)``: per server *s*, ``inputs[s]`` is the
    ``(q_tasks, qpos_tasks, k_buf, v_buf, kpos_buf)`` tuple ``_serve``
    consumes and ``plans_r[s]`` its per-rank plan slice.

    This is the elastic runtime's execution substrate (DESIGN.md §9):
    because each server's task batch is materialized independently, a
    single server's serve can fail, be retried, or be speculatively
    re-executed without touching the others — the per-server
    decomposition the fused shard_map path cannot express.

    The per-server ``k_buf``/``v_buf`` returned here is also the unit
    chunked KV streaming consumes (DESIGN.md §11): when the config sets
    ``stream_chunk``, ``serve_task_batch`` reads the buffer one chunk
    of kv blocks at a time instead of scanning it whole, so a server
    whose budget cannot hold a task's full prefix still serves it."""
    cfg = cad.cfg
    d, blk = cfg.n_servers, cfg.blk
    plan_np = jax.tree.map(np.asarray, dict(plan.items()))

    def stack_ranks(x):
        return x.reshape((d, x.shape[0] // d) + x.shape[1:])

    qs, ks, vs, ps = map(stack_ranks, (q, k, v, pos))
    blocks, sends, plans_r = [], [], []
    for r in range(d):
        plan_r = jax.tree.map(lambda a, r=r: jnp.asarray(a[r]), plan_np)
        qb, kb, vb = (_to_blocks(x, blk) for x in (qs[r], ks[r], vs[r]))
        posb = _to_blocks(ps[r], blk)
        blocks.append((qb, kb, vb, posb))
        sends.append(_make_sends(qb, kb, vb, posb, plan_r))
        plans_r.append(plan_r)
    # stacked exchange: [D_src, D_dst, C, ...] -> [D_dst, D_src, C, ...]
    recv = tuple(jnp.swapaxes(jnp.stack([s[i] for s in sends]), 0, 1)
                 for i in range(len(sends[0])))
    inputs = []
    for s in range(d):
        qb, kb, vb, posb = blocks[s]
        recv_s = tuple(f[s] for f in recv)
        inputs.append(_server_tasks(qb, kb, vb, posb, recv_s, plans_r[s],
                                    cfg))
    return inputs, plans_r


@functools.lru_cache(maxsize=16)
def _stream_serve_fns(n_chunk: int, softcap: float, window: int, scale,
                      sink: int = 0, rate: int = 1):
    """Jitted (chunk_step, finalize) pair for chunked KV streaming —
    cached per chunk geometry like :func:`_probe_serve_fn` (jit then
    caches per input shape underneath).  ``sink``/``rate`` join the
    cache key: a masked chunk body is a different trace."""

    @jax.jit
    def chunk_step(carry, q_tasks, k_buf, v_buf, kv_start, kv_len,
                   q_pos, kv_pos, j0):
        dh = q_tasks.shape[3]
        n = k_buf.shape[0]
        body = _accum_body(
            q_tasks.astype(jnp.float32), k_buf, v_buf, kv_start, kv_len,
            q_pos, kv_pos, softcap=softcap, window=window,
            scale=scale if scale is not None else dh ** -0.5,
            rep=q_tasks.shape[2] // k_buf.shape[2], n=n,
            sink=sink, rate=rate)
        # scan length is padded to >= 2 with a masked no-op iteration
        # (j = n sits past every task's kv_len, so the carry passes
        # through bitwise unchanged): XLA unrolls a trip-count-1 loop
        # and re-fuses the body with its surroundings, which would cost
        # bit-identity with the unstreamed scan's loop body
        length = max(n_chunk, 2)
        idx = jnp.arange(length)
        js = jnp.where(idx < n_chunk, j0 + idx, jnp.int32(n))
        carry, _ = jax.lax.scan(body, carry, js)
        return carry

    @jax.jit
    def finalize(carry, q_tasks):
        return _accum_finalize(*carry, q_tasks.dtype)[0]

    return chunk_step, finalize


def stream_task_batch(cad: CADContext, inputs_s, plan_s, *,
                      chunk_blocks: Optional[int] = None,
                      softcap: float = 0.0, scale=None):
    """Chunked KV streaming serve for ONE server (DESIGN.md §11): the
    fused task batch consumes its kv range in fixed-size chunks of
    ``chunk_blocks`` kv blocks, carrying the running (m, l, acc) flash
    accumulation across chunks, then normalizes once.  The per-chunk
    scan reuses the unstreamed server's scan body verbatim, so the
    streamed output is bit-identical to ``serve_task_batch`` with
    streaming off — the same merge-math discipline as
    :func:`merge_recovered`'s bitwise select, applied to accumulation
    instead of selection.  On hardware each chunk's k/v blocks are
    fetched and discarded per chunk, bounding kv residency by one chunk
    (the planner's model for streamed docs); the host-side simulation
    materializes the full buffer but only ever *reads* one chunk per
    step.  The streamed path always runs the blockwise server — with
    ``kernel='pallas'`` the unstreamed fused kernel remains in charge
    whenever the task batch fits within one chunk."""
    cfg = cad.cfg
    chunk = int(chunk_blocks if chunk_blocks is not None
                else cfg.stream_chunk)
    if chunk <= 0:
        raise ValueError(
            f"stream_task_batch needs chunk_blocks > 0 kv blocks "
            f"(or CADConfig.stream_chunk set), got {chunk}")
    jmax = cad.jmax or cfg.nkv
    q_tasks, qpos, k_buf, v_buf, kpos = inputs_s
    T, blk, hq, dh = q_tasks.shape
    window, sink, rate = mask_params(cad.mask, 0)
    step, finalize = _stream_serve_fns(chunk, float(softcap), window,
                                       scale, sink, rate)
    carry = _accum_init(T, hq, blk, dh)
    kv_start = plan_s["task_kv_start"]
    kv_len = plan_s["task_kv_len"]
    for j0 in range(0, jmax, chunk):
        # the ragged tail runs a full chunk; iterations past jmax are
        # exact no-ops (see _accum_body), preserving bit-identity
        carry = step(carry, q_tasks, k_buf, v_buf, kv_start, kv_len,
                     qpos, kpos, jnp.int32(j0))
    return finalize(carry, q_tasks)


def serve_task_batch(cad: CADContext, inputs_s, plan_s, *,
                     softcap: float = 0.0, scale=None,
                     stream_chunk: Optional[int] = None):
    """Run ONE server's fused CA-task batch eagerly (compiled once per
    pool geometry) — the unit of work the elastic runtime dispatches,
    retries and speculates on.

    When chunked KV streaming is enabled (``cfg.stream_chunk`` > 0, or
    an explicit ``stream_chunk`` override) and the kv range spans more
    than one chunk, the batch is served through
    :func:`stream_task_batch` — so every caller (elastic executor
    primary serves, fabric serve backfill, recovery re-serves) inherits
    memory-bounded serving from the config with no code of its own."""
    chunk = cad.cfg.stream_chunk if stream_chunk is None \
        else int(stream_chunk)
    jmax = cad.jmax or cad.cfg.nkv
    if 0 < chunk < jmax:
        return stream_task_batch(cad, inputs_s, plan_s,
                                 chunk_blocks=chunk, softcap=softcap,
                                 scale=scale)
    q_tasks, qpos, k_buf, v_buf, kpos = inputs_s
    serve = _probe_serve_fn(cad.cfg, cad.kernel, cad.bwd, cad.jmax,
                            softcap, scale, cad.mask)
    return serve(q_tasks, qpos, k_buf, v_buf, kpos,
                 plan_s["task_kv_start"], plan_s["task_kv_len"])


def assemble_step_outputs(cfg: CADConfig, plan, out_tasks, q_shape,
                          dtype):
    """Host-side home-rank reassembly: the transposed return exchange +
    scatter of the distributed path, applied to per-server task outputs.

    ``out_tasks`` maps server -> its ``[T, blk, H, dh]`` fused-batch
    output; servers absent from the dict (failed / killed mid-step)
    contribute zeros, so their blocks can be recovered separately and
    merged with :func:`merge_recovered` — exactly-once by construction.
    Scatter arithmetic is identical to the fused path's
    ``_scatter_outputs``, so outputs are bit-identical to a fault-free
    execution of the same plan."""
    d, blk = cfg.n_servers, cfg.blk
    plan_np = jax.tree.map(np.asarray, dict(plan.items()))
    nb = plan_np["q_home_idx"].shape[1]
    cq = plan_np["q_send_idx"].shape[2]
    n_tasks = plan_np["task_kv_len"].shape[1]
    hq, dh = q_shape[-2], q_shape[-1]
    zeros = None
    outs = []
    for r in range(d):
        ot_r = out_tasks.get(r)
        if ot_r is None:
            if zeros is None:
                zeros = jnp.zeros((n_tasks, blk, hq, dh), dtype)
            ot_r = zeros
        ret_recv = jnp.stack([
            (out_tasks[s][nb + r * cq: nb + (r + 1) * cq]
             if s in out_tasks else
             jnp.zeros((cq, blk, hq, dh), dtype))
            for s in range(d)])
        plan_r = jax.tree.map(lambda a, r=r: jnp.asarray(a[r]), plan_np)
        out_r = _scatter_outputs(ot_r, ret_recv, plan_r, cfg, nb, blk,
                                 hq, dh, dtype)
        outs.append(out_r.reshape((q_shape[0] // d,) + q_shape[1:]))
    return jnp.concatenate(outs, axis=0)


def merge_recovered(cfg: CADConfig, base, recovered,
                    lost_blocks: np.ndarray):
    """Exactly-once merge of a recovery sub-plan's outputs into a step's
    base outputs: every q block's output is *selected* from exactly one
    execution (bitwise — no floating-point accumulation across the two),
    recovered blocks from ``recovered``, everything else from ``base``.
    ``lost_blocks`` is the boolean ``[D, NB]`` (or flat ``[D*NB]``) mask
    of blocks whose primary serve was lost."""
    d, blk = cfg.n_servers, cfg.blk
    lost = np.asarray(lost_blocks, bool).reshape(d, -1)
    tok = np.repeat(lost, blk, axis=1)           # [D, NB*blk] per-token
    mask = tok.reshape((base.shape[0], base.shape[1]))
    return jnp.where(jnp.asarray(mask)[..., None, None], recovered, base)


# ------------------------------------------- ring baseline (DESIGN.md §13)
def _plan_task_q_block(cfg: CADConfig, plan_np, server: int,
                       slot: int) -> Optional[int]:
    """Global q-block index of task ``slot`` on ``server`` (None for a
    dead slot) — the plan-array inverse ``iter_plan_tasks`` walks."""
    nb, cq = cfg.nb, cfg.cq
    if slot < nb:
        idx = int(plan_np["q_home_idx"][server, slot])
        return server * nb + idx if idx >= 0 else None
    src, c = divmod(slot - nb, cq)
    idx = int(plan_np["q_send_idx"][src, server, c])
    return src * nb + idx if idx >= 0 else None


def ring_pass_geometry(cfg: CADConfig, segment_ids: np.ndarray, plan, *,
                       n_passes: Optional[int] = None, mask=None) \
        -> List[Dict[str, Any]]:
    """Host-side ring pass construction (DESIGN.md §13): split every
    task's kv prefix into the P contiguous document shards of the
    DISTFLASHATTN schedule and emit one pseudo-plan per ring pass.

    At pass ``t`` a task whose q block sits in document shard ``i``
    consumes kv shard ``j = (i - t) % P``, i.e. blocks
    ``[j*L, (j+1)*L)`` of its document clipped to the causal prefix
    (``L = ring_shard_size``).  Dead pairs are skipped *exactly*:
    causal-dead shards (``j > i``) get ``kv_len 0``, and with a
    non-trivial ``mask`` the shard range is trimmed to its live columns
    (``live_block_mask``) — a fully mask-dead shard is dropped like a
    causal one.  Returns one dict per pass with the ``task_kv_start`` /
    ``task_kv_len`` ``[D, T]`` arrays the partial serve consumes and
    ``jmax`` (max live kv blocks across the pool; 0 marks a globally
    dead pass the executors skip entirely)."""
    from repro.core.scheduler import layout_from_segments, ring_shard_size
    docs, doc_of, bi_of = layout_from_segments(
        np.asarray(segment_ids).reshape(cfg.n_servers, -1), cfg.blk,
        cfg.n_servers)
    plan_np = jax.tree.map(np.asarray, dict(plan.items()))
    kv_start = plan_np["task_kv_start"]
    kv_len = plan_np["task_kv_len"]
    d, n_tasks = kv_len.shape
    P = int(n_passes) if n_passes else cfg.n_servers
    trivial = mask is None or mask.trivial
    lbm_cache: Dict[int, np.ndarray] = {}

    def lbm(n):
        if n not in lbm_cache:
            lbm_cache[n] = live_block_mask(mask, n, n, cfg.blk)
        return lbm_cache[n]

    starts = [kv_start.copy() for _ in range(P)]
    lens = [np.zeros_like(kv_len) for _ in range(P)]
    for s in range(d):
        for slot in range(n_tasks):
            if kv_len[s, slot] <= 0:
                continue
            g = _plan_task_q_block(cfg, plan_np, s, slot)
            bi = int(bi_of[g])
            n = docs[int(doc_of[g])].n_blocks
            L = ring_shard_size(n, P)
            i = bi // L
            row = None if trivial else lbm(n)[bi]
            for t in range(P):
                j = (i - t) % P
                lo, hi = j * L, min((j + 1) * L, bi + 1)
                if hi <= lo:
                    continue                      # causal-dead ring step
                if row is not None:
                    live = np.nonzero(row[lo:hi])[0]
                    if live.size == 0:
                        continue                  # mask-dead ring step
                    lo, hi = lo + int(live[0]), lo + int(live[-1]) + 1
                starts[t][s, slot] = kv_start[s, slot] + lo
                lens[t][s, slot] = hi - lo
    return [{"task_kv_start": starts[t], "task_kv_len": lens[t],
             "jmax": int(lens[t].max(initial=0))} for t in range(P)]


def _ring_serve_merge(cad: CADContext, inputs_s, pass_plans, server: int,
                      *, softcap: float = 0.0, scale=None):
    """ONE endpoint's ring execution: serve each live pass's kv window as
    a finalized ``(out, lse)`` partial and fold the passes together in
    pass order with ``merge_softmax_partials`` — dead per-task windows
    merge as bitwise no-ops, globally dead passes are never served."""
    from repro.kernels.packed_flash import ops as O
    q_tasks, qpos, k_buf, v_buf, kpos = inputs_s
    window, sink, rate = mask_params(cad.mask, 0)
    merged = None
    for t, pp in enumerate(pass_plans):
        if t > 0 and pp["jmax"] <= 0:
            continue                        # dead ring pass: skipped exactly
        o, l = O.ca_partial_attention(
            q_tasks, k_buf, v_buf,
            jnp.asarray(pp["task_kv_start"][server]),
            jnp.asarray(pp["task_kv_len"][server]), qpos, kpos,
            max(pp["jmax"], 1), window, softcap, scale, sink, rate,
            cad.kernel)
        merged = (o, l) if merged is None \
            else O.merge_softmax_partials(merged[0], merged[1], o, l)
    return merged[0]


def ring_attention(cad: CADContext, plan, segment_ids: np.ndarray,
                   q, k, v, pos, *, n_passes: Optional[int] = None,
                   softcap: float = 0.0, scale=None, pass_plans=None):
    """Decomposed ring-attention execution of one step (DESIGN.md §13):
    the DISTFLASHATTN baseline run through CAD's own dispatch substrate.
    Each endpoint serves its fused task batch one ring pass at a time —
    kv windows rotating through the P document shards — merging the
    per-pass ``(out, lse)`` partials online, then outputs are
    reassembled exactly like the standard serve.  Bit-identical
    (forward *and* vjp) to :func:`ring_global_sim`, the single-pool
    oracle running the same pass schedule through the fused vmapped
    orchestration."""
    cfg = cad.cfg
    if pass_plans is None:
        pass_plans = ring_pass_geometry(cfg, segment_ids, plan,
                                        n_passes=n_passes, mask=cad.mask)
    inputs, _plans_r = build_server_inputs(cad, plan, q, k, v, pos)
    outs = {s: _ring_serve_merge(cad, inputs[s], pass_plans, s,
                                 softcap=softcap, scale=scale)
            for s in range(cfg.n_servers)}
    return assemble_step_outputs(cfg, plan, outs, q.shape, q.dtype)


def ring_global_sim(q, k, v, pos, plan, cad: CADContext,
                    segment_ids: np.ndarray, *,
                    n_passes: Optional[int] = None,
                    softcap: float = 0.0, scale=None, pass_plans=None):
    """Single-pool oracle for the ring schedule: the same per-pass
    partial serves and lse merges as :func:`ring_attention`, executed
    through the fused vmapped single-device orchestration of
    :func:`_global_sim` — same ops in the same order, different
    orchestration, so the decomposed ring dispatch must match it
    bitwise (the PR 5 differential discipline applied to the ring)."""
    from repro.kernels.packed_flash import ops as O
    cfg = cad.cfg
    d = cfg.n_servers
    blk = cfg.blk
    if pass_plans is None:
        pass_plans = ring_pass_geometry(cfg, segment_ids, plan,
                                        n_passes=n_passes, mask=cad.mask)

    def stack_ranks(x):
        return x.reshape((d, x.shape[0] // d) + x.shape[1:])

    qs, ks, vs, ps = map(stack_ranks, (q, k, v, pos))
    qb = jax.vmap(lambda t: _to_blocks(t, blk))(qs)
    kb = jax.vmap(lambda t: _to_blocks(t, blk))(ks)
    vb = jax.vmap(lambda t: _to_blocks(t, blk))(vs)
    posb = jax.vmap(lambda t: _to_blocks(t, blk))(ps)
    nb = qb.shape[1]

    sends = jax.vmap(_make_sends)(qb, kb, vb, posb, plan)
    recv = tuple(_sim_exchange(s) for s in sends)
    q_tasks, qpos_tasks, k_buf, v_buf, kpos_buf = jax.vmap(
        lambda a, b, c, dd, r, pr: _server_tasks(a, b, c, dd, r, pr, cfg)
    )(qb, kb, vb, posb, recv, plan)

    window, sink, rate = mask_params(cad.mask, 0)
    merged = None
    for t, pp in enumerate(pass_plans):
        if t > 0 and pp["jmax"] <= 0:
            continue                        # dead ring pass: skipped exactly
        o, l = jax.vmap(
            lambda qt, kbf, vbf, st, ln, qp, kp, jm=max(pp["jmax"], 1):
            O.ca_partial_attention(qt, kbf, vbf, st, ln, qp, kp, jm,
                                   window, softcap, scale, sink, rate,
                                   cad.kernel)
        )(q_tasks, k_buf, v_buf, jnp.asarray(pp["task_kv_start"]),
          jnp.asarray(pp["task_kv_len"]), qpos_tasks, kpos_buf)
        merged = (o, l) if merged is None \
            else O.merge_softmax_partials(merged[0], merged[1], o, l)
    out_tasks = merged[0]

    ret_send = out_tasks[:, nb:].reshape((d, d, cfg.cq)
                                         + out_tasks.shape[2:])
    ret_recv = _sim_exchange(ret_send)
    out = jax.vmap(
        lambda ot, rr, pr: _scatter_outputs(ot, rr, pr, cfg, nb, blk,
                                            q.shape[2], q.shape[3], q.dtype)
    )(out_tasks, ret_recv, plan)
    return out.reshape(q.shape)


def probe_plan_times(cad: CADContext, plan, *, n_heads: int = 1,
                     head_dim: int = 8, n_kv_heads: Optional[int] = None,
                     dtype=jnp.float32, seed: int = 0,
                     repeats: int = 1, trace_label: str = "probe") \
        -> List[Tuple[int, List[Tuple[int, int]], float]]:
    """Time each server's fused CA-task batch for one plan, eagerly,
    with synthetic q/k/v — the per-task kernel-timing hook of the
    runtime calibration loop (DESIGN.md §3).

    Kernel time depends on shapes, not values, so random tensors give a
    faithful measurement; the compiled serve is warmed up once so every
    server's timing excludes compilation.  Returns one
    ``(server, [(q_tokens, kv_tokens), ...], seconds)`` entry per
    server, ready for ``GridCalibrator.observe_tasks``.

    Honesty note: the blockwise-XLA fallback server scans a jmax-padded
    kv range for every task, so off-TPU its per-task time is nearly
    flat in kv length; the Pallas kernel (block-pruned scalar-prefetch
    ranges) is where timings genuinely track task shapes."""
    cfg = cad.cfg
    d, nb, blk = cfg.n_servers, cfg.nb, cfg.blk
    s_len = nb * blk
    hkv = n_kv_heads or n_heads
    plan_np = jax.tree.map(np.asarray, dict(plan.items()))
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (d, s_len, n_heads, head_dim), dtype)
    k = jax.random.normal(kk, (d, s_len, hkv, head_dim), dtype)
    v = jax.random.normal(kv, (d, s_len, hkv, head_dim), dtype)
    pos = jnp.broadcast_to(jnp.arange(s_len, dtype=jnp.int32)[None],
                           (d, s_len))

    inputs, plans_r = build_server_inputs(cad, plan_np, q, k, v, pos)
    serve = _probe_serve_fn(cfg, cad.kernel, cad.bwd, cad.jmax,
                            mask=cad.mask)

    by_server: Dict[int, List[Tuple[int, int]]] = {s: [] for s in range(d)}
    for s, _slot, qt, kvt in iter_plan_tasks(cfg, plan_np, mask=cad.mask):
        by_server[s].append((qt, kvt))

    results = []
    warm = False
    rec = obs_trace.get_recorder()
    for s in range(d):
        q_tasks, qpos, k_buf, v_buf, kpos = inputs[s]
        args = (q_tasks, qpos, k_buf, v_buf, kpos,
                plans_r[s]["task_kv_start"], plans_r[s]["task_kv_len"])
        if not warm:      # one compile for the shared shape
            jax.block_until_ready(serve(*args))
            warm = True
        # the probe span lands on the server's own gantt track
        # (``trace_label`` distinguishes ping-pong halves — §14)
        with rec.span(trace_label, server_track(s),
                      args={"repeats": max(1, repeats),
                            "n_tasks": len(by_server[s])}):
            t0 = time.perf_counter()
            for _ in range(max(1, repeats)):
                out = serve(*args)
            jax.block_until_ready(out)
            seconds = (time.perf_counter() - t0) / max(1, repeats)
        results.append((s, by_server[s], seconds))
    return results


# --------------------------------------------------------------- frontend
def cad_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv, *, ctx,
                  causal=True, window=0, softcap=0.0, scale=None,
                  mask=None):
    """Core-attention disaggregation entry point.

    Applies to causal full-attention layers (the quadratic-imbalance
    source).  Windowed/cross/non-causal layers fall back to the xla flash
    path: their compute is linear in tokens, so they do not create the
    imbalance CAD exists to fix (DESIGN.md §5).  A non-trivial ``mask``
    (:class:`~repro.core.mask.MaskSpec`) — sliding+sink or dilated —
    IS served through the plan path: the servers' kernels take the mask
    as static params and the plan is priced by live blocks (§12).  The
    spec must match the one the plan was built with; ``cad.mask`` (set
    by the session) is used when the call site passes none."""
    cad: Optional[CADContext] = getattr(ctx, "cad", None)
    if cad is not None and mask is not None and cad.mask != mask:
        cad = dataclasses.replace(cad, mask=mask)
    spec = cad.mask if cad is not None else mask
    if cad is None or cad.plan is None or not causal or window:
        w, sink, rate = mask_params(spec, window)
        return xla_flash_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                                   causal=causal, window=w, sink=sink,
                                   rate=rate, blk=(cad.cfg.blk if cad
                                                   else 128),
                                   softcap=softcap, scale=scale)
    # padding tokens -> position -1 so the server kernels mask them
    pos = jnp.where(seg_q > 0, pos_q, -1)

    def run(qq, kk, vv, pp, plan):
        if ctx.mesh is None:
            return _global_sim(qq, kk, vv, pp, plan, cad, softcap, scale)
        rules = ctx.rules
        bspec = rules.batch
        # TP-shard the head dim inside the dispatch whenever it divides
        # the model axis (self_attn_apply pads/MHA-izes beforehand, so
        # this usually holds); otherwise heads replicate across TP ranks.
        msize = 1
        if ctx.mesh is not None and "model" in ctx.mesh.axis_names:
            msize = dict(zip(ctx.mesh.axis_names,
                             ctx.mesh.devices.shape))["model"]
        hspec = "model" if (msize > 1 and qq.shape[2] % msize == 0) \
            else rules.heads
        if hspec == "model" and kk.shape[2] != qq.shape[2] \
                and kk.shape[2] % msize != 0:
            # per-shard GQA breaks when q heads are TP-sharded but kv
            # heads don't divide the axis: MHA-ize kv so both shard
            # (comm cost noted in DESIGN.md §4)
            from repro.core.attention import _repeat_kv
            rep = qq.shape[2] // kk.shape[2]
            kk = _repeat_kv(kk, rep)
            vv = _repeat_kv(vv, rep)
        khspec = "model" if (msize > 1 and kk.shape[2] % msize == 0) \
            else rules.kv_heads
        axis_names = rules.cad_axis
        in_specs = (P(bspec, None, hspec, None),
                    P(bspec, None, khspec, None),
                    P(bspec, None, khspec, None),
                    P(bspec, None),
                    jax.tree.map(lambda _: P(bspec), plan))
        fn = functools.partial(_rank_fn, cad=cad, softcap=softcap,
                               scale=scale, axis_names=axis_names)

        def body(qq_, kk_, vv_, pp_, plan_):
            plan_ = jax.tree.map(lambda a: a[0], plan_)  # drop local D=1
            return fn(qq_, kk_, vv_, pp_, plan_)

        return _shard_map(
            body, mesh=ctx.mesh,
            in_specs=in_specs,
            out_specs=P(bspec, None, hspec, None),
            check_vma=False,
        )(qq, kk, vv, pp, plan)

    if cad.pingpong and isinstance(cad.plan, (tuple, list, PingPongPlan)):
        # nano-batch interleave: issue both dispatches; XLA overlaps the
        # A2A of one with the serve of the other (paper Fig. 7).  The
        # split is within each rank's rows (rank-major batch layout).
        d = cad.cfg.n_servers
        b = q.shape[0]
        rpr = b // d
        h = rpr // 2

        def nano(x, i):
            xs = x.reshape((d, rpr) + x.shape[1:])
            sel = xs[:, :h] if i == 0 else xs[:, h:]
            return sel.reshape((d * h,) + x.shape[1:])

        out0 = run(nano(q, 0), nano(k, 0), nano(v, 0), nano(pos, 0),
                   cad.plan[0])
        out1 = run(nano(q, 1), nano(k, 1), nano(v, 1), nano(pos, 1),
                   cad.plan[1])
        o = jnp.stack([out0.reshape((d, h) + q.shape[1:]),
                       out1.reshape((d, h) + q.shape[1:])], axis=1)
        return o.reshape(q.shape)
    plan = cad.plan[0] if isinstance(cad.plan, (tuple, list, PingPongPlan)) \
        else cad.plan
    return run(q, k, v, pos, plan)
