"""Core attention disaggregation (the paper's contribution).

- attention:   the CA boundary — ref / xla-flash / pallas / cad impls
- cost_model:  CA FLOPs + profiler-grid latency + comm bytes (App. A/B)
- scheduler:   communication-aware greedy balancing (§4.2)
- plan:        static-shape dispatch plans (identity / per-doc CP / sched)
- dispatch:    shard_map all-to-all runtime + in-place attention servers
"""
from repro.core.attention import core_attention, ref_attention, \
    xla_flash_attention
from repro.core.cost_model import CalibrationSnapshot, CommModel, \
    CostModel, GridCalibrator, MemoryModel, ca_flops, causal_doc_flops
from repro.core.dispatch import CADContext, assemble_step_outputs, \
    build_server_inputs, cad_attention, iter_plan_tasks, \
    merge_recovered, probe_plan_times, serve_task_batch
from repro.core.plan import CADConfig, PingPongPlan, PlanCapacityError, \
    PlanMemoryError, StepPlan, identity_plan, per_document_cp_plan, \
    plan_from_schedule
from repro.core.scheduler import Caps, Schedule, imbalance, schedule

__all__ = [
    "core_attention", "ref_attention", "xla_flash_attention",
    "CalibrationSnapshot", "CommModel", "CostModel", "GridCalibrator",
    "MemoryModel", "ca_flops", "causal_doc_flops",
    "CADContext", "cad_attention", "iter_plan_tasks", "probe_plan_times",
    "build_server_inputs", "serve_task_batch", "assemble_step_outputs",
    "merge_recovered",
    "CADConfig", "identity_plan",
    "per_document_cp_plan", "plan_from_schedule", "Caps", "Schedule",
    "imbalance", "schedule", "StepPlan", "PingPongPlan",
    "PlanCapacityError", "PlanMemoryError",
]
