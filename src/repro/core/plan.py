"""Static-shape dispatch plans.

A :class:`StepPlan` is a typed pytree of int32 arrays — *data*, not
shapes — so a single compiled executable serves every step's schedule
(TPU adaptation of the paper's dynamic batching, DESIGN.md §3).  Legacy
raw-dict plans with the same keys are still accepted by the dispatch
layer for one release.  Layout per rank r (leading axis D is sharded by
the dispatch shard_map):

  q_home_idx   [D, NB]        local q-block ids this rank serves itself
  q_send_idx   [D, D, CQ]     [src, dst] local q-block ids sent src->dst
  kv_send_idx  [D, D, CKV]    [src, dst] local kv-block ids sent src->dst
  kv_gather    [D, NKV]       [server] dense kv buffer: index into the
                              concat(local NB blocks, recv D*CKV slots)
  task_kv_start[D, T]         [server] per task slot: first kv buffer blk
  task_kv_len  [D, T]         [server] blocks of context (0 = empty slot)

Task slots: t in [0, NB) are home tasks (aligned with q_home_idx);
t in [NB + r*CQ + c] is the task received from rank r slot c (aligned with
q_send_idx[r, server, c]).  T = NB + D*CQ.  All pads are -1 (idx) / 0
(len).

Plan builders:
  identity_plan          — every block served at home (baseline; equals
                           plain per-rank attention when docs don't span
                           ranks)
  per_document_cp_plan   — head-tail per-document context parallelism
                           (§2.2) expressed as a CAD plan: the paper's
                           framing of CP as a special case
  plan_from_schedule     — the scheduler's balanced assignment
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.scheduler import (Caps, Doc, Schedule, layout_from_segments,
                                  ring_shard_size)

PLAN_FIELDS = ("q_home_idx", "q_send_idx", "kv_send_idx", "kv_gather",
               "task_kv_start", "task_kv_len")


class PlanMemoryError(RuntimeError):
    """No feasible split fits every endpoint's HBM budget.

    Sibling of :class:`PlanCapacityError`, but for the *memory*
    constraint (DESIGN.md §11): raised only after the planner has
    exhausted re-splitting (and, when enabled, chunked KV streaming) —
    some server's resident bytes necessarily exceed its budget.
    """

    def __init__(self, server: int, resident_bytes: float,
                 budget_bytes: float, detail: str = ""):
        self.server = server
        self.resident_bytes = float(resident_bytes)
        self.budget_bytes = float(budget_bytes)
        extra = f" ({detail})" if detail else ""
        super().__init__(
            f"no feasible split: endpoint {server} needs "
            f"{self.resident_bytes:.4g} resident bytes, HBM budget is "
            f"{self.budget_bytes:.4g}{extra} — raise CADConfig."
            f"server_hbm, enable/shrink stream_chunk, or add servers")


class PlanCapacityError(RuntimeError):
    """A plan build exceeded a static dispatch capacity.

    The compiled dispatch has fixed shapes (CQ/CKV per (src, dst) pair,
    NKV kv-buffer slots per server); an assignment that needs more slots
    cannot be expressed.  Unlike a bare ``assert`` this survives
    ``python -O`` and reports which capacity broke and by how much.
    """

    def __init__(self, capacity: str, src: int, dst: int, needed: int,
                 available: int):
        self.capacity = capacity
        self.src = src
        self.dst = dst
        self.needed = needed
        self.available = available
        super().__init__(
            f"{capacity} capacity exceeded on (src={src}, dst={dst}): "
            f"needed {needed} block slots, only {available} available "
            f"(raise CADConfig.{capacity.lower()} or loosen the schedule)")


def _register_plan_dataclass(cls):
    import jax
    return jax.tree_util.register_dataclass(
        cls, data_fields=list(cls.__dataclass_fields__), meta_fields=[])


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """One step's dispatch plan as a typed JAX pytree.

    Field layouts are documented in the module docstring above; leaves
    are int32 arrays (numpy on the host, jax once traced).  ``StepPlan``
    supports ``plan["q_send_idx"]``-style access so dispatch helpers work
    identically on legacy dict plans and typed plans.
    """
    q_home_idx: Any
    q_send_idx: Any
    kv_send_idx: Any
    kv_gather: Any
    task_kv_start: Any
    task_kv_len: Any

    def __getitem__(self, key: str):
        if key not in PLAN_FIELDS:
            raise KeyError(key)
        return getattr(self, key)

    def __contains__(self, key) -> bool:
        return key in PLAN_FIELDS

    def __iter__(self) -> Iterator[str]:
        return iter(PLAN_FIELDS)

    def keys(self) -> Tuple[str, ...]:
        return PLAN_FIELDS

    def items(self) -> Iterator[Tuple[str, Any]]:
        return ((k, getattr(self, k)) for k in PLAN_FIELDS)

    def to_dict(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in PLAN_FIELDS}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StepPlan":
        return cls(**{k: d[k] for k in PLAN_FIELDS})

    @classmethod
    def empty(cls, cfg: "CADConfig") -> "StepPlan":
        return cls.from_dict(empty_plan(cfg))


@dataclasses.dataclass(frozen=True)
class PingPongPlan:
    """The two nano-batch plans of a ping-pong step (paper §4.1) — a
    first-class pair rather than a tuple convention."""
    ping: StepPlan
    pong: StepPlan

    def __iter__(self):
        return iter((self.ping, self.pong))

    def __getitem__(self, i: int):
        return (self.ping, self.pong)[i]


StepPlan = _register_plan_dataclass(StepPlan)
PingPongPlan = _register_plan_dataclass(PingPongPlan)


def _validated_per_server(name: str, values, n_servers: int) \
        -> Tuple[float, ...]:
    """Validate a per-server float list (speeds, HBM budgets): right
    length, every entry > 0.  Errors name the endpoint index AND the
    offending value — with dozens of pool members, "must be > 0, got
    <whole tuple>" is not actionable."""
    vals = tuple(float(v) for v in values)
    if len(vals) != n_servers:
        raise ValueError(
            f"{name} needs {n_servers} entries, got {len(vals)}")
    for i, v in enumerate(vals):
        if not v > 0:             # also catches NaN
            raise ValueError(
                f"{name}[{i}] must be > 0, got {v} for endpoint {i}")
    return vals


@dataclasses.dataclass(frozen=True)
class CADConfig:
    """Attention-server pool description: geometry (static dispatch
    capacities) plus per-server compute capacity.  ``server_speeds``
    holds relative speed factors — a 0.5 entry is a half-speed server
    that should receive half the FLOPs; ``None`` means a homogeneous
    pool.  Speeds only steer host-side planning (load targets are
    proportional to speed); the dispatch arrays and compiled shapes are
    speed-independent.

    ``server_hbm`` gives each endpoint an HBM budget in bytes
    (DESIGN.md §11); ``None`` means unconstrained.  Budgets, like
    speeds, steer planning only — the planners reject or re-split
    assignments whose modeled resident bytes exceed a budget.
    ``stream_chunk`` (kv blocks, 0 = off) enables chunked KV streaming
    for tasks whose context cannot fit any single endpoint's budget:
    the server consumes the kv range chunk by chunk with a running
    (out, lse) accumulation, bounding kv residency by one chunk."""
    n_servers: int
    blk: int
    nb: int               # q/kv blocks per rank
    cq: int
    ckv: int
    nkv: int
    server_speeds: Optional[Tuple[float, ...]] = None
    server_hbm: Optional[Tuple[float, ...]] = None   # bytes per endpoint
    stream_chunk: int = 0                            # kv blocks (0 = off)

    def __post_init__(self):
        if self.server_speeds is not None:
            object.__setattr__(self, "server_speeds", _validated_per_server(
                "server_speeds", self.server_speeds, self.n_servers))
        if self.server_hbm is not None:
            object.__setattr__(self, "server_hbm", _validated_per_server(
                "server_hbm", self.server_hbm, self.n_servers))
        if self.stream_chunk < 0:
            raise ValueError(
                f"stream_chunk is a kv-block count, must be >= 0, got "
                f"{self.stream_chunk}")

    @property
    def n_tasks(self) -> int:
        return self.nb + self.n_servers * self.cq

    def caps(self) -> Caps:
        return Caps(cq=self.cq, ckv=self.ckv, nkv=self.nkv)

    def speeds(self) -> np.ndarray:
        """Per-server speed factors as an array (1.0 = homogeneous)."""
        if self.server_speeds is None:
            return np.ones(self.n_servers)
        return np.asarray(self.server_speeds, np.float64)

    def budgets(self) -> Optional[np.ndarray]:
        """Per-endpoint HBM budgets in bytes; None = unconstrained."""
        if self.server_hbm is None:
            return None
        return np.asarray(self.server_hbm, np.float64)

    @classmethod
    def default(cls, n_servers: int, tokens_per_rank: int, blk: int = 128,
                max_doc_tokens: int = 0, server_speeds=None,
                server_hbm=None, stream_chunk: int = 0):
        """Per-pair capacities must cover a full document's kv prefix
        (its blocks live on one home rank): ckv >= max_doc_blocks, else
        the scheduler cannot offload long-document tails — the exact case
        CAD exists for (EXPERIMENTS.md §Perf P10)."""
        nb = tokens_per_rank // blk
        per = max(1, -(-nb // n_servers))
        mdb = min(nb, max(1, (max_doc_tokens or tokens_per_rank) // blk))
        cq = max(2 * per, mdb)
        ckv = max(2 * per, mdb)
        nkv = nb + min(n_servers * ckv, 4 * nb)
        return cls(n_servers=n_servers, blk=blk, nb=nb, cq=cq, ckv=ckv,
                   nkv=nkv,
                   server_speeds=None if server_speeds is None
                   else tuple(server_speeds),
                   server_hbm=None if server_hbm is None
                   else tuple(server_hbm),
                   stream_chunk=int(stream_chunk))


def empty_plan(cfg: CADConfig) -> Dict[str, np.ndarray]:
    d, nb = cfg.n_servers, cfg.nb
    return {
        "q_home_idx": -np.ones((d, nb), np.int32),
        "q_send_idx": -np.ones((d, d, cfg.cq), np.int32),
        "kv_send_idx": -np.ones((d, d, cfg.ckv), np.int32),
        "kv_gather": -np.ones((d, cfg.nkv), np.int32),
        "task_kv_start": np.zeros((d, cfg.n_tasks), np.int32),
        "task_kv_len": np.zeros((d, cfg.n_tasks), np.int32),
    }


def plan_from_assignment(cfg: CADConfig, assign: np.ndarray,
                         doc_of: np.ndarray, bi_of: np.ndarray,
                         docs) -> StepPlan:
    """Build the dispatch arrays from a per-block server assignment.

    Raises :class:`PlanCapacityError` when the assignment needs more
    send/buffer slots than the static shapes provide."""
    d, nb = cfg.n_servers, cfg.nb
    plan = empty_plan(cfg)
    q_cnt = np.zeros((d, d), np.int64)

    # ---- q routing + per-server doc needs
    # needs[s][doc_id] = max prefix blocks required on server s
    needs = [dict() for _ in range(d)]
    # remote task bookkeeping: for each (g) served remotely remember its
    # send slot (src rank, c) so task metadata lands in the right slot.
    task_slot_of_g = {}
    for g in range(d * nb):
        dc = int(doc_of[g])
        if dc < 0:
            continue
        s = int(assign[g])
        home = g // nb
        bi = int(bi_of[g])
        needs[s][dc] = max(needs[s].get(dc, 0), bi + 1)
        if s == home:
            # home task slot == local block index (stable, simple)
            plan["q_home_idx"][home, g % nb] = g % nb
            task_slot_of_g[g] = (s, g % nb)
        else:
            c = q_cnt[home, s]
            if c >= cfg.cq:
                raise PlanCapacityError("CQ", home, s, int(c) + 1, cfg.cq)
            plan["q_send_idx"][home, s, c] = g % nb
            q_cnt[home, s] = c + 1
            task_slot_of_g[g] = (s, nb + home * cfg.cq + c)

    # ---- kv routing + dense buffer per server
    kv_cnt = np.zeros((d, d), np.int64)
    for s in range(d):
        # needed global kv blocks, sorted: prefix ranges of each doc
        needed = []
        for dc, pref in needs[s].items():
            g0 = docs[dc].g0
            needed.extend(range(g0, g0 + pref))
        needed = sorted(set(needed))
        if len(needed) > cfg.nkv:
            raise PlanCapacityError("NKV", s, s, len(needed), cfg.nkv)
        # source slot for each needed block
        buf_pos_of_g = {}
        for pos, g in enumerate(needed):
            src = g // nb
            if src == s:
                slot = g % nb                       # local
            else:
                c = kv_cnt[src, s]
                if c >= cfg.ckv:
                    raise PlanCapacityError("CKV", src, s, int(c) + 1,
                                            cfg.ckv)
                plan["kv_send_idx"][src, s, c] = g % nb
                kv_cnt[src, s] = c + 1
                slot = nb + src * cfg.ckv + c       # recv layout
            plan["kv_gather"][s, pos] = slot
            buf_pos_of_g[g] = pos

        # ---- per-task metadata
        for dc, pref in needs[s].items():
            g0 = docs[dc].g0
            start = buf_pos_of_g[g0]
            # contiguity invariant: prefix occupies consecutive buffer slots
            assert buf_pos_of_g[g0 + pref - 1] == start + pref - 1
            for g in range(g0, g0 + docs[dc].n_blocks):
                if int(assign[g]) != s or int(doc_of[g]) != dc:
                    continue
                srv, slot = task_slot_of_g[g]
                assert srv == s
                bi = int(bi_of[g])
                plan["task_kv_start"][s, slot] = start
                plan["task_kv_len"][s, slot] = bi + 1
    return StepPlan.from_dict(plan)


def identity_assignment(cfg: CADConfig) -> np.ndarray:
    """Every block served at its home rank."""
    return (np.arange(cfg.n_servers * cfg.nb) // cfg.nb).astype(np.int64)


def head_tail_assignment(cfg: CADConfig, docs,
                         servers: Optional[Tuple[int, ...]] = None) \
        -> np.ndarray:
    """Head-tail per-document CP (paper §2.2): each doc's blocks are dealt
    to servers in the 0,1,...,D-1,D-1,...,1,0 pairing order.  ``servers``
    restricts the deal to a surviving subset of the pool (elastic
    membership, DESIGN.md §9); the default is the full pool."""
    srv = list(range(cfg.n_servers)) if servers is None else list(servers)
    assign = identity_assignment(cfg)
    ht = srv + srv[::-1]                               # head-tail order
    for doc in docs:
        for j, g in enumerate(doc.blocks()):
            assign[g] = ht[j % len(ht)]
    return assign


def ring_assignment(cfg: CADConfig, docs,
                    servers: Optional[Tuple[int, ...]] = None) \
        -> np.ndarray:
    """Ring / context-parallel sharding (DISTFLASHATTN baseline,
    DESIGN.md §13): each document's blocks are cut into contiguous
    shards of :func:`ring_shard_size` blocks and shard ``p`` is owned by
    the ``p``-th allowed server — endpoint ``p`` holds the ``p``-th kv
    shard of *every* document, the classic sequence-contiguous CP
    layout.  Under causal attention the tail shards see quadratically
    more context than the head shards, which is exactly the imbalance
    ``benchmarks/cad_vs_ring.py`` quantifies CAD's planners against.
    ``servers`` restricts the deal to a surviving subset of the pool."""
    srv = list(range(cfg.n_servers)) if servers is None else list(servers)
    assign = identity_assignment(cfg)
    for doc in docs:
        L = ring_shard_size(doc.n_blocks, len(srv))
        for j, g in enumerate(doc.blocks()):
            assign[g] = srv[j // L]
    return assign


def identity_plan(cfg: CADConfig, segment_ids: np.ndarray) -> StepPlan:
    docs, doc_of, bi_of = layout_from_segments(segment_ids, cfg.blk,
                                               cfg.n_servers)
    return plan_from_assignment(cfg, identity_assignment(cfg), doc_of,
                                bi_of, docs)


def per_document_cp_plan(cfg: CADConfig, segment_ids: np.ndarray) \
        -> StepPlan:
    docs, doc_of, bi_of = layout_from_segments(segment_ids, cfg.blk,
                                               cfg.n_servers)
    return plan_from_assignment(cfg, head_tail_assignment(cfg, docs),
                                doc_of, bi_of, docs)


def plan_from_schedule(cfg: CADConfig, sched: Schedule) -> StepPlan:
    return plan_from_assignment(cfg, sched.assign, sched.doc_of_block,
                                sched.bi_of_block, sched.docs)
