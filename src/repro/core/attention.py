"""Core attention (CA) — the paper's disaggregation boundary.

``core_attention`` is the single entry point every model layer calls.  It
computes ``softmax(QK^T)V`` with packed-document (segment) masking, causal
or bidirectional, optional sliding window and logit softcap, under one of
four interchangeable implementations:

  ref     — materialized-mask jnp oracle (small shapes, tests)
  xla     — blockwise online-softmax flash attention in pure jnp/lax
            (memory-O(S·blk), the dry-run/compile path)
  pallas  — the Pallas TPU kernel (kernels/packed_flash)
  cad     — core attention disaggregation: CA-tasks dispatched across the
            attention-server pool per a scheduler plan (core/dispatch)

All impls share the exact same semantics; the test suite asserts their
pairwise agreement.  DESIGN.md §1 maps the full data → planner →
dispatch → kernels flow this router sits at the center of.

Shapes: q [B,Sq,Hq,dh], k/v [B,Skv,Hkv,dh] with Hq % Hkv == 0 (GQA).
segment ids: int32 [B,S]; 0 marks padding (attends nothing / is masked
out of loss anyway), equal nonzero ids attend within the same document.
positions: absolute position within the *packed chunk* (used for causal
and window tests together with segments).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30  # large-but-finite: keeps padded rows NaN-free


def _softcap(x, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(x / cap) * cap
    return x


def mask_fn(seg_q, pos_q, seg_kv, pos_kv, *, causal: bool, window: int,
            sink: int = 0, rate: int = 1, blk: int = 128):
    """Boolean mask [.., Sq, Skv]: True = may attend.

    ``sink``/``rate``/``blk`` are the unpacked static params of a
    non-causal :class:`~repro.core.mask.MaskSpec` (DESIGN.md §12):
    ``sink`` always-visible leading tokens widen the sliding window and
    ``rate`` strides kv blocks of ``blk`` tokens for the dilated family.
    Positions are in-document (packing restarts them per doc), which is
    what makes both terms well-defined inside a packed chunk."""
    same = (seg_q[..., :, None] == seg_kv[..., None, :])
    valid = (seg_q[..., :, None] > 0) & (seg_kv[..., None, :] > 0)
    m = same & valid
    if causal:
        m &= pos_q[..., :, None] >= pos_kv[..., None, :]
    if window and window > 0:
        w = (pos_q[..., :, None] - pos_kv[..., None, :]) < window
        if sink and sink > 0:
            w = w | (pos_kv[..., None, :] < sink)
        m &= w
    if rate and rate > 1:
        m &= ((pos_q[..., :, None] // blk)
              - (pos_kv[..., None, :] // blk)) % rate == 0
    return m


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


# --------------------------------------------------------------------- ref
def ref_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv, *, causal=True,
                  window=0, sink=0, rate=1, blk=128, softcap=0.0,
                  scale: Optional[float] = None):
    """O(Sq·Skv) materialized oracle."""
    hq, hkv = q.shape[2], k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = _softcap(logits, softcap)
    m = mask_fn(seg_q, pos_q, seg_kv, pos_kv, causal=causal, window=window,
                sink=sink, rate=rate, blk=blk)
    logits = jnp.where(m[:, None, :, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (padding) -> zero output instead of uniform garbage
    any_valid = m.any(axis=-1)[:, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------- xla
def xla_flash_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv, *,
                        causal=True, window=0, sink=0, rate=1, blk=128,
                        softcap=0.0, scale: Optional[float] = None,
                        q_block: int = 512, kv_block: int = 512,
                        skip_masked_blocks: bool = True, shard_hint=None):
    """Blockwise online-softmax attention in pure jnp/lax with a
    flash-style recompute backward (memory O(S·blk) in both passes).

    Baseline enumerates the full (q_block x kv_block) rectangle; with
    ``skip_masked_blocks`` (the paper-faithful causal-triangle variant,
    and a §Perf iteration) only block pairs that can contain unmasked
    entries are visited, via a static lower-triangle pair list.

    ``shard_hint``: optional (mesh, batch_axes, heads_axis) tuple.  The
    scan accumulators are pinned to batch/head sharding; without this
    GSPMD may shard them on the q-block dim, turning every per-pair
    dynamic-slice into a full all-gather (EXPERIMENTS.md §Perf P7).
    """
    return _xla_flash(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal,
                      window, softcap, scale, q_block, kv_block,
                      skip_masked_blocks, shard_hint, sink, rate, blk)


def _hint_cons(x, shard_hint, dims):
    """Pin dims (logical: 'b'atch, 'h'eads, None) when a hint is given."""
    if shard_hint is None:
        return x
    mesh, batch_ax, heads_ax = shard_hint
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ok(ax, size):
        if ax is None:
            return None
        axs = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axs:
            n *= sizes.get(a, 1)
        return ax if size % n == 0 else None

    spec = []
    for i, d in enumerate(dims):
        ax = {"b": batch_ax, "h": heads_ax, None: None}[d]
        spec.append(ok(ax, x.shape[i]))
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))


@functools.partial(jax.custom_vjp, nondiff_argnums=tuple(range(7, 18)))
def _xla_flash(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal, window,
               softcap, scale, q_block, kv_block, skip_masked_blocks,
               shard_hint, sink=0, rate=1, blk=128):
    out, _ = _xla_flash_fwd_impl(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                                 causal, window, softcap, scale, q_block,
                                 kv_block, skip_masked_blocks, shard_hint,
                                 sink, rate, blk)
    return out


def _prep_blocks(q, k, v, seg_q, pos_q, seg_kv, pos_kv, q_block, kv_block,
                 causal, skip_masked_blocks):
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    nq = -(-sq // q_block)
    nk = -(-skv // kv_block)
    pad_q = nq * q_block - sq
    pad_k = nk * kv_block - skv

    def padq(x, val=0):
        return jnp.pad(x, [(0, 0), (0, pad_q)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=val) if pad_q else x

    def padk(x, val=0):
        return jnp.pad(x, [(0, 0), (0, pad_k)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=val) if pad_k else x

    qb = padq(q).reshape(b, nq, q_block, hq, dh)
    kb = padk(k).reshape(b, nk, kv_block, k.shape[2], dh)
    vb = padk(v).reshape(b, nk, kv_block, k.shape[2], dh)
    sqb = padq(seg_q).reshape(b, nq, q_block)
    pqb = padq(pos_q).reshape(b, nq, q_block)
    skb = padk(seg_kv).reshape(b, nk, kv_block)
    pkb = padk(pos_kv).reshape(b, nk, kv_block)

    # static (i, j) pair list.  Packed chunks lay documents out in order,
    # so causal triangle pruning is sound on chunk-position blocks.
    if skip_masked_blocks and causal and sq == skv:
        pairs = [(i, j) for i in range(nq) for j in range(nk)
                 if j * kv_block < (i + 1) * q_block]
    else:
        pairs = [(i, j) for i in range(nq) for j in range(nk)]
    return (qb, kb, vb, sqb, pqb, skb, pkb,
            jnp.asarray(pairs, jnp.int32), (b, sq, hq, dh, nq, nk,
                                            q_block, kv_block))


def _pair_logits(qi, kj, sqi, pqi, skj, pkj, scale, softcap, causal,
                 window, sink=0, rate=1, blk=128):
    """logits + mask for one (q-block, kv-block) pair."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", qi.astype(jnp.float32),
                        kj.astype(jnp.float32)) * scale
    logits = _softcap(logits, softcap)
    msk = mask_fn(sqi, pqi, skj, pkj, causal=causal, window=window,
                  sink=sink, rate=rate, blk=blk)
    return jnp.where(msk[:, None], logits, NEG_INF), msk


def _xla_flash_fwd_impl(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal,
                        window, softcap, scale, q_block, kv_block,
                        skip_masked_blocks, shard_hint=None, sink=0,
                        rate=1, blk=128):
    hq, hkv = q.shape[2], k.shape[2]
    n_rep = hq // hkv
    dh = q.shape[-1]
    scale = scale if scale is not None else dh ** -0.5
    (qb, kb, vb, sqb, pqb, skb, pkb, pairs,
     (b, sq, _, _, nq, nk, qbk, kbk)) = _prep_blocks(
        q, k, v, seg_q, pos_q, seg_kv, pos_kv, q_block, kv_block, causal,
        skip_masked_blocks)
    qb = _hint_cons(qb, shard_hint, ("b", None, None, "h", None))
    kb = _hint_cons(kb, shard_hint, ("b", None, None, "h", None))
    vb = _hint_cons(vb, shard_hint, ("b", None, None, "h", None))

    m0 = _hint_cons(jnp.full((b, nq, hq, qbk), NEG_INF, jnp.float32),
                    shard_hint, ("b", None, "h", None))
    l0 = _hint_cons(jnp.zeros((b, nq, hq, qbk), jnp.float32),
                    shard_hint, ("b", None, "h", None))
    a0 = _hint_cons(jnp.zeros((b, nq, hq, qbk, dh), jnp.float32),
                    shard_hint, ("b", None, "h", None, None))

    def body(carry, pair):
        m_acc, l_acc, o_acc = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, False)
        kj = _repeat_kv(jax.lax.dynamic_index_in_dim(kb, j, 1, False),
                        n_rep)
        vj = _repeat_kv(jax.lax.dynamic_index_in_dim(vb, j, 1, False),
                        n_rep)
        logits, msk = _pair_logits(
            qi, kj,
            jax.lax.dynamic_index_in_dim(sqb, i, 1, False),
            jax.lax.dynamic_index_in_dim(pqb, i, 1, False),
            jax.lax.dynamic_index_in_dim(skb, j, 1, False),
            jax.lax.dynamic_index_in_dim(pkb, j, 1, False),
            scale, softcap, causal, window, sink, rate, blk)
        mi = jax.lax.dynamic_index_in_dim(m_acc, i, 1, False)
        li = jax.lax.dynamic_index_in_dim(l_acc, i, 1, False)
        oi = jax.lax.dynamic_index_in_dim(o_acc, i, 1, False)
        m_new = jnp.maximum(mi, logits.max(axis=-1))
        p = jnp.where(msk[:, None], jnp.exp(logits - m_new[..., None]), 0.0)
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(axis=-1)
        o_new = oi * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32))
        m_acc = jax.lax.dynamic_update_index_in_dim(m_acc, m_new, i, 1)
        l_acc = jax.lax.dynamic_update_index_in_dim(l_acc, l_new, i, 1)
        o_acc = jax.lax.dynamic_update_index_in_dim(o_acc, o_new, i, 1)
        return (m_acc, l_acc, o_acc), None

    (m_acc, l_acc, o_acc), _ = jax.lax.scan(body, (m0, l0, a0), pairs)
    out = o_acc / jnp.maximum(l_acc, 1e-30)[..., None]
    live = m_acc > NEG_INF / 2
    out = jnp.where(live[..., None], out, 0.0)
    # logsumexp per row; dead rows get +big so recomputed p underflows to 0
    lse = jnp.where(live, m_acc + jnp.log(jnp.maximum(l_acc, 1e-30)),
                    jnp.float32(2.0 ** 30))          # [b, nq, hq, qbk]
    out = out.transpose(0, 1, 3, 2, 4).reshape(b, nq * qbk, hq, dh)
    return out[:, :sq].astype(q.dtype), lse


def _xla_flash_fwd(q, k, v, seg_q, pos_q, seg_kv, pos_kv, causal, window,
                   softcap, scale, q_block, kv_block, skip_masked_blocks,
                   shard_hint, sink=0, rate=1, blk=128):
    out, lse = _xla_flash_fwd_impl(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                                   causal, window, softcap, scale, q_block,
                                   kv_block, skip_masked_blocks, shard_hint,
                                   sink, rate, blk)
    return out, (q, k, v, seg_q, pos_q, seg_kv, pos_kv, out, lse)


def _xla_flash_bwd(causal, window, softcap, scale, q_block, kv_block,
                   skip_masked_blocks, shard_hint, sink, rate, blk,
                   res, g):
    """Flash-style recompute backward: per (i, j) pair recompute p from the
    saved logsumexp, accumulate dq/dk/dv.  Memory O(S·blk)."""
    q, k, v, seg_q, pos_q, seg_kv, pos_kv, out, lse = res
    hq, hkv = q.shape[2], k.shape[2]
    n_rep = hq // hkv
    dh = q.shape[-1]
    scale_v = scale if scale is not None else dh ** -0.5
    (qb, kb, vb, sqb, pqb, skb, pkb, pairs,
     (b, sq, _, _, nq, nk, qbk, kbk)) = _prep_blocks(
        q, k, v, seg_q, pos_q, seg_kv, pos_kv, q_block, kv_block, causal,
        skip_masked_blocks)
    qb = _hint_cons(qb, shard_hint, ("b", None, None, "h", None))
    kb = _hint_cons(kb, shard_hint, ("b", None, None, "h", None))
    vb = _hint_cons(vb, shard_hint, ("b", None, None, "h", None))
    pad_q = nq * qbk - sq

    def padq(x):
        return jnp.pad(x, [(0, 0), (0, pad_q)] + [(0, 0)] * (x.ndim - 2)) \
            if pad_q else x

    gb = padq(g.astype(jnp.float32)).reshape(b, nq, qbk, hq, dh)
    ob = padq(out.astype(jnp.float32)).reshape(b, nq, qbk, hq, dh)
    # delta_i = rowsum(do * o)   [b, nq, hq, qbk]
    delta = jnp.einsum("biqhd,biqhd->bihq", gb, ob)

    dq0 = _hint_cons(jnp.zeros((b, nq, qbk, hq, dh), jnp.float32),
                     shard_hint, ("b", None, None, "h", None))
    dk0 = _hint_cons(jnp.zeros((b, nk, kbk, hkv, dh), jnp.float32),
                     shard_hint, ("b", None, None, "h", None))
    dv0 = _hint_cons(jnp.zeros((b, nk, kbk, hkv, dh), jnp.float32),
                     shard_hint, ("b", None, None, "h", None))

    def body(carry, pair):
        dq_acc, dk_acc, dv_acc = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, False)
        kj = _repeat_kv(jax.lax.dynamic_index_in_dim(kb, j, 1, False),
                        n_rep)
        vj = _repeat_kv(jax.lax.dynamic_index_in_dim(vb, j, 1, False),
                        n_rep)
        logits, msk = _pair_logits(
            qi, kj,
            jax.lax.dynamic_index_in_dim(sqb, i, 1, False),
            jax.lax.dynamic_index_in_dim(pqb, i, 1, False),
            jax.lax.dynamic_index_in_dim(skb, j, 1, False),
            jax.lax.dynamic_index_in_dim(pkb, j, 1, False),
            scale_v, softcap, causal, window, sink, rate, blk)
        lse_i = jax.lax.dynamic_index_in_dim(lse, i, 1, False)
        p = jnp.where(msk[:, None], jnp.exp(logits - lse_i[..., None]), 0.0)
        gi = jax.lax.dynamic_index_in_dim(gb, i, 1, False)   # [b,qbk,hq,dh]
        di = jax.lax.dynamic_index_in_dim(delta, i, 1, False)  # [b,hq,qbk]
        # dv_j += p^T do_i
        dvj = jnp.einsum("bhqk,bqhd->bkhd", p, gi)
        # dp = do_i v_j^T ; ds = p (dp - delta)
        dp = jnp.einsum("bqhd,bkhd->bhqk", gi, vj.astype(jnp.float32))
        ds = p * (dp - di[..., None])
        if softcap and softcap > 0:
            # s = cap*tanh(s_raw/cap); ds_raw = ds * (1 - (s/cap)^2)
            sc = jnp.where(msk[:, None], logits / softcap, 0.0)
            ds = ds * (1.0 - sc * sc)
        ds = ds * scale_v
        dqi = jnp.einsum("bhqk,bkhd->bqhd", ds, kj.astype(jnp.float32))
        dkj = jnp.einsum("bhqk,bqhd->bkhd", ds, qi.astype(jnp.float32))
        # fold GQA repeats back onto kv heads
        dkj = dkj.reshape(b, kbk, hkv, n_rep, dh).sum(3)
        dvj = dvj.reshape(b, kbk, hkv, n_rep, dh).sum(3)
        dq_acc = jax.lax.dynamic_update_index_in_dim(
            dq_acc, jax.lax.dynamic_index_in_dim(dq_acc, i, 1, False)
            + dqi, i, 1)
        dk_acc = jax.lax.dynamic_update_index_in_dim(
            dk_acc, jax.lax.dynamic_index_in_dim(dk_acc, j, 1, False)
            + dkj, j, 1)
        dv_acc = jax.lax.dynamic_update_index_in_dim(
            dv_acc, jax.lax.dynamic_index_in_dim(dv_acc, j, 1, False)
            + dvj, j, 1)
        return (dq_acc, dk_acc, dv_acc), None

    (dqb, dkb, dvb), _ = jax.lax.scan(body, (dq0, dk0, dv0), pairs)
    skv = k.shape[1]
    dq = dqb.reshape(b, nq * qbk, hq, dh)[:, :sq].astype(q.dtype)
    dk = dkb.reshape(b, nk * kbk, hkv, dh)[:, :skv].astype(k.dtype)
    dv = dvb.reshape(b, nk * kbk, hkv, dh)[:, :skv].astype(v.dtype)
    return dq, dk, dv, None, None, None, None


_xla_flash.defvjp(_xla_flash_fwd, _xla_flash_bwd)


# ---------------------------------------------------------------- decoding
def decode_attention(q, k_cache, v_cache, cache_len_mask, pos_q, pos_kv, *,
                     window=0, softcap=0.0, scale: Optional[float] = None):
    """One-token (or few-token) query against a cache.

    q [B,1,Hq,dh]; caches [B,S,Hkv,dh]; cache_len_mask [B,S] bool (True =
    slot holds a real token); pos_kv [B,S] absolute positions (supports
    ring buffers where slot order != position order).
    """
    b, sq, hq, dh = q.shape
    hkv = k_cache.shape[2]
    n_rep = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    k = _repeat_kv(k_cache, n_rep)
    v = _repeat_kv(v_cache, n_rep)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = _softcap(logits, softcap)
    m = cache_len_mask[:, None, None, :] & (
        pos_q[:, None, :, None] >= pos_kv[:, None, None, :])
    if window and window > 0:
        m &= (pos_q[:, None, :, None] - pos_kv[:, None, None, :]) < window
    logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(m.any(-1)[..., None], p, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ------------------------------------------------------------------ router
def core_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv, *, causal=True,
                   window=0, softcap=0.0, ctx=None, scale=None, mask=None):
    """Dispatch by ``ctx.attn_impl`` (default ref).

    ``mask`` is an optional :class:`~repro.core.mask.MaskSpec`
    (DESIGN.md §12) applied on top of segment+causal masking; a
    non-trivial spec overrides the layer-local ``window``.  The dilated
    family strides at the packed kernel tile (128 tokens) on this
    router; finer granularities are reachable through the kernel/oracle
    entry points directly."""
    from repro.core.mask import mask_params
    impl = getattr(ctx, "attn_impl", "ref") if ctx is not None else "ref"
    window, sink, rate = mask_params(mask, window)
    kw = dict(causal=causal, window=window, softcap=softcap, scale=scale)
    mkw = dict(sink=sink, rate=rate)
    if impl == "ref":
        return ref_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                             **kw, **mkw)
    if impl == "xla":
        hint = None
        mesh = getattr(ctx, "mesh", None)
        if mesh is not None and "model" in mesh.axis_names:
            msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
            heads_ax = "model" if q.shape[2] % msize == 0 else None
            hint = (mesh, ctx.rules.batch, heads_ax)
        return xla_flash_attention(q, k, v, seg_q, pos_q, seg_kv, pos_kv,
                                   shard_hint=hint, **kw, **mkw)
    if impl == "pallas":
        from repro.kernels.packed_flash import ops as pf_ops
        return pf_ops.packed_flash_attention(
            q, k, v, seg_q, pos_q, seg_kv, pos_kv,
            bwd_impl=getattr(ctx, "attn_bwd", None), **kw, **mkw)
    if impl == "cad":
        from repro.core import dispatch as cad_dispatch
        return cad_dispatch.cad_attention(
            q, k, v, seg_q, pos_q, seg_kv, pos_kv, ctx=ctx, causal=causal,
            window=window if mask is None else 0, softcap=softcap,
            scale=scale, mask=mask)
    raise ValueError(f"unknown attn impl {impl!r}")
