"""jax-version compatibility helpers.

This repo targets current jax but must also run on 0.4.x containers,
where several APIs differ:

  * ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
    replication check ``check_rep`` instead of ``check_vma``
  * ``jax.sharding.AxisType`` / ``make_mesh(axis_types=...)`` don't
    exist

Every version shim lives here (Pallas-kernel renames live in
``repro.kernels.compat`` to keep this module jax-core only).
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:                                           # pragma: no cover
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with auto axis types when the installed jax
    supports them (older jax defaults to the same behavior)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_shapes),
                             **kwargs)
    except (ImportError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
