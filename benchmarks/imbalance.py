"""Paper Figure 1/4 analogue: load imbalance from document packing.

Samples batches from the Pretrain/ProLong distributions, packs them with
fixed-size and WLB-style variable-length strategies, and reports:
  * per-chunk attention-FLOPs divergence (max/mean) — the DP straggler
  * per-chunk token (= activation memory) divergence — WLB's cost
  * compute idle fraction vs DP degree (Fig. 4b)
"""
import numpy as np

from repro.data.distributions import sample_lengths
from repro.data.packing import (chunk_attention_cost, chunk_tokens_used,
                                pack_documents)


def run(n_batches=10, seq_len=65536, max_doc=32768):
    rng = np.random.default_rng(0)
    rows = []
    for dist in ("pretrain", "prolong"):
        for dp in (4, 8, 16):
            att_div_f, att_div_v, mem_div_f, mem_div_v, idle = \
                [], [], [], [], []
            for _ in range(n_batches):
                lens = sample_lengths(dist, rng, 16 * dp, max_doc)
                fixed = pack_documents(lens, seq_len, dp, rng=rng,
                                       strategy="fixed")
                var = pack_documents(lens, seq_len, dp, rng=rng,
                                     strategy="variable")

                def div(cs, fn):
                    v = np.array([max(fn(c), 1) for c in cs], np.float64)
                    return float(v.max() / v.mean())

                att_div_f.append(div(fixed, chunk_attention_cost))
                att_div_v.append(div(var, chunk_attention_cost))
                mem_div_f.append(div(fixed, chunk_tokens_used))
                mem_div_v.append(div(var, chunk_tokens_used))
                # idle fraction: straggler overhang of attention compute
                v = np.array([max(chunk_attention_cost(c), 1)
                              for c in fixed])
                idle.append(float(1 - v.mean() / v.max()))
            rows.append({
                "dist": dist, "dp": dp,
                "attn_divergence_fixed": float(np.mean(att_div_f)),
                "attn_divergence_wlb": float(np.mean(att_div_v)),
                "mem_divergence_fixed": float(np.mean(mem_div_f)),
                "mem_divergence_wlb": float(np.mean(mem_div_v)),
                "idle_frac_fixed": float(np.mean(idle)),
            })
    return rows


def main(fast=False):
    rows = run(n_batches=2, seq_len=16384, max_doc=8192) if fast else run()
    for r in rows:
        d = (f"dist={r['dist']};dp={r['dp']};"
             f"attn_div_fixed={r['attn_divergence_fixed']:.2f};"
             f"attn_div_wlb={r['attn_divergence_wlb']:.2f};"
             f"mem_div_fixed={r['mem_divergence_fixed']:.2f};"
             f"mem_div_wlb={r['mem_divergence_wlb']:.2f};"
             f"idle_fixed={r['idle_frac_fixed']:.2f}")
        print(f"fig4_imbalance,0.0,{d}")
    return rows


if __name__ == "__main__":
    main()
