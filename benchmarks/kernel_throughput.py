"""Paper Figure 5 analogue: CA kernel throughput vs document-shard length.

A 32K-token fused chunk is packed with shards of a fixed length (context
sizes sampled); throughput should be flat down to the 128-token kernel
tile and collapse below it (sub-tile shards waste their whole tile).

Two columns: measured us/call of the jitted blockwise XLA kernel on this
CPU (relative shape of the curve), and the cost-model-predicted TPU v5e
throughput (absolute, used by the scheduler).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core.attention import xla_flash_attention
from repro.core.cost_model import CostModel, ca_flops


def run(chunk=8192, hq=4, hkv=2, dh=64):
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    cm = CostModel.analytic(n_heads=hq, head_dim=dh)
    rows = []
    for shard_len in (32, 64, 128, 256, 512, 1024, 4096):
        n = chunk // shard_len
        seg = np.repeat(np.arange(1, n + 1), shard_len)[None]
        pos = np.tile(np.arange(shard_len), n)[None]
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, chunk, hq, dh), jnp.float32)
        k = jax.random.normal(ks[1], (1, chunk, hkv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (1, chunk, hkv, dh), jnp.float32)
        segj, posj = jnp.asarray(seg), jnp.asarray(pos)
        fn = jax.jit(lambda a, b, c: xla_flash_attention(
            a, b, c, segj, posj, segj, posj, q_block=128, kv_block=128))
        us = time_call(fn, q, k, v, warmup=1, iters=3)
        flops = float(n * ca_flops(shard_len, shard_len / 2, hq, dh))
        meas_tput = flops / (us * 1e-6)
        # cost model: per-shard predicted time at (q=kv=shard_len)
        pred_t = float(n * cm.predict(shard_len, shard_len))
        pred_tput = flops / max(pred_t, 1e-12)
        rows.append({"shard_len": shard_len, "us": us,
                     "measured_flops_s": meas_tput,
                     "model_tpu_flops_s": pred_tput})
    return rows


def main():
    rows = run()
    base = rows[-1]["model_tpu_flops_s"]
    for r in rows:
        d = (f"shard={r['shard_len']};cpu_tput={r['measured_flops_s']:.3e};"
             f"tpu_model_tput={r['model_tpu_flops_s']:.3e};"
             f"rel_model={r['model_tpu_flops_s']/base:.2f}")
        print(f"fig5_kernel_throughput,{r['us']:.1f},{d}")


if __name__ == "__main__":
    main()
