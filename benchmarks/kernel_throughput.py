"""Paper Figure 5 analogue: CA kernel throughput vs document-shard length,
plus a ``--bwd`` mode measuring the hand-written Pallas backward kernels.

Forward mode: a 32K-token fused chunk is packed with shards of a fixed
length (context sizes sampled); throughput should be flat down to the
128-token kernel tile and collapse below it (sub-tile shards waste their
whole tile).  Two columns: measured us/call of the jitted blockwise XLA
kernel on this CPU (relative shape of the curve), and the
cost-model-predicted TPU v5e throughput (absolute, used by the scheduler).

Backward mode (``--bwd``): end-to-end grad call (fwd + bwd) of the Pallas
``packed_flash_attention`` and ``ca_server_attention`` custom-vjps, with
the residual-saving Pallas backward vs the blockwise-XLA recompute
fallback — the A/B the speedup claim rests on.  On CPU the Pallas side
runs in interpret mode, so absolute numbers only mean something on TPU;
the CI smoke records both for the perf trajectory.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core.attention import xla_flash_attention
from repro.core.cost_model import CostModel, ca_flops


def run(chunk=8192, hq=4, hkv=2, dh=64, shard_lens=None):
    key = jax.random.PRNGKey(0)
    cm = CostModel.analytic(n_heads=hq, head_dim=dh)
    rows = []
    for shard_len in shard_lens or (32, 64, 128, 256, 512, 1024, 4096):
        n = chunk // shard_len
        seg = np.repeat(np.arange(1, n + 1), shard_len)[None]
        pos = np.tile(np.arange(shard_len), n)[None]
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, chunk, hq, dh), jnp.float32)
        k = jax.random.normal(ks[1], (1, chunk, hkv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (1, chunk, hkv, dh), jnp.float32)
        segj, posj = jnp.asarray(seg), jnp.asarray(pos)
        fn = jax.jit(lambda a, b, c: xla_flash_attention(
            a, b, c, segj, posj, segj, posj, q_block=128, kv_block=128))
        us = time_call(fn, q, k, v, warmup=1, iters=3)
        flops = float(n * ca_flops(shard_len, shard_len / 2, hq, dh))
        meas_tput = flops / (us * 1e-6)
        # cost model: per-shard predicted time at (q=kv=shard_len)
        pred_t = float(n * cm.predict(shard_len, shard_len))
        pred_tput = flops / max(pred_t, 1e-12)
        rows.append({"shard_len": shard_len, "us": us,
                     "measured_flops_s": meas_tput,
                     "model_tpu_flops_s": pred_tput})
    return rows


# ------------------------------------------------------------- bwd mode
def _packed_inputs(S, hq, hkv, dh, n_docs=4):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, S, hq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, hkv, dh), jnp.float32)
    ln = S // n_docs
    seg = np.repeat(np.arange(1, n_docs + 1), ln)[None]
    pos = np.tile(np.arange(ln), n_docs)[None]
    return q, k, v, jnp.asarray(seg), jnp.asarray(pos)


def _server_inputs(T, blk, hq, hkv, dh, N):
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    rng = np.random.default_rng(0)
    q = jax.random.normal(ks[0], (T, blk, hq, dh), jnp.float32)
    kb = jax.random.normal(ks[1], (N, blk, hkv, dh), jnp.float32)
    vb = jax.random.normal(ks[2], (N, blk, hkv, dh), jnp.float32)
    kv_start = np.zeros(T, np.int32)
    kv_len = np.zeros(T, np.int32)
    q_pos = np.zeros((T, blk), np.int32)
    kv_pos = np.zeros((N, blk), np.int32)
    for t in range(T):
        ln = int(rng.integers(1, N + 1))
        st = int(rng.integers(0, N - ln + 1))
        kv_start[t], kv_len[t] = st, ln
        q_pos[t] = np.arange((ln - 1) * blk, ln * blk)
        for jj in range(ln):
            kv_pos[st + jj] = np.arange(jj * blk, (jj + 1) * blk)
    return (q, kb, vb, jnp.asarray(kv_start), jnp.asarray(kv_len),
            jnp.asarray(q_pos), jnp.asarray(kv_pos))


def _grad_us(attn, *qkv):
    g = jax.jit(jax.grad(lambda a, b, c: jnp.sum(attn(a, b, c) ** 2),
                         argnums=(0, 1, 2)))
    return time_call(g, *qkv, warmup=1, iters=3)


def run_bwd(fast=False):
    """Grad-call us for both Pallas ops, Pallas bwd vs XLA-recompute bwd."""
    from repro.kernels.packed_flash import ops as O
    S = 256 if fast else 1024
    T, blk, N = (3, 128, 4) if fast else (8, 128, 12)
    hq, hkv, dh = 4, 2, 64
    rows = []

    q, k, v, seg, pos = _packed_inputs(S, hq, hkv, dh)
    fwd = jax.jit(lambda a, b, c: O.packed_flash_attention(
        a, b, c, seg, pos, seg, pos))
    row = {"kernel": "packed_flash", "seq": S,
           "fwd_us": time_call(fwd, q, k, v, warmup=1, iters=3)}
    for impl in ("pallas", "xla"):
        attn = lambda a, b, c, i=impl: O.packed_flash_attention(
            a, b, c, seg, pos, seg, pos, True, 0, 0.0, None, i)
        row[f"grad_{impl}_us"] = _grad_us(attn, q, k, v)
    rows.append(row)

    qs, kb, vb, st, ln, qp, kp = _server_inputs(T, blk, hq, hkv, dh, N)
    fwd = jax.jit(lambda a, b, c: O.ca_server_attention(
        a, b, c, st, ln, qp, kp))
    row = {"kernel": "ca_server", "tasks": T, "kv_blocks": N,
           "fwd_us": time_call(fwd, qs, kb, vb, warmup=1, iters=3)}
    for impl in ("pallas", "xla"):
        attn = lambda a, b, c, i=impl: O.ca_server_attention(
            a, b, c, st, ln, qp, kp, True, 0, 0.0, None, 0, i)
        row[f"grad_{impl}_us"] = _grad_us(attn, qs, kb, vb)
    rows.append(row)
    return rows


def main_bwd(fast=False):
    rows = run_bwd(fast=fast)
    for r in rows:
        d = ";".join(f"{k}={v:.1f}" if isinstance(v, float) else f"{k}={v}"
                     for k, v in r.items() if k != "grad_pallas_us")
        print(f"kernel_bwd,{r['grad_pallas_us']:.1f},{d}")
    return rows


def main(fast=False):
    # fast: chunk small enough for the CI smoke, keeping the sub-tile
    # collapse (64 < 128-token tile) and one above-tile point visible
    rows = run(chunk=2048, shard_lens=(64, 128, 512)) if fast else run()
    base = rows[-1]["model_tpu_flops_s"]
    for r in rows:
        d = (f"shard={r['shard_len']};cpu_tput={r['measured_flops_s']:.3e};"
             f"tpu_model_tput={r['model_tpu_flops_s']:.3e};"
             f"rel_model={r['model_tpu_flops_s']/base:.2f}")
        print(f"fig5_kernel_throughput,{r['us']:.1f},{d}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--bwd", action="store_true",
                    help="measure the Pallas backward kernels vs the "
                         "XLA recompute fallback")
    args = ap.parse_args()
    if args.bwd:
        main_bwd(fast=args.fast)
    else:
        main(fast=args.fast)
