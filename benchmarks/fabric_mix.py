"""Multi-tenant fabric: one shared elastic pool vs a static partition.

The acceptance experiment for DESIGN.md §10.  Two systems serve the
same tenants — packed CAD training steps plus a saturating backlog of
inference prefill/decode CA tasks — on four attention servers:

  * **partitioned**: servers {0, 1} train (slots {2, 3} drained, so the
    planner never places primary tasks there) and servers {2, 3} serve
    (``AdmissionPolicy.allowed``) — a dedicated static split expressed
    in the same admission machinery;
  * **shared**: the full pool trains (load per server halves) and serve
    traffic backfills every server's idle capacity up to the common
    step cadence.

Both run at the *same* cadence ``interval = 2 * T2`` (T2 = the
partitioned system's per-server train CA time), so training step time
is equal by construction; the shared pool converts the partition's
stranded capacity into serve throughput.  Modeled capacity ratio:
partitioned offers ``2 * interval`` idle seconds per step, shared
``4 * interval - W`` with train work ``W = 2 * T2`` — ratio 1.5.

A second phase kills one server mid-decode: both tenants must still
complete, recovery runs through the elastic runtime's path for train
and same-round re-admission for serve, the whole run replays
deterministically, and per-request serve digests are placement-
independent across all three systems (statelessness, made visible).

Emits ``fabric_mix,<us>,...`` CSV rows and returns the dict wired into
``benchmarks/run.py --json`` under ``"fabric"``.
"""
import hashlib

import numpy as np

from repro.cad import CADConfig, CADSession
from repro.core.cost_model import CommModel
from repro.fabric import AdmissionPolicy, FabricExecutor, ServeWorkload
from repro.runtime import ElasticExecutor, FaultSchedule, ServerPool

BLK = 16
D, NB = 4, 8


def _digest(x) -> str:
    return hashlib.sha1(np.ascontiguousarray(np.asarray(x))
                        .tobytes()).hexdigest()


def _make_segs(d, nb, seed=0, max_doc_blocks=4):
    rng = np.random.default_rng(seed)
    segs = np.zeros((d, nb * BLK), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < nb:
            dbl = int(rng.integers(1, min(max_doc_blocks, nb - t) + 1))
            segs[r, t * BLK:(t + dbl) * BLK] = sid
            sid += 1
            t += dbl
    return segs


def _session(drained=()):
    cfg = CADConfig(n_servers=D, blk=BLK, nb=NB, cq=2 * NB, ckv=4 * NB,
                    nkv=4 * NB)
    sess = CADSession(cfg=cfg, comm=CommModel(2, 8, 2), tolerance=0.05,
                      jmax=NB, prefetch=0)
    pool = ServerPool(D)
    for s in drained:
        pool.drain(s)
    return sess.with_pool(pool)


def _workload(arrivals, seed=7):
    return ServeWorkload(arrivals, n_heads=2, head_dim=8, blk=BLK,
                         slots=4, seed=seed)


def _train_interval() -> float:
    """``2 * T2``: twice the partitioned system's max per-server
    predicted train CA time — the common cadence of both systems (the
    extra T2 stands in for the step's linear non-CA work)."""
    ex = FabricExecutor(_session(drained=(2, 3)), _workload([(0, 2, 1)]))
    segs = _make_segs(D, NB)
    pos = np.broadcast_to(np.arange(segs.shape[1]), segs.shape).copy()
    q, k, v, pos = ex.synth_inputs(segs, pos, seed=0)
    st = ex.begin_step(0, q, k, v, pos, segs)
    return 2.0 * max(st.preds.values())


def _run(arrivals, steps, *, drained=(), allowed=None, faults=None,
         interval, seed=0, max_steps=None):
    """One mixed run; train batches repeat ``_make_segs(step)`` per
    step and continue past ``steps`` (up to ``max_steps``) until the
    serve workload drains."""
    wl = _workload(arrivals)
    ex = FabricExecutor(
        _session(drained=drained), wl,
        faults=FaultSchedule.parse(faults) if faults else None,
        policy=AdmissionPolicy(allowed=allowed))
    train_digests, reports = [], []
    step = 0
    while step < steps or (max_steps and step < max_steps
                           and not wl.all_done()):
        segs = _make_segs(D, NB, seed=step)
        pos = np.broadcast_to(np.arange(segs.shape[1]), segs.shape).copy()
        q, k, v, pos = ex.synth_inputs(segs, pos, seed=seed + step)
        out, rep = ex.run_mixed_step(step, q, k, v, pos, segs,
                                     interval=interval)
        train_digests.append(_digest(out))
        reports.append(rep)
        step += 1
    return wl, train_digests, reports


def _train_only(steps, *, drained=(), seed=0):
    """The dedicated-pool baseline: same pool, no serve tenant."""
    ex = ElasticExecutor(_session(drained=drained))
    digests = []
    for step in range(steps):
        segs = _make_segs(D, NB, seed=step)
        pos = np.broadcast_to(np.arange(segs.shape[1]), segs.shape).copy()
        q, k, v, pos = ex.synth_inputs(segs, pos, seed=seed + step)
        out, _rep = ex.run_step(step, q, k, v, pos, segs)
        digests.append(_digest(out))
    return digests


def _prefixes(a, b) -> bool:
    """Per rid, one digest list must be a prefix of the other — the
    task sequence is fixed, only how far each system got differs."""
    for rid in a:
        da, db = a[rid], b[rid]
        n = min(len(da), len(db))
        if da[:n] != db[:n]:
            return False
    return True


def run(steps=10, n_reqs=160, prompt_blocks=8, decodes=2, kill_step=2,
        victim=1):
    interval = _train_interval()
    # ---- phase 1: saturating backlog, equal cadence ------------------
    arrivals = [(0, prompt_blocks * BLK, decodes)] * n_reqs
    shared, sh_digests, sh_reps = _run(arrivals, steps,
                                       interval=interval)
    part, pt_digests, pt_reps = _run(arrivals, steps,
                                     drained=(2, 3), allowed=(2, 3),
                                     interval=interval)
    ratio = shared.tokens_executed / max(part.tokens_executed, 1)
    # equal training cadence: neither system's train step exceeds it
    train_sh = max(r.train.step_seconds for r in sh_reps)
    train_pt = max(r.train.step_seconds for r in pt_reps)
    dedicated = _train_only(steps)
    placement_independent = _prefixes(shared.digest_map(),
                                      part.digest_map())

    # ---- phase 2: kill one server mid-decode -------------------------
    karr = [(0, 4 * BLK, 3)] * 6
    ksteps = 6
    kw = dict(interval=interval, faults=f"kill:{victim}@{kill_step}",
              max_steps=40)
    k1, kd1, kr1 = _run(karr, ksteps, **kw)
    k2, kd2, kr2 = _run(karr, ksteps, **kw)
    base, bd, _br = _run(karr, ksteps, interval=interval, max_steps=40)
    kill_complete = k1.all_done() and len(kr1) >= ksteps
    kill_determ = kd1 == kd2 and k1.digest_map() == k2.digest_map() \
        and k1.completion() == k2.completion() \
        and [r.step_seconds for r in kr1] \
        == [r.step_seconds for r in kr2]
    kill_placement = _prefixes(k1.digest_map(), base.digest_map())

    return {
        "interval_us": interval * 1e6,
        "steps": steps,
        "serve_tokens_shared": shared.tokens_executed,
        "serve_tokens_partitioned": part.tokens_executed,
        "throughput_ratio": float(ratio),
        "train_step_shared_us": train_sh * 1e6,
        "train_step_partitioned_us": train_pt * 1e6,
        "equal_train_cadence": bool(train_sh <= interval * (1 + 1e-9)
                                    and train_pt <= interval
                                    * (1 + 1e-9)),
        "train_bit_identical": sh_digests == dedicated,
        "serve_placement_independent": bool(placement_independent),
        "kill_step": kill_step,
        "victim": victim,
        "kill_lost_serve": sum(r.lost_serve for r in kr1),
        "kill_readmitted": sum(r.readmitted for r in kr1),
        "kill_both_tenants_complete": bool(kill_complete),
        "kill_deterministic_replay": bool(kill_determ),
        "kill_placement_independent": bool(kill_placement),
        "pool_epoch_final": kr1[-1].pool_epoch,
    }


def main(fast=False):
    kw = dict(steps=6, n_reqs=96) if fast else {}
    r = run(**kw)
    ok = r["throughput_ratio"] >= 1.2 and r["equal_train_cadence"] \
        and r["train_bit_identical"] \
        and r["serve_placement_independent"] \
        and r["kill_both_tenants_complete"] \
        and r["kill_deterministic_replay"] \
        and r["kill_placement_independent"]
    print(f"fabric_mix,{r['interval_us']:.2f},phase=throughput;"
          f"shared_tok={r['serve_tokens_shared']};"
          f"partitioned_tok={r['serve_tokens_partitioned']};"
          f"ratio={r['throughput_ratio']:.2f}")
    print(f"fabric_mix,{r['train_step_shared_us']:.2f},phase=train;"
          f"partitioned_us={r['train_step_partitioned_us']:.2f};"
          f"equal_cadence={r['equal_train_cadence']};"
          f"bit_identical={r['train_bit_identical']}")
    print(f"fabric_mix,0.0,phase=kill;"
          f"lost={r['kill_lost_serve']};"
          f"readmitted={r['kill_readmitted']};"
          f"complete={r['kill_both_tenants_complete']};"
          f"deterministic={r['kill_deterministic_replay']}")
    print(f"fabric_mix,0.0,phase=verdict;ok={ok}")
    if not ok:
        raise RuntimeError(f"fabric mix acceptance failed: {r}")
    return r


if __name__ == "__main__":
    main()
