"""Memory pressure: plan 1M-token-class contexts into finite HBM.

The acceptance experiment for memory-aware planning + chunked KV
streaming (DESIGN.md §11).  The workload is the long-context failure
mode in miniature: one rank packs a single document spanning its whole
token span (the causal kv prefix of its final q block alone overflows
any endpoint's budget), the other ranks are nearly idle.

  * **time-only planning overflows**: planned with no budgets, the
    peak resident bytes on the busiest endpoint exceed the per-server
    HBM budget — the plan could not execute on real hardware;
  * **memory-aware planning completes**: the same workload planned
    with ``server_hbm`` budgets + ``stream_chunk`` yields an
    assignment whose resident bytes fit every budget, with the
    oversized document's kv prefix marked for chunked streaming;
  * **balance curve**: sweeping the budget from loose to tight traces
    peak-resident max/mean — the tighter the budget, the flatter the
    residency (the memory analogue of Fig. 4's load divergence); the
    tightest point must reach max/mean <= 1.15;
  * **streaming is free of numerics**: serving the memory-aware plan
    with chunked KV streaming is bit-identical to the unstreamed
    dispatch path (same flash accumulation body, carry threaded
    across chunks).

Emits ``memory_pressure,<us>,...`` CSV rows and returns the
machine-readable dict wired into ``benchmarks/run.py --json`` under
``"memory"``.
"""
import time

import jax
import numpy as np

from repro.cad.planner import get_planner
from repro.core.cost_model import CommModel, MemoryModel
from repro.core.dispatch import (CADContext, assemble_step_outputs,
                                 build_server_inputs, serve_task_batch)
from repro.core.plan import CADConfig

N_HEADS, HEAD_DIM, N_KV = 2, 16, 2


def _segs(n_ranks: int, nb: int, blk: int) -> np.ndarray:
    """Rank 0: one document spanning all ``nb`` blocks (the oversized
    long-context doc).  Every other rank: a single one-block document,
    rest padding — almost no local work, plenty of balancing headroom."""
    segs = np.zeros((n_ranks, nb * blk), np.int64)
    segs[0, :] = 1
    for r in range(1, n_ranks):
        segs[r, :blk] = 10 * r + 1
    return segs


def _peak(resident, budgets=None) -> float:
    return float(np.max(np.asarray(resident, np.float64)))


def _ratio(resident) -> float:
    r = np.asarray(resident, np.float64)
    return float(r.max() / max(r.mean(), 1e-30))


def _stream_digest(cfg: CADConfig, plan, segs, *, seed=0):
    """(streamed bytes, unstreamed bytes) of the full assembled step
    output for ``plan`` — equal iff streaming is bit-identical."""
    import jax.numpy as jnp
    D, s_len = segs.shape
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (D, s_len, N_HEADS, HEAD_DIM), jnp.float32)
    k = jax.random.normal(kk, (D, s_len, N_KV, HEAD_DIM), jnp.float32)
    v = jax.random.normal(kv, (D, s_len, N_KV, HEAD_DIM), jnp.float32)
    pos = jnp.asarray(np.where(
        segs > 0, np.arange(s_len)[None, :], -1).astype(np.int32))
    outs = {}
    for chunk in (cfg.stream_chunk, 0):
        cad = CADContext(cfg=cfg, kernel="xla")
        inputs, plans_r = build_server_inputs(cad, plan, q, k, v, pos)
        per = {s: serve_task_batch(cad, inputs[s], plans_r[s],
                                   stream_chunk=chunk)
               for s in range(cfg.n_servers)}
        outs[chunk] = np.asarray(assemble_step_outputs(
            cfg, plan, per, q.shape, q.dtype)).tobytes()
    return outs[cfg.stream_chunk], outs[0]


def run(n_ranks=4, nb=8, blk=16, stream_chunk=2, seed=0,
        budget_factors=(1.0, 0.75, 0.55)):
    comm = CommModel(N_HEADS, HEAD_DIM, N_KV)
    mem = MemoryModel(comm)
    segs = _segs(n_ranks, nb, blk)
    planner = get_planner("balanced")

    # time-only baseline: no budgets, resident bytes reported only
    cfg0 = CADConfig.default(n_ranks, nb * blk, blk=blk)
    res0 = planner(cfg0, segs, comm=comm, tolerance=0.05, mem_model=mem)
    peak0 = _peak(res0.resident_bytes)

    # the tightest budget is the even-split residency: each endpoint
    # holds its own one-block doc plus an equal share of the oversized
    # doc's q blocks and one streaming chunk of its kv — any plan that
    # fits it is residency-flat by construction, and it sits far below
    # the oversized doc's full-prefix task bytes (which forces
    # streaming)
    q_unit = mem.q_bytes(blk) + mem.residual_bytes(blk)
    share = -(-nb // n_ranks)                                # ceil
    tightest = (q_unit + mem.kv_bytes(blk)) \
        + share * q_unit + mem.kv_bytes(stream_chunk * blk)
    curve = []
    chosen = None
    for f in budget_factors:
        budget = max(tightest, f * peak0)
        cfg = CADConfig.default(n_ranks, nb * blk, blk=blk,
                                server_hbm=(budget,) * n_ranks,
                                stream_chunk=stream_chunk)
        t0 = time.perf_counter()
        res = planner(cfg, segs, comm=comm, tolerance=0.05)
        plan_us = (time.perf_counter() - t0) * 1e6
        resident = np.asarray(res.resident_bytes, np.float64)
        point = {
            "budget_factor": float(f),
            "budget_bytes": float(budget),
            "peak_resident_bytes": _peak(resident),
            "resident_max_over_mean": _ratio(resident),
            "within_budget": bool((resident <= budget + 1e-9).all()),
            "streamed_docs": len(res.streamed),
            "n_moves": int(res.stats["n_moves"]),
            "plan_us": plan_us,
        }
        curve.append(point)
        chosen = (cfg, res, point)       # tightest budget last

    cfg1, res1, tight = chosen
    sb, ub = _stream_digest(cfg1, res1.plan, segs, seed=seed)
    return {
        "n_ranks": n_ranks,
        "blocks_per_rank": nb,
        "stream_chunk": stream_chunk,
        "time_only_peak_resident": peak0,
        "budget_bytes": tight["budget_bytes"],
        "over_budget_time_only": bool(peak0 > tight["budget_bytes"]),
        "oversized_doc_streams": bool(
            mem.task_bytes(blk, nb * blk) > tight["budget_bytes"]
            and tight["streamed_docs"] >= 1),
        "peak_resident_bytes": tight["peak_resident_bytes"],
        "resident_max_over_mean": tight["resident_max_over_mean"],
        "within_budget": tight["within_budget"],
        "stream_bit_identical": bool(sb == ub),
        "curve": curve,
    }


def main(fast=False):
    kw = dict(budget_factors=(1.0, 0.55)) if fast else {}
    r = run(**kw)
    ok = r["over_budget_time_only"] and r["within_budget"] \
        and r["oversized_doc_streams"] and r["stream_bit_identical"] \
        and r["resident_max_over_mean"] <= 1.15
    print(f"memory_pressure,{r['time_only_peak_resident']:.0f},"
          f"phase=time_only;peak_resident_bytes;"
          f"ranks={r['n_ranks']};blocks={r['blocks_per_rank']}")
    for p in r["curve"]:
        print(f"memory_pressure,{p['plan_us']:.1f},"
              f"phase=curve;budget_factor={p['budget_factor']};"
              f"budget={p['budget_bytes']:.0f};"
              f"peak={p['peak_resident_bytes']:.0f};"
              f"max_over_mean={p['resident_max_over_mean']:.3f};"
              f"within={p['within_budget']};"
              f"streamed={p['streamed_docs']};moves={p['n_moves']}")
    print(f"memory_pressure,0.0,phase=verdict;"
          f"over_budget_time_only={r['over_budget_time_only']};"
          f"within_budget={r['within_budget']};"
          f"streams={r['oversized_doc_streams']};"
          f"bit_identical={r['stream_bit_identical']};"
          f"max_over_mean={r['resident_max_over_mean']:.3f};ok={ok}")
    if not ok:
        raise RuntimeError(f"memory pressure acceptance failed: {r}")
    return r


if __name__ == "__main__":
    main()
