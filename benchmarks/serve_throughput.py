"""Serving throughput: fused packed chunked prefill vs the per-token
prefill loop, plus continuous-batching decode rate (DESIGN.md §8).

The paper's core observation — attention kernels stay efficient over
fused batches of token-level shards with arbitrary lengths — applied to
serving: a 1k-token ragged prompt batch prefills in
``total / chunk_tokens`` fused ``serve_chunk_step`` calls instead of
``max_prompt_len`` per-token decode steps.  Both paths are bit-identical
(asserted here on every run — the speedup is never bought with drift),
so the measured gap is pure batching: per-call dispatch amortization and
the linear layers running over 128-512 packed rows instead of B.

  serve_prefill,<us per fused prefill>,tok_s=...;speedup_vs_loop=...
  serve_decode,<us per decode step>,steps_s=...;tok_s=...

Run: PYTHONPATH=src python -m benchmarks.serve_throughput [--fast]
"""
import argparse
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.models import model as M
from repro.parallel import ParallelContext
from repro.serve import Engine, ServeConfig

CTX = ParallelContext(attn_impl="ref", remat=False)


def _mk_engine(cfg, params, scfg, batch):
    return Engine(cfg, params, CTX, scfg, batch_size=batch)


def _time_prefill(cfg, params, scfg, prompt, mode, iters):
    # ONE engine (so the jitted chunk step stays warm across runs —
    # jax.jit caches per wrapper); prefill() resets the cache itself
    eng = _mk_engine(cfg, params, scfg, prompt.shape[0])

    def once():
        t0 = time.perf_counter()
        out = eng.prefill(prompt, mode=mode)
        jax.block_until_ready(out)
        return time.perf_counter() - t0, out
    once()                        # compile
    best, out = min((once() for _ in range(iters)), key=lambda r: r[0])
    return best, out, eng


def main(fast=False, arch="gemma2-2b", batch=8, prompt_len=128,
         new_tokens=32):
    """1k-token prompt batch (8 x 128) by default."""
    cfg = get_config(arch).reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 1, cfg.vocab_size)
    total = batch * prompt_len
    scfg = ServeConfig(max_seq=prompt_len + new_tokens + 1,
                       max_new_tokens=new_tokens, chunk_tokens=512)
    iters = 2 if fast else 3

    t_fused, lg_fused, eng_f = _time_prefill(cfg, params, scfg, prompt,
                                             "fused", iters)
    t_loop, lg_loop, eng_l = _time_prefill(cfg, params, scfg, prompt,
                                           "loop", iters)
    # parity on the FULL teacher-forced [B, P, V] logits, not just the
    # last position — the documented bit-exactness guarantee (untimed;
    # reuses the warm engines, prefill() resets their caches)
    _, full_fused = eng_f.prefill(prompt, mode="fused", return_logits=True)
    _, full_loop = eng_l.prefill(prompt, mode="loop", return_logits=True)
    exact = bool((np.asarray(full_fused) == np.asarray(full_loop)).all()) \
        and bool((np.asarray(lg_fused) == np.asarray(lg_loop)).all())
    assert exact, "fused prefill logits diverged from the per-token loop"
    speedup = t_loop / t_fused
    csv_row("serve_prefill", t_fused * 1e6,
            f"tok_s={total / t_fused:.0f};loop_tok_s={total / t_loop:.0f};"
            f"speedup_vs_loop={speedup:.1f};parity=bitwise;"
            f"batch={batch};prompt={prompt_len}")

    # decode steps/s: continuous greedy decode over the full batch
    # (reuse the warm fused engine; prefill resets its cache)
    eng = eng_f
    eng.prefill(prompt, mode="fused")
    import jax.numpy as jnp
    block_req = jnp.arange(batch, dtype=jnp.int32)
    nxt = jnp.argmax(lg_fused, -1).astype(jnp.int32)

    def step(nxt, i):
        lg, eng.cache = eng._chunk(
            eng.params, eng.cache, nxt,
            jnp.full((batch,), prompt_len + i, jnp.int32), block_req,
            jnp.full((batch,), prompt_len + i + 1, jnp.int32))
        return jnp.argmax(lg, -1).astype(jnp.int32)

    nxt = step(nxt, 0)                       # compile
    jax.block_until_ready(nxt)
    steps = 4 if fast else min(16, new_tokens - 2)
    t0 = time.perf_counter()
    for i in range(1, steps + 1):
        nxt = step(nxt, i)
    jax.block_until_ready(nxt)
    t_step = (time.perf_counter() - t0) / steps
    csv_row("serve_decode", t_step * 1e6,
            f"steps_s={1.0 / t_step:.1f};tok_s={batch / t_step:.1f};"
            f"batch={batch}")
    return {"prefill_us": t_fused * 1e6,
            "prefill_tok_s": total / t_fused,
            "loop_prefill_tok_s": total / t_loop,
            "prefill_speedup_vs_loop": speedup,
            "prefill_parity_bitwise": exact,
            "decode_us_per_step": t_step * 1e6,
            "decode_steps_s": 1.0 / t_step,
            "decode_tok_s": batch / t_step}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=128)
    args = ap.parse_args()
    main(fast=args.fast, arch=args.arch, batch=args.batch,
         prompt_len=args.prompt_len)
