"""Perf-trajectory trend table: every committed baseline, one view.

Each PR that moves a benchmark commits a ``BENCH_<n>.json`` snapshot
(the ``--gate auto`` baseline chain).  This module folds the whole
chain into one Markdown table — metric per row, one column per
snapshot oldest -> newest, plus the relative delta newest vs oldest —
so a reviewer reads the repo's performance *trajectory*, not just the
latest gate verdict.

CI appends the table to the job summary and uploads it as the
``BENCH_trend.md`` artifact next to ``BENCH_ci.json``:

  PYTHONPATH=src python -m benchmarks.trend --out BENCH_trend.md

Booleans render as ``yes``/``no`` (a ``yes -> no`` flip is exactly
what the gate fails on); numeric cells use 4 significant digits.
"""
import argparse
import glob
import json
import os
import re
import sys

from benchmarks.run import _flatten


def find_baselines(root=None):
    """[(n, path)] of committed BENCH_<n>.json snapshots, oldest
    first."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    found = []
    for p in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(p))
        if m:
            found.append((int(m.group(1)), p))
    return sorted(found)


def _cell(v) -> str:
    if isinstance(v, bool):
        return "yes" if v else "no"
    if v is None:
        return "—"
    return f"{v:.4g}"


def trend_table(baselines) -> str:
    """The Markdown trend table over [(n, path)] snapshots."""
    cols, flats = [], []
    for n, path in baselines:
        with open(path) as f:
            payload = json.load(f)
        cols.append(f"PR {n}")
        flats.append(_flatten(payload.get("results", payload)))
    metrics = sorted(set().union(*flats)) if flats else []
    lines = ["# Benchmark trend",
             "",
             f"{len(cols)} committed baseline(s): "
             + ", ".join(f"`BENCH_{n}.json`" for n, _ in baselines),
             "",
             "| metric | " + " | ".join(cols) + " | delta |",
             "|---" * (len(cols) + 2) + "|"]
    for m in metrics:
        vals = [fl.get(m) for fl in flats]
        first = next((v for v in vals if v is not None), None)
        last = next((v for v in reversed(vals) if v is not None), None)
        if isinstance(first, bool) or isinstance(last, bool):
            delta = "ok" if last or not first else "**flipped**"
        elif first is None or last is None or not first:
            delta = "—"
        else:
            delta = f"{(last / first - 1) * 100:+.1f}%"
        lines.append("| " + " | ".join([f"`{m}`"]
                                       + [_cell(v) for v in vals]
                                       + [delta]) + " |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="directory holding BENCH_<n>.json (default: "
                         "repo root)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write the table to PATH")
    args = ap.parse_args(argv)
    baselines = find_baselines(args.root)
    if not baselines:
        print("no committed BENCH_<n>.json baselines found",
              file=sys.stderr)
        return 1
    table = trend_table(baselines)
    print(table, end="")
    if args.out:
        with open(args.out, "w") as f:
            f.write(table)
    return 0


if __name__ == "__main__":
    sys.exit(main())
