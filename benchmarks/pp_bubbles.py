"""Paper Figure 10 analogue (4D parallelism): pipeline-bubble
amplification of attention imbalance, and its elimination by CAD.

In PP, each logical tick advances when the SLOWEST stage finishes its
microbatch; attention-heavy microbatches stall every other stage, and the
stalls compound over (n_micro + n_stages - 1) ticks (paper §2.2, Fig. 8).

  baseline  T = Σ_t [ lin + max_s ca(microbatch at stage s, tick t) ]
  distca    T = Σ_t [ lin + balanced-ca(tick t) ]  (real scheduler per
            tick, idle warm-up/drain stages serve CA-tasks)
"""
import numpy as np

from repro.configs import get_config
from repro.core.cost_model import (CommModel, CostModel, ICI_BW,
                                   PEAK_FLOPS_BF16, linear_flops_per_token)
from repro.core.plan import CADConfig
from repro.core.scheduler import Caps, schedule
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents
from benchmarks.e2e_sim import MFU_LINEAR, _chunks_to_segs, \
    _per_rank_ca_time


def run(arch="llama3-8b", n_stages=4, n_micro=8, tokens_mb=262144,
        max_doc=262144, n_batches=4, seed=0):
    cfg = get_config(arch)
    cm = CostModel.analytic(cfg.n_heads, cfg.head_dim)
    comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
    # per-tick linear work of one stage = layers/stages share
    lin_tick = tokens_mb * linear_flops_per_token(cfg) \
        / (MFU_LINEAR * PEAK_FLOPS_BF16) / n_stages
    rng = np.random.default_rng(seed)
    blk = BLOCK
    nb = tokens_mb // blk
    base, cad = [], []
    for _ in range(n_batches):
        lens = []
        while sum(lens) < n_micro * tokens_mb * 1.2:
            lens.extend(sample_lengths("pretrain", rng, 64,
                                       max_doc).tolist())
        chunks = pack_documents(lens, tokens_mb, n_micro, rng=rng)
        segs_mb = _chunks_to_segs(chunks, tokens_mb)
        # per-microbatch CA time (per stage share: CA splits over layers
        # too, so one stage's tick carries ca_mb / n_stages)
        home = np.zeros(nb, np.int64)
        ca_mb = np.array([
            _per_rank_ca_time(cm, segs_mb[m:m + 1], home, blk, 1)[0]
            for m in range(n_micro)]) / n_stages

        n_ticks = n_micro + n_stages - 1
        t_base = t_cad = 0.0
        for t in range(n_ticks):
            active = [t - s for s in range(n_stages)
                      if 0 <= t - s < n_micro]
            if not active:
                continue
            # baseline: tick ends when the slowest active stage ends
            t_base += lin_tick + max(ca_mb[m] for m in active)
            # CAD: schedule this tick's CA over ALL stages (idle included)
            segs_tick = np.zeros((n_stages, tokens_mb), segs_mb.dtype)
            for s in range(n_stages):
                m = t - s
                if 0 <= m < n_micro:
                    segs_tick[s] = np.where(segs_mb[m] > 0,
                                            segs_mb[m] + m * 100000, 0)
            sch = schedule(segs_tick, blk=blk, n_servers=n_stages,
                           comm=comm, caps=Caps(cq=nb, ckv=2 * nb,
                                                nkv=4 * nb),
                           tolerance=0.1)
            ca_srv = _per_rank_ca_time(cm, segs_tick, sch.assign, blk,
                                       n_stages) / n_stages
            t_comm = sch.comm_bytes * cfg.n_layers / n_stages / n_stages \
                / ICI_BW
            t_cad += max(lin_tick + float(ca_srv.max()), t_comm)
        base.append(t_base)
        cad.append(t_cad)
    return {"baseline": float(np.mean(base)),
            "distca": float(np.mean(cad))}


def main(fast=False):
    for arch, tokens in (("llama3-8b", 262144), ("llama3-34b", 131072)):
        r = run(arch=arch, tokens_mb=tokens, n_batches=2 if fast else 4)
        sp = r["baseline"] / r["distca"]
        print(f"fig10_pp,{r['distca']*1e6:.1f},arch={arch};"
              f"t_pp_baseline={r['baseline']:.3f};"
              f"t_pp_distca={r['distca']:.3f};speedup={sp:.2f}")


if __name__ == "__main__":
    main()
