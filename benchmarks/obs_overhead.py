"""Observability overhead: tracing must observe, never perturb.

The acceptance experiment for DESIGN.md §14's overhead contract:

  * the same fault-injected elastic run (kill one server mid-run)
    executes twice — once with the global recorder disabled (the
    production default) and once with tracing enabled into a live
    ring recorder + fresh metrics registry;
  * outputs must be **bit-identical**: recording writes spans and
    counters, it never touches a tensor, an RNG stream or a planning
    decision;
  * the traced run must cost < 2% extra wall time per step (full mode;
    fast mode reports the number without enforcing — CI smoke runners
    are too noisy for a 2% wall assertion);
  * the exported Chrome trace must be schema-valid (loadable by
    Perfetto: thread-name metadata, complete events with ``dur``,
    microsecond timestamps) and ``launch/trace_report.py`` must
    attribute the kill step's max to the *correct* straggler — the
    server the StepReports themselves say was slowest.

Emits ``obs_overhead,<us>,...`` CSV rows and returns the
machine-readable dict wired into ``benchmarks/run.py --json`` under
``"obs"``.
"""
import hashlib
import json
import time
import types

import numpy as np

from repro.cad import CADSession
from repro.data.pipeline import PipelineConfig, raw_batches
from repro.launch.trace_report import attribute_step, load_steps
from repro.obs import (MetricsRegistry, TraceRecorder, get_registry,
                       set_recorder, set_registry)
from repro.runtime import ElasticExecutor, FaultSchedule, ServerPool

HEADS = types.SimpleNamespace(n_heads=2, head_dim=16, n_kv_heads=2)


def _digest(x) -> str:
    return hashlib.sha1(np.ascontiguousarray(np.asarray(x))
                        .tobytes()).hexdigest()


def _batches(n_ranks, tokens_per_rank, max_doc, steps, seed):
    pipe = PipelineConfig(distribution="pretrain", max_doc_len=max_doc,
                          seq_len=tokens_per_rank, global_batch=n_ranks,
                          n_ranks=n_ranks, seed=seed)
    gen = raw_batches(pipe)
    out = []
    for _ in range(steps):
        b = next(gen)
        out.append((b["segment_ids"], b["positions"]))
    return pipe, out


def _run(pipe, batches, faults_spec, *, seed=0):
    """One elastic run under the *current* global recorder/registry.
    Returns (digests, reports, wall_seconds)."""
    session = CADSession.for_pipeline(HEADS, pipe,
                                      plan_policy="balanced", prefetch=0)
    session = session.with_pool(ServerPool(session.cfg.n_servers))
    ex = ElasticExecutor(session,
                         faults=FaultSchedule.parse(faults_spec),
                         feed_calibrator=False)
    digests, reports = [], []
    t0 = time.perf_counter()
    for step, (segs, positions) in enumerate(batches):
        q, k, v, pos = ex.synth_inputs(segs, positions, seed=seed + step)
        out, rep = ex.run_step(step, q, k, v, pos, segs)
        digests.append(_digest(out))
        reports.append(rep)
    return digests, reports, time.perf_counter() - t0


def _trace_valid(trace: dict) -> bool:
    """Perfetto-loadable: serializable, thread names declared, spans
    carry microsecond ts + dur, instants carry a scope."""
    try:
        json.dumps(trace)
    except (TypeError, ValueError):
        return False
    evs = trace.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return False
    tids = {e["tid"] for e in evs
            if e.get("ph") == "M" and e.get("name") == "thread_name"}
    if not tids:
        return False
    for e in evs:
        if e.get("ph") == "M":
            continue
        if not {"ph", "name", "pid", "tid", "ts"} <= set(e):
            return False
        if e["tid"] not in tids:
            return False
        if e["ph"] == "X" and "dur" not in e:
            return False
        if e["ph"] == "i" and e.get("s") not in ("t", "p", "g"):
            return False
    return True


def run(n_ranks=4, tokens_per_rank=2048, max_doc=1024, steps=10,
        kill_step=4, victim=1, repeats=3, seed=0):
    pipe, batches = _batches(n_ranks, tokens_per_rank, max_doc, steps,
                             seed)
    faults = f"kill:{victim}@{kill_step}"

    # alternate untraced/traced repeats so slow time-varying machine
    # drift (jit caches warming, CPU contention) cancels instead of
    # loading onto whichever phase happened to run second; best-of-N
    # mins then estimate each phase's true floor
    prev_reg = get_registry()
    rec = TraceRecorder(capacity=65536)
    set_recorder(None)
    _run(pipe, batches, faults, seed=seed)      # jit warm-up, untimed
    untraced_walls, traced_walls = [], []
    try:
        for _ in range(max(1, repeats)):
            # untraced: the production default — disabled no-op recorder
            set_recorder(None)
            set_registry(prev_reg)
            base_d, base_r, wall = _run(pipe, batches, faults, seed=seed)
            untraced_walls.append(wall)
            # traced: live ring recorder + a fresh registry
            rec.clear()
            set_recorder(rec)
            set_registry(MetricsRegistry())
            traced_d, traced_r, wall = _run(pipe, batches, faults,
                                            seed=seed)
            traced_walls.append(wall)
        trace = rec.to_chrome_trace()
        steps_traced = get_registry().counter("cad_steps_total").value()
    finally:
        set_recorder(None)
        set_registry(prev_reg)

    bit_identical = base_d == traced_d
    untraced_s = min(untraced_walls)         # best-of-N: least noise
    traced_s = min(traced_walls)
    overhead_pct = (traced_s - untraced_s) / max(untraced_s, 1e-12) * 100

    trace_valid = _trace_valid(trace)
    # straggler attribution vs ground truth: the reports' own slowest
    # server at the kill step (serve + recovery seconds)
    kill_rep = traced_r[kill_step]
    totals = {s: kill_rep.server_seconds.get(s, 0.0)
              + kill_rep.recovery_seconds.get(s, 0.0)
              for s in set(kill_rep.server_seconds)
              | set(kill_rep.recovery_seconds)}
    expect = max(sorted(totals), key=lambda s: totals[s])
    by_step = load_steps(trace)
    attr = attribute_step(by_step[kill_step]) if kill_step in by_step \
        else None
    straggler_attributed = attr is not None \
        and attr["server"] == expect \
        and abs(attr["max_seconds"] - totals[expect]) \
        <= 1e-9 + 1e-6 * totals[expect]

    return {
        "steps": steps,
        "kill_step": kill_step,
        "bit_identical": bool(bit_identical),
        "trace_valid": bool(trace_valid),
        "straggler_attributed": bool(straggler_attributed),
        "events_recorded": len(rec),
        "metric_steps_counted": steps_traced,
        "untraced_us_per_step": untraced_s / steps * 1e6,
        "traced_us_per_step": traced_s / steps * 1e6,
        "overhead_pct": float(overhead_pct),
    }


def main(fast=False):
    kw = dict(n_ranks=3, tokens_per_rank=1024, max_doc=512, steps=6,
              kill_step=2, repeats=2) if fast else {}
    r = run(**kw)
    ok = r["bit_identical"] and r["trace_valid"] \
        and r["straggler_attributed"]
    if not fast:
        # the §14 overhead contract is asserted only in full mode:
        # smoke runners are too noisy for a 2% wall-clock bound
        ok = ok and r["overhead_pct"] < 2.0
    print(f"obs_overhead,{r['traced_us_per_step']:.2f},"
          f"phase=traced;events={r['events_recorded']};"
          f"steps={r['steps']}")
    print(f"obs_overhead,{r['untraced_us_per_step']:.2f},"
          f"phase=untraced;overhead_pct={r['overhead_pct']:.2f}")
    print(f"obs_overhead,0.0,phase=verdict;"
          f"bit_identical={r['bit_identical']};"
          f"trace_valid={r['trace_valid']};"
          f"straggler_attributed={r['straggler_attributed']};ok={ok}")
    if not ok:
        raise RuntimeError(f"obs overhead acceptance failed: {r}")
    return r


if __name__ == "__main__":
    main()
