"""CAD vs ring attention: the in-repo context-parallel baseline.

The paper's headline comparison needs a real competitor, not just this
repo's own identity planner: ``ring`` is the DISTFLASHATTN-style
context-parallel schedule (DESIGN.md §13) — every document cut into P
contiguous kv shards, q blocks rotating through P ring passes, partials
merged by online softmax.  This benchmark quantifies the structural
difference at long context (128k–512k tokens global) on two workloads:

  * **dense-causal straggler**: every rank packs one document spanning
    its whole token span.  Ring's tail-shard endpoint owns the deepest
    q blocks of *every* document — its causal compute grows
    quadratically with shard index, so ring's live compute max/mean
    approaches ``(2P-1)/P`` (~1.9 at P=8) while CAD's balanced planner
    stays within 1.1;
  * **doc-masked (sliding+sink)**: the window bounds every block's live
    kv, flattening ring's tail-shard quadratic — the regime where ring
    is a *good* baseline.  CAD must still match or beat it.

Balance is measured by one independent live-block repricing
(``block_costs``) of both layouts, never by what either planner
believed.  Modeled step time honors the schedules' different
synchronization structure: ring has a barrier per pass (stragglers
stall every rotation), so ring time is ``sum over passes of the
per-pass max`` (``ring_pass_costs``), while CAD's single fused serve is
``max over servers of total``.  Plans are costed with
``build_plan=False``: at P=8 the ring layout needs kv-prefix capacity
beyond the standard ``nkv = 4*nb`` geometry, and the comparison is
about schedule shape, not dispatch-array construction.

Emits ``cad_vs_ring,<us>,...`` CSV rows and returns the
machine-readable dict wired into ``benchmarks/run.py --json`` under
``"ring"``.
"""
import time

import numpy as np

from repro.cad.planner import get_planner
from repro.core.mask import MaskSpec
from repro.core.plan import CADConfig
from repro.core.scheduler import (block_costs, layout_from_segments,
                                  ring_pass_costs)


def _segs(n_ranks: int, nb: int, blk: int) -> np.ndarray:
    """One document per rank spanning the rank's whole token span —
    the straggler workload: every document's tail shard lands on the
    same ring endpoint."""
    segs = np.zeros((n_ranks, nb * blk), np.int64)
    for r in range(n_ranks):
        segs[r, :] = r + 1
    return segs


def _loads(assign, cost, doc_of, n_ranks) -> np.ndarray:
    live = doc_of >= 0
    loads = np.zeros(n_ranks)
    np.add.at(loads, np.asarray(assign)[live].astype(np.int64),
              cost[live])
    return loads


def _ratio(loads) -> float:
    loads = np.asarray(loads, np.float64)
    return float(loads.max() / max(loads.mean(), 1e-30))


def _one(cfg, segs, spec, tolerance):
    n_ranks = cfg.n_servers
    docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk, n_ranks)
    cost = block_costs(doc_of, bi_of, cfg.blk, None, spec)

    t0 = time.perf_counter()
    cad = get_planner("balanced")(cfg, segs, comm=None,
                                  tolerance=tolerance, build_plan=False,
                                  mask=spec)
    cad_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    ring = get_planner("ring")(cfg, segs, comm=None, build_plan=False,
                               mask=spec)
    ring_us = (time.perf_counter() - t0) * 1e6

    cad_loads = _loads(cad.assign, cost, doc_of, n_ranks)
    ring_loads = _loads(ring.assign, cost, doc_of, n_ranks)
    table = ring_pass_costs(docs, cfg.blk, n_ranks, mask=spec)
    # pass decomposition conserves work exactly
    np.testing.assert_allclose(table.sum(axis=0), ring_loads, rtol=1e-9)

    cad_step = float(cad_loads.max())          # one fused serve
    ring_step = float(table.max(axis=1).sum())  # barrier per ring pass
    return {
        "cad_max_over_mean": _ratio(cad_loads),
        "ring_max_over_mean": _ratio(ring_loads),
        "ring_over_cad_balance": _ratio(ring_loads) / _ratio(cad_loads),
        "ring_step_over_cad_step": ring_step / max(cad_step, 1e-30),
        "plan_us": {"cad": cad_us, "ring": ring_us},
    }


def run(contexts=(131072, 262144, 524288), n_ranks=8, blk=128,
        window_blocks=2, sink_blocks=1, tolerance=0.05):
    spec = MaskSpec(kind="sliding", window=window_blocks * blk,
                    sink=sink_blocks * blk)
    curve = []
    for ctx in contexts:
        nb = ctx // n_ranks // blk
        cfg = CADConfig(n_servers=n_ranks, blk=blk, nb=nb, cq=nb,
                        ckv=2 * nb, nkv=4 * nb)
        segs = _segs(n_ranks, nb, blk)
        point = {"context_tokens": int(ctx),
                 "dense": _one(cfg, segs, None, tolerance),
                 "masked": _one(cfg, segs, spec, tolerance)}
        curve.append(point)
    top = curve[-1]                           # largest context decides
    return {
        "n_ranks": n_ranks,
        "blk": blk,
        "mask": spec.describe(),
        "contexts": [p["context_tokens"] for p in curve],
        "curve": curve,
        "dense": top["dense"],
        "masked": top["masked"],
        "cad_beats_ring_balance": bool(
            top["dense"]["cad_max_over_mean"]
            < top["dense"]["ring_max_over_mean"]),
        "cad_within_1_1": bool(top["dense"]["cad_max_over_mean"] <= 1.1),
        "ring_step_not_faster": bool(
            top["dense"]["ring_step_over_cad_step"] >= 1.0),
        "masked_cad_not_worse": bool(
            top["masked"]["cad_max_over_mean"]
            <= top["masked"]["ring_max_over_mean"] + 1e-9),
    }


def main(fast=False):
    # planning-only (build_plan=False): even 512k runs in well under a
    # second; fast mode keeps 128k for the CI smoke
    r = run(contexts=(131072,) if fast else (131072, 262144, 524288))
    ok = r["cad_beats_ring_balance"] and r["cad_within_1_1"] \
        and r["ring_step_not_faster"] and r["masked_cad_not_worse"]
    for p in r["curve"]:
        for wl in ("dense", "masked"):
            m = p[wl]
            print(f"cad_vs_ring,{m['plan_us']['ring']:.1f},"
                  f"workload={wl};context={p['context_tokens']};"
                  f"cad_max_over_mean={m['cad_max_over_mean']:.3f};"
                  f"ring_max_over_mean={m['ring_max_over_mean']:.3f};"
                  f"ring_step_over_cad={m['ring_step_over_cad_step']:.3f}")
    print(f"cad_vs_ring,0.0,phase=verdict;"
          f"cad={r['dense']['cad_max_over_mean']:.3f}(<=1.1:"
          f"{r['cad_within_1_1']});"
          f"ring={r['dense']['ring_max_over_mean']:.3f};"
          f"cad_beats_ring={r['cad_beats_ring_balance']};ok={ok}")
    if not ok:
        raise RuntimeError(f"cad vs ring acceptance failed: {r}")
    return r


if __name__ == "__main__":
    main()
