"""Paper Figures 9/10 analogue: end-to-end training throughput, DistCA vs
baselines, on the cost model calibrated to TPU v5e.

For each (model, MaxDocLen) config the simulator samples 30 batches
(paper §6.1), packs them, and computes the per-iteration time under:

  fixed-DP     fixed-size packing, CA computed where it lands
  wlb          WLB-LLM-style: best of (variable-length chunking, per-doc
               CP at swept degrees) — the paper's "WLB-ideal"
  distca       CAD with the real greedy scheduler + ping-pong overlap

Iteration time model (per rank r):
  linear(r)  = tokens_r * linear_flops_per_token / (mfu * peak)
  ca(r)      = predicted CA time of the blocks r computes (cost model)
  comm       = bytes moved / ICI_BW   (CAD: overlapped -> max(., .))
  T_iter     = max_r (linear(r) + ca(r)) (+ comm if not hidden)

The CAD rows run the real plan policies through the repro.cad registry
("balanced" = the paper's greedy scheduler) — this benchmark exercises
the actual system component, not a re-derivation.
"""
import numpy as np

from repro.cad import get_planner
from repro.configs import get_config
from repro.core.cost_model import (CommModel, CostModel, ICI_BW,
                                   PEAK_FLOPS_BF16, ca_flops,
                                   linear_flops_per_token)
from repro.core.plan import CADConfig
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents

MFU_LINEAR = 0.5


def _chunks_to_segs(chunks, seq_len):
    return np.stack([c.segment_ids for c in chunks])


def _ca_time_of_blocks(cm, bi_counts, blk):
    """Predicted CA time for a set of blocks given as per-block context
    lengths (bi+1)*blk."""
    t = 0.0
    for ctx_blocks, cnt in bi_counts.items():
        t += cnt * float(cm.predict(blk, ctx_blocks * blk))
    return t


def _per_rank_ca_time(cm, segs, assign, blk, n):
    """Time per server given block assignment (vectorized)."""
    from repro.core.scheduler import layout_from_segments
    docs, doc_of, bi_of = layout_from_segments(segs, blk, n)
    live = doc_of >= 0
    t_block = np.zeros(len(doc_of))
    t_block[live] = cm.predict(blk, (bi_of[live] + 1) * blk)
    times = np.zeros(n)
    np.add.at(times, assign[live].astype(np.int64), t_block[live])
    return times


def simulate(arch, max_doc, n_ranks, tokens_per_rank, n_batches=8,
             dist="pretrain", tolerance=0.1, seed=0,
             plan_policy="balanced"):
    cfg = get_config(arch)
    cm = CostModel.analytic(cfg.n_heads, cfg.head_dim,
                            peak_flops=PEAK_FLOPS_BF16)
    comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
    lin_per_tok = linear_flops_per_token(cfg) / (MFU_LINEAR
                                                 * PEAK_FLOPS_BF16)
    rng = np.random.default_rng(seed)
    blk = BLOCK
    res = {"fixed": [], "wlb": [], "distca": [], "distca_noover": []}
    for _ in range(n_batches):
        need = n_ranks * tokens_per_rank
        lens = []
        while sum(lens) < need * 1.2:
            lens.extend(sample_lengths(dist, rng, 64, max_doc).tolist())

        # ---- fixed packing
        fixed = pack_documents(lens, tokens_per_rank, n_ranks, rng=rng,
                               strategy="fixed")
        segs = _chunks_to_segs(fixed, tokens_per_rank)
        nb = tokens_per_rank // blk
        home = (np.arange(n_ranks * nb) // nb)
        ca_fixed = _per_rank_ca_time(cm, segs, home, blk, n_ranks)
        lin = tokens_per_rank * lin_per_tok
        res["fixed"].append(float((lin + ca_fixed).max()))

        # ---- WLB-ideal: variable-length chunking (memory-capped) OR
        # per-doc CP; take the best (paper sweeps DP-CP configs)
        var = pack_documents(lens, tokens_per_rank, n_ranks, rng=rng,
                             strategy="variable")
        vsegs = _chunks_to_segs(var, tokens_per_rank)
        ca_var = _per_rank_ca_time(cm, vsegs, home, blk, n_ranks)
        lin_var = np.array([(c.segment_ids > 0).sum() * lin_per_tok
                            for c in var])
        t_var = float((lin_var + ca_var).max())
        # per-doc CP: balanced CA but all-gather of all KV per rank + tile
        # waste on short docs (shards < 128 pad to the tile)
        total_ca = ca_fixed.sum()
        shard_waste = 0.0
        for c in fixed:
            for dl in c.doc_lengths:
                sh = dl / (2 * n_ranks)
                if sh < blk:
                    shard_waste += 1.0  # one wasted tile per shard approx
        # CP all-gathers KV on EVERY layer, fwd + bwd (§3.2 Fig. 3a)
        kv_bytes = (n_ranks * tokens_per_rank) * comm.size_kv \
            * cfg.n_layers * 3
        t_cp = total_ca / n_ranks * (1 + 0.1) \
            + shard_waste * float(cm.predict(blk, blk)) \
            + kv_bytes / n_ranks / ICI_BW
        res["wlb"].append(min(t_var, lin + t_cp))

        # ---- DistCA: the registered plan policy (default: the real
        # greedy scheduler), overlap per ping-pong.  The plan's q/kv
        # transfers recur on EVERY layer, fwd + bwd (~3x fwd volume).
        cadcfg = CADConfig(n_servers=n_ranks, blk=blk, nb=nb, cq=nb,
                           ckv=2 * nb, nkv=4 * nb)
        pres = get_planner(plan_policy)(cadcfg, segs, comm=comm,
                                        tolerance=tolerance,
                                        build_plan=False)
        ca_cad = _per_rank_ca_time(cm, segs, pres.assign, blk, n_ranks)
        t_comm = pres.stats["comm_bytes"] * cfg.n_layers * 3 \
            / n_ranks / ICI_BW
        compute = float((lin + ca_cad).max())
        res["distca"].append(max(compute, t_comm))       # ping-pong hides
        res["distca_noover"].append(compute + t_comm)    # single stream
    return {k: float(np.mean(v)) for k, v in res.items()}


# (arch, MaxDocLen, DP ranks, tokens per rank) — the paper's regime:
# chunk size == MaxDocLen so one rank can hold a single max-length doc
CONFIGS = [
    ("llama3-8b", 256 * 1024, 8, 262144),
    ("llama3-8b", 512 * 1024, 8, 524288),
    ("llama3-34b", 256 * 1024, 8, 262144),
    ("llama3-34b", 512 * 1024, 8, 524288),
]


def main(fast=False):
    confs = CONFIGS[:2] if fast else CONFIGS
    for arch, max_doc, n, tpr in confs:
        for dist in ("pretrain", "prolong"):
            r = simulate(arch, max_doc, n, tpr, dist=dist,
                         n_batches=3 if fast else 8)
            sp_fixed = r["fixed"] / r["distca"]
            sp_wlb = r["wlb"] / r["distca"]
            d = (f"arch={arch};maxdoc={max_doc};dist={dist};"
                 f"t_fixed={r['fixed']:.4f};t_wlb={r['wlb']:.4f};"
                 f"t_distca={r['distca']:.4f};"
                 f"speedup_vs_fixed={sp_fixed:.2f};"
                 f"speedup_vs_wlb={sp_wlb:.2f}")
            print(f"fig9_e2e,{r['distca']*1e6:.1f},{d}")


if __name__ == "__main__":
    main()
