"""Benchmark driver — one benchmark per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (benchmarks/common.py format).

  table1_scaling        Table 1   — CA quadratic vs linear scaling
  fig4_imbalance        Fig. 1/4  — packing-induced load/memory divergence
  fig5_kernel_tput      Fig. 5    — CA throughput vs shard length
  kernel_bwd            §Perf     — Pallas bwd kernels vs XLA recompute
  fig9_e2e              Fig. 9/10 — DistCA vs fixed/WLB throughput
  fig11_overlap         Fig. 11   — ping-pong communication hiding
  fig12_tolerance       Fig. 12   — tolerance factor sweep (real scheduler)
  sched_microbench      §4.2      — scheduler wall-time per batch
  prefetch_microbench   §4.2      — async plan prefetch vs inline planning
  straggler_elim        §4.2/D§3  — runtime calibration on a pool with an
                                    injected 0.5x server: measured
                                    max/mean per-server compute,
                                    calibrated vs uncalibrated
  serve_throughput      DESIGN §8 — fused chunked prefill vs per-token
                                    loop + continuous-batching decode rate
  elastic_recovery      DESIGN §9 — kill one of N servers mid-run:
                                    recovery sub-plan outputs bit-identical
                                    to a fault-free (N-1)-pool run,
                                    deterministic seeded replay,
                                    steady-state within 10% of baseline
  fabric_mix            DESIGN §10 — multi-tenant fabric: shared pool vs
                                    static partition at equal training
                                    cadence; serve throughput ratio,
                                    train bit-identity, kill-mid-decode
                                    recovery of both tenants
  sparse_balance        DESIGN §12 — mask-structured task shapes: on a
                                    doc-masked (sliding+sink) workload,
                                    live-block-priced planning reaches
                                    <=1.1 compute max/mean where
                                    area-priced planning exceeds 1.4
  cad_vs_ring           DESIGN §13 — CAD vs the in-repo ring/context-
                                    parallel baseline at 128k-512k:
                                    live-compute max/mean and modeled
                                    step time (barrier-per-pass ring vs
                                    one fused serve), dense-causal and
                                    doc-masked workloads
  obs_overhead          DESIGN §14 — tracing observes, never perturbs:
                                    traced vs untraced fault-injected
                                    elastic run, bit-identical outputs,
                                    <2% wall overhead (full mode),
                                    Perfetto-valid trace, trace_report
                                    attributes the right straggler
  memory_pressure       DESIGN §11 — memory-aware planning + chunked KV
                                    streaming: a workload whose kv
                                    prefix overflows any endpoint
                                    completes within per-server HBM
                                    budgets, residency max/mean curve,
                                    streamed == unstreamed bitwise

Run: PYTHONPATH=src python -m benchmarks.run [--fast] [--json PATH]
                                             [--gate BASELINE.json|auto]

``--json PATH`` additionally writes the machine-readable results the CI
perf-trajectory artifact is built from (kernel fwd/bwd us, packing plan
imbalance, prefetch overlap) plus environment metadata.

``--gate BASELINE.json`` compares this run's results against a
committed baseline snapshot: deterministic modeled ratios must stay
within 15% of the baseline, boolean acceptance checks must not flip
false, and (with ``--gate-times``) wall-clock metrics must not regress
past a generous noise allowance.  A gate failure exits non-zero.
``--gate auto`` resolves the baseline to the newest committed
``BENCH_<n>.json`` in the repo root, so the CI gate follows the
perf trajectory without a workflow edit per PR.
"""
import argparse
import glob
import json
import os
import platform
import re
import sys
import time
import traceback

import numpy as np


def sched_microbench(fast=False):
    """Scheduler wall time — it must keep up with training steps (the
    paper prefetches the next batch's plan on CPU)."""
    from repro.configs import get_config
    from repro.core.cost_model import CommModel
    from repro.core.scheduler import Caps, schedule
    from repro.data.distributions import sample_lengths
    from repro.data.packing import BLOCK, pack_documents
    from benchmarks.e2e_sim import _chunks_to_segs
    cfg = get_config("llama3-8b")
    comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
    rng = np.random.default_rng(0)
    for n_ranks, tpr in ((8, 65536), (16, 65536)):
        nb = tpr // BLOCK
        lens = []
        while sum(lens) < n_ranks * tpr * 1.2:
            lens.extend(sample_lengths("pretrain", rng, 64,
                                       65536).tolist())
        segs = _chunks_to_segs(
            pack_documents(lens, tpr, n_ranks, rng=rng), tpr)
        t0 = time.perf_counter()
        iters = 1 if fast else 3
        for _ in range(iters):
            sch = schedule(segs, blk=BLOCK, n_servers=n_ranks, comm=comm,
                           caps=Caps(cq=nb, ckv=2 * nb, nkv=4 * nb),
                           tolerance=0.1)
        us = (time.perf_counter() - t0) / iters * 1e6
        print(f"sched_microbench,{us:.1f},ranks={n_ranks};"
              f"blocks={n_ranks*nb};moves={sch.n_moves}")


def prefetch_microbench(fast=False):
    """CADSession async plan prefetch: step-loop wall time with the
    scheduler planning batch i+1 on a background thread while "the
    device" (a sleep stand-in; XLA releases the GIL the same way)
    computes batch i, vs planning inline every step."""
    from repro.cad import CADSession
    from repro.configs import get_config
    from repro.data.pipeline import PipelineConfig, raw_batches

    cfg = get_config("llama3-8b")
    n_ranks, seq = 8, 16384
    steps = 4 if fast else 10
    pipe = PipelineConfig(distribution="pretrain", max_doc_len=seq,
                          seq_len=seq, global_batch=n_ranks,
                          n_ranks=n_ranks, seed=0)
    session = CADSession.for_pipeline(cfg, pipe)
    # calibrate the simulated device step to one planning call, the
    # regime where hiding the scheduler matters most
    gen0 = raw_batches(pipe)
    b0 = next(gen0)
    t0 = time.perf_counter()
    session.plan_batch(b0)
    compute_s = max(time.perf_counter() - t0, 0.02)

    walls = {}
    for mode, depth in (("sync", 0), ("async", 2)):
        gen = session.attach_plans(raw_batches(pipe), prefetch=depth)
        t0 = time.perf_counter()
        for _ in range(steps):
            next(gen)
            time.sleep(compute_s)    # device step stand-in
        walls[mode] = time.perf_counter() - t0
        gen.close()
        print(f"prefetch_microbench,{walls[mode]/steps*1e6:.1f},"
              f"mode={mode};steps={steps};ranks={n_ranks};"
              f"compute_ms={compute_s*1e3:.1f}")
    overlap = walls["sync"] / max(walls["async"], 1e-9)
    print(f"prefetch_microbench,{walls['async']/steps*1e6:.1f},"
          f"mode=speedup;sync_over_async={overlap:.2f}")
    return {"sync_us_per_step": walls["sync"] / steps * 1e6,
            "async_us_per_step": walls["async"] / steps * 1e6,
            "sync_over_async": overlap}


# --------------------------------------------------------------- gate
# (path regex, direction, threshold, needs --gate-times).
# "lower" = metric must not rise past base*(1+thr); "higher" = must not
# fall below base*(1-thr); "lower_abs" = must not exceed base+thr (an
# absolute delta — for metrics like overhead percentages whose baseline
# sits near zero, where relative bounds degenerate).  Deterministic
# modeled ratios gate at 15%; wall-clock-derived ratios get generous
# noise allowances; raw *_us timings only gate under --gate-times (CI
# runners are too noisy).
GATE_RULES = (
    (r"^obs\.overhead_pct$", "lower_abs", 2.0, True),
    (r"^fabric\.throughput_ratio$", "higher", 0.15, False),
    (r"^elastic\.steady_ratio$", "lower", 0.15, False),
    (r"^straggler\.(calibrated|declared)_max_over_mean$",
     "lower", 0.15, False),
    (r"^plan_imbalance\.\d+\.(attn|mem)_divergence_wlb$",
     "lower", 0.15, False),
    (r"^prefetch\.sync_over_async$", "higher", 0.40, False),
    (r"^serve\.prefill_speedup_vs_loop$", "higher", 0.50, False),
    (r"^sparse\.live_max_over_mean$", "lower", 0.15, False),
    (r"^sparse\.area_max_over_mean$", "higher", 0.15, False),
    (r"^ring\.dense\.ring_over_cad_balance$", "higher", 0.15, False),
    (r"^ring\.dense\.cad_max_over_mean$", "lower", 0.15, False),
    (r"^ring\.dense\.ring_step_over_cad_step$", "higher", 0.15, False),
    (r"^memory\.resident_max_over_mean$", "lower", 0.15, False),
    (r"^memory\.curve\.\d+\.resident_max_over_mean$",
     "lower", 0.15, False),
    (r"_us(_per_step|_per_call)?$", "lower", 0.50, True),
)


def resolve_gate(arg: str) -> str:
    """``auto`` -> the newest committed ``BENCH_<n>.json`` baseline in
    the repo root; any other value passes through as a path."""
    if arg != "auto":
        return arg
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    found = []
    for p in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(p))
        if m:
            found.append((int(m.group(1)), p))
    if not found:
        raise SystemExit("--gate auto: no committed BENCH_<n>.json "
                         f"baseline under {root}")
    return max(found)[1]


def _flatten(obj, prefix=""):
    """{path: scalar} over nested dicts/lists (numbers and bools)."""
    out = {}
    if isinstance(obj, dict):
        items = obj.items()
    elif isinstance(obj, (list, tuple)):
        items = enumerate(obj)
    else:
        if isinstance(obj, (bool, int, float)) and not (
                isinstance(obj, float) and np.isnan(obj)):
            out[prefix] = obj
        return out
    for k, v in items:
        p = f"{prefix}.{k}" if prefix else str(k)
        out.update(_flatten(v, p))
    return out


def check_gate(baseline_results, results, *, gate_times=False):
    """Regression failures of ``results`` vs the committed baseline.
    Returns a list of human-readable failure strings (empty = pass)."""
    base = _flatten(baseline_results)
    cur = _flatten(results)
    fails = []
    for path, bval in sorted(base.items()):
        # benchmarks absent from this run (--only, bench error -> its
        # own failure) are not gate regressions
        if path.split(".")[0] not in results:
            continue
        if isinstance(bval, bool):
            if bval and cur.get(path) is False:
                fails.append(f"{path}: acceptance flipped true -> false")
            continue
        for pat, direction, thr, needs_times in GATE_RULES:
            if not re.search(pat, path):
                continue
            if needs_times and not gate_times:
                break
            cval = cur.get(path)
            if cval is None:
                fails.append(f"{path}: metric disappeared "
                             f"(baseline {bval:.4g})")
            elif direction == "lower_abs" and cval > bval + thr:
                fails.append(f"{path}: {bval:.4g} -> {cval:.4g} "
                             f"(+{cval - bval:.2f} absolute, "
                             f"limit +{thr:.2f})")
            elif direction == "lower" and cval > bval * (1 + thr) \
                    and cval - bval > 1e-12:
                fails.append(f"{path}: {bval:.4g} -> {cval:.4g} "
                             f"(+{(cval / bval - 1) * 100:.0f}%, "
                             f"limit +{thr * 100:.0f}%)")
            elif direction == "higher" and cval < bval * (1 - thr):
                fails.append(f"{path}: {bval:.4g} -> {cval:.4g} "
                             f"(-{(1 - cval / bval) * 100:.0f}%, "
                             f"limit -{thr * 100:.0f}%)")
            break                      # first matching rule wins
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (BENCH_ci.json)")
    ap.add_argument("--gate", default=None, metavar="BASELINE",
                    help="fail if results regress vs this baseline "
                         "snapshot; 'auto' picks the newest committed "
                         "BENCH_<n>.json")
    ap.add_argument("--gate-times", action="store_true",
                    help="also gate wall-clock *_us metrics (noisy; "
                         "off by default)")
    args = ap.parse_args()

    from benchmarks import (cad_vs_ring, cp_overheads, dedicated_pool,
                            e2e_sim, elastic_recovery, fabric_mix,
                            imbalance, kernel_throughput,
                            memory_pressure, obs_overhead, overlap,
                            pp_bubbles, serve_throughput, sparse_balance,
                            straggler_elim, table1_scaling,
                            tolerance_sweep)
    benches = {
        "table1": table1_scaling.main,
        "fig3": cp_overheads.main,
        "fig4": lambda: imbalance.main(fast=args.fast),
        "fig5": lambda: kernel_throughput.main(fast=args.fast),
        "kernel_bwd": lambda: kernel_throughput.main_bwd(fast=args.fast),
        "fig9": lambda: e2e_sim.main(fast=args.fast),
        "fig10": lambda: pp_bubbles.main(fast=args.fast),
        "fig11": lambda: overlap.main(fast=args.fast),
        "fig12": lambda: tolerance_sweep.main(fast=args.fast),
        "sched": lambda: sched_microbench(fast=args.fast),
        "prefetch": lambda: prefetch_microbench(fast=args.fast),
        "straggler": lambda: straggler_elim.main(fast=args.fast),
        "dedicated": dedicated_pool.main,
        "serve": lambda: serve_throughput.main(fast=args.fast),
        "elastic": lambda: elastic_recovery.main(fast=args.fast),
        "fabric": lambda: fabric_mix.main(fast=args.fast),
        "memory": lambda: memory_pressure.main(fast=args.fast),
        "obs": lambda: obs_overhead.main(fast=args.fast),
        "sparse": lambda: sparse_balance.main(fast=args.fast),
        "ring": lambda: cad_vs_ring.main(fast=args.fast),
    }
    # the machine-readable subset: kernel fwd/bwd, plan imbalance,
    # prefetch overlap, straggler elimination, serve throughput,
    # elastic recovery, fabric mix, memory pressure — the CI perf
    # trajectory
    json_keys = ("fig5", "kernel_bwd", "fig4", "prefetch", "straggler",
                 "serve", "elastic", "fabric", "memory", "sparse",
                 "ring", "obs")
    results, failed = {}, 0
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        try:
            out = fn()
            if out is not None and name in json_keys:
                results[name.replace("fig5", "kernel_fwd")
                        .replace("fig4", "plan_imbalance")] = out
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},nan,ERROR")
    if args.json:
        import jax
        payload = {
            "meta": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "python": platform.python_version(),
                "platform": platform.platform(),
                "fast": args.fast,
                "failed_benchmarks": failed,
            },
            "results": results,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"json_results,{len(results)},path={args.json}")
    if args.gate:
        gate_path = resolve_gate(args.gate)
        with open(gate_path) as f:
            baseline = json.load(f)
        fails = check_gate(baseline.get("results", baseline), results,
                           gate_times=args.gate_times)
        for msg in fails:
            print(f"gate_regression,nan,{msg}")
        print(f"gate,{len(fails)},baseline={gate_path};"
              f"checked={'times+ratios' if args.gate_times else 'ratios'}")
        failed += len(fails)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
