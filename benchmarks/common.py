"""Shared benchmark utilities."""
import time

import numpy as np


def time_call(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        r = fn(*args)
    _block(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    _block(r)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _block(r):
    try:
        import jax
        jax.block_until_ready(r)
    except Exception:
        pass


def csv_row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
