"""Straggler elimination on a heterogeneous pool via runtime
calibration (DESIGN.md §3; the paper's §4.2 profiler closed-loop).

One server in the pool runs at ``slow_factor``x speed (an injected
hardware straggler — a thermally-throttled chip, a slow host, a noisy
neighbor).  The simulated hardware is also uniformly ``hw_scale``x
slower than the analytic roofline model, so the calibrator has to learn
both the absolute grid and the relative speeds from measurements; the
"timers" report exactly what a per-server kernel timer would: the
ground-truth latency model evaluated on each server's assigned tasks,
divided by that server's true speed.

Four per-step policies on identical packed batches:

  identity      CA computed where packed (no disaggregation)
  uncalibrated  the balanced greedy scheduler, FLOPs-equalizing —
                blind to the slow server, so its *time* is ~2x the mean
  declared      balanced with the true speeds passed statically
                (``server_speeds``) — the known-heterogeneity ceiling
  calibrated    the full measure -> fit -> replan loop through
                ``CADSession``/``GridCalibrator``: batch i+1 is planned
                from batch i's measured costs, speeds start unknown

Metric: measured per-server compute time max/mean (straggler overhang
+ 1), averaged over the trailing half of the run (the calibrated row's
first steps are its convergence transient).  The headline claim — the
regression test pins it — is calibrated <= 1.1 while uncalibrated
stays > 1.4 with a 0.5x server in the pool.
"""
import numpy as np

from repro.cad import CADSession, GridCalibrator, get_planner
from repro.configs import get_config
from repro.core import iter_plan_tasks
from repro.core.cost_model import CommModel, CostModel
from repro.core.plan import CADConfig
from repro.core.scheduler import layout_from_segments
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents
# the benchmark measures what would actually execute, not the
# scheduler's claim: recover the assignment from the dispatch arrays
from repro.runtime import assignment_of_plan


def _measured_times(truth: CostModel, speeds: np.ndarray,
                    assign: np.ndarray, doc_of: np.ndarray,
                    bi_of: np.ndarray, blk: int,
                    n_servers: int) -> np.ndarray:
    """Ground-truth per-server compute time of an assignment."""
    live = doc_of >= 0
    t_block = np.zeros(len(doc_of))
    t_block[live] = truth.predict(blk, (bi_of[live] + 1) * blk)
    per_server = np.zeros(n_servers)
    srv = assign[live].astype(np.int64)
    np.add.at(per_server, srv, t_block[live] / speeds[srv])
    return per_server


def run(arch="llama3-8b", n_ranks=8, tokens_per_rank=65536,
        max_doc=32768, slow_server=0, slow_factor=0.5, hw_scale=2.0,
        steps=10, tolerance=0.02, seed=0, dist="pretrain"):
    cfg = get_config(arch)
    comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
    blk = BLOCK
    nb = tokens_per_rank // blk
    cadcfg = CADConfig(n_servers=n_ranks, blk=blk, nb=nb, cq=2 * nb,
                       ckv=2 * nb, nkv=4 * nb)
    true_speeds = np.ones(n_ranks)
    true_speeds[slow_server] = slow_factor
    truth = CostModel.analytic(cfg.n_heads, cfg.head_dim) \
        .scaled(hw_scale)

    session = CADSession(
        cfg=cadcfg, comm=comm, tolerance=tolerance,
        plan_policy="balanced", prefetch=0,
        calibrator=GridCalibrator(
            CostModel.analytic(cfg.n_heads, cfg.head_dim), n_ranks))

    balanced = get_planner("balanced")
    identity = get_planner("identity")
    rng = np.random.default_rng(seed)
    rows = {k: [] for k in ("identity", "uncalibrated", "declared",
                            "calibrated")}
    for step in range(steps):
        lens = []
        while sum(lens) < n_ranks * tokens_per_rank * 1.2:
            lens.extend(sample_lengths(dist, rng, 64, max_doc).tolist())
        segs = np.stack([c.segment_ids for c in pack_documents(
            lens, tokens_per_rank, n_ranks, rng=rng)])
        docs, doc_of, bi_of = layout_from_segments(segs, blk, n_ranks)

        def max_over_mean(assign):
            t = _measured_times(truth, true_speeds, assign, doc_of,
                                bi_of, blk, n_ranks)
            return float(t.max() / t.mean())

        rows["identity"].append(max_over_mean(
            identity(cadcfg, segs, comm=comm, build_plan=False).assign))
        rows["uncalibrated"].append(max_over_mean(
            balanced(cadcfg, segs, comm=comm, tolerance=tolerance,
                     build_plan=False).assign))
        rows["declared"].append(max_over_mean(
            balanced(cadcfg, segs, comm=comm, tolerance=tolerance,
                     build_plan=False, speeds=true_speeds).assign))

        # the closed loop: plan from the current snapshot, "execute",
        # feed the per-task timings back for the next step's plan
        plan, _stats = session.plan(segs)
        rows["calibrated"].append(max_over_mean(
            assignment_of_plan(cadcfg, plan)))
        for s, _slot, qt, kvt in iter_plan_tasks(cadcfg, plan):
            session.observe(qt, kvt,
                            float(truth.predict(qt, kvt))
                            / true_speeds[s], server=s)

    tail = slice(steps // 2, None)      # calibrated convergence transient
    out = {f"{k}_max_over_mean": float(np.mean(v[tail]))
           for k, v in rows.items()}
    out["calibrated_first_step"] = rows["calibrated"][0]
    out["estimated_speeds"] = [float(s)
                               for s in session.calibrator.speeds()]
    out["true_speeds"] = true_speeds.tolist()
    out["n_ranks"] = n_ranks
    out["slow_factor"] = slow_factor
    return out


def main(fast=False):
    kw = dict(n_ranks=4, tokens_per_rank=16384, max_doc=8192, steps=8) \
        if fast else {}
    r = run(**kw)
    for k in ("identity", "uncalibrated", "declared", "calibrated"):
        print(f"straggler_elim,{r[f'{k}_max_over_mean']*1e6:.1f},"
              f"policy={k};max_over_mean={r[f'{k}_max_over_mean']:.3f};"
              f"ranks={r['n_ranks']};slow={r['slow_factor']}")
    est = ";".join(f"{s:.2f}" for s in r["estimated_speeds"])
    print(f"straggler_elim,0.0,policy=speeds;estimated={est}")
    return r


if __name__ == "__main__":
    main()
