"""Beyond-paper (paper §8 'Limitations'): dedicated attention-server
pools vs in-place time-sharing, at a fixed chip budget.

The paper uses in-place servers to keep memory utilization high and
conjectures that, memory permitting, dedicating chips to CA could reduce
compute time further. We quantify that with the cost model + the real
scheduler:

  in-place (paper): N chips each run linear layers on T/N tokens AND
      serve a 1/N share of balanced CA.
      T_iter = lin(T/N) + ca_total/N
  dedicated (k servers): N-k chips run linear layers on T/(N-k) tokens;
      k chips serve all CA. With ping-pong nano-batches the CA of one
      nano overlaps the linear compute of the other:
      T_iter = max(lin(T/(N-k)), ca_total/k) + dispatch
  (activation memory per compute chip grows by N/(N-k) — the paper's
  reason for in-place; we report it alongside.)
"""
import numpy as np

from repro.cad import get_planner
from repro.configs import get_config
from repro.core.cost_model import (CommModel, CostModel, ICI_BW,
                                   PEAK_FLOPS_BF16, linear_flops_per_token)
from repro.core.plan import CADConfig
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents
from benchmarks.e2e_sim import MFU_LINEAR, _chunks_to_segs, \
    _per_rank_ca_time


def run(arch="llama3-8b", n_chips=16, tokens_total=16 * 262144,
        max_doc=262144, n_batches=4, seed=0, plan_policy="identity"):
    cfg = get_config(arch)
    cm = CostModel.analytic(cfg.n_heads, cfg.head_dim)
    rng = np.random.default_rng(seed)
    lin_tok = linear_flops_per_token(cfg) / (MFU_LINEAR * PEAK_FLOPS_BF16)
    rows = []
    # CA totals per batch at a reference packing; the assignment comes
    # from the plan-policy registry (identity = compute-where-packed,
    # matching the in-place reference)
    planner = get_planner(plan_policy)
    comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
    tpr = tokens_total // n_chips
    nb = tpr // BLOCK
    cadcfg = CADConfig(n_servers=n_chips, blk=BLOCK, nb=nb, cq=nb,
                       ckv=2 * nb, nkv=4 * nb)
    ca_totals = []
    for _ in range(n_batches):
        lens = []
        while sum(lens) < tokens_total * 1.2:
            lens.extend(sample_lengths("pretrain", rng, 64,
                                       max_doc).tolist())
        chunks = pack_documents(lens, tpr, n_chips, rng=rng)
        segs = _chunks_to_segs(chunks, tpr)
        res = planner(cadcfg, segs, comm=comm, build_plan=False)
        ca_totals.append(
            _per_rank_ca_time(cm, segs, res.assign, BLOCK, n_chips).sum())
    ca_total = float(np.mean(ca_totals))

    for k in (0, 1, 2, 4, 8):
        n_comp = n_chips - k
        if n_comp <= 0:
            continue
        lin = (tokens_total / n_comp) * lin_tok
        if k == 0:  # in-place (the paper's design)
            t = lin + ca_total / n_chips
            mode = "in-place"
        else:
            t = max(lin, ca_total / k)
            mode = f"dedicated k={k}"
        rows.append({"mode": mode, "k": k, "t_iter": t,
                     "mem_blowup": n_chips / n_comp,
                     "lin_s": lin, "ca_share_s": ca_total / max(k, 1)})
    return rows


def main():
    for r in run():
        print(f"dedicated_pool,{r['t_iter']*1e6:.1f},mode={r['mode']};"
              f"t={r['t_iter']:.3f};mem_blowup={r['mem_blowup']:.2f};"
              f"lin={r['lin_s']:.3f};ca_on_pool={r['ca_share_s']:.3f}")


if __name__ == "__main__":
    main()
