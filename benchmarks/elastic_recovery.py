"""Elastic recovery: kill an attention server mid-run, lose no step.

The acceptance experiment for the elastic runtime (DESIGN.md §9):

  * a pool of N in-place attention servers executes packed CAD steps
    through :class:`ElasticExecutor` (decomposed per-server dispatch);
  * a seeded :class:`FaultSchedule` kills one server *during* step K:
    its in-flight CA tasks are lost, recovered onto survivors via a
    recovery sub-plan, and the merged step output must be
    **bit-identical** to a fault-free run of the same batches on the
    (N-1)-server pool — core attention is stateless, so where a task
    runs can never change its value;
  * after the kill the planner is re-invoked against the surviving
    endpoints (membership epoch bump): steady-state modeled step time
    must be within 10% of the (N-1)-pool baseline (it is in fact
    identical here — same planner, same survivors, same batches);
  * the same schedule replays deterministically: a second run produces
    identical step times, events and output digests.

Emits ``elastic_recovery,<us>,...`` CSV rows and returns the
machine-readable dict wired into ``benchmarks/run.py --json`` under
``"elastic"``.
"""
import hashlib
import types

import numpy as np

from repro.cad import CADSession
from repro.data.pipeline import PipelineConfig, raw_batches
from repro.runtime import ElasticExecutor, FaultSchedule, ServerPool

HEADS = types.SimpleNamespace(n_heads=2, head_dim=16, n_kv_heads=2)


def _digest(x) -> str:
    return hashlib.sha1(np.ascontiguousarray(np.asarray(x))
                        .tobytes()).hexdigest()


def _batches(n_ranks, tokens_per_rank, max_doc, steps, seed):
    pipe = PipelineConfig(distribution="pretrain", max_doc_len=max_doc,
                          seq_len=tokens_per_rank, global_batch=n_ranks,
                          n_ranks=n_ranks, seed=seed)
    gen = raw_batches(pipe)
    out = []
    for _ in range(steps):
        b = next(gen)
        out.append((b["segment_ids"], b["positions"]))
    return pipe, out


def _run(pipe, batches, *, faults=None, dead=(), speculate_pct=0.0,
         seed=0):
    """One elastic run over ``batches``; ``dead`` slots are removed
    before step 0 (the fault-free reduced-pool baseline)."""
    session = CADSession.for_pipeline(HEADS, pipe, plan_policy="balanced",
                                      prefetch=0)
    pool = ServerPool(session.cfg.n_servers)
    for s in dead:
        pool.remove(s)
    session = session.with_pool(pool)
    ex = ElasticExecutor(session, faults=faults,
                         speculate_pct=speculate_pct,
                         feed_calibrator=False)
    digests, reports = [], []
    for step, (segs, positions) in enumerate(batches):
        q, k, v, pos = ex.synth_inputs(segs, positions,
                                       seed=seed + step)
        out, rep = ex.run_step(step, q, k, v, pos, segs)
        digests.append(_digest(out))
        reports.append(rep)
    return digests, reports


def run(n_ranks=4, tokens_per_rank=2048, max_doc=1024, steps=10,
        kill_step=4, victim=1, speculate_pct=0.0, seed=0):
    pipe, batches = _batches(n_ranks, tokens_per_rank, max_doc, steps,
                             seed)
    faults = FaultSchedule.parse(f"kill:{victim}@{kill_step}")

    fault_d, fault_r = _run(pipe, batches, faults=faults,
                            speculate_pct=speculate_pct, seed=seed)
    replay_d, replay_r = _run(pipe, batches, faults=faults,
                              speculate_pct=speculate_pct, seed=seed)
    base_d, base_r = _run(pipe, batches, dead=(victim,), seed=seed)

    deterministic = fault_d == replay_d and \
        [r.step_seconds for r in fault_r] \
        == [r.step_seconds for r in replay_r] and \
        [r.events for r in fault_r] == [r.events for r in replay_r]
    # every step's output (including the kill step's recovered merge)
    # must match the fault-free reduced-pool run bit-identically: CA
    # tasks are pure functions of (q block, kv prefix)
    bit_identical = fault_d == base_d
    post = slice(kill_step + 1, None)
    steady_fault = float(np.mean([r.step_seconds
                                  for r in fault_r[post]]))
    steady_base = float(np.mean([r.step_seconds
                                 for r in base_r[post]]))
    steady_ratio = steady_fault / max(steady_base, 1e-30)
    kill_rep = fault_r[kill_step]
    return {
        "n_ranks": n_ranks,
        "steps": steps,
        "kill_step": kill_step,
        "victim": victim,
        "no_step_failed": len(fault_r) == steps,
        "bit_identical": bool(bit_identical),
        "deterministic_replay": bool(deterministic),
        "recovered_blocks": kill_rep.recovered_blocks,
        "kill_step_seconds": kill_rep.step_seconds,
        "baseline_kill_step_seconds": base_r[kill_step].step_seconds,
        "steady_fault_seconds": steady_fault,
        "steady_base_seconds": steady_base,
        "steady_ratio": float(steady_ratio),
        "epoch_final": fault_r[-1].epoch,
    }


def main(fast=False):
    kw = dict(n_ranks=3, tokens_per_rank=1024, max_doc=512, steps=8,
              kill_step=3) if fast else {}
    r = run(**kw)
    ok = r["no_step_failed"] and r["bit_identical"] \
        and r["deterministic_replay"] and abs(r["steady_ratio"] - 1) < 0.1
    print(f"elastic_recovery,{r['kill_step_seconds']*1e6:.2f},"
          f"phase=kill_step;recovered={r['recovered_blocks']};"
          f"ranks={r['n_ranks']};victim={r['victim']}")
    print(f"elastic_recovery,{r['steady_fault_seconds']*1e6:.2f},"
          f"phase=steady;ratio_vs_reduced={r['steady_ratio']:.3f}")
    print(f"elastic_recovery,0.0,phase=verdict;"
          f"bit_identical={r['bit_identical']};"
          f"deterministic={r['deterministic_replay']};"
          f"no_step_failed={r['no_step_failed']};ok={ok}")
    if not ok:
        raise RuntimeError(f"elastic recovery acceptance failed: {r}")
    return r


if __name__ == "__main__":
    main()
