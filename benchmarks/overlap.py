"""Paper Figure 11 analogue: communication overlap ablation.

Three execution modes over the same scheduled batches:
  signal         1-byte comms — pure compute imbalance floor
  single_stream  comm serialized with compute (no ping-pong)
  distca         ping-pong: comm of one nano-batch overlaps compute of
                 the other -> T = max(compute, comm)
"""
import numpy as np

from repro.configs import get_config
from repro.core.cost_model import CommModel, CostModel, ICI_BW, \
    PEAK_FLOPS_BF16, linear_flops_per_token
from repro.core.scheduler import Caps, schedule
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents
from benchmarks.e2e_sim import MFU_LINEAR, _chunks_to_segs, \
    _per_rank_ca_time


def run(arch="llama3-8b", n_ranks=8, tokens_per_rank=131072,
        max_doc=131072, n_batches=4, seed=1):
    cfg = get_config(arch)
    cm = CostModel.analytic(cfg.n_heads, cfg.head_dim)
    comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
    lin = tokens_per_rank * linear_flops_per_token(cfg) \
        / (MFU_LINEAR * PEAK_FLOPS_BF16)
    rng = np.random.default_rng(seed)
    blk = BLOCK
    nb = tokens_per_rank // blk
    sig, single, pp = [], [], []
    for _ in range(n_batches):
        lens = []
        while sum(lens) < n_ranks * tokens_per_rank * 1.2:
            lens.extend(sample_lengths("pretrain", rng, 64,
                                       max_doc).tolist())
        chunks = pack_documents(lens, tokens_per_rank, n_ranks, rng=rng)
        segs = _chunks_to_segs(chunks, tokens_per_rank)
        sch = schedule(segs, blk=blk, n_servers=n_ranks, comm=comm,
                       caps=Caps(cq=nb, ckv=2 * nb, nkv=4 * nb),
                       tolerance=0.1)
        ca = _per_rank_ca_time(cm, segs, sch.assign, blk, n_ranks)
        compute = float(lin + ca.max())
        t_comm = sch.comm_bytes / n_ranks / ICI_BW
        sig.append(compute)
        single.append(compute + t_comm)
        pp.append(max(compute, t_comm))
    return {"signal": float(np.mean(sig)),
            "single_stream": float(np.mean(single)),
            "distca": float(np.mean(pp))}


def main(fast=False):
    for arch, tpr in (("llama3-8b", 131072), ("llama3-34b", 65536)):
        r = run(arch=arch, tokens_per_rank=tpr,
                n_batches=2 if fast else 4)
        hidden = (r["single_stream"] - r["distca"]) / max(
            r["single_stream"] - r["signal"], 1e-12)
        d = (f"arch={arch};t_signal={r['signal']:.4f};"
             f"t_single={r['single_stream']:.4f};"
             f"t_distca={r['distca']:.4f};overlap_hidden={hidden:.2f}")
        print(f"fig11_overlap,{r['distca']*1e6:.1f},{d}")


if __name__ == "__main__":
    main()
