"""Sparse balance: live-block pricing on mask-structured workloads.

The acceptance experiment for mask-aware planning (DESIGN.md §12).  The
workload is doc-masked long-context training in miniature: rank 0 packs
one document spanning its whole token span under a sliding-window +
sink mask, the other ranks are nearly idle.  Under the mask, the deep
q-blocks of the long document are *cheap* — each sees only a
window-bounded band of kv — but their dense-causal rectangle area still
grows linearly with depth.

  * **identity is imbalanced**: with no balancing, rank 0 holds all
    the live-block compute — max/mean is ~n_ranks;
  * **area pricing balances the wrong number**: the balanced planner
    run *without* the mask equalizes rectangle area, so it exports a
    few deep (area-heavy, mask-cheap) blocks and keeps the many
    shallow ones — measured in live blocks, the split exceeds 1.4
    max/mean;
  * **live-block pricing balances the real compute**: the same planner
    with the mask prices every block by its live kv band and splits
    along the mask structure — measured live-block max/mean <= 1.1.

All three plans are re-priced by one independent live-block recompute
(``block_costs`` with the mask), so the comparison measures what the
kernels will actually execute, not what each planner believed.

Emits ``sparse_balance,<us>,...`` CSV rows and returns the
machine-readable dict wired into ``benchmarks/run.py --json`` under
``"sparse"``.
"""
import time

import numpy as np

from repro.cad.planner import get_planner
from repro.core.mask import MaskSpec
from repro.core.plan import CADConfig
from repro.core.scheduler import block_costs, layout_from_segments


def _segs(n_ranks: int, nb: int, blk: int) -> np.ndarray:
    """Rank 0: one document spanning all ``nb`` blocks.  Every other
    rank: a single one-block document, rest padding."""
    segs = np.zeros((n_ranks, nb * blk), np.int64)
    segs[0, :] = 1
    for r in range(1, n_ranks):
        segs[r, :blk] = 10 * r + 1
    return segs


def _live_loads(res, segs, blk, n_ranks, spec) -> np.ndarray:
    """Per-server compute under the TRUE live-block pricing, whatever
    pricing the planner itself used."""
    _docs, doc_of, bi_of = layout_from_segments(segs, blk, n_ranks)
    cost = block_costs(doc_of, bi_of, blk, None, spec)
    live = doc_of >= 0
    loads = np.zeros(n_ranks)
    np.add.at(loads, np.asarray(res.assign)[live].astype(np.int64),
              cost[live])
    return loads


def _ratio(loads) -> float:
    loads = np.asarray(loads, np.float64)
    return float(loads.max() / max(loads.mean(), 1e-30))


def run(n_ranks=4, nb=96, blk=16, window_blocks=2, sink_blocks=1,
        tolerance=0.05):
    spec = MaskSpec(kind="sliding", window=window_blocks * blk,
                    sink=sink_blocks * blk)
    segs = _segs(n_ranks, nb, blk)
    cfg = CADConfig(n_servers=n_ranks, blk=blk, nb=nb, cq=nb,
                    ckv=2 * nb, nkv=4 * nb)
    planner = get_planner("balanced")

    plans, times = {}, {}
    t0 = time.perf_counter()
    plans["identity"] = get_planner("identity")(
        cfg, segs, comm=None, tolerance=tolerance, mask=spec)
    times["identity"] = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    # area pricing: the balanced planner with the mask withheld — it
    # equalizes dense-causal rectangle area on a masked workload
    plans["area"] = planner(cfg, segs, comm=None, tolerance=tolerance)
    times["area"] = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    plans["live"] = planner(cfg, segs, comm=None, tolerance=tolerance,
                            mask=spec)
    times["live"] = (time.perf_counter() - t0) * 1e6

    ratios = {name: _ratio(_live_loads(res, segs, cfg.blk, n_ranks,
                                       spec))
              for name, res in plans.items()}
    return {
        "n_ranks": n_ranks,
        "blocks_per_rank": nb,
        "mask": spec.describe(),
        "identity_max_over_mean": ratios["identity"],
        "area_max_over_mean": ratios["area"],
        "live_max_over_mean": ratios["live"],
        "area_exceeds_1_4": bool(ratios["area"] > 1.4),
        "live_within_1_1": bool(ratios["live"] <= 1.1),
        "moves_live": int(plans["live"].stats["n_moves"]),
        "plan_us": times,
    }


def main(fast=False):
    # planning-only (no kernels): nb=96 runs in ~1 ms, so fast mode
    # keeps the full acceptance geometry
    r = run()
    ok = r["area_exceeds_1_4"] and r["live_within_1_1"] \
        and r["identity_max_over_mean"] >= r["area_max_over_mean"]
    for name in ("identity", "area", "live"):
        print(f"sparse_balance,{r['plan_us'][name]:.1f},"
              f"policy={name};mask={r['mask']};"
              f"live_max_over_mean={r[name + '_max_over_mean']:.3f};"
              f"ranks={r['n_ranks']};blocks={r['blocks_per_rank']}")
    print(f"sparse_balance,0.0,phase=verdict;"
          f"area={r['area_max_over_mean']:.3f}(>1.4:"
          f"{r['area_exceeds_1_4']});"
          f"live={r['live_max_over_mean']:.3f}(<=1.1:"
          f"{r['live_within_1_1']});ok={ok}")
    if not ok:
        raise RuntimeError(f"sparse balance acceptance failed: {r}")
    return r


if __name__ == "__main__":
    main()
