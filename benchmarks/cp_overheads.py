"""Paper Figure 3 analogue: per-document context parallelism overheads
grow with CP degree — (a) the KV all-gather's share of CA latency and
(b) the gathered-KV share of memory — the two §3.2 bottlenecks CAD
removes.
"""
import numpy as np

from repro.configs import get_config
from repro.core.cost_model import (CommModel, CostModel, ICI_BW,
                                   PEAK_FLOPS_BF16)


def run(arch="llama3-8b", doc_len=32768, n_docs=8):
    """All documents 32K (paper Fig. 3 setup), Llama-8B."""
    cfg = get_config(arch)
    cm = CostModel.analytic(cfg.n_heads, cfg.head_dim)
    comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
    rows = []
    total_tokens = doc_len * n_docs
    # per-rank CA time of a doc under CP-c: each rank computes 1/c of
    # every doc's triangle
    blk = 128
    nb = doc_len // blk
    ca_doc = float(sum(cm.predict(blk, (i + 1) * blk) for i in range(nb)))
    for c in (2, 4, 8, 16, 32):
        ca_rank = n_docs * ca_doc / c
        # all-gather: every rank receives all KV of the docs it shards
        ag_bytes = total_tokens * comm.size_kv
        t_ag = ag_bytes / ICI_BW
        ag_share = t_ag / (t_ag + ca_rank)
        # memory: the last CP rank holds the docs' full gathered KV
        kv_bytes = total_tokens * comm.size_kv
        act_bytes = (total_tokens / c) * cfg.d_model * 2 * 8  # rough act
        kv_share = kv_bytes / (kv_bytes + act_bytes)
        rows.append({"cp": c, "allgather_latency_share": ag_share,
                     "kv_memory_share": kv_share})
    return rows


def main():
    for r in run():
        print(f"fig3_cp_overheads,0.0,cp={r['cp']};"
              f"ag_latency_share={r['allgather_latency_share']:.3f};"
              f"kv_memory_share={r['kv_memory_share']:.3f}")


if __name__ == "__main__":
    main()
