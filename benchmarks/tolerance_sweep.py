"""Paper Figure 12 analogue: the scheduler tolerance factor trades CA load
balance against communication volume.  Runs the REAL greedy scheduler
through the repro.cad plan-policy registry."""
import numpy as np

from repro.cad import get_planner
from repro.configs import get_config
from repro.core.cost_model import CommModel, CostModel, ICI_BW, \
    PEAK_FLOPS_BF16, linear_flops_per_token
from repro.core.plan import CADConfig
from repro.core.scheduler import imbalance
from repro.data.distributions import sample_lengths
from repro.data.packing import BLOCK, pack_documents
from benchmarks.e2e_sim import MFU_LINEAR, _chunks_to_segs, \
    _per_rank_ca_time


def run(arch="llama3-8b", n_ranks=8, tokens_per_rank=131072,
        max_doc=131072, n_batches=4, seed=0, plan_policy="balanced"):
    cfg = get_config(arch)
    cm = CostModel.analytic(cfg.n_heads, cfg.head_dim)
    comm = CommModel(cfg.n_heads, cfg.head_dim, cfg.n_kv_heads)
    lin = tokens_per_rank * linear_flops_per_token(cfg) \
        / (MFU_LINEAR * PEAK_FLOPS_BF16)
    rng = np.random.default_rng(seed)
    blk = BLOCK
    nb = tokens_per_rank // blk
    batches = []
    for _ in range(n_batches):
        lens = []
        while sum(lens) < n_ranks * tokens_per_rank * 1.2:
            lens.extend(sample_lengths("pretrain", rng, 64,
                                       max_doc).tolist())
        chunks = pack_documents(lens, tokens_per_rank, n_ranks, rng=rng)
        batches.append(_chunks_to_segs(chunks, tokens_per_rank))

    cadcfg = CADConfig(n_servers=n_ranks, blk=blk, nb=nb, cq=nb,
                       ckv=2 * nb, nkv=4 * nb)
    planner = get_planner(plan_policy)
    rows = []
    for tol in (0.0, 0.05, 0.10, 0.15, 0.20, 0.30, 0.50):
        imb, comm_gb, lat = [], [], []
        for segs in batches:
            res = planner(cadcfg, segs, comm=comm, tolerance=tol,
                          build_plan=False)
            ca = _per_rank_ca_time(cm, segs, res.assign, blk, n_ranks)
            t_comm = res.stats["comm_bytes"] / n_ranks / ICI_BW
            lat.append(max(lin + ca.max(), t_comm))
            imb.append(imbalance(res.loads))
            comm_gb.append(res.stats["comm_bytes"] / 2 ** 30)
        rows.append({"tolerance": tol,
                     "imbalance": float(np.mean(imb)),
                     "comm_gib": float(np.mean(comm_gb)),
                     "latency_s": float(np.mean(lat))})
    return rows


def main(fast=False):
    for r in run(n_batches=2 if fast else 4):
        d = (f"tol={r['tolerance']};imb={r['imbalance']:.3f};"
             f"comm_gib={r['comm_gib']:.2f};lat={r['latency_s']:.4f}")
        print(f"fig12_tolerance,{r['latency_s']*1e6:.1f},{d}")


if __name__ == "__main__":
    main()
