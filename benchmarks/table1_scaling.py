"""Paper Table 1 analogue: compute/memory scaling of CA vs linear layers,
verified empirically — CA FLOPs grow quadratically with doc length while
linear FLOPs and activation memory grow linearly (measured via the HLO
analyzer on compiled forward passes)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.hlo_analysis import analyze
from repro.models import model as M
from repro.parallel import ParallelContext


def run(arch="smollm-360m"):
    cfg = get_config(arch).reduced()
    ctx = ParallelContext(attn_impl="xla", remat=False)
    params = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    rows = []
    for s in (256, 512, 1024):
        batch = {"tokens": jax.ShapeDtypeStruct((1, s), jnp.int32),
                 "segment_ids": jax.ShapeDtypeStruct((1, s), jnp.int32),
                 "positions": jax.ShapeDtypeStruct((1, s), jnp.int32)}
        txt = jax.jit(lambda p, b: M.forward(p, cfg, b, ctx)[0]) \
            .lower(params, batch).compile().as_text()
        c = analyze(txt)
        rows.append({"seq": s, "flops": c.flops, "bytes": c.hbm_bytes})
    # fit flops ~ a*s^2 + b*s: quadratic share at the largest s
    s = np.array([r["seq"] for r in rows], np.float64)
    f = np.array([r["flops"] for r in rows], np.float64)
    coef = np.linalg.lstsq(np.stack([s * s, s], 1), f, rcond=None)[0]
    quad_share = coef[0] * s[-1] ** 2 / f[-1]
    return rows, float(quad_share)


def main():
    rows, quad = run()
    for r in rows:
        print(f"table1_scaling,0.0,seq={r['seq']};flops={r['flops']:.3e};"
              f"bytes={r['bytes']:.3e}")
    print(f"table1_scaling,0.0,quadratic_flops_share_at_1k={quad:.3f}")


if __name__ == "__main__":
    main()
