"""Elastic attention-server runtime (DESIGN.md §9).

Covers the ServerPool membership/epoch machinery, deterministic fault
injection, recovery sub-plans (exactly-once coverage + bit-identical
outputs vs a fault-free reduced-pool run), straggler speculation,
epoch-aware plan-prefetch invalidation, the trainer's fault-schedule
integration, and calibration state riding along in checkpoints.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cad import CADConfig, CADSession
from repro.core.cost_model import CommModel, CostModel, GridCalibrator
from repro.core.dispatch import CADContext, _global_sim, iter_plan_tasks
from repro.core.mask import MaskSpec
from repro.core.plan import PlanCapacityError
from repro.core.scheduler import (block_costs, check_exclude,
                                  layout_from_segments)
from repro.runtime import (ElasticExecutor, FaultEvent, FaultSchedule,
                           PoolExhaustedError, ServerPool,
                           assignment_of_plan, build_recovery_plan,
                           lost_block_mask)

BLK = 16


def make_segs(d, nb, seed=0, max_doc_blocks=4):
    rng = np.random.default_rng(seed)
    segs = np.zeros((d, nb * BLK), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < nb:
            dbl = int(rng.integers(1, min(max_doc_blocks, nb - t) + 1))
            segs[r, t * BLK:(t + dbl) * BLK] = sid
            sid += 1
            t += dbl
    return segs


def make_cfg(d, nb):
    return CADConfig(n_servers=d, blk=BLK, nb=nb, cq=nb, ckv=2 * nb,
                     nkv=4 * nb)


def make_session(d=4, nb=8, **kw):
    kw.setdefault("comm", CommModel(2, 8, 2))
    kw.setdefault("tolerance", 0.05)
    kw.setdefault("jmax", nb)
    kw.setdefault("prefetch", 0)
    return CADSession(cfg=make_cfg(d, nb), **kw)


def make_executor(session=None, *, faults=None, **kw):
    session = session or make_session()
    if session.pool is None:
        session = session.with_pool(ServerPool(session.cfg.n_servers))
    return ElasticExecutor(session, faults=faults, **kw)


def synth(ex, segs, seed=0):
    pos = np.broadcast_to(np.arange(segs.shape[1]), segs.shape).copy()
    return ex.synth_inputs(segs, pos, seed=seed)


# ===================================================================
# ServerPool: membership epochs + calibration carryover
# ===================================================================

def test_pool_epochs_and_views():
    pool = ServerPool(4)
    v0 = pool.view()
    assert v0.epoch == 0 and v0.active == (0, 1, 2, 3)
    assert v0.excluded == ()
    assert pool.drain(1) == 1
    v1 = pool.view()
    assert v1.active == (0, 2, 3) and v1.excluded == (1,)
    assert pool.remove(2) == 2
    v2 = pool.view()
    assert v2.dead == (2,) and set(v2.excluded) == {1, 2}
    assert pool.add(2) == 3                   # flap back in
    assert pool.view().active == (0, 2, 3)
    assert pool.add(1) == 4                   # undrain
    assert pool.view().active == (0, 1, 2, 3)
    assert len(pool.history()) == 4
    # immutability: old views unchanged
    assert v1.active == (0, 2, 3)


def test_pool_refuses_exhaustion_and_bad_transitions():
    pool = ServerPool(2)
    pool.remove(0)
    with pytest.raises(PoolExhaustedError):
        pool.remove(1)
    with pytest.raises(PoolExhaustedError):
        pool.drain(1)
    with pytest.raises(ValueError):
        pool.remove(0)                        # already dead
    with pytest.raises(ValueError):
        pool.add(1)                           # already active
    with pytest.raises(ValueError):
        ServerPool(0)
    with pytest.raises(ValueError):
        pool.drain(7)


def test_pool_calibrator_carryover():
    """Survivors and flapped (same-endpoint) rejoins keep their measured
    speed state; only a *new* endpoint resets its slot to the base."""
    calib = GridCalibrator(CostModel.analytic(2, 8), 3)
    for s in range(3):
        for _ in range(4):
            calib.observe(128, 1024, 1e-3 * (s + 1), server=s)
    speeds_before = calib.speeds()
    pool = ServerPool(3, calibrator=calib)
    pool.remove(2)
    pool.add(2)                               # flap: same endpoint
    np.testing.assert_allclose(calib.speeds(), speeds_before)
    pool.remove(2)
    pool.add(2, endpoint="replacement-host")  # new endpoint: reset
    after = calib.speeds()
    assert not np.allclose(after, speeds_before)
    # surviving servers' ratios untouched (relative order intact)
    assert after[0] > after[1] or speeds_before[0] > speeds_before[1]


def test_calibrator_reset_server_validates():
    calib = GridCalibrator(CostModel.analytic(2, 8), 2)
    with pytest.raises(ValueError):
        calib.reset_server(5)
    with pytest.raises(ValueError):
        calib.reset_server(0, prior_speed=-1.0)
    v = calib.version
    calib.reset_server(0, prior_speed=0.5)
    assert calib.version > v                  # snapshots invalidate


# ===================================================================
# FaultSchedule: deterministic, replayable injection
# ===================================================================

def test_fault_schedule_parse_roundtrip():
    spec = "kill:2@5,slow:0x4@3-9,flap:1@4+3,drain:3@2"
    fs = FaultSchedule.parse(spec)
    assert FaultSchedule.parse(fs.spec()) == fs
    assert {e.kind for e in fs.events} == {"kill", "slow", "flap",
                                           "drain"}
    assert fs.failures_at(5) == (FaultEvent(5, "kill", 2),)
    assert fs.failures_at(4) == (FaultEvent(4, "flap", 1, until=7),)
    assert fs.rejoins_at(7) == (1,)
    assert fs.drains_at(2) == (3,)
    assert fs.slow_factor(3, 0) == 4.0
    assert fs.slow_factor(9, 0) == 1.0        # end-exclusive
    assert fs.slow_factor(3, 1) == 1.0


@pytest.mark.parametrize("bad", [
    "boom:1@2", "kill:1", "slow:1@3", "flap:1@3", "kill:1x2@3",
    "slow:0x0@1", "kill:1@2,kill:1@2",
    "slow:1x2@3+5",                           # flap syntax on a slow
    "flap:1@4+3-9",                           # slow syntax on a flap
])
def test_fault_schedule_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        FaultSchedule.parse(bad)


def test_fault_schedule_random_replays():
    a = FaultSchedule.random(8, 100, seed=7)
    b = FaultSchedule.random(8, 100, seed=7)
    assert a == b and len(a) > 0
    assert FaultSchedule.random(8, 100, seed=8) != a
    # kills capped so the pool never exhausts
    kills = [e for e in a.events if e.kind == "kill"]
    assert len(kills) <= 7
    assert FaultSchedule.parse(a.spec()) == a


# ===================================================================
# Scheduler / planner endpoint subsets
# ===================================================================

def test_check_exclude_validates():
    assert check_exclude((2, 1), 4) == (1, 2)
    assert check_exclude(None, 4) == ()
    with pytest.raises(ValueError):
        check_exclude((0, 1), 2)              # no survivors
    with pytest.raises(ValueError):
        check_exclude((9,), 4)


def test_exclude_evacuates_with_tight_caps_raises():
    d, nb = 2, 4
    segs = make_segs(d, nb, seed=3)
    tiny = CADConfig(n_servers=d, blk=BLK, nb=nb, cq=1, ckv=1, nkv=nb)
    from repro.cad import get_planner
    with pytest.raises(PlanCapacityError):
        get_planner("balanced")(tiny, segs, comm=None, exclude=(0,))


# ===================================================================
# Recovery sub-plans
# ===================================================================

def test_recovery_plan_exactly_once():
    """The sub-plan's tasks are exactly the lost blocks — no survivor
    task is recomputed, no lost block is dropped."""
    d, nb = 4, 8
    cfg = make_cfg(d, nb)
    segs = make_segs(d, nb, seed=1)
    sess = make_session(d, nb)
    plan, _ = sess.plan(segs)
    docs, doc_of, bi_of = layout_from_segments(segs, BLK, d)
    failed = (1,)
    lost = lost_block_mask(cfg, plan, failed, doc_of)
    rec = build_recovery_plan(cfg, segs, plan, failed,
                              allowed=(0, 2, 3))
    assert rec is not None
    np.testing.assert_array_equal(rec.lost, lost)
    kv_len = np.asarray(rec.plan["task_kv_len"])
    assert kv_len[1].sum() == 0               # nothing lands on the dead
    # every lost block appears exactly once in the sub-plan; others never
    from tests.test_planner_properties import plan_served_blocks
    served, dupes = plan_served_blocks(cfg, rec.plan)
    assert not dupes
    assert set(served) == set(np.nonzero(lost)[0])
    assert all(srv in (0, 2, 3) for srv in served.values())
    assert rec.n_blocks == int(lost.sum()) > 0


def test_recovery_plan_none_when_nothing_lost():
    d, nb = 2, 4
    cfg = make_cfg(d, nb)
    segs = make_segs(d, nb)
    sess = make_session(d, nb, plan_policy="identity")
    plan, _ = sess.plan(segs)
    # identity serves everything at home; kill a server that holds only
    # padding -> nothing can be lost on an all-live layout, so instead
    # check the validation paths
    with pytest.raises(ValueError):
        build_recovery_plan(cfg, segs, plan, (0,), allowed=())
    with pytest.raises(ValueError):
        build_recovery_plan(cfg, segs, plan, (0,), allowed=(0, 1))


# ===================================================================
# ElasticExecutor: kill, recover, bit-identical merge
# ===================================================================

def test_executor_matches_global_sim_fault_free():
    d, nb = 3, 6
    sess = make_session(d, nb).with_pool(ServerPool(3))
    ex = ElasticExecutor(sess)
    segs = make_segs(d, nb, seed=5)
    q, k, v, pos = synth(ex, segs, seed=2)
    out, rep = ex.run_step(0, q, k, v, pos, segs)
    plan, _ = sess.plan(segs)
    cad = CADContext(cfg=sess.cfg, kernel=sess.kernel, jmax=sess.jmax)
    ref = _global_sim(q, k, v, pos, jax.tree.map(jnp.asarray, plan),
                      cad, 0.0, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert rep.failed == () and rep.recovered_blocks == 0


def test_executor_kill_bit_identical_to_reduced_pool():
    """The acceptance property: with a server killed mid-step, the
    merged output is bit-identical to a fault-free run on the (N-1)
    pool, and after the epoch bump the plans coincide exactly."""
    d, nb = 4, 8
    segs = make_segs(d, nb, seed=7)
    faults = FaultSchedule.parse("kill:2@1")
    ex = make_executor(faults=faults)
    q, k, v, pos = synth(ex, segs, seed=9)

    outs, reps = [], []
    for step in range(3):
        o, r = ex.run_step(step, q, k, v, pos, segs)
        outs.append(np.asarray(o))
        reps.append(r)
    assert reps[1].failed == (2,)
    assert reps[1].recovered_blocks > 0
    assert reps[2].epoch == reps[1].epoch + 1

    pool_b = ServerPool(d)
    pool_b.remove(2)
    ex_b = make_executor(make_session(d, nb).with_pool(pool_b))
    for step in (1, 2):
        ob, rb = ex_b.run_step(step, q, k, v, pos, segs)
        np.testing.assert_array_equal(outs[step], np.asarray(ob))
        if step == 2:   # steady state: identical plan -> identical time
            assert reps[2].step_seconds == pytest.approx(
                rb.step_seconds, rel=1e-12)
    # the dead server's slot never hosts tasks again
    assert 2 not in reps[2].server_seconds


def test_executor_flap_rejoins_with_epoch_bumps():
    d, nb = 3, 6
    segs = make_segs(d, nb, seed=11)
    ex = make_executor(make_session(d, nb).with_pool(ServerPool(d)),
                       faults=FaultSchedule.parse("flap:0@1+2"))
    q, k, v, pos = synth(ex, segs)
    epochs, actives = [], []
    for step in range(4):
        _, r = ex.run_step(step, q, k, v, pos, segs)
        epochs.append(r.epoch)
        actives.append(len(r.server_seconds))
    assert actives == [3, 2, 2, 3]            # dead during 2, back at 3
    assert epochs[1] < epochs[2] < epochs[3]  # remove, then rejoin


def test_executor_survives_events_on_non_active_servers():
    """Membership events targeting servers in another state are applied
    with the shared idempotent semantics: a drain scheduled after a
    kill is skipped (never a crash), and a kill striking a *draining*
    server still fells it so its flap rejoin can fire later."""
    d, nb = 3, 6
    segs = make_segs(d, nb, seed=23)
    ex = make_executor(
        make_session(d, nb).with_pool(ServerPool(d)),
        faults=FaultSchedule.parse("kill:1@0,drain:1@2"))
    q, k, v, pos = synth(ex, segs)
    for step in range(4):                     # drain on dead: no-op
        _, r = ex.run_step(step, q, k, v, pos, segs)
    assert ex.pool.status(1) == "dead"

    ex2 = make_executor(
        make_session(d, nb).with_pool(ServerPool(d)),
        faults=FaultSchedule.parse("drain:1@0,flap:1@1+2"))
    actives = []
    for step in range(4):
        _, r = ex2.run_step(step, q, k, v, pos, segs)
        actives.append(len(r.server_seconds))
    # drained at 0, killed-while-draining at 1, rejoined before 3
    assert actives == [2, 2, 2, 3]
    assert ex2.pool.status(1) == "active"


def test_executor_speculation_exact_and_faster():
    d, nb = 4, 8
    segs = make_segs(d, nb, seed=13)
    sess = make_session(d, nb).with_pool(ServerPool(d))
    ex_ref = ElasticExecutor(sess.with_pool(ServerPool(d)))
    ex = ElasticExecutor(sess, faults=FaultSchedule.parse("slow:1x8@0-1"),
                         speculate_pct=0.9, speculate_slack=1.2)
    q, k, v, pos = synth(ex, segs, seed=3)
    out, rep = ex.run_step(0, q, k, v, pos, segs)
    ref, _ = ex_ref.run_step(0, q, k, v, pos, segs)
    assert rep.speculated == (1,)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert rep.step_seconds < max(rep.server_seconds.values())
    # speculation is an optimization, never a membership change
    assert ex.pool.view().active == tuple(range(d))


def test_executor_replay_is_deterministic():
    d, nb = 3, 6
    segs = make_segs(d, nb, seed=17)
    fs = FaultSchedule.random(d, 5, seed=4, p_kill=0.05, p_slow=0.2,
                              p_flap=0.05, max_kills=1)

    def run_once():
        ex = make_executor(make_session(d, nb).with_pool(ServerPool(d)),
                           faults=fs, speculate_pct=0.9)
        digests, secs = [], []
        q, k, v, pos = synth(ex, segs)
        for step in range(5):
            o, r = ex.run_step(step, q, k, v, pos, segs)
            digests.append(np.asarray(o).tobytes())
            secs.append((r.step_seconds, r.failed, r.speculated,
                         r.events))
        return digests, secs

    a, b = run_once(), run_once()
    assert a[0] == b[0]
    assert a[1] == b[1]


def test_executor_requires_pool_and_rejects_pingpong():
    sess = make_session()
    with pytest.raises(ValueError):
        ElasticExecutor(sess)
    sess2 = make_session(pingpong=True).with_pool(ServerPool(4))
    with pytest.raises(NotImplementedError):
        ElasticExecutor(sess2)
    with pytest.raises(ValueError):
        ElasticExecutor(make_session().with_pool(ServerPool(4)),
                        timer="sundial")


def test_executor_pool_exhaustion_raises():
    d, nb = 2, 4
    segs = make_segs(d, nb)
    pool = ServerPool(d)
    pool.remove(0)
    ex = make_executor(make_session(d, nb).with_pool(pool),
                       faults=FaultSchedule.parse("kill:1@0"))
    q, k, v, pos = synth(ex, segs)
    with pytest.raises(PoolExhaustedError):
        ex.run_step(0, q, k, v, pos, segs)


# ===================================================================
# Session + prefetch epoch invalidation
# ===================================================================

def test_session_with_pool_validates_geometry():
    sess = make_session(4, 8)
    with pytest.raises(ValueError):
        sess.with_pool(ServerPool(3))


def test_plans_replan_on_epoch_change_through_prefetch():
    """A membership change mid-stream invalidates queued plans: every
    batch pulled after the change is planned against the survivors,
    even though it was prefetched under the old epoch."""
    d, nb = 2, 4
    pool = ServerPool(d)
    sess = make_session(d, nb, prefetch=2).with_pool(pool)
    segs = make_segs(d, nb)

    def batches(n):
        for _ in range(n):
            yield {"segment_ids": segs.reshape(d * 2, -1)}

    gen = sess.attach_plans(batches(6))
    first = next(gen)
    assert first["schedule_stats"]["pool_epoch"] == 0.0
    pool.remove(1)
    got = list(gen)
    assert len(got) == 5
    for b in got:
        assert b["schedule_stats"]["pool_epoch"] == 1.0
        kv_len = np.asarray(b["plan"]["task_kv_len"])
        assert kv_len[1].sum() == 0           # dead server: no tasks
    names = [t.name for t in threading.enumerate()]
    assert "cad-plan-prefetch" not in names


def test_prefetcher_close_drops_queued_items():
    """After close(), queued items (planned for a now-dead world) are
    never delivered."""
    from repro.cad.prefetch import PlanPrefetcher
    import time as _t
    pf = PlanPrefetcher(iter(range(10)), lambda x: x, depth=3)
    _t.sleep(0.2)                             # let the worker fill up
    assert next(pf) == 0
    pf.close()
    with pytest.raises(StopIteration):
        next(pf)


# ===================================================================
# Trainer + checkpoint satellites
# ===================================================================

def test_train_with_fault_schedule_finishes(tmp_path):
    from repro.configs import get_config
    from repro.data.pipeline import PipelineConfig
    from repro.train.trainer import TrainConfig, train
    cfg = get_config("smollm-360m").reduced()
    pipe = PipelineConfig(distribution="pretrain", max_doc_len=256,
                          seq_len=256, global_batch=4, n_ranks=2,
                          vocab_size=cfg.vocab_size, seed=3)
    session = CADSession.for_pipeline(cfg, pipe, plan_policy="balanced")
    res = train(cfg, pipe, TrainConfig(steps=4, peak_lr=1e-3, warmup=1,
                                       log_every=1,
                                       fault_schedule="kill:1@2"),
                session=session)
    h = res["history"]
    assert len(h) == 4
    assert np.isfinite(h[-1]["loss"])
    assert h[0]["sched_pool_epoch"] == 0.0
    assert h[-1]["sched_pool_epoch"] == 1.0
    assert any("kill 1" in m.get("pool_events", "") for m in h)


def _assert_state_equal(a, b):
    assert a.keys() == b.keys()
    for key in a:
        if isinstance(a[key], list):          # grids with NaN cells
            np.testing.assert_array_equal(np.asarray(a[key], float),
                                          np.asarray(b[key], float),
                                          err_msg=key)
        else:
            assert a[key] == b[key], key


def test_ckpt_calibration_roundtrip(tmp_path):
    from repro.checkpoint import ckpt
    calib = GridCalibrator(CostModel.analytic(2, 8), 2)
    calib.observe(128, 1024, 3e-3, server=0)
    calib.observe(128, 2048, 5e-3, server=1)
    params = {"w": np.ones((2, 2))}
    ckpt.save(str(tmp_path), 7, params, calibrator=calib)
    fresh = GridCalibrator(CostModel.analytic(2, 8), 2)
    assert ckpt.restore_calibration(str(tmp_path), 7, fresh)
    _assert_state_equal(fresh.state_dict(), calib.state_dict())
    np.testing.assert_allclose(fresh.speeds(), calib.speeds())
    # older checkpoints (no calibration) restore as a no-op
    ckpt.save(str(tmp_path), 8, params)
    untouched = GridCalibrator(CostModel.analytic(2, 8), 2)
    before = untouched.state_dict()
    assert not ckpt.restore_calibration(str(tmp_path), 8, untouched)
    _assert_state_equal(untouched.state_dict(), before)
    assert not ckpt.restore_calibration(str(tmp_path), 99, untouched)
    # a checkpoint from a differently-sized pool must not corrupt the
    # calibrator: geometry-mismatched state restores as a no-op
    other = GridCalibrator(CostModel.analytic(2, 8), 5)
    before = other.state_dict()
    assert not ckpt.restore_calibration(str(tmp_path), 7, other)
    _assert_state_equal(other.state_dict(), before)
    with pytest.raises(ValueError):
        other.load_state_dict(calib.state_dict())


def test_elastic_recovery_benchmark_fast():
    """The acceptance benchmark end to end (fast geometry): no step
    fails, outputs bit-identical to the reduced-pool run, deterministic
    replay, steady state within 10%."""
    import sys
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks import elastic_recovery
    r = elastic_recovery.run(n_ranks=3, tokens_per_rank=1024,
                             max_doc=512, steps=6, kill_step=2)
    assert r["no_step_failed"]
    assert r["bit_identical"]
    assert r["deterministic_replay"]
    assert abs(r["steady_ratio"] - 1.0) < 0.1
    assert r["recovered_blocks"] > 0


# ===================================================================
# Mask-era pricing in the elastic paths (DESIGN.md §9 + §12)
# ===================================================================

SLIDING = MaskSpec(kind="sliding", window=2 * BLK, sink=0)


def _sliding_segs(d=3, nb=16):
    """The recovery-drift layout: the killed rank holds one deep doc
    (area-heavy, mask-cheap under sliding) plus four shallow docs; the
    survivors sit at staggered base loads chosen so dense-area pricing
    funnels every shallow run onto the busier survivor while live
    pricing alternates them — derived analytically from the sliding
    live-cost profile l(n) = 3n - 3."""
    segs = np.zeros((d, nb * BLK), np.int32)

    def put(r, t0, nblocks, sid):
        segs[r, t0 * BLK:(t0 + nblocks) * BLK] = sid
        return t0 + nblocks

    t = put(0, 0, 4, 1)                       # rank 0: live 9 + 1 = 10
    put(0, t, 1, 2)
    t = put(1, 0, 8, 3)                       # rank 1: live 21 + 4*3
    for i in range(4):
        t = put(1, t, 2, 4 + i)
    put(2, 0, 11, 8)                          # rank 2: live 30
    return segs


def test_masked_recovery_balances_live_compute():
    """Killing one of N under a sliding mask: recovery priced by live
    blocks keeps the survivors' realized live-compute max/mean within
    1.1 — the same layout priced by dense rectangle area (the pre-fix
    drift) exceeds it, because area pricing deals deep mask-cheap runs
    as if they were expensive."""
    d, nb = 3, 16
    cfg = make_cfg(d, nb)
    segs = _sliding_segs(d, nb)
    sess = make_session(d, nb, plan_policy="identity", mask=SLIDING)
    plan, _ = sess.plan(segs)
    docs, doc_of, bi_of = layout_from_segments(segs, BLK, d)
    cost = block_costs(doc_of, bi_of, BLK, None, SLIDING)  # true compute
    full = assignment_of_plan(cfg, plan)
    surv = [0, 2]
    base = {s: float(cost[(full == s) & (doc_of >= 0)].sum())
            for s in surv}

    def realized_ratio(pricing_mask):
        rec = build_recovery_plan(cfg, segs, plan, (1,), allowed=surv,
                                  base_loads=base, mask=pricing_mask)
        final = np.where(rec.lost, rec.assign, full)
        loads = np.array([cost[(final == s) & (doc_of >= 0)].sum()
                          for s in surv])
        return float(loads.max() / loads.mean())

    assert realized_ratio(SLIDING) <= 1.1     # live pricing: balanced
    assert realized_ratio(None) > 1.1         # area pricing: drifts


def test_speculation_prices_masked_tasks_by_live_kv():
    """The straggler deadline math consumes *live* kv lengths under a
    mask: ``begin_step``'s task shapes equal ``iter_plan_tasks`` with
    the session mask (strictly below the dense rectangle lengths), and
    the per-server predictions it derives — the spread the speculation
    deadline compares against — equal the live-kv cost-model sum, not
    the rectangle one."""
    d, nb = 3, 16
    segs = _sliding_segs(d, nb)
    sess = make_session(d, nb, mask=SLIDING)
    ex = make_executor(sess)
    q, k, v, pos = synth(ex, segs)
    st = ex.begin_step(0, q, k, v, pos, segs)

    live, rect = {}, {}
    for s, _slot, qt, kvt in iter_plan_tasks(sess.cfg, st.plan,
                                             sess.mask):
        live.setdefault(s, []).append((qt, kvt))
    for s, _slot, qt, kvt in iter_plan_tasks(sess.cfg, st.plan):
        rect.setdefault(s, []).append((qt, kvt))
    assert {s: t for s, t in st.tasks_by.items() if t} == live
    assert live != rect                       # the mask genuinely trims
    pl, pr = {}, {}
    for s in live:
        pl[s] = sum(float(st.cm.predict(qt, kvt))
                    for qt, kvt in live[s]) / float(st.speeds[s])
        pr[s] = sum(float(st.cm.predict(qt, kvt))
                    for qt, kvt in rect[s]) / float(st.speeds[s])
        assert st.preds[s] == pytest.approx(pl[s], rel=1e-12)
        assert pl[s] <= pr[s]
    assert sum(pl.values()) < sum(pr.values())   # trimming is real


def test_executor_masked_kill_bit_identical_to_reduced_pool():
    """The §9 acceptance property under a non-trivial mask: a masked
    step with a mid-step kill merges to the bit-identical output of a
    fault-free masked run on the reduced pool (and the fault-free full
    pool matches the masked single-pool oracle, proving the mask
    reached the serve)."""
    d, nb = 3, 16
    segs = _sliding_segs(d, nb)
    sess = make_session(d, nb, mask=SLIDING)
    ex_ref = make_executor(make_session(d, nb, mask=SLIDING))
    ex = make_executor(sess, faults=FaultSchedule.parse("kill:1@0"))
    q, k, v, pos = synth(ex, segs, seed=21)

    ref, rep0 = ex_ref.run_step(0, q, k, v, pos, segs)
    plan, _ = ex_ref.session.plan(segs)
    cad = CADContext(cfg=sess.cfg, kernel=sess.kernel, jmax=sess.jmax,
                     mask=SLIDING)
    oracle = _global_sim(q, k, v, pos, jax.tree.map(jnp.asarray, plan),
                         cad, 0.0, None)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(oracle))

    out, rep = ex.run_step(0, q, k, v, pos, segs)
    assert rep.failed == (1,) and rep.recovered_blocks > 0

    pool_b = ServerPool(d)
    pool_b.remove(1)
    ex_b = make_executor(make_session(d, nb, mask=SLIDING)
                         .with_pool(pool_b))
    out_b, _ = ex_b.run_step(0, q, k, v, pos, segs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_b))
