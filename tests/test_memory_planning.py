"""Memory-aware planning + chunked KV streaming (DESIGN.md §11).

Targeted scenarios complementing the property sweep in
``test_planner_properties.py::test_memory_budget_invariant``:

  * exact-budget fit: budgets equal to the layout's resident bytes
    plan successfully with zero slack;
  * a document whose final task overflows *every* budget streams its
    kv prefix instead of failing — and raises :class:`PlanMemoryError`
    when streaming is off;
  * heterogeneous budgets + speeds: the scheduler balances modeled
    time while never crossing any endpoint's individual budget;
  * chunked streaming is bit-identical to the unstreamed dispatch
    path, for every chunk size including ragged final chunks;
  * budget-aware recovery lands lost tasks on survivors with memory
    headroom;
  * CADConfig per-server list validation reports the index AND the
    offending value (regression: the old message omitted both).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cad import CADConfig, PlanMemoryError, get_planner
from repro.cad.session import CADSession
from repro.core.cost_model import CommModel, MemoryModel
from repro.core.mask import MaskSpec
from repro.core.dispatch import (CADContext, assemble_step_outputs,
                                 build_server_inputs, serve_task_batch,
                                 stream_task_batch)
from repro.core.scheduler import (assignment_resident_bytes,
                                  layout_from_segments,
                                  streamed_doc_ids)
from repro.runtime.recovery import build_recovery_plan

BLK = 16
COMM = CommModel(n_heads=2, head_dim=16, n_kv_heads=2)
MEM = MemoryModel(COMM)


def _segs_one_long_doc(n_ranks=4, nb=8):
    """Rank 0: one doc spanning all blocks; ranks 1+: one 1-block doc."""
    segs = np.zeros((n_ranks, nb * BLK), np.int64)
    segs[0, :] = 1
    for r in range(1, n_ranks):
        segs[r, :BLK] = 10 * r + 1
    return segs


def _cfg(n_ranks=4, nb=8, **kw):
    return CADConfig.default(n_ranks, nb * BLK, blk=BLK, **kw)


def _resident_of(cfg, res, segs, *, stream_chunk=0):
    docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk,
                                               cfg.n_servers)
    return assignment_resident_bytes(res.assign, doc_of, bi_of, cfg.blk,
                                     cfg.n_servers, MEM,
                                     streamed=res.streamed,
                                     stream_chunk=stream_chunk)


# ---------------------------------------------------------------- budgets
def test_exact_budget_fit():
    """Budgets equal to the identity layout's resident bytes (zero
    slack) must plan, not raise — the boundary is inclusive."""
    segs = _segs_one_long_doc()
    cfg0 = _cfg()
    ident = get_planner("identity")(cfg0, segs, comm=COMM,
                                    mem_model=MEM)
    exact = tuple(float(b) for b in ident.resident_bytes)
    cfg = _cfg(server_hbm=exact)
    res = get_planner("identity")(cfg, segs, comm=COMM)
    np.testing.assert_allclose(np.asarray(res.resident_bytes), exact)
    assert res.stats["peak_resident_bytes"] == max(exact)
    assert res.stats["resident_max_over_mean"] >= 1.0


def test_oversized_task_streams_instead_of_failing():
    """A doc whose final task (one q block + full kv prefix) exceeds
    every endpoint's budget streams; the plan completes within budget
    with the doc's kv clamped to the chunk."""
    segs = _segs_one_long_doc()
    nb = 8
    final_task = MEM.task_bytes(BLK, nb * BLK)
    budget = 0.7 * final_task                # no endpoint can hold it
    cfg = _cfg(server_hbm=(budget,) * 4, stream_chunk=2)
    res = get_planner("balanced")(cfg, segs, comm=COMM, tolerance=0.05)
    assert res.streamed == (0,)              # the long doc, id order 0
    resident = np.asarray(res.resident_bytes)
    assert (resident <= budget + 1e-9).all()
    np.testing.assert_allclose(
        resident, _resident_of(cfg, res, segs, stream_chunk=2))


def test_oversized_task_without_streaming_raises():
    segs = _segs_one_long_doc()
    budget = 0.7 * MEM.task_bytes(BLK, 8 * BLK)
    cfg = _cfg(server_hbm=(budget,) * 4)     # stream_chunk = 0
    with pytest.raises(PlanMemoryError) as ei:
        get_planner("balanced")(cfg, segs, comm=COMM, tolerance=0.05)
    assert ei.value.resident_bytes > ei.value.budget_bytes
    assert "stream" in str(ei.value)


def test_heterogeneous_budgets_and_speeds():
    """A fast server attracts work for time balance but its small
    budget caps what it may hold; slower servers with room absorb the
    spill.  Both constraints hold simultaneously."""
    segs = _segs_one_long_doc()
    speeds = (1.0, 1.0, 1.0, 4.0)
    cfg0 = _cfg(server_speeds=speeds)
    free = get_planner("balanced")(cfg0, segs, comm=COMM,
                                   tolerance=0.05, mem_model=MEM)
    resident0 = np.asarray(free.resident_bytes)
    # the fast server's unconstrained residency becomes its ceiling cut
    hbm = tuple(1e9 if s != 3 else 0.7 * resident0[3]
                for s in range(4))
    cfg = _cfg(server_speeds=speeds, server_hbm=hbm, stream_chunk=2)
    res = get_planner("balanced")(cfg, segs, comm=COMM, tolerance=0.05)
    resident = np.asarray(res.resident_bytes)
    assert (resident <= np.asarray(hbm) + 1e-9).all()
    assert resident0[3] > hbm[3]             # the cut actually binds
    assert res.loads.max() > 0
    np.testing.assert_allclose(
        resident, _resident_of(cfg, res, segs,
                               stream_chunk=cfg.stream_chunk))


def test_fixed_layout_over_budget_raises_with_hint():
    segs = _segs_one_long_doc()
    cfg0 = _cfg()
    ident = get_planner("identity")(cfg0, segs, comm=COMM,
                                    mem_model=MEM)
    # above the oversized doc's final-task bytes (so nothing needs to
    # stream) yet below the identity layout's residency on rank 0
    tight = tuple(0.6 * float(b) if b > 0 else 1.0
                  for b in ident.resident_bytes)
    assert max(tight) > MEM.task_bytes(BLK, 8 * BLK)
    cfg = _cfg(server_hbm=tight)
    with pytest.raises(PlanMemoryError, match="fixed layout"):
        get_planner("identity")(cfg, segs, comm=COMM)


def test_streamed_doc_ids_scope():
    segs = _segs_one_long_doc()
    docs, _doc_of, _bi = layout_from_segments(segs, BLK, 4)
    budgets = np.full(4, 0.7 * MEM.task_bytes(BLK, 8 * BLK))
    assert streamed_doc_ids(docs, BLK, MEM, budgets,
                            stream_chunk=2) == (0,)
    # one roomy endpoint in the pool -> nothing needs to stream
    budgets[2] = 1e9
    assert streamed_doc_ids(docs, BLK, MEM, budgets,
                            stream_chunk=2) == ()
    # ... unless that endpoint is not in the allowed set
    assert streamed_doc_ids(docs, BLK, MEM, budgets, stream_chunk=2,
                            allowed=(0, 1, 3)) == (0,)


# -------------------------------------------------------------- streaming
@pytest.mark.parametrize("chunk", [1, 2, 3, 5, 8])
def test_stream_serve_bit_identical(chunk):
    """Chunked kv streaming partitions the flash scan; outputs must be
    bit-identical to the unstreamed path for every chunk size,
    including ragged final chunks."""
    segs = _segs_one_long_doc(n_ranks=2, nb=4)
    cfg = _cfg(n_ranks=2, nb=4)
    res = get_planner("balanced")(cfg, segs, comm=COMM, tolerance=0.05)
    D, s_len = segs.shape
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(kq, (D, s_len, 2, 16), jnp.float32)
    k = jax.random.normal(kk, (D, s_len, 2, 16), jnp.float32)
    v = jax.random.normal(kv, (D, s_len, 2, 16), jnp.float32)
    pos = jnp.asarray(np.where(segs > 0, np.arange(s_len)[None, :],
                               -1).astype(np.int32))
    cad = CADContext(cfg=cfg, kernel="xla")
    inputs, plans_r = build_server_inputs(cad, res.plan, q, k, v, pos)
    for s in range(D):
        plain = np.asarray(serve_task_batch(cad, inputs[s], plans_r[s]))
        streamed = np.asarray(serve_task_batch(
            cad, inputs[s], plans_r[s], stream_chunk=chunk))
        assert plain.tobytes() == streamed.tobytes(), \
            f"server {s} chunk {chunk} not bit-identical"


@pytest.mark.parametrize("spec", [
    MaskSpec(kind="sliding", window=24),
    MaskSpec(kind="sliding", window=16, sink=16),
    MaskSpec(kind="dilated", rate=2),
])
@pytest.mark.parametrize("chunk", [1, 3, 5])
def test_stream_bit_identical_under_masks(chunk, spec):
    """Streaming must commute with every task shape (DESIGN.md §12):
    chunked kv serving under sliding/sink/dilated masks is bit-identical
    to the unstreamed masked path, for ragged chunk sizes too.  The
    online-softmax no-op property makes this exact, not approximate:
    fully-masked kv positions contribute exp(-inf) = 0 in either
    partitioning."""
    segs = _segs_one_long_doc(n_ranks=2, nb=4)
    cfg = _cfg(n_ranks=2, nb=4)
    res = get_planner("balanced")(cfg, segs, comm=COMM, tolerance=0.05,
                                  mask=spec)
    D, s_len = segs.shape
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(kq, (D, s_len, 2, 16), jnp.float32)
    k = jax.random.normal(kk, (D, s_len, 2, 16), jnp.float32)
    v = jax.random.normal(kv, (D, s_len, 2, 16), jnp.float32)
    pos = jnp.asarray(np.where(segs > 0, np.arange(s_len)[None, :],
                               -1).astype(np.int32))
    cad = CADContext(cfg=cfg, kernel="xla", mask=spec)
    inputs, plans_r = build_server_inputs(cad, res.plan, q, k, v, pos)
    for s in range(D):
        plain = np.asarray(serve_task_batch(cad, inputs[s], plans_r[s]))
        streamed = np.asarray(serve_task_batch(
            cad, inputs[s], plans_r[s], stream_chunk=chunk))
        explicit = np.asarray(stream_task_batch(
            cad, inputs[s], plans_r[s], chunk_blocks=chunk))
        assert plain.tobytes() == streamed.tobytes() \
            == explicit.tobytes(), \
            f"server {s} chunk {chunk} mask {spec.describe()} " \
            f"not bit-identical"


def test_stream_via_config_and_explicit_call():
    """``cfg.stream_chunk`` turns on streaming for the whole dispatch
    path; ``stream_task_batch`` is the explicit entry and rejects a
    zero chunk."""
    segs = _segs_one_long_doc(n_ranks=2, nb=4)
    cfg = _cfg(n_ranks=2, nb=4)
    res = get_planner("balanced")(cfg, segs, comm=COMM, tolerance=0.05)
    cfg_s = dataclasses.replace(cfg, stream_chunk=3)
    D, s_len = segs.shape
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(kq, (D, s_len, 2, 16), jnp.float32)
    k = jax.random.normal(kk, (D, s_len, 2, 16), jnp.float32)
    v = jax.random.normal(kv, (D, s_len, 2, 16), jnp.float32)
    pos = jnp.asarray(np.where(segs > 0, np.arange(s_len)[None, :],
                               -1).astype(np.int32))
    cad0 = CADContext(cfg=cfg, kernel="xla")
    cad1 = CADContext(cfg=cfg_s, kernel="xla")
    inputs, plans_r = build_server_inputs(cad0, res.plan, q, k, v, pos)
    outs0 = {s: serve_task_batch(cad0, inputs[s], plans_r[s])
             for s in range(D)}
    outs1 = {s: serve_task_batch(cad1, inputs[s], plans_r[s])
             for s in range(D)}
    outs2 = {s: stream_task_batch(cad0, inputs[s], plans_r[s],
                                  chunk_blocks=3) for s in range(D)}
    a = np.asarray(assemble_step_outputs(cfg, res.plan, outs0, q.shape,
                                         q.dtype))
    b = np.asarray(assemble_step_outputs(cfg_s, res.plan, outs1,
                                         q.shape, q.dtype))
    c = np.asarray(assemble_step_outputs(cfg, res.plan, outs2, q.shape,
                                         q.dtype))
    assert a.tobytes() == b.tobytes() == c.tobytes()
    with pytest.raises(ValueError, match="chunk"):
        stream_task_batch(cad0, inputs[0], plans_r[0], chunk_blocks=0)


# --------------------------------------------------------------- recovery
def test_recovery_prefers_survivor_with_headroom():
    """Budget-aware recovery: a survivor already at its HBM ceiling is
    skipped; the lost run lands on the survivor with room even when it
    is the more loaded one."""
    segs = _segs_one_long_doc(n_ranks=3, nb=4)
    cfg = _cfg(n_ranks=3, nb=4)
    res = get_planner("balanced")(cfg, segs, comm=COMM, tolerance=0.05)
    budgets = np.full(3, 1e9)
    # survivor 1 is declared full; survivor 2 idle-but-roomy
    rec = build_recovery_plan(
        cfg, segs, res.plan, [0], allowed=[1, 2],
        base_loads={1: 0.0, 2: 1e6}, mem_model=MEM, budgets=budgets,
        base_resident={1: 1e9, 2: 0.0})
    assert rec is not None
    moved_to = set(int(s) for s in rec.assign[rec.lost])
    assert moved_to == {2}
    # without budgets the same loads send everything to survivor 1
    rec0 = build_recovery_plan(cfg, segs, res.plan, [0],
                               allowed=[1, 2],
                               base_loads={1: 0.0, 2: 1e6})
    assert set(int(s) for s in rec0.assign[rec0.lost]) == {1}


def test_recovery_never_drops_when_nothing_fits():
    """When no survivor has budget headroom the least-loaded one takes
    the run anyway (streaming bounds the hardware residency) — a lost
    task is never dropped for memory."""
    segs = _segs_one_long_doc(n_ranks=3, nb=4)
    cfg = _cfg(n_ranks=3, nb=4, stream_chunk=1)
    res = get_planner("balanced")(cfg, segs, comm=COMM, tolerance=0.05)
    rec = build_recovery_plan(
        cfg, segs, res.plan, [0], allowed=[1, 2],
        base_loads={1: 0.0, 2: 5.0}, mem_model=MEM,
        budgets=np.full(3, 1.0), base_resident={1: 0.0, 2: 0.0},
        stream_chunk=1)
    assert rec is not None and rec.n_blocks > 0


# ------------------------------------------------------------ validation
@pytest.mark.parametrize("field", ["server_speeds", "server_hbm"])
def test_per_server_list_reports_index_and_value(field):
    bad = (1.0, -2.5, 1.0)
    with pytest.raises(ValueError) as ei:
        CADConfig(n_servers=3, blk=BLK, nb=4, cq=4, ckv=8, nkv=16,
                  **{field: bad})
    msg = str(ei.value)
    assert f"{field}[1]" in msg              # the index
    assert "-2.5" in msg                     # the offending value
    with pytest.raises(ValueError, match="needs 3 entries, got 2"):
        CADConfig(n_servers=3, blk=BLK, nb=4, cq=4, ckv=8, nkv=16,
                  **{field: (1.0, 1.0)})


def test_nan_budget_rejected():
    with pytest.raises(ValueError, match=r"server_hbm\[0\]"):
        CADConfig(n_servers=2, blk=BLK, nb=4, cq=4, ckv=8, nkv=16,
                  server_hbm=(float("nan"), 1.0))


def test_config_accessors_and_session_threading():
    cfg = _cfg(n_ranks=2, nb=4, server_hbm=(100.0, 200.0),
               stream_chunk=3)
    np.testing.assert_allclose(cfg.budgets(), [100.0, 200.0])
    assert _cfg(n_ranks=2, nb=4).budgets() is None
    assert cfg.stream_chunk == 3
    with pytest.raises(ValueError, match="stream_chunk"):
        _cfg(n_ranks=2, nb=4, stream_chunk=-1)

    import types
    heads = types.SimpleNamespace(n_heads=2, head_dim=16, n_kv_heads=2)
    pipe = types.SimpleNamespace(n_ranks=2, global_batch=2, seq_len=64,
                                 max_doc_len=64)
    session = CADSession.for_pipeline(heads, pipe,
                                      server_hbm=(1e6, 2e6),
                                      stream_chunk=4)
    np.testing.assert_allclose(session.cfg.budgets(), [1e6, 2e6])
    assert session.cfg.stream_chunk == 4
