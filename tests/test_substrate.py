"""Substrate tests: data pipeline invariants (hypothesis), optimizer,
checkpointing round-trip, and end-to-end training-loss descent with CAD."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev extra; property tests only
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.data.distributions import sample_lengths
from repro.data.packing import (BLOCK, chunk_attention_cost,
                                chunk_tokens_used, pack_documents)
from repro.models import model as M
from repro.optim.adamw import AdamW, cosine_schedule
from repro.parallel import ParallelContext


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       dist=st.sampled_from(["pretrain", "prolong"]),
       strategy=st.sampled_from(["fixed", "variable"]))
def test_packing_invariants(seed, dist, strategy):
    rng = np.random.default_rng(seed)
    lens = sample_lengths(dist, rng, 64, 2048)
    chunks = pack_documents(lens, 2048, 4, rng=rng, strategy=strategy)
    assert len(chunks) == 4
    for c in chunks:
        assert c.tokens.shape == (2048,)
        # doc starts 128-aligned, blocks document-pure
        seg_b = c.segment_ids.reshape(-1, BLOCK)
        for blk_row in seg_b:
            nz = blk_row[blk_row != 0]
            assert len(set(nz.tolist())) <= 1
        # positions are within-doc arange
        for s in set(c.segment_ids.tolist()) - {0}:
            p = c.positions[c.segment_ids == s]
            np.testing.assert_array_equal(p, np.arange(len(p)))


def test_variable_packing_balances_cost():
    """WLB-style variable packing has lower Σl² divergence than fixed
    packing but (generally) higher token divergence — §3.2's trade-off."""
    rng = np.random.default_rng(0)
    lens = sample_lengths("pretrain", rng, 512, 8192)
    fixed = pack_documents(lens, 16384, 8, rng=np.random.default_rng(1),
                           strategy="fixed")
    var = pack_documents(lens, 16384, 8, rng=np.random.default_rng(1),
                         strategy="variable")

    def div(cs, fn):
        v = np.array([fn(c) for c in cs], np.float64)
        return v.max() / max(v.mean(), 1e-9)

    assert div(var, chunk_attention_cost) <= div(fixed,
                                                 chunk_attention_cost) + 0.05


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.ones(4) * 5.0}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) < 2e-4


def test_checkpoint_roundtrip():
    from repro.checkpoint import ckpt
    cfg = get_config("smollm-360m").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW()
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, params, state)
        assert ckpt.latest_step(d) == 7
        restored = ckpt.restore(d, 7, {"params": params,
                                       "opt_state": state})
        for a, b in zip(jax.tree.leaves(restored["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_train_loss_decreases_with_cad():
    """30 steps on a tiny llama with the full CAD path (scheduler plans,
    global-sim pool of 2 servers): loss must drop."""
    from repro.cad import CADSession
    from repro.data.pipeline import PipelineConfig
    from repro.train.trainer import TrainConfig, train
    cfg = get_config("smollm-360m").reduced()
    pipe = PipelineConfig(distribution="pretrain", max_doc_len=256,
                          seq_len=256, global_batch=4, n_ranks=2,
                          vocab_size=cfg.vocab_size, seed=0)
    session = CADSession.for_pipeline(cfg, pipe, kernel="xla")
    res = train(cfg, pipe, TrainConfig(steps=40, peak_lr=5e-3, warmup=5,
                                       log_every=39), session=session)
    first = res["history"][0]["loss"]
    last = res["history"][-1]["loss"]
    # uniform-random tokens: floor is ln(V)≈6.24; require clear descent
    assert last < first - 0.2, (first, last)
