"""Validate the trip-count-aware HLO analyzer against known modules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    txt = _compile(lambda x, y: x @ y, a, b)
    c = analyze(txt)
    expected = 2 * 1024 * 512 * 256
    assert abs(c.flops - expected) / expected < 0.01, c.flops


def test_scan_flops_trip_weighted():
    """The whole point: a scan of length 10 must count 10x the body."""
    def f(a, b):
        def body(c, _):
            return c @ b, None
        c, _ = jax.lax.scan(body, a, None, length=10)
        return c
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    txt = _compile(f, a, b)
    c = analyze(txt)
    expected = 10 * 2 * 512 ** 3
    assert abs(c.flops - expected) / expected < 0.05, c.flops
    # sanity: XLA's own cost_analysis misses the trip count
    ca = jax.jit(f).lower(a, b).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):      # pre-0.5 jax returns a list
        ca = ca[0]
    assert ca["flops"] < expected / 5


def test_nested_scan():
    def f(a, b):
        def outer(c, _):
            def inner(d, _):
                return d @ b, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        c, _ = jax.lax.scan(outer, a, None, length=4)
        return c
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = analyze(_compile(f, a, b))
    expected = 12 * 2 * 256 ** 3
    assert abs(c.flops - expected) / expected < 0.05, c.flops


def test_collective_bytes_counted():
    """all-reduce inside a pjit'd sum over a sharded axis (subprocess-free:
    uses the single device, so check the parser on synthetic HLO)."""
    hlo = """
HloModule m

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %ar = f32[64,64]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[64,64]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[64,64]) tuple(%z, %a)
  %w = (s32[], f32[64,64]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""
    c = analyze(hlo)
    assert c.collective_bytes == 7 * 64 * 64 * 4, c.collective_bytes
    assert c.collective_counts.get("all-reduce") == 7


def test_model_flops_match_analytic():
    """A reduced llama forward's analyzed flops land within 2x of 2*N*D
    (embedding gather and attention add the rest)."""
    from repro.configs import get_config
    from repro.models import model as M
    from repro.parallel import ParallelContext
    cfg = get_config("smollm-360m").reduced()
    ctx = ParallelContext(attn_impl="xla", remat=False)
    B, S = 2, 256
    params = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "segment_ids": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "positions": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    txt = jax.jit(lambda p, b: M.forward(p, cfg, b, ctx)[0]) \
        .lower(params, batch).compile().as_text()
    c = analyze(txt)
    n_matmul = cfg.n_params() - cfg.vocab_size * cfg.d_model  # embed gather
    lower = 2 * (n_matmul + cfg.vocab_size * cfg.d_model) * B * S  # +unembed
    assert c.flops > 0.8 * lower, (c.flops, lower)
    assert c.flops < 3.0 * lower, (c.flops, lower)
