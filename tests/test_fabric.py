"""Multi-tenant attention fabric (DESIGN.md §10).

Covers the tenant/priority model and SLO-aware admission (FCFS
head-of-line blocking, best-fit placement, forced admission after
``max_wait_rounds``), the serve workload's fixed task sequence and
fused-batch builder, the FabricExecutor's isolation contract (training
outputs bit-identical with serve backfilling vs a dedicated pool),
speculation preemption, kill-mid-decode recovery with deterministic
replay, serve-scheduler snapshot-provider repricing, and the
``repro.launch.serve`` HTTP daemon.
"""
import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.cad import CADConfig, CADSession
from repro.core.cost_model import (CalibrationSnapshot, CommModel,
                                   CostModel)
from repro.fabric import (LATENCY, SERVE, THROUGHPUT, TRAIN,
                          AdmissionPolicy, FabricExecutor, ServeWorkload,
                          TenantClass, admit_serve)
from repro.fabric.tenancy import ServeTaskReq
from repro.runtime import ElasticExecutor, FaultSchedule, ServerPool

BLK = 16
D, NB = 4, 8


def make_segs(d=D, nb=NB, seed=0, max_doc_blocks=4):
    rng = np.random.default_rng(seed)
    segs = np.zeros((d, nb * BLK), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < nb:
            dbl = int(rng.integers(1, min(max_doc_blocks, nb - t) + 1))
            segs[r, t * BLK:(t + dbl) * BLK] = sid
            sid += 1
            t += dbl
    return segs


def make_session(drained=()):
    cfg = CADConfig(n_servers=D, blk=BLK, nb=NB, cq=2 * NB, ckv=4 * NB,
                    nkv=4 * NB)
    sess = CADSession(cfg=cfg, comm=CommModel(2, 8, 2), tolerance=0.05,
                      jmax=NB, prefetch=0)
    pool = ServerPool(D)
    for s in drained:
        pool.drain(s)
    return sess.with_pool(pool)


def make_workload(arrivals, seed=7, slots=4):
    return ServeWorkload(arrivals, n_heads=2, head_dim=8, blk=BLK,
                         slots=slots, seed=seed)


def run_fabric(arrivals, steps, *, drained=(), allowed=None, faults=None,
               interval=1e-3, speculate_pct=0.0, max_steps=None,
               seed=0):
    wl = make_workload(arrivals)
    ex = FabricExecutor(
        make_session(drained=drained), wl,
        faults=FaultSchedule.parse(faults) if faults else None,
        policy=AdmissionPolicy(allowed=allowed),
        speculate_pct=speculate_pct)
    digests, reports = [], []
    step = 0
    while step < steps or (max_steps and step < max_steps
                           and not wl.all_done()):
        segs = make_segs(seed=step)
        pos = np.broadcast_to(np.arange(segs.shape[1]), segs.shape).copy()
        q, k, v, pos = ex.synth_inputs(segs, pos, seed=seed + step)
        out, rep = ex.run_mixed_step(step, q, k, v, pos, segs,
                                     interval=interval)
        digests.append(np.asarray(out).tobytes())
        reports.append(rep)
        step += 1
    return wl, digests, reports


def snap_of(cm=None, speeds=(1.0,) * D, version=0):
    return CalibrationSnapshot(version=version,
                               cost_model=cm or CostModel.analytic(2, 8),
                               speeds=tuple(speeds))


def task(rid, q=BLK, kv=2 * BLK, seq=0, arrival=0):
    return ServeTaskReq(rid=rid, seq=seq, q_tokens=q, kv_tokens=kv,
                        arrival_step=arrival)


# ===================================================================
# tenancy: classes + admission
# ===================================================================

def test_tenant_classes():
    assert TRAIN.kind == THROUGHPUT and SERVE.kind == LATENCY
    assert TRAIN.priority < SERVE.priority
    assert SERVE.preempts_speculation and not TRAIN.preempts_speculation
    with pytest.raises(ValueError, match="tenant kind"):
        TenantClass(name="x", kind="bursty", priority=2)


def test_admission_backfills_idle_capacity():
    """Tasks land on the candidate with the most remaining idle; busy
    servers receive nothing they cannot fit."""
    cm = CostModel.analytic(2, 8)
    cost = float(cm.predict(BLK, 2 * BLK))
    interval = 4 * cost
    # server 0 fully busy, 1 half busy, 2 and 3 idle
    busy = {0: interval, 1: interval - 2 * cost, 2: 0.0, 3: 0.0}
    rnd = admit_serve([task(r) for r in range(6)], busy, interval,
                      snap_of(cm), None, candidates=(0, 1, 2, 3))
    assert rnd.n_admitted == 6 and not rnd.deferred
    assert 0 not in rnd.placements
    # best-fit max-idle, ties to the lowest slot: 2,3,2,3, then the
    # half-busy 1 ties with the drained-down 2/3 at 2*cost left
    placed = {s: len(t) for s, t in rnd.placements.items()}
    assert placed[2] + placed[3] >= 4
    assert sum(placed.values()) == 6
    assert all(v >= -1e-12 for v in rnd.idle_after.values())


def test_admission_fcfs_head_of_line_blocks():
    """The first unfittable task defers everything behind it, even
    tasks that would fit — deterministic FCFS, no reordering."""
    cm = CostModel.analytic(2, 8)
    cost = float(cm.predict(BLK, 2 * BLK))
    small = task(1, q=1, kv=BLK)
    big = task(0, q=BLK, kv=2 * BLK)
    rnd = admit_serve([big, small], {0: 0.0}, 0.5 * cost, snap_of(cm),
                      None, candidates=(0,))
    assert rnd.n_admitted == 0
    assert [t.rid for t in rnd.deferred] == [0, 1]


def test_admission_forced_after_max_wait():
    """A head-of-line task past ``max_wait_rounds`` goes through even
    with no idle budget left (the forward-progress guarantee), and
    admission continues behind it."""
    cm = CostModel.analytic(2, 8)
    cost = float(cm.predict(BLK, 2 * BLK))
    pol = AdmissionPolicy(max_wait_rounds=3)
    waits = {0: 3}
    rnd = admit_serve([task(0), task(1, q=1, kv=BLK)], {0: 0.0, 1: 0.0},
                      0.1 * cost, snap_of(cm), None, policy=pol,
                      candidates=(0, 1), waits=waits)
    assert rnd.forced == (0,)
    assert 0 in {t.rid for g in rnd.placements.values() for t in g}
    # without the wait history the same round defers everything
    rnd2 = admit_serve([task(0), task(1, q=1, kv=BLK)],
                       {0: 0.0, 1: 0.0}, 0.1 * cost, snap_of(cm), None,
                       policy=pol, candidates=(0, 1))
    assert rnd2.n_admitted == 0 and len(rnd2.deferred) == 2


def test_admission_allowed_partition_and_slo():
    """``policy.allowed`` confines serve placement (the static-partition
    baseline); deferred tasks older than ``slo_rounds`` count as
    misses; the round is stamped with the view's epoch."""
    cm = CostModel.analytic(2, 8)
    cost = float(cm.predict(BLK, 2 * BLK))
    pol = AdmissionPolicy(slo_rounds=2, allowed=(2, 3))
    rnd = admit_serve([task(r) for r in range(4)],
                      {s: 0.0 for s in range(4)}, 1.01 * cost,
                      snap_of(cm), None, policy=pol,
                      candidates=(0, 1, 2, 3), waits={2: 2, 3: 5})
    assert set(rnd.placements) <= {2, 3}
    assert rnd.n_admitted == 2 and len(rnd.deferred) == 2
    assert rnd.slo_misses == 2          # rids 2 and 3 both past the SLO
    assert rnd.pool_epoch == -1         # no view supplied

    view = make_session().pool.view()
    rnd2 = admit_serve([], {}, 1.0, snap_of(cm), view)
    assert rnd2.pool_epoch == view.epoch


# ===================================================================
# workload: task sequence + fused batch builder
# ===================================================================

def test_workload_task_sequence_is_fixed():
    """Prefill chunks of <= blk tokens, then one decode per round —
    content (hence output) of task ``seq`` never depends on timing."""
    wl = make_workload([(0, 3 * BLK + 4, 2)])
    r = wl.requests[0]
    seen = []
    while not r.done:
        seq, qt, kvt = r.next_task(BLK)
        seen.append((seq, qt, kvt))
        if r.n_prefilled < r.prompt_len:
            r.n_prefilled += qt
        else:
            r.n_decoded += 1
    assert seen == [(0, BLK, BLK), (1, BLK, 2 * BLK),
                    (2, BLK, 3 * BLK), (3, 4, 3 * BLK + 4),
                    (4, 1, 3 * BLK + 5), (5, 1, 3 * BLK + 6)]


def test_workload_build_batch_layout():
    wl = make_workload([(0, 2 * BLK, 1), (0, BLK // 2, 1)])
    tasks = wl.pending(0)
    assert [t.q_tokens for t in tasks] == [BLK, BLK // 2]
    inputs, plan = wl.build_batch(tasks)
    q_tasks, qpos, k_buf, v_buf, kpos = (np.asarray(a) for a in inputs)
    assert q_tasks.shape == (wl.slots, BLK, 2, 8)
    assert k_buf.shape[0] == wl.kv_blocks
    # dead q rows/pad kv rows carry position -1
    assert (np.asarray(qpos)[1, BLK // 2:] == -1).all()
    start = np.asarray(plan["task_kv_start"])
    ln = np.asarray(plan["task_kv_len"])
    assert ln[0] == 1 and ln[1] == 1 and start[1] == 1
    assert (np.asarray(kpos)[1, BLK // 2:] == -1).all()
    with pytest.raises(ValueError, match="slots"):
        wl.build_batch([task(0)] * (wl.slots + 1))


def test_workload_rejects_empty_prompt_and_blk_mismatch():
    with pytest.raises(ValueError, match="empty prompt"):
        make_workload([(0, 0, 1)])
    with pytest.raises(ValueError, match="blk"):
        FabricExecutor(make_session(),
                       ServeWorkload([(0, 8, 1)], blk=128))


# ===================================================================
# fabric executor: isolation, preemption, recovery
# ===================================================================

def _train_only(steps, seed=0):
    ex = ElasticExecutor(make_session())
    digests = []
    for step in range(steps):
        segs = make_segs(seed=step)
        pos = np.broadcast_to(np.arange(segs.shape[1]), segs.shape).copy()
        q, k, v, pos = ex.synth_inputs(segs, pos, seed=seed + step)
        out, _rep = ex.run_step(step, q, k, v, pos, segs)
        digests.append(np.asarray(out).tobytes())
    return digests


def test_train_bit_identical_with_serve_backfill():
    """The isolation contract: training outputs with serve traffic
    backfilling the same pool match a dedicated-pool run bit-for-bit,
    and the serve tenant also completes."""
    arr = [(0, 2 * BLK, 2), (1, BLK, 1), (1, 3 * BLK, 2)]
    wl, digests, reps = run_fabric(arr, 8)
    assert digests == _train_only(8)
    assert wl.all_done()
    assert sum(r.executed for r in reps) \
        == sum(len(r.digests) for r in wl.requests)
    assert all(r.calib_version == reps[0].calib_version for r in reps)


def test_serve_preempts_speculation_not_primaries():
    """With serve tasks pending, the step's speculation budget goes to
    the latency tenant (spec_preempted, no backups run); once the
    workload drains, speculation resumes (a straggler in the late
    steps gets a backup).  Primary-task outputs are untouched
    throughout."""
    wl, digests, reps = run_fabric([(0, BLK, 1)], 6, speculate_pct=0.9,
                                   faults="slow:1x8@3-5")
    assert reps[0].spec_preempted
    assert reps[0].train.speculated == ()
    drained = [r for r in reps if r.admitted == 0 and r.deferred == 0]
    assert drained and not any(r.spec_preempted for r in drained)
    assert any(r.train.speculated for r in drained)
    assert digests == _train_only(6)


def test_kill_mid_decode_recovers_and_replays():
    """Killing a server mid-step loses its serve placements along with
    its train tasks: serve re-places onto least-loaded survivors in the
    same round, both tenants complete, and the whole run replays
    deterministically."""
    arr = [(0, 2 * BLK, 3)] * 8        # enough load to cover the victim
    kw = dict(steps=6, faults="kill:1@3", max_steps=30)
    wl1, d1, r1 = run_fabric(arr, **kw)
    wl2, d2, r2 = run_fabric(arr, **kw)
    kill = r1[3]
    assert kill.train.failed == (1,)
    assert kill.lost_serve > 0 and kill.readmitted == kill.lost_serve
    assert wl1.all_done()
    assert r1[-1].pool_epoch == 1
    # deterministic replay: train + serve outputs, completion, timing
    assert d1 == d2
    assert wl1.digest_map() == wl2.digest_map()
    assert wl1.completion() == wl2.completion()
    assert [r.step_seconds for r in r1] == [r.step_seconds for r in r2]
    # placement-independence: the kill run's per-request digests match
    # the fault-free run's (prefix — both ran the same task sequences)
    wl0, _d0, _r0 = run_fabric(arr, steps=6, max_steps=30)
    assert wl0.digest_map() == wl1.digest_map()


def test_partition_vs_shared_placement_independent():
    """Per-request serve digests agree between a shared pool and a
    static partition — outputs are pure functions of (request, task)."""
    arr = [(0, 2 * BLK, 2)] * 6
    shared, _d, _r = run_fabric(arr, 6, max_steps=30)
    part, _d2, _r2 = run_fabric(arr, 6, drained=(2, 3), allowed=(2, 3),
                                max_steps=30)
    assert shared.all_done() and part.all_done()
    assert shared.digest_map() == part.digest_map()


def test_admission_round_reports_budget_pressure():
    """A tight cadence defers work and stamps SLO misses; waits clear
    once a request's task finally runs."""
    arr = [(0, 2 * BLK, 1)] * 12
    wl, _d, reps = run_fabric(arr, 6, interval=1e-7, allowed=(3,),
                              max_steps=6)
    assert any(r.deferred > 0 for r in reps)
    assert any(r.slo_misses > 0 for r in reps[4:])
    assert not wl.all_done()


# ===================================================================
# session admission view + scheduler snapshot provider
# ===================================================================

def test_session_admission_view_fallback_and_provider():
    sess = make_session()
    snap, view = sess.admission_view()
    assert snap.version == -1                 # no calibrator: analytic
    assert len(snap.speeds) == D
    assert view.epoch == 0
    provider = sess.snapshot_provider()
    assert provider().version == snap.version


def test_scheduler_snapshot_provider_reprices_each_round():
    from repro.serve.scheduler import (ContinuousScheduler, Request,
                                       SchedulerConfig)
    calls = []

    def provider():
        calls.append(len(calls))
        return snap_of(version=len(calls))

    s = ContinuousScheduler(SchedulerConfig(
        n_slots=2, max_seq=256, admission="cost",
        snapshot_provider=provider))
    s.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                     max_new_tokens=2))
    assert [r.rid for r in s.admit()] == [0]
    assert calls == [0] and s.last_calib_version == 1   # one per round
    s.admit()
    assert len(calls) == 2 and s.last_calib_version == 2

    # cost admission needs SOME pricing source
    with pytest.raises(ValueError, match="cost_model or a "
                                         "snapshot_provider"):
        SchedulerConfig(n_slots=1, max_seq=64, admission="cost")


# ===================================================================
# HTTP daemon
# ===================================================================

def test_daemon_http_roundtrip():
    """submit/stream/health/drain through the real HTTP stack on an
    ephemeral port, with cost admission priced by the live calibrator."""
    from repro.launch import serve as L
    args = L.parse_args(["--slots", "2", "--max-seq", "64",
                         "--max-new", "4", "--admission", "cost",
                         "--calibrate"])
    daemon = L.EngineDaemon(L.build_engine(args), calibrate=True)
    srv = L.make_server(daemon, "127.0.0.1", 0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    base = f"http://127.0.0.1:{srv.server_port}"

    def post(path, obj):
        rq = urllib.request.Request(base + path,
                                    json.dumps(obj).encode())
        with urllib.request.urlopen(rq) as r:
            return json.loads(r.read())

    try:
        out = post("/generate", {"prompt": [3, 14, 15],
                                 "max_new_tokens": 3})
        assert len(out["tokens"]) == 3

        rq = urllib.request.Request(
            base + "/generate",
            json.dumps({"prompt": [1, 2], "stream": True}).encode())
        with urllib.request.urlopen(rq) as r:
            lines = [json.loads(ln) for ln in r]
        assert lines[-1]["done"] and len(lines[-1]["tokens"]) == 4
        assert [ln["token"] for ln in lines[:-1]] \
            == lines[-1]["tokens"][:-1]

        with urllib.request.urlopen(base + "/health") as r:
            h = json.loads(r.read())
        assert h["status"] == "ok" and h["done"] == 2 and h["rounds"] > 0
        # DESIGN.md §14: pool/queue fields come from the same registry
        # /metrics exports, so the two endpoints can never disagree
        assert h["queue_depth"] == 0 and h["pool_epoch"] >= 0
        assert h["calib_version"] >= -1
        with urllib.request.urlopen(base + "/metrics") as r:
            assert r.headers["Content-Type"] \
                == "text/plain; version=0.0.4"
            text = r.read().decode()
        assert "# TYPE serve_admitted_total counter" in text
        assert "# TYPE serve_rounds_total counter" in text
        assert "serve_queue_depth 0" in text

        with pytest.raises(urllib.error.HTTPError) as ei:
            post("/generate", {"prompt": []})
        assert ei.value.code == 400
        ei.value.close()

        assert post("/drain", {})["draining"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            post("/generate", {"prompt": [1]})
        assert ei.value.code == 503
        ei.value.close()
        with urllib.request.urlopen(base + "/health") as r:
            assert json.loads(r.read())["status"] == "drained"
    finally:
        daemon.stop()
        srv.shutdown()
        srv.server_close()
