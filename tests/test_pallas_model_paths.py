"""attn_impl="pallas" routes every perf-critical op through the Pallas
kernels (flash attention, RG-LRU scan, SSD intra-chunk) — the full-model
outputs must match the reference path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.parallel import ParallelContext

REF = ParallelContext(attn_impl="ref", remat=False)
PAL = ParallelContext(attn_impl="pallas", remat=False)


@pytest.mark.parametrize("arch,tol", [
    ("smollm-360m", 2e-4),
    ("gemma2-2b", 2e-4),          # softcap + sliding window kernels
    ("mamba2-370m", 5e-4),        # ssd intra-chunk kernel
    ("recurrentgemma-9b", 5e-4),  # rg-lru kernel + local attention
])
def test_pallas_model_path_matches_ref(arch, tol):
    cfg = get_config(arch).reduced()
    # kernel-friendly sizes: seq multiple of 128
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg)
    B, S = 1, 256
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    seg = jnp.concatenate([jnp.ones((B, S // 2), jnp.int32),
                           2 * jnp.ones((B, S // 2), jnp.int32)], 1)
    pos = jnp.concatenate([jnp.arange(S // 2, dtype=jnp.int32)] * 2)[
        None].repeat(B, 0)
    batch = dict(tokens=toks, labels=toks, segment_ids=seg, positions=pos)
    ref_logits, _ = M.forward(params, cfg, batch, REF)
    pal_logits, _ = M.forward(params, cfg, batch, PAL)
    np.testing.assert_allclose(np.asarray(pal_logits),
                               np.asarray(ref_logits), atol=tol, rtol=tol)


def test_pallas_model_path_grads():
    """Gradients flow through the kernel paths (custom VJPs)."""
    cfg = get_config("recurrentgemma-9b").reduced()
    key = jax.random.PRNGKey(1)
    params = M.init(key, cfg)
    B, S = 1, 128
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    batch = dict(tokens=toks, labels=toks,
                 segment_ids=jnp.ones((B, S), jnp.int32),
                 positions=jnp.arange(S, dtype=jnp.int32)[None])

    def loss(p, ctx):
        lg, _ = M.forward(p, cfg, batch, ctx)
        return jnp.mean(lg ** 2)

    g_ref = jax.grad(loss)(params, REF)
    g_pal = jax.grad(loss)(params, PAL)
    errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal))]
    assert max(errs) < 5e-3, max(errs)
