"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward and one train step on CPU with shape
and finiteness asserts.  Full configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config
from repro.models import model as M
from repro.optim.adamw import AdamW
from repro.parallel import ParallelContext
from repro.train.step import make_serve_step, make_train_step

CTX = ParallelContext(attn_impl="ref", remat=False)


def tiny_batch(cfg, key, B=2, S=64, n_docs=2):
    ks = jax.random.split(key, 3)
    toks = jax.random.randint(ks[0], (B, S), 1, cfg.vocab_size)
    dl = S // n_docs
    seg = jnp.concatenate(
        [jnp.full((B, dl), i + 1, jnp.int32) for i in range(n_docs)], axis=1)
    pos = jnp.concatenate([jnp.arange(dl, dtype=jnp.int32)] * n_docs)[
        None].repeat(B, 0)
    labels = jnp.where(
        jnp.roll(seg, -1, axis=1) == seg, jnp.roll(toks, -1, axis=1), -1)
    batch = dict(tokens=toks, labels=labels, segment_ids=seg, positions=pos)
    if cfg.encoder or cfg.family == "vlm":
        m = cfg.encoder.n_ctx if cfg.encoder else 16
        batch["memory"] = jax.random.normal(ks[1], (B, m, cfg.d_model),
                                            jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg)
    batch = tiny_batch(cfg, key)
    logits, aux = M.forward(params, cfg, batch, CTX)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    for v in aux.values():
        assert jnp.isfinite(v)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = M.init(key, cfg)
    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    step = make_train_step(cfg, CTX, opt)
    batch = tiny_batch(cfg, key, B=2, S=64)
    params2, state2, metrics = step(params, state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert metrics["grad_norm"] > 0
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2))
    assert moved


@pytest.mark.parametrize("arch", [
    "smollm-360m", "gemma2-2b", "mamba2-370m", "recurrentgemma-9b",
    "whisper-large-v3", "llama-3.2-vision-11b", "qwen2-moe-a2.7b"])
def test_decode_matches_forward(arch):
    """Incremental decode with KV/SSM/LRU caches reproduces the
    teacher-forced forward logits."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = M.init(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    seg = jnp.ones((B, S), jnp.int32)
    pos = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    batch = dict(tokens=toks, labels=toks, segment_ids=seg, positions=pos)
    mem = None
    if cfg.encoder or cfg.family == "vlm":
        m = cfg.encoder.n_ctx if cfg.encoder else 16
        mem = jax.random.normal(key, (B, m, cfg.d_model), jnp.float32) * 0.02
        batch["memory"] = mem
    logits_tf, _ = M.forward(params, cfg, batch, CTX)
    cache = M.init_cache(params, cfg, B, S, memory=mem, ctx=CTX)
    serve = make_serve_step(cfg, CTX)
    outs = []
    for t in range(S):
        _, lg, cache = serve(params, cache, toks[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    err = jnp.max(jnp.abs(logits_tf - jnp.stack(outs, 1)))
    assert err < 5e-4, f"decode mismatch {err}"


def test_local_ring_buffer_window():
    """gemma2 local layers keep only `window` tokens; decoding past the
    window must still match the windowed teacher-forced forward."""
    cfg = get_config("gemma2-2b").reduced()  # window 64 -> shrink further
    import dataclasses
    cfg = dataclasses.replace(cfg, window=8)
    key = jax.random.PRNGKey(3)
    params = M.init(key, cfg)
    B, S = 1, 32
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    seg = jnp.ones((B, S), jnp.int32)
    pos = jnp.arange(S, dtype=jnp.int32)[None]
    batch = dict(tokens=toks, labels=toks, segment_ids=seg, positions=pos)
    logits_tf, _ = M.forward(params, cfg, batch, CTX)
    cache = M.init_cache(params, cfg, B, S, ctx=CTX)
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32), CTX)
        outs.append(lg[:, 0])
    err = jnp.max(jnp.abs(logits_tf - jnp.stack(outs, 1)))
    assert err < 5e-4, f"ring-buffer decode mismatch {err}"


def test_packed_doc_isolation():
    """Packing two docs in one row gives identical logits to running each
    doc alone (no cross-document leakage) for attention AND ssm families."""
    for arch in ("smollm-360m", "mamba2-370m", "recurrentgemma-9b"):
        cfg = get_config(arch).reduced()
        key = jax.random.PRNGKey(4)
        params = M.init(key, cfg)
        S = 32
        t1 = jax.random.randint(jax.random.PRNGKey(5), (1, S), 1,
                                cfg.vocab_size)
        t2 = jax.random.randint(jax.random.PRNGKey(6), (1, S), 1,
                                cfg.vocab_size)
        packed = dict(
            tokens=jnp.concatenate([t1, t2], 1),
            labels=jnp.concatenate([t1, t2], 1),
            segment_ids=jnp.concatenate(
                [jnp.ones((1, S), jnp.int32), 2 * jnp.ones((1, S), jnp.int32)],
                1),
            positions=jnp.concatenate(
                [jnp.arange(S, dtype=jnp.int32)[None]] * 2, 1))
        lp, _ = M.forward(params, cfg, packed, CTX)
        single = dict(tokens=t2, labels=t2,
                      segment_ids=jnp.ones((1, S), jnp.int32),
                      positions=jnp.arange(S, dtype=jnp.int32)[None])
        ls, _ = M.forward(params, cfg, single, CTX)
        err = jnp.max(jnp.abs(lp[:, S:] - ls))
        assert err < 5e-4, f"{arch}: doc leakage, err={err}"
