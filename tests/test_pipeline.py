"""Pipeline parallelism + CAD-across-stages tests (paper §4.1, Fig. 8).
The real shard_map pipeline runs in a subprocess on fake stage devices."""
import subprocess
import sys

import numpy as np

PIPE_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import sys; sys.path.insert(0, 'src')
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map as _shard_map
from repro.configs import get_config
from repro.models import model as M
from repro.models.model import block_apply
from repro.parallel import ParallelContext
from repro.pipeline_par import pipeline_apply, split_stages

N_STAGES, N_MICRO = 4, 6
cfg = get_config('smollm-360m').reduced()
import dataclasses
cfg = dataclasses.replace(cfg, n_layers=4)
ctx = ParallelContext(attn_impl='xla', remat=False)
key = jax.random.PRNGKey(0)
params = M.init(key, cfg)

Bm, S = 1, 64
toks = jax.random.randint(key, (N_MICRO, Bm, S), 1, cfg.vocab_size)
seg = jnp.ones((N_MICRO, Bm, S), jnp.int32)
pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (N_MICRO, Bm, S))

# reference: plain forward per microbatch
ref_h = []
for m in range(N_MICRO):
    batch = dict(tokens=toks[m], segment_ids=seg[m], positions=pos[m])
    logits, _ = M.forward(params, cfg, batch, ctx)
    ref_h.append(logits)
ref = jnp.stack(ref_h)

# pipelined: embed outside, blocks inside pipeline, unembed outside
stage_blocks = split_stages(params['blocks'], N_STAGES)
h_mb = jnp.stack([
    M._embed(params, cfg, toks[m], ctx) for m in range(N_MICRO)])

from repro.compat import make_mesh
mesh = make_mesh((N_STAGES,), ('stage',))

def body(sp, h_mb_, seg_, pos_):
    sp = jax.tree.map(lambda a: a[0], sp)       # drop local stage dim

    def stage_fn(h, m, _plan):
        batch = dict(segment_ids=seg_[m], positions=pos_[m])
        aux = {}
        n_groups_local = jax.tree.leaves(sp)[0].shape[0]
        for g in range(n_groups_local):
            gp = jax.tree.map(lambda a: a[g], sp)
            for kind, slot in zip(cfg.layer_pattern, gp):
                h, aux = block_apply(kind, slot, h, batch, cfg, ctx, aux)
        return h

    return pipeline_apply(sp, h_mb_, stage_fn, n_stages=N_STAGES)

out_h = jax.jit(_shard_map(
    body, mesh=mesh,
    in_specs=(P('stage'), P(), P(), P()),
    out_specs=P(), check_vma=False))(stage_blocks, h_mb, seg, pos)

outs = []
for m in range(N_MICRO):
    h = M.norm_apply = None  # avoid confusion
from repro.models import layers as L
logits_pipe = []
for m in range(N_MICRO):
    h = L.norm_apply(params['final_norm'], out_h[m], cfg.norm)
    logits_pipe.append(M._unembed(params, cfg, h))
pipe = jnp.stack(logits_pipe)
err = float(jnp.max(jnp.abs(pipe - ref)))
assert err < 2e-4, err
print('PIPE-OK', err)
"""

CAD_PP_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import sys; sys.path.insert(0, 'src')
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map as _shard_map
from repro.core import CADConfig, CADContext, CommModel, ref_attention
from repro.core.dispatch import _rank_fn
from repro.pipeline_par import pipeline_apply, tick_schedules

N_STAGES, N_MICRO = 4, 5
BLK, S, H, DH = 64, 512, 2, 32
nb = S // BLK
rng = np.random.default_rng(0)
segs_mb = np.zeros((N_MICRO, S), np.int32)
poss_mb = np.zeros((N_MICRO, S), np.int32)
sid = 1
for m in range(N_MICRO):
    t = 0
    while t < S:
        dl = min(int(rng.integers(1, 5)) * BLK, S - t)
        segs_mb[m, t:t+dl] = sid; poss_mb[m, t:t+dl] = np.arange(dl)
        sid += 1; t += dl

cadcfg = CADConfig(n_servers=N_STAGES, blk=BLK, nb=nb, cq=nb, ckv=2*nb,
                   nkv=4*nb)
comm = CommModel(H, DH, H)
plans_np, stats = tick_schedules(segs_mb, N_STAGES, cadcfg, comm,
                                 tolerance=0.05)
# warm-up tick 0: only stage 0 active; scheduler must offload to idle
# stages (the paper's idle-as-attention-server claim)
assert stats[0]['moves'] > 0, 'idle stages were not used as servers'
plans = jax.tree.map(jnp.asarray, plans_np)
cad = CADContext(cfg=cadcfg, kernel='xla', jmax=nb)

key = jax.random.PRNGKey(1)
x_mb = jax.random.normal(key, (N_MICRO, 1, S, H, DH))
pos_m = jnp.asarray(np.where(segs_mb > 0, poss_mb, -1))[:, None, :]

from repro.compat import make_mesh
mesh = make_mesh((N_STAGES,), ('stage',))

def body(x_mb_, pos_):
    def stage_fn(h, m, tick_plan):
        # plans are closed over (replicated): pick this stage's row
        sid = jax.lax.axis_index('stage')
        tick_plan = jax.tree.map(lambda a: a[sid], tick_plan)
        q = h  # [1, S, H, DH]; use h as q=k=v (weightless CA layer)
        return _rank_fn(q, q, q, pos_[m], tick_plan, cad, 0.0, None,
                        ('stage',))
    return pipeline_apply(None if False else {}, x_mb_,
                          lambda h, m, p: stage_fn(h, m, p),
                          n_stages=N_STAGES, plans=plans)

out = jax.jit(_shard_map(
    body, mesh=mesh, in_specs=(P(), P()),
    out_specs=P(), check_vma=False))(x_mb, pos_m)

seg_j = jnp.asarray(segs_mb)[:, None, :]
pos_j = jnp.asarray(poss_mb)[:, None, :]
for m in range(N_MICRO):
    # each stage applies the (weightless) CA layer once -> CA^N_STAGES
    exp = x_mb[m]
    for _ in range(N_STAGES):
        exp = ref_attention(exp, exp, exp, seg_j[m], pos_j[m], seg_j[m],
                            pos_j[m])
    err = float(jnp.max(jnp.abs(out[m] - exp)))
    assert err < 2e-4, (m, err)
print('CADPP-OK')
"""


def _run(script):
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_pipeline_matches_sequential():
    """GPipe tick schedule over 4 fake stage devices reproduces the
    non-pipelined forward exactly."""
    assert "PIPE-OK" in _run(PIPE_SCRIPT)


def test_cad_tasks_balance_across_stages():
    """CA-tasks of microbatches at different pipeline stages are
    rebalanced over the whole stage pool per tick; warm-up/drain idle
    stages serve other stages' tasks (paper §4.1)."""
    assert "CADPP-OK" in _run(CAD_PP_SCRIPT)
