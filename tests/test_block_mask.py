"""Mask-oracle differential suite (DESIGN.md §12).

Block-sparse and document-masked attention as first-class task shapes:

  * spec validation — every malformed :class:`MaskSpec` (and spec ×
    layout combination) raises a typed :class:`MaskSpecError` naming the
    offending parameter, segment, or task;
  * live-block accounting — the cost model's ``live_block_table`` equals
    an independent any-pair-visible recompute at token granularity, for
    random specs (the count planners price tasks by);
  * kernel parity — packed pallas kernels (interpret mode) and the XLA
    fallback match the materialized ``ref_masked_attention`` oracle,
    forward AND gradients, across causal/sliding/dilated masks;
  * CAD dispatch parity — a planned, disaggregated step under a mask
    matches the monolithic oracle, and live-block-priced loads match an
    independent recompute;
  * cross-document isolation — an impulse-response regression proves
    ZERO attention mass crosses packed document boundaries in fused
    batches, on every implementation and mask family (the doc-boundary
    wiring of data/packing.py).

Runs under hypothesis when installed; otherwise the same generators run
as a seeded random sweep (the ``property_case`` pattern of
``test_planner_properties.py``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cad import get_planner
from repro.core import CADConfig, CADContext, cad_attention, ref_attention
from repro.core.attention import xla_flash_attention
from repro.core.cost_model import CommModel, MemoryModel
from repro.core.mask import (MaskSpec, MaskSpecError, live_block_mask,
                             live_block_table, live_kv_len, mask_params,
                             pair_visible, parse_mask, spec_from_params,
                             validate_mask_layout)
from repro.core.scheduler import block_costs, layout_from_segments
from repro.data.packing import pack_documents
from repro.kernels.packed_flash import kernel as K
from repro.kernels.packed_flash import ops as O
from repro.kernels.packed_flash.ref import ref_masked_attention
from repro.parallel import ParallelContext

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

N_EXAMPLES = 25


class RngSampler:
    def __init__(self, rng):
        self._rng = rng

    def int_(self, lo, hi):
        return int(self._rng.integers(lo, hi + 1))

    def choice(self, seq):
        return seq[self.int_(0, len(seq) - 1)]

    def bool_(self, p=0.5):
        return bool(self._rng.random() < p)


class HypSampler:
    def __init__(self, draw):
        self._draw = draw

    def int_(self, lo, hi):
        return self._draw(st.integers(lo, hi))

    def choice(self, seq):
        return self._draw(st.sampled_from(list(seq)))

    def bool_(self, p=0.5):
        return self._draw(st.booleans())


def property_case(fn):
    if HAVE_HYPOTHESIS:
        def hyp_wrapper(data):
            fn(HypSampler(data.draw))
        hyp_wrapper.__name__ = fn.__name__
        hyp_wrapper.__doc__ = fn.__doc__
        return settings(max_examples=N_EXAMPLES, deadline=None)(
            given(st.data())(hyp_wrapper))

    def sweep_wrapper(seed):
        fn(RngSampler(np.random.default_rng(seed)))
    sweep_wrapper.__name__ = fn.__name__
    sweep_wrapper.__doc__ = fn.__doc__
    return pytest.mark.parametrize("seed", range(N_EXAMPLES))(sweep_wrapper)


def gen_mask(s, blk):
    """A random spec (None = dense causal) with parameters scaled to
    ``blk`` so the mask actually bites on small layouts."""
    kind = s.choice([None, "causal", "sliding", "dilated"])
    if kind is None:
        return None
    if kind == "causal":
        return MaskSpec()
    if kind == "sliding":
        return MaskSpec(kind="sliding",
                        window=s.choice([blk // 2, blk, 2 * blk]),
                        sink=s.choice([0, 0, blk // 4, blk]))
    return MaskSpec(kind="dilated", rate=s.choice([2, 3, 4]))


def aligned_layout(s, rows, n_blocks, blk):
    """Random packed layout honoring the pipeline contract: doc starts
    are blk-aligned, a doc's last block may be partially filled, ids are
    globally unique, in-doc positions restart at 0."""
    segs = np.zeros((rows, n_blocks * blk), np.int32)
    poss = np.zeros((rows, n_blocks * blk), np.int32)
    sid = 1
    for r in range(rows):
        t = 0
        while t < n_blocks:
            if s.bool_(0.15):
                t += 1
                continue
            dbl = s.int_(1, min(4, n_blocks - t))
            tokens = dbl * blk
            if s.bool_(0.3):
                tokens -= s.int_(0, blk - 1)
            segs[r, t * blk:t * blk + tokens] = sid
            poss[r, t * blk:t * blk + tokens] = np.arange(tokens)
            sid += 1
            t += dbl
    return segs, poss


# ======================================================== spec validation
@pytest.mark.parametrize("ctor,match", [
    (lambda: MaskSpec(kind="bogus"), "unknown mask kind"),
    (lambda: MaskSpec(kind="causal", window=5),
     "takes no window/sink/rate"),
    (lambda: MaskSpec(kind="sliding", window=0), "zero-live-block"),
    (lambda: MaskSpec(kind="sliding", window=4, sink=-1),
     "sink must be >= 0"),
    (lambda: MaskSpec(kind="sliding", window=4, rate=2),
     "does not take rate"),
    (lambda: MaskSpec(kind="dilated", rate=0), "zero-live-block"),
    (lambda: MaskSpec(kind="dilated", rate=2, window=3),
     "does not take window/sink"),
    (lambda: parse_mask("sliding:width=4"), "bad mask parameter"),
    (lambda: parse_mask("sliding:window=abc"), "not an integer"),
    (lambda: parse_mask("blocky"), "unknown mask kind"),
])
def test_malformed_specs_raise_typed_errors(ctor, match):
    with pytest.raises(MaskSpecError, match=match):
        ctor()
    with pytest.raises(ValueError):        # MaskSpecError IS a ValueError
        ctor()


def test_error_names_segment_and_task():
    e = MaskSpecError("boom", segment=7)
    assert "(segment 7)" in str(e) and e.segment == 7
    e = MaskSpecError("boom", task=3)
    assert "(task 3)" in str(e) and e.task == 3


@pytest.mark.parametrize("text,spec", [
    ("", MaskSpec()),
    ("causal", MaskSpec()),
    ("sliding:window=256,sink=16",
     MaskSpec(kind="sliding", window=256, sink=16)),
    ("dilated:rate=4", MaskSpec(kind="dilated", rate=4)),
])
def test_parse_roundtrip(text, spec):
    assert parse_mask(text) == spec
    assert parse_mask(spec.describe()) == spec


def test_mask_params_spec_roundtrip():
    for spec in (MaskSpec(kind="sliding", window=32, sink=8),
                 MaskSpec(kind="dilated", rate=3)):
        assert spec_from_params(*mask_params(spec)) == spec
    # trivial specs unpack to the caller's window and reconstruct to None
    assert mask_params(None, 7) == (7, 0, 1)
    assert mask_params(MaskSpec(), 7) == (7, 0, 1)
    assert spec_from_params(7, 0, 1) is None


# ==================================================== layout validation
BLK = 16


def test_layout_overlapping_runs_in_row():
    seg = np.zeros(8 * BLK, np.int32)
    seg[0:BLK] = 1
    seg[2 * BLK:3 * BLK] = 1          # id 1 again, non-contiguous
    with pytest.raises(MaskSpecError,
                       match="occupies multiple runs") as ei:
        validate_mask_layout(None, seg, BLK)
    assert ei.value.segment == 1


def test_layout_segment_spans_rows():
    seg = np.zeros((2, 4 * BLK), np.int32)
    seg[0, :BLK] = 5
    seg[1, :BLK] = 5
    with pytest.raises(MaskSpecError, match="spans rows") as ei:
        validate_mask_layout(None, seg, BLK)
    assert ei.value.segment == 5


def test_layout_misaligned_segment_start():
    seg = np.zeros(4 * BLK, np.int32)
    seg[BLK + 3: 2 * BLK] = 1          # starts mid-block
    with pytest.raises(MaskSpecError, match="not aligned"):
        validate_mask_layout(None, seg, BLK)


def test_window_larger_than_kv_names_longest_doc():
    seg = np.zeros((1, 8 * BLK), np.int32)
    seg[0, :2 * BLK] = 1
    seg[0, 2 * BLK:5 * BLK] = 2        # longest: 3 blocks
    spec = MaskSpec(kind="sliding", window=100 * BLK)
    with pytest.raises(MaskSpecError, match="larger than kv") as ei:
        validate_mask_layout(spec, seg, BLK)
    assert ei.value.segment == 2
    # a window that fits the longest doc passes
    validate_mask_layout(MaskSpec(kind="sliding", window=BLK), seg, BLK)


def test_packed_pipeline_layout_validates():
    chunks = pack_documents([100, 300, 60, 500, 17], 512, 2, block=128)
    segs = np.stack([c.segment_ids for c in chunks])
    validate_mask_layout(None, segs, 128)
    validate_mask_layout(MaskSpec(kind="dilated", rate=2), segs, 128)


# ================================================== live-block accounting
@property_case
def test_live_block_table_equals_independent_recompute(s):
    """Cost-model liveness == brute-force any-pair-visible at token
    granularity (full blocks): the count planners price tasks by is
    exactly what a kernel that skips fully-dead blocks executes."""
    blk = s.choice([8, 16])
    nb = s.int_(1, 6)
    spec = gen_mask(s, blk)
    got = live_block_mask(spec, nb, nb, blk)
    pq = np.arange(nb * blk)[:, None]
    pk = np.arange(nb * blk)[None, :]
    vis = pq >= pk
    extra = pair_visible(spec, pq, pk, blk)
    if extra is not None:
        vis = vis & extra
    exact = vis.reshape(nb, blk, nb, blk).any(axis=(1, 3))
    np.testing.assert_array_equal(got, exact)
    np.testing.assert_array_equal(live_block_table(spec, nb, blk),
                                  exact.sum(axis=1))
    for kvb in range(1, nb + 1):
        assert live_kv_len(spec, kvb, blk) \
            == int(exact[kvb - 1].sum()) * blk


@property_case
def test_masked_cost_never_exceeds_dense(s):
    """Live-block pricing is monotone: a mask can only remove work."""
    blk = s.choice([8, 16])
    spec = gen_mask(s, blk)
    segs, _ = aligned_layout(s, s.int_(1, 3), s.int_(2, 8), blk)
    _docs, doc_of, bi_of = layout_from_segments(segs, blk, segs.shape[0])
    dense = block_costs(doc_of, bi_of, blk)
    masked = block_costs(doc_of, bi_of, blk, None, spec)
    assert (masked <= dense + 1e-9).all()
    assert (masked[doc_of >= 0] > 0).all()      # no zero-live-block task
    mm = MemoryModel(CommModel(2, 8, 2))
    for kvb in (1, 3):
        assert mm.task_bytes(blk, kvb * blk, spec, blk) \
            <= mm.task_bytes(blk, kvb * blk) + 1e-9


# ===================================================== oracle differential
def _rand_inputs(key, segs, poss, hq=4, hkv=2, dh=32):
    b, sl = segs.shape
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sl, hq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, sl, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, sl, hkv, dh), jnp.float32)
    return q, k, v, jnp.asarray(segs), jnp.asarray(poss)


MASKS_128 = [
    None,
    MaskSpec(kind="sliding", window=96),
    MaskSpec(kind="sliding", window=64, sink=32),
    MaskSpec(kind="dilated", rate=2),
    MaskSpec(kind="dilated", rate=3),
]


@property_case
def test_ref_paths_agree(s):
    """Two independent oracle constructions (scan-free mask_fn vs the
    materialized matrix) agree for random specs and layouts."""
    blk = 128
    spec = gen_mask(s, blk)
    segs, poss = aligned_layout(s, 1, s.int_(2, 4), blk)
    q, k, v, seg, pos = _rand_inputs(jax.random.PRNGKey(s.int_(0, 99)),
                                     segs, poss)
    window, sink, rate = mask_params(spec)
    a = ref_attention(q, k, v, seg, pos, seg, pos, window=window,
                      sink=sink, rate=rate, blk=blk)
    b = ref_masked_attention(q, k, v, seg, pos, seg, pos, mask=spec,
                             blk=blk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("spec", MASKS_128)
def test_xla_flash_matches_oracle_fwd_bwd(spec):
    segs, poss = aligned_layout(RngSampler(np.random.default_rng(5)),
                                2, 3, 128)
    q, k, v, seg, pos = _rand_inputs(jax.random.PRNGKey(7), segs, poss)
    window, sink, rate = mask_params(spec)

    def loss_x(q_, k_, v_):
        return jnp.sum(xla_flash_attention(
            q_, k_, v_, seg, pos, seg, pos, window=window, sink=sink,
            rate=rate, blk=128, q_block=128, kv_block=128))

    def loss_r(q_, k_, v_):
        return jnp.sum(ref_masked_attention(q_, k_, v_, seg, pos, seg,
                                            pos, mask=spec, blk=128))

    np.testing.assert_allclose(
        np.asarray(xla_flash_attention(q, k, v, seg, pos, seg, pos,
                                       window=window, sink=sink,
                                       rate=rate, blk=128)),
        np.asarray(ref_masked_attention(q, k, v, seg, pos, seg, pos,
                                        mask=spec, blk=128)), atol=2e-5)
    gx = jax.grad(loss_x, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gx, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4)


@pytest.mark.parametrize("spec", MASKS_128)
def test_pallas_packed_matches_oracle_fwd_bwd(spec):
    segs, poss = aligned_layout(RngSampler(np.random.default_rng(11)),
                                1, 3, 128)
    q, k, v, seg, pos = _rand_inputs(jax.random.PRNGKey(13), segs, poss)
    window, sink, rate = mask_params(spec)

    def loss_p(q_, k_, v_):
        return jnp.sum(O.packed_flash_attention(
            q_, k_, v_, seg, pos, seg, pos, True, window, 0.0, None,
            None, sink, rate))

    def loss_r(q_, k_, v_):
        return jnp.sum(ref_masked_attention(q_, k_, v_, seg, pos, seg,
                                            pos, mask=spec, blk=128))

    out = K.flash_fwd(q, k, v, seg, pos, seg, pos, window=window,
                      sink=sink, rate=rate)
    exp = ref_masked_attention(q, k, v, seg, pos, seg, pos, mask=spec,
                               blk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5)
    gp = jax.grad(loss_p, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4)


# ========================================================= CAD dispatch
def _cad_setup(policy, spec, seed=0, d=2, nb=6, blk=16):
    s = RngSampler(np.random.default_rng(seed))
    segs, poss = aligned_layout(s, d, nb, blk)
    cfg = CADConfig(n_servers=d, blk=blk, nb=nb, cq=nb, ckv=2 * nb,
                    nkv=4 * nb)
    res = get_planner(policy)(cfg, segs, comm=CommModel(4, 32, 2),
                              tolerance=0.1, mask=spec)
    return cfg, segs, poss, res


@pytest.mark.parametrize("spec", [
    MaskSpec(kind="sliding", window=24),
    MaskSpec(kind="sliding", window=16, sink=16),
    MaskSpec(kind="dilated", rate=2),
])
@pytest.mark.parametrize("policy", ["identity", "balanced"])
def test_cad_masked_matches_oracle(policy, spec):
    """Disaggregated serving under a mask-structured plan equals the
    monolithic oracle — q/kv routing, live-block splits and all."""
    cfg, segs, poss, res = _cad_setup(policy, spec, seed=3)
    plan = jax.tree.map(jnp.asarray, res.plan)
    q, k, v, seg, pos = _rand_inputs(jax.random.PRNGKey(17), segs, poss)
    cad = CADContext(cfg=cfg, plan=plan, kernel="xla", jmax=cfg.nkv,
                     mask=spec)
    ctx = ParallelContext(mesh=None, attn_impl="cad", cad=cad)
    out = cad_attention(q, k, v, seg, pos, seg, pos, ctx=ctx, mask=spec)
    exp = ref_masked_attention(q, k, v, seg, pos, seg, pos, mask=spec,
                               blk=cfg.blk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5)


def test_cad_masked_grads_match_oracle():
    spec = MaskSpec(kind="sliding", window=24, sink=16)
    cfg, segs, poss, res = _cad_setup("balanced", spec, seed=4)
    plan = jax.tree.map(jnp.asarray, res.plan)
    q, k, v, seg, pos = _rand_inputs(jax.random.PRNGKey(19), segs, poss)
    cad = CADContext(cfg=cfg, plan=plan, kernel="xla", jmax=cfg.nkv,
                     mask=spec)
    ctx = ParallelContext(mesh=None, attn_impl="cad", cad=cad)

    def loss_c(q_, k_, v_):
        return jnp.sum(cad_attention(q_, k_, v_, seg, pos, seg, pos,
                                     ctx=ctx, mask=spec))

    def loss_r(q_, k_, v_):
        return jnp.sum(ref_masked_attention(q_, k_, v_, seg, pos, seg,
                                            pos, mask=spec, blk=cfg.blk))

    gc = jax.grad(loss_c, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4)


@property_case
def test_masked_plan_loads_match_recompute(s):
    """Planner loads under a mask equal the independent live-block
    recompute, and masked balanced planning never leaves a server with
    more modeled time than identity."""
    blk = 16
    spec = gen_mask(s, blk)
    d = s.int_(2, 4)
    segs, _ = aligned_layout(s, d, s.int_(3, 8), blk)
    cfg = CADConfig(n_servers=d, blk=blk, nb=segs.shape[1] // blk,
                    cq=segs.shape[1] // blk,
                    ckv=2 * (segs.shape[1] // blk),
                    nkv=4 * (segs.shape[1] // blk))
    for policy in ("identity", "balanced"):
        res = get_planner(policy)(cfg, segs, comm=None, tolerance=0.1,
                                  mask=spec)
        _docs, doc_of, bi_of = layout_from_segments(segs, blk, d)
        cost = block_costs(doc_of, bi_of, blk, None, spec)
        live = doc_of >= 0
        expect = np.zeros(d)
        np.add.at(expect, res.assign[live].astype(np.int64), cost[live])
        np.testing.assert_allclose(res.loads, expect, rtol=1e-9)
    ident = get_planner("identity")(cfg, segs, comm=None, tolerance=0.1,
                                    mask=spec)
    bal = get_planner("balanced")(cfg, segs, comm=None, tolerance=0.1,
                                  mask=spec)
    assert bal.loads.max() <= ident.loads.max() * (1 + 1e-9)


# ==================================================== cross-doc isolation
def _impulse_v(segs, n_docs, hkv, blk_dh):
    """v whose channel ``sid - 1`` is 1 for tokens of doc ``sid`` — any
    output mass on another doc's channel IS cross-document attention."""
    b, sl = segs.shape
    v = np.zeros((b, sl, hkv, blk_dh), np.float32)
    for sid in range(1, n_docs + 1):
        rows, cols = np.nonzero(segs == sid)
        v[rows, cols, :, sid - 1] = 1.0
    return jnp.asarray(v)


@pytest.mark.parametrize("spec", [None,
                                  MaskSpec(kind="sliding", window=96,
                                           sink=32),
                                  MaskSpec(kind="dilated", rate=2)])
def test_zero_cross_document_attention_mass(spec):
    """Impulse-response regression over the REAL packing path: docs
    sharing a fused chunk must exchange exactly zero attention mass, on
    the oracle, the XLA fallback, the pallas kernel, and the planned CAD
    dispatch."""
    chunks = pack_documents([200, 100, 150, 300, 60, 180], 512, 2,
                            block=128)
    segs = np.stack([c.segment_ids for c in chunks])
    poss = np.stack([c.positions for c in chunks])
    n_docs = int(segs.max())
    dh = max(32, n_docs)
    key = jax.random.PRNGKey(23)
    ks = jax.random.split(key, 2)
    q = jax.random.normal(ks[0], (2, 512, 4, dh), jnp.float32)
    k = jax.random.normal(ks[1], (2, 512, 2, dh), jnp.float32)
    v = _impulse_v(segs, n_docs, 2, dh)
    seg, pos = jnp.asarray(segs), jnp.asarray(poss)
    window, sink, rate = mask_params(spec)

    outs = {
        "oracle": ref_masked_attention(q, k, v, seg, pos, seg, pos,
                                       mask=spec, blk=128),
        "xla": xla_flash_attention(q, k, v, seg, pos, seg, pos,
                                   window=window, sink=sink, rate=rate,
                                   blk=128),
        "pallas": K.flash_fwd(q, k, v, seg, pos, seg, pos, window=window,
                              sink=sink, rate=rate),
    }
    cfg = CADConfig(n_servers=2, blk=128, nb=4, cq=4, ckv=8, nkv=16)
    res = get_planner("balanced")(cfg, segs, comm=CommModel(4, dh, 2),
                                  tolerance=0.1, mask=spec)
    cad = CADContext(cfg=cfg, plan=jax.tree.map(jnp.asarray, res.plan),
                     kernel="xla", jmax=cfg.nkv, mask=spec)
    ctx = ParallelContext(mesh=None, attn_impl="cad", cad=cad)
    outs["cad"] = cad_attention(q, k, v, seg, pos, seg, pos, ctx=ctx,
                                mask=spec)

    for name, out in outs.items():
        arr = np.asarray(out)
        for sid in range(1, n_docs + 1):
            rows, cols = np.nonzero(segs == sid)
            others = [c for c in range(n_docs) if c != sid - 1]
            leak = np.abs(arr[rows, cols][..., others]).max() \
                if len(rows) else 0.0
            assert leak == 0.0, \
                f"{name}: doc {sid} receives attention mass {leak} " \
                f"from other documents (spec={spec})"
        # padding tokens attend nothing at all
        prow, pcol = np.nonzero(segs == 0)
        if len(prow):
            assert np.abs(arr[prow, pcol]).max() == 0.0, \
                f"{name}: padding rows carry attention output"
