"""System-level end-to-end properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cad import CADSession
from repro.configs import get_config
from repro.core.cost_model import CommModel
from repro.core.plan import CADConfig
from repro.data.pipeline import PipelineConfig, raw_batches
from repro.models import model as M
from repro.optim.adamw import AdamW
from repro.parallel import (ParallelContext, ShardingRules, make_rules,
                            param_pspecs)
from repro.train.step import make_train_step


def test_cad_training_grads_match_baseline():
    """One full train step with CAD (scheduler plan, dispatch, server
    kernels, flash backward) produces the same parameter update as the
    plain xla path — the whole-system correctness claim."""
    cfg = get_config("smollm-360m").reduced()
    pipe = PipelineConfig(distribution="pretrain", max_doc_len=256,
                          seq_len=256, global_batch=4, n_ranks=2,
                          vocab_size=cfg.vocab_size, seed=3)
    cadcfg = CADConfig.default(2, 2 * 256, max_doc_tokens=256)
    session = CADSession.from_legacy(
        cadcfg, comm=CommModel(n_heads=cfg.n_heads, head_dim=cfg.head_dim,
                               n_kv_heads=cfg.n_kv_heads))
    gen = session.attach_plans(raw_batches(pipe), prefetch=0)
    batch = next(gen)
    batch.pop("schedule_stats", None)

    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-2)

    from repro.core.dispatch import CADContext
    cad = CADContext(cfg=cadcfg, kernel="xla",
                     jmax=pipe.max_doc_len // cadcfg.blk)
    ctx_cad = ParallelContext(attn_impl="cad", cad=cad, remat=False)
    ctx_ref = ParallelContext(attn_impl="xla", remat=False)

    p1, _, m1 = make_train_step(cfg, ctx_cad, opt)(params, opt.init(params),
                                                   dict(batch))
    batch.pop("plan")
    p2, _, m2 = make_train_step(cfg, ctx_ref, opt)(params, opt.init(params),
                                                   batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert err < 5e-3, err


def test_make_rules_divisibility():
    """Sharding rules never propose a non-dividing axis (run on 4 fake
    devices would be nicer, but the rule logic is pure)."""
    class FakeMesh:
        axis_names = ("data", "model")

        class _D:
            shape = (16, 16)
        devices = _D()

    for arch in ("smollm-360m", "mistral-large-123b", "qwen2-moe-a2.7b"):
        cfg = get_config(arch)
        rules = make_rules(FakeMesh(), cfg)
        if cfg.n_heads % 16:
            assert rules.heads is None
        if cfg.n_kv_heads % 16:
            assert rules.kv_heads is None
        if cfg.d_ff and cfg.d_ff % 16 == 0 and not cfg.moe:
            assert rules.ffn == "model"


def test_param_pspecs_cover_all_leaves():
    """Every arch's param tree gets a valid spec for every leaf (specs
    match ndim, no axis repeated)."""
    import jax.tree_util as jtu
    for arch in ("gemma2-2b", "mamba2-370m", "recurrentgemma-9b",
                 "whisper-large-v3", "llama4-maverick-400b-a17b"):
        cfg = get_config(arch).reduced()
        shapes = jax.eval_shape(lambda c=cfg: M.init(jax.random.PRNGKey(0),
                                                     c))
        rules = ShardingRules(heads="model", kv_heads="model", ffn="model",
                              dmodel=("data",), vocab="model",
                              batch=("data",))
        specs = param_pspecs(cfg, shapes, rules)

        def check(path, leaf, spec):
            assert len(spec) <= leaf.ndim, (jtu.keystr(path), spec)
            flat = [a for s in spec if s is not None
                    for a in (s if isinstance(s, tuple) else (s,))]
            assert len(flat) == len(set(flat)), (jtu.keystr(path), spec)
        jtu.tree_map_with_path(check, shapes, specs)
