"""Serving-path tests (DESIGN.md §8).

Three contracts:

1. **Prefill parity** — fused chunked prefill reproduces the per-token
   loop's teacher-forced logits *bit-exactly*.  Both paths route every
   token through the same row-independent block kernels
   (``serve_chunk_step`` with blk_q 128 vs 1), so equality is exact, not
   approximate — any reduction-order change in the packed path is a bug.
2. **Continuous batching** — admission/eviction ordering is
   deterministic (the ``trace`` contract) and slot recycling never leaks
   state between requests (every request's tokens equal a solo run).
3. **Ragged decode kernel** — ``ragged_decode_attention`` (pallas
   interpret and the blockwise-XLA fallback) agrees with the dense
   ``decode_attention`` reference and the materialized oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.attention import decode_attention
from repro.core.cost_model import CostModel
from repro.kernels.packed_flash import ops as pf_ops
from repro.kernels.packed_flash import ref as pf_ref
from repro.models import model as M
from repro.parallel import ParallelContext
from repro.serve import (ContinuousScheduler, Engine, Request,
                         SchedulerConfig, ServeConfig)
from repro.train.step import make_serve_step

CTX = ParallelContext(attn_impl="ref", remat=False)


# ------------------------------------------------------ ragged decode kernel
@pytest.mark.parametrize("impl", ["pallas", "xla"])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (37, 0.0), (0, 30.0)])
def test_ragged_decode_parity_vs_dense(impl, window, softcap):
    """Fused ragged decode (one call, per-request kv_len) vs the dense
    ``decode_attention`` reference, one request at a time."""
    rng = np.random.default_rng(0)
    R, S, hq, hkv, dh = 4, 256, 4, 2, 64
    kc = jnp.asarray(rng.normal(size=(R, S, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(R, S, hkv, dh)), jnp.float32)
    kv_len = jnp.asarray([200, 1, 130, 77], jnp.int32)
    q = jnp.asarray(rng.normal(size=(R, hq, dh)), jnp.float32)
    q_pos = kv_len - 1

    out = pf_ops.ragged_decode_attention(
        q, kc, vc, jnp.arange(R, dtype=jnp.int32), q_pos, kv_len,
        window=window, softcap=softcap, impl=impl)

    # dense reference: full-cache mask per request
    s_idx = jnp.arange(S, dtype=jnp.int32)
    mask = s_idx[None, :] < kv_len[:, None]
    dense = decode_attention(q[:, None], kc, vc, mask, q_pos[:, None],
                             jnp.broadcast_to(s_idx, (R, S)),
                             window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense[:, 0]),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_ragged_decode_prefill_blocks_vs_oracle(impl):
    """Chunk-prefill shape (blk_q=128, dead blocks, padded rows, window)
    vs the materialized oracle."""
    rng = np.random.default_rng(1)
    R, S, hq, hkv, dh = 3, 256, 4, 2, 32
    kc = jnp.asarray(rng.normal(size=(R, S, hkv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(R, S, hkv, dh)), jnp.float32)
    kv_len = jnp.asarray([190, 0, 130], jnp.int32)
    q = jnp.asarray(rng.normal(size=(384, hq, dh)), jnp.float32)
    block_req = jnp.asarray([2, 0, -1], jnp.int32)
    pos = np.concatenate([np.arange(60, 188),       # req 2 rows
                          np.arange(62, 190),       # req 0 rows
                          -np.ones(128)]).astype(np.int32)
    pos[100:128] = -1                               # padded rows mid-block
    pos = jnp.asarray(pos)
    out = pf_ops.ragged_decode_attention(q, kc, vc, block_req, pos, kv_len,
                                         window=50, impl=impl)
    ref = pf_ref.ref_ragged_decode(q.reshape(3, 128, hq, dh), kc, vc,
                                   block_req, kv_len, pos.reshape(3, 128),
                                   window=50)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.reshape(384, hq, dh)),
                               atol=2e-5, rtol=2e-5)
    assert np.asarray(out[256:] == 0).all(), "dead block must be zero"


def test_ragged_decode_impl_resolution(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_DECODE", "nope")
    with pytest.raises(ValueError, match="unknown kernel decode impl"):
        pf_ops._resolve_decode(None)
    assert pf_ops._resolve_decode("xla") == "xla"
    monkeypatch.setenv("REPRO_KERNEL_DECODE", "xla")
    assert pf_ops._resolve_decode(None) == "xla"
    monkeypatch.delenv("REPRO_KERNEL_DECODE")
    assert pf_ops._resolve_decode(None) == "pallas"


# ----------------------------------------------------------- prefill parity
@pytest.mark.parametrize("arch", ["gemma2-2b", "smollm-360m"])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_prefill_fused_matches_loop_bitwise(arch, impl):
    """Fused chunked prefill == per-token loop, bit for bit, on every
    teacher-forced logit (gemma2: local+global+softcaps; smollm: pure
    global GQA)."""
    cfg = get_config(arch).reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    B, P = 3, 33          # ragged vs the 128 block: padded rows in chunk
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 1,
                                cfg.vocab_size)
    scfg = ServeConfig(max_seq=P + 8, chunk_tokens=128, decode_impl=impl)
    fused = Engine(cfg, params, CTX, scfg, batch_size=B)
    _, lg_fused = fused.prefill(prompt, mode="fused", return_logits=True)
    loop = Engine(cfg, params, CTX, scfg, batch_size=B)
    _, lg_loop = loop.prefill(prompt, mode="loop", return_logits=True)
    np.testing.assert_array_equal(np.asarray(lg_fused),
                                  np.asarray(lg_loop))


def test_generate_matches_legacy_decode_path():
    """Serve-layout generation (ragged kernel, non-ring local cache)
    reproduces the legacy dense decode path's greedy tokens."""
    for arch in ("gemma2-2b", "mamba2-370m"):
        cfg = get_config(arch).reduced()
        params = M.init(jax.random.PRNGKey(0), cfg)
        B, P, new = 2, 12, 6
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 1,
                                    cfg.vocab_size)
        eng = Engine(cfg, params, CTX,
                     ServeConfig(max_seq=P + new + 1, max_new_tokens=new),
                     batch_size=B)
        out = eng.generate(prompt)

        cache = M.init_cache(params, cfg, B, P + new + 1, ctx=CTX)
        step = jax.jit(make_serve_step(cfg, CTX))
        last = None
        for t in range(P):
            last, _, cache = step(params, cache, prompt[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32))
        ref = [last]
        for i in range(new - 1):
            last, _, cache = step(params, cache, last[:, None],
                                  jnp.full((B,), P + i, jnp.int32))
            ref.append(last)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jnp.stack(ref, 1)))


# ------------------------------------------------------- continuous batching
def _mk_reqs(lens, max_new=4, vocab=100, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, vocab, int(l))
                    .astype(np.int32), max_new_tokens=max_new)
            for i, l in enumerate(lens)]


def _drain(sched):
    """Drive the scheduler without a model: prefill chunks + dummy decode
    commits, recording nothing but the trace."""
    guard = 0
    while sched.has_work():
        guard += 1
        assert guard < 500, "scheduler did not converge"
        sched.admit()
        chunk = sched.next_prefill_chunk(fused=True)
        if chunk is not None:
            sched.commit_prefill(chunk, {s: 7 for s, _ in chunk.last_rows})
            continue
        sched.evict_for_budget()
        batch = sched.decode_batch()
        if batch is None:
            continue
        sched.commit_decode(np.full(sched.cfg.n_slots, 7, np.int32))


def test_admission_fcfs_ordering():
    """FCFS admission with head-of-line blocking: slots fill in arrival
    order; later requests wait for finishes, deterministically."""
    sched = ContinuousScheduler(SchedulerConfig(
        n_slots=2, max_seq=64, chunk_tokens=128))
    for r in _mk_reqs([8, 8, 8, 8]):
        sched.submit(r)
    _drain(sched)
    admits = [rid for ev, rid in sched.trace if ev == "admit"]
    finishes = [rid for ev, rid in sched.trace if ev == "finish"]
    assert admits == [0, 1, 2, 3]
    assert finishes == [0, 1, 2, 3]
    # requests 2/3 were admitted only after 0/1 freed their slots
    assert sched.trace.index(("admit", 2)) \
        > sched.trace.index(("finish", 0))


def test_admission_cost_policy_orders_by_predicted_cost():
    """"cost" admission = the CAD cost model repurposed: cheapest
    predicted steady-state CA first."""
    cm = CostModel.analytic(n_heads=4, head_dim=64)
    sched = ContinuousScheduler(SchedulerConfig(
        n_slots=1, max_seq=2048, chunk_tokens=128, admission="cost",
        cost_model=cm))
    for r in _mk_reqs([1024, 8, 300], max_new=2):
        sched.submit(r)
    _drain(sched)
    admits = [rid for ev, rid in sched.trace if ev == "admit"]
    assert admits == [1, 2, 0]          # shortest predicted cost first


def test_eviction_lifo_under_token_budget():
    """Decode growth past the token budget preempts the most recently
    admitted request, which requeues at the FRONT and reruns."""
    sched = ContinuousScheduler(SchedulerConfig(
        n_slots=2, max_seq=40, chunk_tokens=128, token_budget=28))
    for r in _mk_reqs([8, 8], max_new=16):
        sched.submit(r)
    _drain(sched)
    assert ("evict", 1) in sched.trace, "LIFO evicts the younger request"
    assert ("evict", 0) not in sched.trace
    t = sched.trace
    assert t.index(("evict", 1)) < t.index(("finish", 0)) \
        < t.index(("finish", 1))
    req1 = next(r for r in sched.done if r.rid == 1)
    assert req1.n_evictions >= 1
    assert len(req1.out_tokens) == 16   # full generation after rerun


def test_unadmissible_request_raises():
    sched = ContinuousScheduler(SchedulerConfig(
        n_slots=1, max_seq=64, chunk_tokens=128, token_budget=8))
    sched.submit(_mk_reqs([32], max_new=4)[0])
    with pytest.raises(RuntimeError, match="never be admitted"):
        sched.admit()


def test_continuous_batching_matches_solo_runs():
    """Slot recycling and packed prefill across concurrent ragged
    requests must not change any request's tokens vs running it alone
    (state isolation across admissions)."""
    cfg = get_config("gemma2-2b").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, int(l)).astype(np.int32)
               for l in (9, 30, 5, 17)]
    scfg = ServeConfig(max_seq=64, max_new_tokens=4, chunk_tokens=128)
    eng = Engine(cfg, params, CTX, scfg, batch_size=2)
    res = eng.serve(prompts)
    assert sorted(res) == [0, 1, 2, 3]
    solo = Engine(cfg, params, CTX, scfg, batch_size=2)
    for i, pr in enumerate(prompts):
        np.testing.assert_array_equal(solo.serve([pr])[0], res[i])


def test_continuous_batching_eviction_end_to_end():
    """A request evicted mid-decode re-prefills from scratch and still
    produces its solo tokens."""
    cfg = get_config("smollm-360m").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2)]
    scfg = ServeConfig(max_seq=40, max_new_tokens=12, chunk_tokens=128,
                       token_budget=28)
    eng = Engine(cfg, params, CTX, scfg, batch_size=2)
    res = eng.serve(prompts)
    assert ("evict", 1) in eng.last_trace
    solo = Engine(cfg, params, CTX, scfg, batch_size=2)
    for i, pr in enumerate(prompts):
        np.testing.assert_array_equal(solo.serve([pr])[0], res[i])


def test_serve_loop_prefill_mode_matches_fused():
    """prefill="loop" continuous batching (the recurrent/MoE path) yields
    the same tokens as fused — they are bit-identical computations."""
    cfg = get_config("gemma2-2b").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, int(l)).astype(np.int32)
               for l in (7, 13, 4)]
    out = {}
    for mode in ("fused", "loop"):
        eng = Engine(cfg, params, CTX,
                     ServeConfig(max_seq=48, max_new_tokens=3,
                                 chunk_tokens=128, prefill=mode),
                     batch_size=2)
        out[mode] = eng.serve(prompts)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(out["fused"][i], out["loop"][i])


def test_continuous_batching_recurrent_state_isolation():
    """Recurrent archs: a DECODE-state request idling (pos = -1 rows)
    while another request prefills must keep its conv/SSM/LRU state
    frozen — ragged concurrent serving equals solo serving."""
    for arch in ("mamba2-370m", "recurrentgemma-9b"):
        cfg = get_config(arch).reduced()
        params = M.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, cfg.vocab_size, int(l))
                   .astype(np.int32) for l in (4, 19, 7)]
        scfg = ServeConfig(max_seq=48, max_new_tokens=4, chunk_tokens=128)
        eng = Engine(cfg, params, CTX, scfg, batch_size=2)
        res = eng.serve(prompts)
        solo = Engine(cfg, params, CTX, scfg, batch_size=2)
        for i, pr in enumerate(prompts):
            np.testing.assert_array_equal(solo.serve([pr])[0], res[i],
                                          err_msg=f"{arch} req {i}")


def test_single_over_budget_request_completes():
    """The budget goes soft for the oldest active request: a request
    whose decode growth alone busts the budget still completes instead
    of evict/re-admit livelocking."""
    sched = ContinuousScheduler(SchedulerConfig(
        n_slots=1, max_seq=64, chunk_tokens=128, token_budget=16))
    sched.submit(_mk_reqs([10], max_new=20)[0])
    _drain(sched)
    assert [e for e, _ in sched.trace] == ["admit", "finish"]
    assert len(sched.done[0].out_tokens) == 20


def test_engine_rejects_overflowing_requests():
    cfg = get_config("smollm-360m").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, CTX,
                 ServeConfig(max_seq=32, max_new_tokens=16), batch_size=1)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        eng.prefill(jnp.ones((1, 40), jnp.int32))
    with pytest.raises(ValueError, match="does not fit max_seq"):
        eng.generate(jnp.ones((1, 20), jnp.int32))
    with pytest.raises(ValueError, match="exceeds"):
        eng.serve([np.ones(40, np.int32)])


def test_recurrent_batch_size_one():
    """Recurrent archs at batch_size=1: the single-row chunk must NOT be
    dead-row padded (their per-request state is indexed by the row dim);
    generate and serve both work and agree with a 2-slot engine."""
    cfg = get_config("mamba2-370m").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 1,
                                cfg.vocab_size)
    scfg = ServeConfig(max_seq=24, max_new_tokens=3)
    out1 = Engine(cfg, params, CTX, scfg, batch_size=1).generate(prompt)
    res = Engine(cfg, params, CTX, scfg, batch_size=1).serve(
        [np.asarray(prompt[0])])
    out2 = Engine(cfg, params, CTX, scfg, batch_size=2).generate(
        jnp.concatenate([prompt, prompt]))
    np.testing.assert_array_equal(np.asarray(out1[0]), res[0])
    np.testing.assert_array_equal(np.asarray(out1[0]),
                                  np.asarray(out2[0]))


def test_admission_counts_committed_prefill():
    """Two large prompts must not co-admit past the token budget just
    because their kv_len is still 0 at admission time (the committed
    prompt counts from admission)."""
    sched = ContinuousScheduler(SchedulerConfig(
        n_slots=2, max_seq=640, chunk_tokens=128, token_budget=1024))
    for r in _mk_reqs([600, 600], max_new=4):
        sched.submit(r)
    assert [r.rid for r in sched.admit()] == [0]
    _drain(sched)
    assert [e for e, _ in sched.trace] == \
        ["admit", "finish", "admit", "finish"]
    assert not any(e == "evict" for e, _ in sched.trace)


def test_empty_prompt_rejected():
    sched = ContinuousScheduler(SchedulerConfig(
        n_slots=1, max_seq=64, chunk_tokens=128))
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(Request(rid=0, prompt=np.zeros(0, np.int32)))


def test_misaligned_chunk_tokens_rejected():
    with pytest.raises(ValueError, match="multiple of"):
        SchedulerConfig(n_slots=1, max_seq=64, chunk_tokens=100)


def test_prefill_accepts_full_max_seq_prompt():
    """A prompt of exactly max_seq tokens is legal on BOTH prefill paths
    even at batch_size=1 (the fused path's internal scheduler must not
    impose a stricter capacity check than the loop's)."""
    cfg = get_config("smollm-360m").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    P = 128
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, P), 1,
                                cfg.vocab_size)
    scfg = ServeConfig(max_seq=P, max_new_tokens=1, chunk_tokens=128)
    eng = Engine(cfg, params, CTX, scfg, batch_size=1)
    lf = eng.prefill(prompt, mode="fused")
    ll = eng.prefill(prompt, mode="loop")
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(ll))


def test_serve_explicit_zero_max_new_tokens():
    """serve(..., max_new_tokens=0) means prefill-only — the explicit 0
    must not fall back to the config default."""
    cfg = get_config("smollm-360m").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, CTX,
                 ServeConfig(max_seq=32, max_new_tokens=8), batch_size=1)
    res = eng.serve([np.arange(1, 30, dtype=np.int32)], max_new_tokens=0)
    assert res[0].shape == (0,)


def test_legacy_prefill_rejects_fused_and_return_logits():
    cfg = get_config("whisper-large-v3").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    mem = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.encoder.n_ctx, cfg.d_model),
                            jnp.float32) * 0.02
    eng = Engine(cfg, params, CTX, ServeConfig(max_seq=16), memory=mem,
                 batch_size=1)
    toks = jnp.ones((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="fused prefill unsupported"):
        eng.prefill(toks, mode="fused")
    with pytest.raises(ValueError, match="return_logits"):
        eng.prefill(toks, return_logits=True)
    assert eng.prefill(toks).shape == (1, cfg.vocab_size)


def test_engine_reuse_resets_recurrent_state():
    """A second generate() on the same engine must match a fresh engine
    (prefill resets kv visibility AND recurrent state)."""
    cfg = get_config("mamba2-370m").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 1,
                                cfg.vocab_size)
    scfg = ServeConfig(max_seq=24, max_new_tokens=4)
    eng = Engine(cfg, params, CTX, scfg, batch_size=2)
    first = eng.generate(prompt)
    second = eng.generate(prompt)
    np.testing.assert_array_equal(np.asarray(first), np.asarray(second))


def test_serve_cache_layout_guards():
    cfg = get_config("gemma2-2b").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="unknown cache layout"):
        M.init_cache(params, cfg, 1, 32, layout="paged")
    vcfg = get_config("llama-3.2-vision-11b").reduced()
    vparams = M.init(jax.random.PRNGKey(0), vcfg)
    with pytest.raises(ValueError, match="cross-attention"):
        M.init_cache(vparams, vcfg, 1, 32, layout="serve")
