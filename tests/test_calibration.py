"""Runtime calibration: the measure → fit → replan loop (DESIGN.md §3).

Covers the ``GridCalibrator`` (EMA grid fitting, per-server speed
estimation, snapshot/version semantics, serialization), heterogeneous
scheduling (a 0.5x server receives half the FLOPs), the CADSession
feedback channel (stats annotation, stale-plan refresh across the
prefetch thread boundary), the dispatch timing probe, and the
straggler regression that pins the ``benchmarks/straggler_elim.py``
headline: with one 0.5x server, the calibrated ``balanced`` planner
keeps measured per-server time near-flat while ``identity`` and the
uncalibrated balance demonstrably do not.
"""
import threading

import numpy as np
import pytest

from repro.cad import (CADConfig, CADSession, GridCalibrator,
                       PlanPrefetcher, get_planner)
from repro.core import iter_plan_tasks, probe_plan_times
from repro.core.cost_model import CalibrationSnapshot, CommModel, \
    CostModel
from repro.core.dispatch import CADContext
from repro.core.scheduler import layout_from_segments, schedule

BLK = 32


def make_cfg(d, nb, blk=BLK, speeds=None):
    return CADConfig(n_servers=d, blk=blk, nb=nb, cq=2 * nb, ckv=2 * nb,
                     nkv=4 * nb, server_speeds=speeds)


def uniform_doc_segs(d, nb, blk=BLK, doc_blocks=2):
    """Every rank packed with doc_blocks-block documents, no padding."""
    segs = np.zeros((d, nb * blk), np.int32)
    sid = 1
    for r in range(d):
        for t in range(0, nb, doc_blocks):
            n = min(doc_blocks, nb - t)
            segs[r, t * blk:(t + n) * blk] = sid
            sid += 1
    return segs


def random_segs(rng, d, nb, blk=BLK, max_doc_blocks=8):
    segs = np.zeros((d, nb * blk), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < nb:
            n = min(int(rng.integers(1, max_doc_blocks + 1)), nb - t)
            segs[r, t * blk:(t + n) * blk] = sid
            sid += 1
            t += n
    return segs


# ------------------------------------------------------------ cost model
def test_cost_model_serialization_roundtrip(tmp_path):
    cm = CostModel.analytic(8, 64)
    path = str(tmp_path / "grid.json")
    cm.save(path)
    back = CostModel.load(path)
    q = np.array([64, 128, 1000])
    kv = np.array([256, 4096, 100000])
    np.testing.assert_allclose(back.predict(q, kv), cm.predict(q, kv))
    assert back.n_heads == cm.n_heads and back.head_dim == cm.head_dim
    assert back.peak_flops == cm.peak_flops


def test_cost_model_scaled():
    cm = CostModel.analytic(4, 32)
    np.testing.assert_allclose(cm.scaled(2.5).predict(128, 4096),
                               2.5 * cm.predict(128, 4096))


# ------------------------------------------------------------ calibrator
def test_calibrator_fits_measured_grid():
    """Measured timings 3x the analytic model: the fitted grid predicts
    the measured hardware, not the analytic prior."""
    base = CostModel.analytic(4, 32)
    truth = base.scaled(3.0)
    cal = GridCalibrator(base, n_servers=1, ema=1.0)
    shapes = [(128, kv) for kv in (128, 512, 2048, 8192, 65536)]
    for _ in range(3):
        for q, kv in shapes:
            cal.observe(q, kv, float(truth.predict(q, kv)))
    fitted = cal.snapshot().cost_model
    for q, kv in shapes:
        np.testing.assert_allclose(float(fitted.predict(q, kv)),
                                   float(truth.predict(q, kv)),
                                   rtol=0.05)
    # unobserved region falls back to the base model
    np.testing.assert_allclose(float(fitted.predict(16, 524288)),
                               float(base.predict(16, 524288)), rtol=1e-6)


def test_calibrator_estimates_relative_speeds():
    """A server measuring 2x slower converges to speed 0.5, independent
    of a uniform hardware-vs-model scale error."""
    base = CostModel.analytic(4, 32)
    truth = base.scaled(2.0)               # hardware 2x the model
    speeds = np.array([1.0, 0.5, 1.0])
    cal = GridCalibrator(base, n_servers=3, ema=0.5)
    rng = np.random.default_rng(0)
    for _ in range(40):
        s = int(rng.integers(3))
        kv = int(rng.choice([256, 1024, 4096]))
        cal.observe(128, kv, float(truth.predict(128, kv)) / speeds[s],
                    server=s)
    np.testing.assert_allclose(cal.speeds(), speeds, rtol=0.05)


def test_calibrator_snapshot_version_and_cache():
    cal = GridCalibrator(CostModel.analytic(2, 16), n_servers=2)
    s0 = cal.snapshot()
    assert isinstance(s0, CalibrationSnapshot)
    assert s0.version == 0 and cal.snapshot() is s0       # cached
    cal.observe(128, 256, 1e-3, server=0)
    s1 = cal.snapshot()
    assert s1.version == cal.version > 0
    assert s1 is not s0
    assert len(s1.speeds) == 2


def test_calibrator_ignores_degenerate_samples():
    cal = GridCalibrator(CostModel.analytic(2, 16), n_servers=1)
    cal.observe(128, 256, 0.0)             # non-positive time
    cal.observe(0, 256, 1.0)               # empty task
    cal.observe_tasks([], 1.0)             # empty batch
    assert cal.version == 0 and cal.n_observations == 0


def test_calibrator_observe_tasks_batch_attribution():
    """A fused-batch timing updates the server's speed from the batch
    total — same estimate a per-task timer would converge to."""
    base = CostModel.analytic(4, 32)
    cal = GridCalibrator(base, n_servers=2, ema=1.0)
    tasks = [(128, 512), (128, 2048), (128, 8192)]
    total = float(sum(base.predict(q, kv) for q, kv in tasks))
    cal.observe_tasks(tasks, 2.0 * total, server=0)   # server 0 at 0.5x
    cal.observe_tasks(tasks, total, server=1)
    np.testing.assert_allclose(cal.speeds(), [0.5, 1.0], rtol=1e-6)


def test_calibrator_anchors_unobserved_servers_to_observed_scale():
    """Partial observation must not skew relative speeds: with hardware
    1000x slower than the analytic model, observing only server 0 keeps
    the unobserved server at the *observed* scale (prior-anchored), not
    at raw prior 1.0 — which would make server 0 look 1000x slower."""
    base = CostModel.analytic(4, 32)
    truth = base.scaled(1000.0)
    cal = GridCalibrator(base, n_servers=2, ema=1.0)
    cal.observe(128, 2048, float(truth.predict(128, 2048)), server=0)
    np.testing.assert_allclose(cal.speeds(), [1.0, 1.0])
    # declared priors stay relative under the same anchoring
    cal2 = GridCalibrator(base, n_servers=2, ema=1.0,
                          prior_speeds=(1.0, 0.5))
    cal2.observe(128, 2048, float(truth.predict(128, 2048)), server=0)
    np.testing.assert_allclose(cal2.speeds(), [1.0, 0.5])


def test_observe_plan_accepts_pingpong_plans():
    """The feedback channel unwraps PingPongPlan (both nano-batches'
    tasks) instead of crashing on string indexing."""
    from repro.core.plan import PingPongPlan
    d, nb = 2, 8
    cfg = make_cfg(d, nb)
    session = CADSession(cfg=cfg, comm=CommModel(2, 16, 2),
                         pingpong=True, tolerance=0.05, prefetch=0,
                         calibrator=GridCalibrator(
                             CostModel.analytic(2, 16), d))
    segs = uniform_doc_segs(d, 2 * nb)      # full step = 2 nano-batches
    plan, _stats = session.plan(segs)
    assert isinstance(plan, PingPongPlan)
    session.observe_plan(plan, np.full(d, 1e-3))
    assert session.calibrator.version > 0


def test_calibrator_state_dict_roundtrip():
    cal = GridCalibrator(CostModel.analytic(4, 32), n_servers=2)
    cal.observe(128, 512, 1e-3, server=0)
    cal.observe(128, 2048, 2e-3, server=1)
    state = cal.state_dict()
    cal2 = GridCalibrator(CostModel.analytic(4, 32), n_servers=2)
    cal2.load_state_dict(state)
    assert cal2.version == cal.version
    np.testing.assert_allclose(cal2.speeds(), cal.speeds())
    np.testing.assert_allclose(
        cal2.snapshot().cost_model.time_grid,
        cal.snapshot().cost_model.time_grid)


def test_calibrator_thread_safety_smoke():
    """Concurrent observe + snapshot never corrupts state (the prefetch
    worker snapshots while the train loop observes)."""
    cal = GridCalibrator(CostModel.analytic(2, 16), n_servers=2)
    stop = threading.Event()
    errs = []

    def snapshotter():
        try:
            while not stop.is_set():
                snap = cal.snapshot()
                assert np.isfinite(snap.cost_model.time_grid).all()
        except Exception as e:              # pragma: no cover
            errs.append(e)

    t = threading.Thread(target=snapshotter)
    t.start()
    for i in range(300):
        cal.observe(128, 256 * (1 + i % 4), 1e-3, server=i % 2)
    stop.set()
    t.join(timeout=5)
    assert not errs
    assert cal.version == 300


# ------------------------------------------------- heterogeneous pools
def test_cadconfig_validates_speeds():
    with pytest.raises(ValueError, match="entries"):
        make_cfg(2, 4, speeds=(1.0,))
    with pytest.raises(ValueError, match="> 0"):
        make_cfg(2, 4, speeds=(1.0, 0.0))
    np.testing.assert_array_equal(make_cfg(2, 4).speeds(), [1.0, 1.0])
    np.testing.assert_array_equal(
        make_cfg(2, 4, speeds=(1, 0.5)).speeds(), [1.0, 0.5])


def test_schedule_gives_slow_server_proportional_flops():
    """With speeds (1, 0.5) a perfectly divisible workload ends up ~2:1
    in FLOPs — the slow server receives half the work — and near-flat
    in modeled time."""
    d, nb = 2, 16
    segs = uniform_doc_segs(d, nb, doc_blocks=2)
    speeds = np.array([1.0, 0.5])
    sch = schedule(segs, blk=BLK, n_servers=d,
                   comm=CommModel(2, 16, 2), caps=make_cfg(d, nb).caps(),
                   tolerance=0.02, speeds=speeds)
    flops = sch.loads * speeds
    ratio = flops[0] / flops[1]
    assert 1.7 <= ratio <= 2.4, ratio
    assert sch.loads.max() / sch.loads.mean() <= 1.1


def test_planners_report_time_loads_on_heterogeneous_pool():
    """identity/per_doc_cp don't re-route for speeds (fixed policies)
    but must report speed-scaled time loads."""
    d, nb = 2, 8
    segs = uniform_doc_segs(d, nb)
    cfg_flat = make_cfg(d, nb)
    cfg_het = make_cfg(d, nb, speeds=(1.0, 0.25))
    for policy in ("identity", "per_doc_cp"):
        flat = get_planner(policy)(cfg_flat, segs, build_plan=False)
        het = get_planner(policy)(cfg_het, segs, build_plan=False)
        np.testing.assert_array_equal(flat.assign, het.assign)
        np.testing.assert_allclose(het.loads,
                                   flat.loads / np.array([1.0, 0.25]))


# ------------------------------------------------ straggler regression
def test_straggler_elimination_regression():
    """The benchmark's headline claim as a test: on a pool with one
    0.5x server, the calibrated balanced planner keeps measured
    per-server time within ~tolerance of flat, while identity and the
    uncalibrated (FLOPs-equalizing) balance sit far outside it."""
    d, nb, blk = 4, 16, 128
    cfg = CADConfig(n_servers=d, blk=blk, nb=nb, cq=2 * nb, ckv=2 * nb,
                    nkv=4 * nb)
    comm = CommModel(8, 64, 4)
    true_speeds = np.array([1.0, 1.0, 0.5, 1.0])
    truth = CostModel.analytic(8, 64).scaled(2.0)
    session = CADSession(
        cfg=cfg, comm=comm, tolerance=0.02, plan_policy="balanced",
        prefetch=0,
        calibrator=GridCalibrator(CostModel.analytic(8, 64), d))
    rng = np.random.default_rng(0)

    def measured(assign, doc_of, bi_of):
        live = doc_of >= 0
        t = np.zeros(len(doc_of))
        t[live] = truth.predict(blk, (bi_of[live] + 1) * blk)
        per = np.zeros(d)
        srv = assign[live].astype(np.int64)
        np.add.at(per, srv, t[live] / true_speeds[srv])
        return per

    calibrated, identity, uncal = [], [], []
    for step in range(8):
        segs = random_segs(rng, d, nb, blk=blk)
        _docs, doc_of, bi_of = layout_from_segments(segs, blk, d)
        identity.append(measured(
            get_planner("identity")(cfg, segs, build_plan=False).assign,
            doc_of, bi_of))
        uncal.append(measured(
            get_planner("balanced")(cfg, segs, comm=comm, tolerance=0.02,
                                    build_plan=False).assign,
            doc_of, bi_of))
        plan, stats = session.plan(segs)
        assert stats["calib_version"] == float(session.calibrator
                                               .snapshot().version)
        per_server = np.zeros(d)
        for s, _slot, qt, kvt in iter_plan_tasks(cfg, plan):
            t = float(truth.predict(qt, kvt)) / true_speeds[s]
            per_server[s] += t
            session.observe(qt, kvt, t, server=s)
        calibrated.append(per_server)

    def max_over_mean(rows):
        return float(np.mean([r.max() / r.mean() for r in rows]))

    tail = slice(4, None)                    # skip convergence transient
    cal_mm = max_over_mean(calibrated[tail])
    id_mm = max_over_mean(identity[tail])
    uc_mm = max_over_mean(uncal[tail])
    assert cal_mm <= 1.1, (cal_mm, id_mm, uc_mm)
    assert id_mm > 1.4, id_mm
    assert uc_mm > 1.4, uc_mm
    # ... and the speeds were actually learned, not declared
    np.testing.assert_allclose(session.calibrator.speeds(), true_speeds,
                               rtol=0.05)


# ----------------------------------------------- session feedback path
def test_session_plan_annotates_calibration_stats():
    d, nb = 2, 8
    cfg = make_cfg(d, nb)
    session = CADSession(cfg=cfg, comm=CommModel(2, 16, 2),
                         tolerance=0.05, prefetch=0,
                         calibrator=GridCalibrator(
                             CostModel.analytic(2, 16), d))
    segs = uniform_doc_segs(d, nb)
    _plan, stats = session.plan(segs)
    assert stats["calib_version"] == 0.0
    assert stats["calib_speed_0"] == 1.0
    assert stats["calib_speed_1"] == 1.0
    # without a calibrator the keys stay absent (legacy stats shape)
    plain = CADSession(cfg=cfg, comm=CommModel(2, 16, 2), prefetch=0)
    _plan, stats2 = plain.plan(segs)
    assert "calib_version" not in stats2


def test_prefetcher_stale_refresh():
    """Items planned ahead are re-planned at pull time when flagged
    stale — on the consumer thread, preserving order."""
    calls = []

    def plan(x):
        # idempotent on planned items, like CADSession.plan_batch
        item = x["item"] if isinstance(x, dict) else x
        calls.append(item)
        return {"item": item, "planned_at": len(calls)}

    stale_items = {1}
    pf = PlanPrefetcher(iter(range(4)), plan, depth=2,
                        is_stale=lambda it: it["item"] in stale_items)
    out = list(pf)
    assert [o["item"] for o in out] == [0, 1, 2, 3]
    assert pf.stale_refreshes == 1
    assert calls.count(1) == 2 and calls.count(0) == 1


def test_session_attach_plans_refreshes_on_speed_drift():
    """The cross-thread loop: plans prefetched with stale speeds are
    re-planned at pull after feedback shifts the speed estimates."""
    d, nb = 2, 8
    cfg = make_cfg(d, nb)
    base = CostModel.analytic(2, 16)
    session = CADSession(cfg=cfg, comm=CommModel(2, 16, 2),
                         tolerance=0.05, prefetch=2,
                         calibrator=GridCalibrator(base, d, ema=1.0))
    segs = uniform_doc_segs(d, nb)

    def batches(n):
        for _ in range(n):
            yield {"segment_ids": segs.copy()}

    gen = session.attach_plans(batches(4))
    first = next(gen)
    assert first["schedule_stats"]["calib_version"] == 0.0
    # big drift: server 1 measures 4x slower than server 0
    for kv in (256, 512, 1024):
        session.observe(BLK, kv, float(base.predict(BLK, kv)), server=0)
        session.observe(BLK, kv, 4 * float(base.predict(BLK, kv)),
                        server=1)
    later = [next(gen) for _ in range(3)]
    for b in later:
        # the guarantee is *speed* freshness: a plan built from drifted
        # speeds is re-planned at pull; one built between observes with
        # the same speeds may keep its (older) version
        st = b["schedule_stats"]
        np.testing.assert_allclose(
            [st["calib_speed_0"], st["calib_speed_1"]], [1.0, 0.25])
    gen.close()


def test_probe_plan_times_feeds_calibrator():
    """The dispatch probe measures real (eager) serve time per server
    and the session feeds it back — version advances, speeds defined."""
    d, nb = 2, 2
    cfg = make_cfg(d, nb)
    comm = CommModel(2, 8, 2)
    session = CADSession(cfg=cfg, comm=comm, tolerance=0.05, prefetch=0,
                         jmax=cfg.nkv,
                         calibrator=GridCalibrator(
                             CostModel.analytic(2, 8), d))
    segs = uniform_doc_segs(d, nb)
    plan, _ = session.plan(segs)

    cad = CADContext(cfg=cfg, kernel="xla", jmax=cfg.nkv)
    res = probe_plan_times(cad, plan, n_heads=2, head_dim=8,
                           n_kv_heads=2)
    assert [s for s, _t, _sec in res] == list(range(d))
    assert all(sec > 0 for _s, _t, sec in res)
    tasks_of = {s: t for s, t, _sec in res}
    expect = {}
    for s, _slot, qt, kvt in iter_plan_tasks(cfg, plan):
        expect.setdefault(s, []).append((qt, kvt))
    assert tasks_of == expect

    session.observe_probe(plan)
    assert session.calibrator.version > 0
    assert len(session.calibrator.speeds()) == d


def test_trainer_calibrate_smoke():
    """train(..., calibrate_every=1) runs the probe + feedback loop and
    logs calibration stats in the history."""
    from repro.configs import get_config
    from repro.data.pipeline import PipelineConfig
    from repro.train.trainer import TrainConfig, train
    cfg = get_config("smollm-360m").reduced()
    pipe = PipelineConfig(distribution="pretrain", max_doc_len=256,
                          seq_len=256, global_batch=4, n_ranks=2,
                          vocab_size=cfg.vocab_size, seed=3)
    session = CADSession.for_pipeline(cfg, pipe, plan_policy="balanced",
                                      calibrate=True)
    assert session.calibrator is not None
    res = train(cfg, pipe, TrainConfig(steps=2, peak_lr=1e-3, warmup=1,
                                       log_every=1, calibrate_every=1),
                session=session)
    assert np.isfinite(res["history"][-1]["loss"])
    assert "sched_calib_version" in res["history"][-1]
    assert session.calibrator.version > 0
