"""Paper Appendix A reproduction: the max-shards-without-overhead bound."""
import pytest

from repro.configs import get_config
from repro.core.analysis import max_partition_size


def test_paper_appendix_a_llama34b():
    """Paper: Llama-34B config (h=8192, h_kv=2048, i=22016), 50 GB/s IB,
    50% MFU of a 990 TFLOP/s H200 -> s ≈ 31."""
    cfg = get_config("llama3-34b")
    s = max_partition_size(cfg, bandwidth=50e9, peak_flops=990e12, mfu=0.5)
    assert 25 <= s <= 38, s


def test_bound_grows_with_model_size():
    """Paper: 'for larger models, this upper bound even increases.'"""
    s_small = max_partition_size(get_config("llama3-8b"))
    s_large = max_partition_size(get_config("mistral-large-123b"))
    assert s_large > s_small


def test_bound_positive_on_tpu_for_all_attention_archs():
    """On v5e ICI every attention arch can shard at least a little."""
    from repro.configs import ASSIGNED_ARCHS
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        if not cfg.has_attention():
            continue
        assert max_partition_size(cfg) > 1, a
