"""Ring-attention baseline (DESIGN.md §13).

The differential discipline of PR 5 applied to the ring schedule:
``ring_attention`` (decomposed per-endpoint dispatch) must match
``ring_global_sim`` (single-pool oracle running the identical pass
schedule through the fused vmapped orchestration) **bitwise**, forward
and vjp, on dense-causal and doc-masked inputs; both must agree with
the standard full serve to float tolerance.  Plus the host-side
geometry invariants: contiguous shard ownership, per-pass cost
conservation, and exact dead-pass skipping.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cad import CADConfig, get_planner
from repro.core.dispatch import (CADContext, _global_sim, ring_attention,
                                 ring_global_sim, ring_pass_geometry)
from repro.core.mask import MaskSpec
from repro.core.plan import ring_assignment
from repro.core.scheduler import (block_costs, layout_from_segments,
                                  ring_pass_costs, ring_shard_size)
from repro.kernels.packed_flash import kernel as K
from repro.kernels.packed_flash import ops as O

BLK = 16

MASKS = {
    "dense": None,
    "sliding": MaskSpec(kind="sliding", window=2 * BLK, sink=BLK),
    "dilated": MaskSpec(kind="dilated", rate=2),
}


def make_cfg(d, nb):
    return CADConfig(n_servers=d, blk=BLK, nb=nb, cq=nb, ckv=2 * nb,
                     nkv=4 * nb)


def make_layout(d, nb, seed=0, max_doc_blocks=4):
    rng = np.random.default_rng(seed)
    segs = np.zeros((d, nb * BLK), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < nb:
            dbl = int(rng.integers(1, min(max_doc_blocks, nb - t) + 1))
            segs[r, t * BLK:(t + dbl) * BLK] = sid
            sid += 1
            t += dbl
    poss = np.broadcast_to(np.arange(nb * BLK), segs.shape)
    return segs, np.where(segs > 0, poss, -1).astype(np.int32)


def make_qkv(d, s_len, nh=2, hkv=2, dh=8, seed=0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (d, s_len, nh, dh), jnp.float32)
    k = jax.random.normal(kk, (d, s_len, hkv, dh), jnp.float32)
    v = jax.random.normal(kv, (d, s_len, hkv, dh), jnp.float32)
    return q, k, v


def ring_setup(d=4, nb=8, seed=0, mask=None):
    cfg = make_cfg(d, nb)
    segs, pos = make_layout(d, nb, seed)
    res = get_planner("ring")(cfg, segs, comm=None, mask=mask)
    plan = jax.tree.map(jnp.asarray, res.plan)
    q, k, v = make_qkv(d, nb * BLK, seed=seed)
    cad = CADContext(cfg=cfg, plan=plan, kernel="xla", jmax=cfg.nkv,
                     mask=mask)
    return cfg, segs, jnp.asarray(pos), plan, q, k, v, cad


def bitwise_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.tobytes() == b.tobytes()


# ===================================================================
# host-side geometry invariants
# ===================================================================

def test_ring_assignment_contiguous_shards():
    """Every document is cut into P contiguous shards of equal ceil
    size, owned by the allowed servers in order — the DISTFLASHATTN
    layout, independent of where the doc's home rank is."""
    cfg = make_cfg(4, 8)
    segs, _ = make_layout(4, 8, seed=3)
    docs, doc_of, bi_of = layout_from_segments(segs, BLK, 4)
    assign = ring_assignment(cfg, docs)
    for doc in docs:
        L = ring_shard_size(doc.n_blocks, 4)
        owners = [assign[g] for g in doc.blocks()]
        expect = [j // L for j in range(doc.n_blocks)]
        assert owners == expect
    # restricted pool: shards land on the allowed servers, in order
    assign2 = ring_assignment(cfg, docs, servers=(1, 3))
    for doc in docs:
        L = ring_shard_size(doc.n_blocks, 2)
        owners = {assign2[g] for g in doc.blocks()}
        assert owners <= {1, 3}


@pytest.mark.parametrize("mask_name", sorted(MASKS))
def test_ring_pass_costs_conserve_loads(mask_name):
    """Summing the [P, n_servers] per-pass cost table over passes gives
    exactly the ring assignment's per-server live-block loads: the pass
    decomposition neither drops nor double-counts work."""
    mask = MASKS[mask_name]
    cfg = make_cfg(4, 8)
    segs, _ = make_layout(4, 8, seed=5)
    docs, doc_of, bi_of = layout_from_segments(segs, BLK, 4)
    table = ring_pass_costs(docs, BLK, 4, mask=mask)
    assert table.shape == (4, 4)
    cost = block_costs(doc_of, bi_of, BLK, None, mask)
    assign = ring_assignment(cfg, docs)
    loads = np.array([cost[assign == s].sum() for s in range(4)])
    np.testing.assert_allclose(table.sum(axis=0), loads, rtol=1e-12)


def test_ring_geometry_skips_dead_passes_exactly():
    """Causal-dead (q shard strictly left of the rotated kv shard) and
    mask-dead windows get kv_len 0; pass 0 (the diagonal) is always
    live for every live task."""
    cfg = make_cfg(4, 8)
    segs, _ = make_layout(4, 8, seed=1)
    res = get_planner("ring")(cfg, segs, comm=None)
    pps = ring_pass_geometry(cfg, segs, res.plan)
    assert len(pps) == 4
    live0 = np.asarray(res.plan["task_kv_len"]) > 0
    assert (pps[0]["task_kv_len"][live0] > 0).all()
    # rotation covers each task's prefix exactly once across passes
    total = sum(pp["task_kv_len"] for pp in pps)
    np.testing.assert_array_equal(total, np.asarray(res.plan["task_kv_len"]))
    # a masked geometry never serves more kv than the dense one
    pps_m = ring_pass_geometry(cfg, segs, res.plan,
                               mask=MASKS["sliding"])
    for pp_d, pp_m in zip(pps, pps_m):
        assert (pp_m["task_kv_len"] <= pp_d["task_kv_len"]).all()


# ===================================================================
# merge op: online-softmax partial combination
# ===================================================================

def test_merge_dead_partial_is_bitwise_noop():
    """Merging a dead partial (finalized lse >= LSE_DEAD marker) into a
    live one returns the live side bitwise — forward and gradient: the
    dead side contributes exactly nothing, not epsilon."""
    key = jax.random.PRNGKey(7)
    ka, kb = jax.random.split(key)
    out_a = jax.random.normal(ka, (3, 4, 2, 8))       # [b, blk, hq, dh]
    lse_a = jax.random.normal(kb, (3, 2, 4))          # [b, hq, blk]
    out_dead = jnp.zeros_like(out_a)
    lse_dead = jnp.full_like(lse_a, K.LSE_DEAD)
    o, l = O.merge_softmax_partials(out_a, lse_a, out_dead, lse_dead)
    assert bitwise_equal(o, out_a) and bitwise_equal(l, lse_a)
    o2, l2 = O.merge_softmax_partials(out_dead, lse_dead, out_a, lse_a)
    assert bitwise_equal(o2, out_a) and bitwise_equal(l2, lse_a)

    def loss(oa, la, ob, lb):
        o, l = O.merge_softmax_partials(oa, la, ob, lb)
        return jnp.sum(o * o) + jnp.sum(jnp.sin(l))

    g = jax.grad(loss, argnums=(0, 1, 2, 3))(out_a, lse_a, out_dead,
                                             lse_dead)
    gr = jax.grad(lambda oa, la: jnp.sum(oa * oa) + jnp.sum(jnp.sin(la)))
    ga, gl = gr(out_a, lse_a), jax.grad(
        lambda la: jnp.sum(out_a * out_a) + jnp.sum(jnp.sin(la)))(lse_a)
    assert bitwise_equal(g[0], ga) and bitwise_equal(g[1], gl)
    assert not np.asarray(g[2]).any() and not np.asarray(g[3]).any()


def test_merge_two_live_halves_match_whole():
    """Splitting one softmax into two kv halves and merging the
    finalized partials reproduces the unsplit attention to float
    tolerance, and gradients flow through both halves."""
    key = jax.random.PRNGKey(11)
    kq, kk, kv = jax.random.split(key, 3)
    T, H, dh, S = 4, 2, 8, 32
    q = jax.random.normal(kq, (T, H, dh))
    k = jax.random.normal(kk, (S, H, dh))
    v = jax.random.normal(kv, (S, H, dh))

    def soft(q, k, v):                                # dense reference
        s = jnp.einsum("thd,shd->hts", q, k) / np.sqrt(dh)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("hts,shd->thd", p, v)

    def half(q, k, v):                                # finalized partial
        s = jnp.einsum("thd,shd->hts", q, k) / np.sqrt(dh)
        lse = jax.nn.logsumexp(s, axis=-1)            # [H, T]
        return jnp.einsum("hts,shd->thd", jnp.exp(s - lse[..., None]),
                          v), lse

    oa, la = half(q, k[:S // 2], v[:S // 2])
    ob, lb = half(q, k[S // 2:], v[S // 2:])
    o, _ = O.merge_softmax_partials(oa[None], la[None], ob[None],
                                    lb[None])
    np.testing.assert_allclose(np.asarray(o[0]), np.asarray(soft(q, k, v)),
                               atol=1e-6)
    g = jax.grad(lambda oa, ob: jnp.sum(
        O.merge_softmax_partials(oa[None], la[None], ob[None],
                                 lb[None])[0] ** 2))(oa, ob)
    assert np.isfinite(np.asarray(g)).all() and np.asarray(g).any()


# ===================================================================
# differential: decomposed ring == single-pool oracle, bitwise
# ===================================================================

@pytest.mark.parametrize("mask_name", sorted(MASKS))
def test_ring_bitwise_vs_oracle(mask_name):
    """Decomposed per-endpoint ring execution is bit-identical —
    forward AND vjp — to the fused single-pool oracle running the same
    pass schedule (same ops, same order, different orchestration)."""
    mask = MASKS[mask_name]
    cfg, segs, pos, plan, q, k, v, cad = ring_setup(seed=2, mask=mask)

    def f_ring(q, k, v):
        return ring_attention(cad, plan, segs, q, k, v, pos)

    def f_sim(q, k, v):
        return ring_global_sim(q, k, v, pos, plan, cad, segs)

    out_r = f_ring(q, k, v)
    out_s = f_sim(q, k, v)
    assert bitwise_equal(out_r, out_s)

    def loss(f):
        return lambda q, k, v: jnp.sum(jnp.abs(f(q, k, v)))

    gr = jax.grad(loss(f_ring), argnums=(0, 1, 2))(q, k, v)
    gs = jax.grad(loss(f_sim), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gs):
        assert bitwise_equal(a, b)


@pytest.mark.parametrize("mask_name", sorted(MASKS))
def test_ring_matches_full_serve(mask_name):
    """The merged ring output (and its grads) agree with the standard
    one-shot serve of the same plan to float32 tolerance — the ring
    decomposition changes the reduction order, nothing else."""
    mask = MASKS[mask_name]
    cfg, segs, pos, plan, q, k, v, cad = ring_setup(seed=4, mask=mask)
    out_r = ring_global_sim(q, k, v, pos, plan, cad, segs)
    out_f = _global_sim(q, k, v, pos, plan, cad, 0.0, None)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_f),
                               atol=2e-6)
    gr = jax.grad(lambda q, k, v: jnp.sum(jnp.abs(
        ring_global_sim(q, k, v, pos, plan, cad, segs))),
        argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(lambda q, k, v: jnp.sum(jnp.abs(
        _global_sim(q, k, v, pos, plan, cad, 0.0, None))),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-6)
